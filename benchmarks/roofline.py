"""Roofline analysis from the dry-run records (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod 16x16 mesh, three terms in seconds:

  compute term    = exec_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = per-device weighted collective bytes / link_bw

Sources.  ``bytes_accessed`` / collective bytes come from the dry-run's
compiled artifact (scan-linearized: XLA counts a while-loop body once; the
layer stack is uniform so terms are affine in L).  For *executed FLOPs* the
CPU backend's ``cost_analysis()`` is unreliable (it loses remat recompute
and some fused dots), so the roofline uses the exact loop-aware jaxpr walk
(``launch.flops``) as the primary source and the HLO number as a
cross-check — both are recorded.

MODEL_FLOPS is the standard MFU numerator (6*N*D train / 2*N*D prefill,
active params for MoE).  The reported roofline fraction is kind-aware:

  train/prefill:  (model_flops/dev / peak) / max(term)   — FLOP roofline
  decode:         (min_bytes/dev / HBM_bw) / max(term)   — bandwidth roofline
                  (decode is bandwidth-bound; FLOP-MFU is meaningless there)

Hardware: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import os

from repro.configs import SHAPES, get_arch
from repro.launch.model_flops import (model_bytes_decode, model_flops,
                                      param_count)

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def load_records(mesh: str = "16x16"):
    out = {}
    for name in sorted(os.listdir(RESULTS)):
        if not name.startswith("dryrun_") or "__" in name:
            continue                     # skip __variant perf experiments
        r = json.load(open(os.path.join(RESULTS, name)))
        if r["mesh"] != mesh:
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    dev = rec["devices"]
    cfg = get_arch(rec["arch"])
    shape = SHAPES[rec["shape"]]

    e = rec.get("extrapolated") or rec
    hlo_flops_dev = max(e["flops"], rec["flops"])
    bytes_dev = max(e["bytes_accessed"], rec["bytes_accessed"])
    coll_dev = max(e["collective_bytes"]["weighted"],
                   rec["collective_bytes"]["weighted"])
    exec_flops_dev = max(hlo_flops_dev,
                         rec.get("jaxpr_flops_global", 0.0) / dev)

    t_compute = exec_flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = terms[dominant]

    mf_dev = model_flops(cfg, shape) / dev
    useful = mf_dev / exec_flops_dev if exec_flops_dev else 0.0
    if shape.kind == "decode":
        mb_dev = model_bytes_decode(cfg, shape) / dev
        frac = (mb_dev / HBM_BW) / t_bound if t_bound else 0.0
        kind = "bandwidth"
    else:
        frac = (mf_dev / PEAK_FLOPS) / t_bound if t_bound else 0.0
        kind = "flops"

    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "exec_flops_per_device": exec_flops_dev,
        "hlo_flops_per_device": hlo_flops_dev,
        "model_flops_per_device": mf_dev,
        "useful_flops_ratio": useful,
        "roofline_kind": kind,
        "roofline_fraction": frac,
        "params_b": param_count(cfg) / 1e9,
    }


def run(mesh: str = "16x16") -> list:
    rows = []
    for (arch, shape), rec in load_records(mesh).items():
        row = roofline_row(rec)
        if row:
            rows.append(row)
    rows.sort(key=lambda r: r["roofline_fraction"])
    return rows


def format_table(rows: list) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'coll':>9s} {'dom':>10s} {'useful':>7s} {'kind':>10s} "
           f"{'roofline':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} "
            f"{r['t_compute_s']:9.4f} {r['t_memory_s']:9.4f} "
            f"{r['t_collective_s']:9.4f} {r['dominant']:>10s} "
            f"{r['useful_flops_ratio']:7.2f} {r['roofline_kind']:>10s} "
            f"{r['roofline_fraction']:8.3f}")
    return "\n".join(lines)


if __name__ == "__main__":
    rows = run()
    print(format_table(rows))
    print("\nworst cells (hillclimb candidates):")
    for r in rows[:6]:
        print(f"  {r['arch']} x {r['shape']}: dom={r['dominant']} "
              f"frac={r['roofline_fraction']:.3f}")
