"""BENCH — Phase-aware sampling: few-step solvers, step budgets, mixed tiers.

The 28.6 mJ/iter headline is per ITERATION; the other end-to-end energy
axis is how many iterations an image needs.  This bench sweeps the
``SamplerPolicy`` runtime (DESIGN.md §10) over solver x step-budget and
records, per (solver, steps) pair: imgs/s, the modeled mJ/image
(``mj_per_iter_with_ema * num_steps`` from the same integer-counter
ledger every other bench uses), and a quality proxy — the relative L2
distance of the final latents to a 25-step DDIM reference from the SAME
initial noise and prompt.  Headline: DPM-Solver++(2M) few-step tiers vs
25-step DDIM — the draft tier (8 steps) at >=2x imgs/s and >=1.8x lower
mJ/image within its stated quality tolerance, the balanced tier (12
steps) at the tight 0.25 tolerance (wall capped ~1.9x by per-image
encode+decode overhead at smoke geometry; the modeled mJ/image isolates
the step lever at the full 25/12).

The second half drives a MIXED-TIER slot batch (draft/balanced/quality
with phase schedules active) through the continuous scheduler and pins
the two §10 exactness contracts: every request's image is bit-identical
to a one-shot run of its own (solver, steps) policy under the same bank
AND the same batch signature (``generate(..., sampler_bank=)`` with the
request tiled to the slot count — the structural-identity oracle; XLA
specializes codegen per batch size, so parity is defined at matching
shapes, exactly like the legacy slot contracts), and the banked ledger's
energy summary is bit-identical across slot counts {2, 5} (integer
accumulation is occupancy-invariant).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks import timing

SOLVER_SWEEP = ("ddim", "plms", "dpm2m")
BUDGET_SWEEP = (8, 12, 25)
REFERENCE = "ddim-25"
# candidate -> stated quality-proxy tolerance (rel-L2 of final latents
# vs the 25-step DDIM reference).  Two few-step operating points: the
# draft tier (dpm2m@8) carries the throughput headline; the balanced
# tier (dpm2m@12) the tight-quality one.  NOTE the wall-clock physics at
# smoke geometry: per-image encode+decode costs ~3.5 step-equivalents,
# so the 25->12-step wall ratio saturates near 1.9x even though the
# MODELED mJ/image (pure step lever) scales the full 25/12 = 2.08x —
# at paper geometry the UNet steps dominate and wall approaches the
# step ratio.  The 8-step draft tier clears 2x wall even with the
# overhead priced in.
CANDIDATES = {"dpm2m-8": 0.40, "dpm2m-12": 0.25}


def run() -> dict:
    import jax
    import jax.numpy as jnp

    from repro.diffusion import solvers
    from repro.diffusion.engine import DiffusionEngine
    from repro.diffusion.pipeline import PipelineConfig, energy_report
    from repro.diffusion.sampler import DDIMConfig
    from repro.launch.scheduler import ContinuousScheduler, make_requests

    steps = 25
    cfg = PipelineConfig.smoke()
    cfg = dataclasses.replace(
        cfg,
        ddim=DDIMConfig(num_inference_steps=steps, guidance_scale=1.0,
                        tips_active_iters=steps * 20 // 25))
    eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))

    # ---- solver x step-budget sweep (one prompt, one fixed noise draw)
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (1, cfg.text.max_len), 0, cfg.text.vocab_size)
    lat0 = eng.init_latents(1, jax.random.PRNGKey(2))

    sweep: dict = {}
    latents_by_key: dict = {}
    for solver in SOLVER_SWEEP:
        for n in BUDGET_SWEEP:
            pol = solvers.SamplerPolicy(solver=solver, num_steps=n)
            out = eng.generate(toks, None, latents=jnp.array(lat0),
                               sampler_policy=pol)
            # repeat the compiled executable and take the MIN wall
            # (benchmarks.timing rationale); the engine carries its own
            # clock, so min_over samples last_wall_s
            wall = timing.min_over(3, lambda: (
                eng.generate(toks, None, latents=jnp.array(lat0),
                             sampler_policy=pol), eng.last_wall_s)[1])
            rep = energy_report(cfg, out.stats, sampler_policy=pol)
            latents_by_key[pol.key()] = np.asarray(out.latents[0])
            sweep[pol.key()] = {
                "wall_s": wall,
                "imgs_per_s": 1.0 / max(wall, 1e-9),
                "energy": {
                    "mj_per_iter_with_ema": rep.mj_per_iter_with_ema,
                    "mj_per_image": rep.mj_per_iter_with_ema * n,
                },
            }

    ref = latents_by_key[REFERENCE]
    for key, rec in sweep.items():
        d = latents_by_key[key] - ref
        rec["quality_rel_l2"] = float(np.linalg.norm(d)
                                      / max(np.linalg.norm(ref), 1e-12))

    base = sweep[REFERENCE]
    candidates = {}
    for key, tol in CANDIDATES.items():
        cand = sweep[key]
        speedup = cand["imgs_per_s"] / base["imgs_per_s"]
        mj_ratio = (base["energy"]["mj_per_image"]
                    / cand["energy"]["mj_per_image"])
        candidates[key] = {
            "imgs_per_s_speedup": speedup,
            "energy_comparison": {"mj_per_image_ratio": mj_ratio},
            "quality_rel_l2": cand["quality_rel_l2"],
            "quality_tol": tol,
            "meets_target": bool(speedup >= 2.0 and mj_ratio >= 1.8
                                 and cand["quality_rel_l2"] <= tol),
        }
    headline = {
        "reference": REFERENCE,
        "candidates": candidates,
        # the ISSUE bar (>=2x imgs/s, >=1.8x mJ/image, quality within the
        # stated tol) — met by the draft tier; the balanced tier trades
        # wall speedup (overhead-capped at 1.9x, see module comment) for
        # the tighter 0.25 quality proxy
        "meets_target": any(c["meets_target"] for c in candidates.values()),
    }

    # ---- mixed-tier slot trace with phase schedules active
    guard = solvers.PhaseSchedule.detail_guard()
    bank = (dataclasses.replace(solvers.SamplerPolicy.tier("draft"),
                                phases=guard),
            dataclasses.replace(solvers.SamplerPolicy.tier("balanced"),
                                phases=guard),
            solvers.SamplerPolicy.tier("quality"))
    n_requests = 6

    def fresh_requests():
        return make_requests(cfg, n_requests, seed=11, bank=bank)

    cont2 = ContinuousScheduler(eng, num_slots=2, bank=bank)
    compile_s = cont2.warmup()
    reqs = fresh_requests()
    m2 = cont2.run(reqs, ledger=True)
    m2.pop("state")

    per_request = []
    for r in reqs:
        pol = bank[r.policy_index]
        # oracle at the SLOT batch signature: request tiled to num_slots
        out = eng.generate(jnp.tile(r.tokens, (2, 1)), None,
                           latents=jnp.tile(jnp.array(r.latents),
                                            (2, 1, 1, 1)),
                           sampler_policy=pol, sampler_bank=bank)
        per_request.append({
            "rid": r.rid,
            "tier": r.tier,
            "policy": pol.key(),
            "bit_identical": bool(np.array_equal(
                r.image, np.asarray(out.images[0]))),
        })
    images_bit_identical = all(p["bit_identical"] for p in per_request)

    # same request set through a 5-slot state: the banked integer
    # accumulator must produce the SAME energy summary (occupancy and
    # retirement order differ; the per-(policy, step) buckets must not)
    cont5 = ContinuousScheduler(eng, num_slots=5, bank=bank)
    compile_s += cont5.warmup()
    m5 = cont5.run(fresh_requests(), ledger=True)
    m5.pop("state")
    ledger_bit_identical = (m2["energy"] == m5["energy"])
    phases_bit_identical = (m2["phase_breakdown"] == m5["phase_breakdown"])

    return {
        "config": {"steps": steps, "latent": cfg.unet.latent_size,
                   "solvers": list(SOLVER_SWEEP),
                   "budgets": list(BUDGET_SWEEP),
                   "trace_requests": n_requests},
        "compile_s": compile_s,
        "sweep": sweep,
        "headline": headline,
        "mixed_tier_trace": {
            "slots": 2,
            "bank": [p.describe() for p in bank],
            "per_request": per_request,
            "images_bit_identical": images_bit_identical,
            "goodput_steps_per_s": m2["goodput_steps_per_s"],
            "mean_occupancy": m2["mean_occupancy"],
            "per_tier": m2["per_tier"],
        },
        "ledger": {
            "energy": m2["energy"],
            "phase_breakdown": m2["phase_breakdown"],
            "ledger_bit_identical": ledger_bit_identical,
            "phase_breakdown_bit_identical": phases_bit_identical,
        },
        "meets_target": bool(headline["meets_target"]
                             and images_bit_identical
                             and ledger_bit_identical
                             and phases_bit_identical),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
