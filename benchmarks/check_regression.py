"""Bench-regression gate: re-run smoke benches, diff against committed JSON.

The committed ``benchmarks/results/*.json`` are the repo's performance
memory — without a gate they rot silently: a refactor can break the
stats-parity contract or shift the energy headline and nothing fails until
a human re-reads the numbers.  This script re-runs benches through the
``benchmarks/run.py`` registry and classifies every leaf of the fresh
record against the committed one:

  hard-fail (exact equality required)
    * ``*_bit_identical`` booleans — the kernel/sharding/serving parity
      contracts.  A committed ``false`` stays allowed (e.g. dp>2 image
      tiling); a ``true`` may never regress.
    * energy-ledger numbers (any leaf under an ``energy*`` key, or named
      ``mj_per_iter*`` / ``*ema_reduction*``) — integer-counter exactness
      means these are deterministic on a fixed jax/platform; ANY drift is
      an accounting change and must ship with regenerated results.
    * ``interpreted`` flipping false -> true — committed results that
      claim a compiled backend may not be re-validated by an interpret-
      mode machine (the fresh numbers would measure the Pallas
      interpreter, not the kernels).

  tolerance band (ratio within [1/tol, tol], default tol=4)
    * wall-clock-derived leaves (``*wall*``, ``imgs_per_s``, ``speedup``,
      ``latency``, ``goodput``, ``scaling``, ...) — CI machines differ
      from the box that committed the numbers; only collapse-scale drift
      fails.

  structure (presence) — every committed leaf must exist in the fresh
    record and vice versa, so a bench schema change forces regenerated
    results; all other values are informational.

Usage:
  PYTHONPATH=src:. python benchmarks/check_regression.py [--only NAME]...
      [--wall-tolerance 4.0]
  PYTHONPATH=src:. python benchmarks/run.py --check      # same default set

The default set covers every bench with committed results (the roofline
table has none — it is machine-shape-dependent); ``--only NAME`` narrows
the gate to one section.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

RESULTS = os.path.join(os.path.dirname(__file__), "results")

# every bench with committed results is gated (roofline has no committed
# JSON — its table is machine-shape-dependent — so it stays out)
DEFAULT_BENCHES = ("ema_breakdown", "pssa", "tips", "dbsc", "energy_iter",
                   "engine", "fused_attention", "fused_cross_attention",
                   "compiled_kernels", "sharded_engine",
                   "continuous_serving", "temporal_reuse",
                   "phase_sampling", "dit_serving", "cluster_router")

_WALL_MARKERS = ("wall", "imgs_per_s", "speedup", "compile_s", "latency",
                 "goodput", "makespan", "scaling", "efficiency",
                 "peak_temp", "occupancy", "queue_wait", "improvement",
                 "ratio_vs", "step_s")
_HEADLINE_MARKERS = ("mj_per_iter", "ema_reduction", "ema_gb_per_iter")


def _is_wall_key(key: str) -> bool:
    return any(m in key for m in _WALL_MARKERS)


def _is_headline(path: str, key: str) -> bool:
    if any(m in key for m in _HEADLINE_MARKERS):
        return True
    return any(part.startswith("energy")
               for part in path.split(".") if part)


def _leaves(rec, path=""):
    if isinstance(rec, dict):
        for k, v in rec.items():
            yield from _leaves(v, f"{path}.{k}" if path else str(k))
    elif isinstance(rec, list):
        for i, v in enumerate(rec):
            yield from _leaves(v, f"{path}[{i}]")
    else:
        yield path, rec


def compare_records(name: str, committed, fresh,
                    wall_tolerance: float = 4.0) -> list:
    """Classify every leaf; return a list of problem strings (empty = ok)."""
    problems = []
    com = dict(_leaves(committed))
    new = dict(_leaves(fresh))
    for path in com.keys() - new.keys():
        problems.append(f"{name}: {path} missing from fresh run "
                        "(bench schema drifted — regenerate results)")
    for path in new.keys() - com.keys():
        problems.append(f"{name}: {path} not in committed results "
                        "(bench schema drifted — regenerate results)")
    for path in com.keys() & new.keys():
        c, f = com[path], new[path]
        key = path.rsplit(".", 1)[-1]
        if key.endswith("_bit_identical"):
            if bool(f) != bool(c):
                problems.append(
                    f"{name}: {path} flipped {c} -> {f} (parity contract)")
        elif key == "interpreted":
            # committed false = a COMPILED-path claim; a fresh interpret
            # run cannot stand in for it (the numbers measure the Pallas
            # interpreter, not the kernels) — regenerate on the same
            # class of machine.  true -> false only widens the claim.
            if bool(c) is False and bool(f) is True:
                problems.append(
                    f"{name}: {path} flipped false -> true (committed "
                    f"results claim a compiled backend; this machine "
                    f"only interprets — regenerate on a compiled backend "
                    f"or drop the claim)")
        elif isinstance(c, bool) or isinstance(f, bool):
            continue                       # other booleans: informational
        elif isinstance(c, (int, float)) and isinstance(f, (int, float)):
            if _is_wall_key(key):
                lo, hi = min(abs(c), abs(f)), max(abs(c), abs(f))
                if hi > 0 and (lo == 0 or hi / lo > wall_tolerance):
                    problems.append(
                        f"{name}: {path} wall-clock ratio {c} -> {f} "
                        f"outside x{wall_tolerance} band")
            elif _is_headline(path, key):
                same = (f == c) or (math.isnan(f) and math.isnan(c))
                if not same:
                    problems.append(
                        f"{name}: {path} energy headline drifted "
                        f"{c!r} -> {f!r} (must be bit-identical)")
        # strings / None / mixed types: presence-checked only
    return problems


def check(names, wall_tolerance: float = 4.0, rerun: bool = True) -> int:
    """Run the gate for ``names``; prints a report, returns the exit code."""
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    import benchmarks.run as R

    failures = []
    for name in names:
        if name not in R.BENCHES:
            failures.append(f"{name}: not in the bench registry "
                            f"{list(R.BENCHES)}")
            continue
        committed_path = os.path.join(RESULTS, f"bench_{name}.json")
        if not os.path.exists(committed_path):
            failures.append(f"{name}: no committed results at "
                            f"{committed_path}")
            continue
        with open(committed_path) as fh:
            committed = json.load(fh)
        print(f"[check_regression] re-running {name} ...", flush=True)
        fresh = R._runner(name)()
        # round-trip through JSON so both sides see identical coercions
        fresh = json.loads(json.dumps(fresh, default=str))
        probs = compare_records(name, committed, fresh,
                                wall_tolerance=wall_tolerance)
        if probs:
            failures.extend(probs)
            print(f"[check_regression] {name}: "
                  f"{len(probs)} problem(s)")
        else:
            print(f"[check_regression] {name}: ok")
    if failures:
        print("\nBENCH REGRESSION GATE FAILED:")
        for p in failures:
            print(f"  - {p}")
        return 1
    print(f"\nbench-regression gate passed for {list(names)}")
    return 0


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", action="append", default=None,
                    help="bench name to check (repeatable); default: "
                         f"{DEFAULT_BENCHES}")
    ap.add_argument("--wall-tolerance", type=float, default=4.0,
                    help="allowed wall-clock ratio between committed and "
                         "fresh (CI machines differ; default 4x)")
    args = ap.parse_args(argv)
    names = tuple(args.only) if args.only else DEFAULT_BENCHES
    raise SystemExit(check(names, wall_tolerance=args.wall_tolerance))


if __name__ == "__main__":
    main()
