"""Benchmark driver: one section per paper table/figure + the roofline.

  bench_ema_breakdown — Fig. 1(b): 1.9 GB/iter EMA + stage breakdown
  bench_pssa          — Fig. 5:   PSSA vs baseline/RLE/CSR + index overhead
  bench_tips          — Fig. 9(b): TIPS low-precision ratio per iteration
  bench_dbsc          — Fig. 9(c): DBSC FFN energy efficiency + exactness
  bench_energy_iter   — Table I:  28.6 / 213.3 mJ per iteration
  bench_engine        — jitted scan/fused-CFG engine vs seed Python loop
  bench_fused_attention — Pallas fused-attention path vs materializing
                        reference: peak temp bytes, wall, imgs/s, parity
  bench_sharded_engine — data-parallel mesh serving: imgs/s at
                        dp ∈ {1,2,4,8} on simulated host devices + the
                        dp-vs-unsharded parity contract
  roofline            — §Roofline table from the dry-run records

Each section prints measured vs paper numbers; exit code 1 if any section
errors.  Results also land in benchmarks/results/bench_<name>.json.
"""
from __future__ import annotations

import json
import os
import time
import traceback

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _section(name, fn):
    t0 = time.perf_counter()
    print(f"\n=== {name} " + "=" * max(1, 60 - len(name)))
    try:
        out = fn()
        dt = time.perf_counter() - t0
        print(json.dumps(out, indent=2, default=str)[:4000])
        print(f"[{name} ok in {dt:.1f}s]")
        os.makedirs(RESULTS, exist_ok=True)
        with open(os.path.join(RESULTS, f"bench_{name}.json"), "w") as f:
            json.dump(out, f, indent=1, default=str)
        return True
    except Exception:
        traceback.print_exc()
        print(f"[{name} FAILED]")
        return False


def main() -> None:
    from benchmarks import (bench_dbsc, bench_ema_breakdown,
                            bench_energy_iter, bench_engine,
                            bench_fused_attention, bench_pssa,
                            bench_sharded_engine, bench_tips, roofline)

    ok = True
    ok &= _section("ema_breakdown", bench_ema_breakdown.run)
    ok &= _section("pssa", bench_pssa.run)
    ok &= _section("tips", bench_tips.run)
    ok &= _section("dbsc", bench_dbsc.run)
    ok &= _section("energy_iter", bench_energy_iter.run)
    ok &= _section("engine", bench_engine.run)
    ok &= _section("fused_attention", bench_fused_attention.run)
    ok &= _section("sharded_engine", bench_sharded_engine.run)

    def _roof():
        rows = roofline.run()
        print(roofline.format_table(rows))
        return {"cells": len(rows),
                "worst": rows[:3], "best": rows[-3:]}
    ok &= _section("roofline", _roof)

    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
