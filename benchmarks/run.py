"""Benchmark driver: one section per paper table/figure + perf trajectories.

The section listing is GENERATED from ``BENCHES`` (name -> module), with
each section's one-line summary pulled from the bench module's own
docstring — run with ``--list`` to print it, so the listing can never
drift from the registry the way a hand-maintained docstring table did.

Each section prints measured vs paper numbers; exit code 1 if any section
errors.  Results also land in benchmarks/results/bench_<name>.json.
"""
from __future__ import annotations

import argparse
import ast
import importlib
import json
import os
import time
import traceback

RESULTS = os.path.join(os.path.dirname(__file__), "results")

# Registry: section name -> (module, runner attr).  Order is the run
# order; the roofline has a custom formatter, handled in _runner().
BENCHES = {
    "ema_breakdown": "benchmarks.bench_ema_breakdown",
    "pssa": "benchmarks.bench_pssa",
    "tips": "benchmarks.bench_tips",
    "dbsc": "benchmarks.bench_dbsc",
    "energy_iter": "benchmarks.bench_energy_iter",
    "engine": "benchmarks.bench_engine",
    "fused_attention": "benchmarks.bench_fused_attention",
    "fused_cross_attention": "benchmarks.bench_fused_cross_attention",
    "compiled_kernels": "benchmarks.bench_compiled_kernels",
    "sharded_engine": "benchmarks.bench_sharded_engine",
    "continuous_serving": "benchmarks.bench_continuous_serving",
    "temporal_reuse": "benchmarks.bench_temporal_reuse",
    "phase_sampling": "benchmarks.bench_phase_sampling",
    "dit_serving": "benchmarks.bench_dit_serving",
    "cluster_router": "benchmarks.bench_cluster_router",
    "roofline": "benchmarks.roofline",
}

# leaf keys worth a headline line, in display order; "*_bit_identical"
# and "meets_target" are the contract flags, the rest are the numbers a
# reader checks first
_SUMMARY_KEYS = ("meets_target", "mj_per_iter_with_ema", "ema_reduction",
                 "mj_per_image_ratio", "imgs_per_s_speedup",
                 "p95_latency_improvement", "goodput_ratio_vs_fixed",
                 "quality_rel_l2")


def _summary_leaves(rec, path=""):
    if isinstance(rec, dict):
        for k, v in rec.items():
            yield from _summary_leaves(v, f"{path}.{k}" if path else str(k))
    elif not isinstance(rec, (list, tuple)):
        yield path, rec


def summarize(names) -> dict:
    """One headline line per bench, from the results JSON on disk."""
    lines = {}
    for name in names:
        path = os.path.join(RESULTS, f"bench_{name}.json")
        if not os.path.exists(path):
            lines[name] = "(no results on disk)"
            continue
        with open(path) as f:
            rec = json.load(f)
        picked = []
        for p, v in _summary_leaves(rec):
            key = p.rsplit(".", 1)[-1]
            if key in _SUMMARY_KEYS or key.endswith("_bit_identical"):
                if isinstance(v, float):
                    v = round(v, 4)
                picked.append((p.count("."),
                               f"{key}={v}" if "." not in p else f"{p}={v}"))
        # shallow leaves are the headline (contract flags, top-level
        # ratios); deep sweep entries only fill leftover slots
        picked = [s for _, s in sorted(picked, key=lambda t: t[0])]
        if not picked:
            # no contract flags: fall back to the first few numeric leaves
            picked = [f"{p}={round(v, 4) if isinstance(v, float) else v}"
                      for p, v in _summary_leaves(rec)
                      if isinstance(v, (int, float))
                      and not isinstance(v, bool)][:4]
        lines[name] = "; ".join(picked[:8]) or "(empty record)"
    return lines


def _summary_line(modname: str) -> str:
    """First docstring line of a bench module, sans the 'BENCH —' prefix.

    Read from SOURCE (``ast.get_docstring``), not by importing: ``--list``
    must not pay the jax import cost of ten bench modules, and a bench
    with a broken import should still be listable.
    """
    path = os.path.join(os.path.dirname(__file__),
                        modname.rsplit(".", 1)[1] + ".py")
    with open(path) as f:
        doc = (ast.get_docstring(ast.parse(f.read())) or "").strip()
    first = doc.splitlines()[0] if doc else ""
    for prefix in ("BENCH — ", "BENCH -- ", "Paper "):
        if first.startswith(prefix):
            first = first[len(prefix):]
            break
    return first.rstrip(".")


def bench_listing() -> str:
    """The section listing, generated from the registry (never drifts)."""
    width = max(len(n) for n in BENCHES)
    return "\n".join(f"  {name:<{width}}  {_summary_line(modname)}"
                     for name, modname in BENCHES.items())


def _runner(name: str):
    mod = importlib.import_module(BENCHES[name])
    if name == "roofline":
        def _roof():
            rows = mod.run()
            print(mod.format_table(rows))
            return {"cells": len(rows), "worst": rows[:3], "best": rows[-3:]}
        return _roof
    return mod.run


def _section(name, fn):
    t0 = time.perf_counter()
    print(f"\n=== {name} " + "=" * max(1, 60 - len(name)))
    try:
        out = fn()
        dt = time.perf_counter() - t0
        print(json.dumps(out, indent=2, default=str)[:4000])
        print(f"[{name} ok in {dt:.1f}s]")
        os.makedirs(RESULTS, exist_ok=True)
        with open(os.path.join(RESULTS, f"bench_{name}.json"), "w") as f:
            json.dump(out, f, indent=1, default=str)
        return True
    except Exception:
        traceback.print_exc()
        print(f"[{name} FAILED]")
        return False


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--list", action="store_true",
                    help="print the generated section listing and exit")
    ap.add_argument("--only", default=None,
                    help="run a single section by name")
    ap.add_argument("--check", action="store_true",
                    help="bench-regression gate: re-run the smoke benches "
                         "and diff against the committed results "
                         "(delegates to benchmarks/check_regression.py; "
                         "combine with --only to gate one section)")
    ap.add_argument("--compiled", action="store_true",
                    help="run only the compiled-path kernel bench "
                         "(reference vs fused vs autotuned blocks at full "
                         "serving geometry + the int8 FFN datapath); "
                         "records backend/interpreted so the claim is "
                         "machine-honest.  With --smoke: tiny geometry, "
                         "printed only — committed results stay untouched")
    ap.add_argument("--smoke", action="store_true",
                    help="with --compiled: tiny-geometry wiring check")
    ap.add_argument("--summary", action="store_true",
                    help="write benchmarks/results/summary.json (one "
                         "headline line per bench, from the results JSON "
                         "on disk) and exit — the CI artifact; run "
                         "sections first to summarize fresh numbers")
    args = ap.parse_args()
    if args.list:
        print(bench_listing())
        raise SystemExit(0)
    if args.summary:
        names = [n for n in BENCHES if n != "roofline"]
        if args.only is not None:
            if args.only not in BENCHES:
                ap.error(f"--only {args.only!r}: expected one of "
                         f"{list(BENCHES)}")
            names = [args.only]
        lines = summarize(names)
        os.makedirs(RESULTS, exist_ok=True)
        with open(os.path.join(RESULTS, "summary.json"), "w") as f:
            json.dump(lines, f, indent=1)
        width = max(len(n) for n in lines)
        for name, line in lines.items():
            print(f"{name:<{width}}  {line}")
        raise SystemExit(0)
    if args.compiled:
        from benchmarks.bench_compiled_kernels import run as run_compiled
        if args.smoke:
            rec = run_compiled(smoke=True)
            print(json.dumps(rec, indent=2))
            raise SystemExit(0)
        raise SystemExit(0 if _section("compiled_kernels",
                                       run_compiled) else 1)
    if args.check:
        from benchmarks.check_regression import DEFAULT_BENCHES, check
        names = (args.only,) if args.only is not None else DEFAULT_BENCHES
        skipped = [n for n in BENCHES if n not in names]
        if skipped:
            print(f"[check] benches NOT gated this run (use --only): "
                  f"{skipped}")
        raise SystemExit(check(names))
    names = list(BENCHES)
    if args.only is not None:
        if args.only not in BENCHES:
            ap.error(f"--only {args.only!r}: expected one of {names}")
        names = [args.only]

    ok = True
    for name in names:
        ok &= _section(name, _runner(name))
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
