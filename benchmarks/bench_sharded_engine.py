"""Data-parallel serving throughput: dp ∈ {1, 2, 4, 8} on a simulated mesh.

Each dp degree runs in its OWN subprocess (the fake-host-device count is an
``XLA_FLAGS`` decision made before jax initializes, like the dry-run), at a
fixed per-device batch — weak scaling, the serving-throughput question:
"how many imgs/s do N chips sustain?".  Each child also checks the §6
parity contract: engine output on the mesh vs the unsharded engine at the
same seed — bit-identical integer PSSA counters (the ledger is drift-free
by construction), images bit-identical at dp=1 and within float tolerance
at dp>1 (XLA tiles per-shard batches differently; recorded, not hidden).

Honest-reporting note: imgs/s scaling saturates at the HOST's physical
core count — data parallelism cannot mint compute on a shared-memory CPU,
so the json records ``host_cores`` and the core-ceiling-relative
efficiency alongside the raw ratios.  On a real multi-device host (TPU
pod / many-core CPU) the same harness measures true dp scaling.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

PER_DEVICE_BATCH = 2
REQUEST_ROUNDS = 4      # requests = rounds * micro_batch (even: the padded
                        # -tail path is pinned by tests/test_sharded_engine;
                        # a pad-heavy tail call would understate imgs/s)

_CHILD = r"""
import json, os, sys
dp = int(sys.argv[1]); per_dev = int(sys.argv[2]); rounds = int(sys.argv[3])
if dp > 1:
    from repro.launch.mesh import simulate_host_devices
    simulate_host_devices(dp)
import jax
import jax.numpy as jnp
import numpy as np
from repro.diffusion.engine import DiffusionEngine
from repro.diffusion.pipeline import PipelineConfig
from repro.launch.mesh import make_data_mesh
from repro.launch import serve_diffusion as S

class A: pass
a = A(); a.smoke = True; a.steps = 3; a.guidance = 1.0; a.kernels = "reference"
a.tips = "fixed"
cfg = S.make_config(a)
mesh = make_data_mesh(dp) if dp > 1 else None
mb = per_dev * dp
reqs = S.synthetic_requests(cfg, rounds * mb)
metrics = S.serve(cfg, reqs, mb, ledger=True, mesh=mesh)

# parity vs the unsharded engine at the same seed (fixed latents)
key = jax.random.PRNGKey(42)
toks = S.synthetic_requests(cfg, mb, seed=5)
lat = jax.random.normal(jax.random.PRNGKey(3),
                        (mb, cfg.unet.latent_size, cfg.unet.latent_size,
                         cfg.unet.in_channels))
ref = DiffusionEngine(cfg, key=key).generate(toks, None, latents=lat.copy())
shd = DiffusionEngine(cfg, key=key, mesh=mesh).generate(
    toks, None, latents=lat.copy()) if mesh is not None else ref
ri, si = np.asarray(ref.images), np.asarray(shd.images)
metrics["parity"] = {
    "images_bit_identical": bool(np.array_equal(ri, si)),
    "images_max_abs_diff": float(np.abs(ri - si).max()),
    "stats_counters_bit_identical": bool(all(
        np.array_equal(np.asarray(x.nnz), np.asarray(y.nnz))
        and np.array_equal(np.asarray(x.bitmap_ones_xor),
                           np.asarray(y.bitmap_ones_xor))
        for x, y in zip(ref.stats.pssa, shd.stats.pssa))),
}
print("BENCH_JSON:" + json.dumps(metrics))
"""


def _run_child(dp: int) -> dict:
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    r = subprocess.run(
        [sys.executable, "-c", _CHILD, str(dp), str(PER_DEVICE_BATCH),
         str(REQUEST_ROUNDS)],
        env=env, capture_output=True, text=True, timeout=580)
    for line in r.stdout.splitlines():
        if line.startswith("BENCH_JSON:"):
            return json.loads(line[len("BENCH_JSON:"):])
    raise RuntimeError(f"dp={dp} child failed:\n{r.stdout}\n{r.stderr}")


def run() -> dict:
    cores = os.cpu_count() or 1
    per_dp = {}
    for dp in (1, 2, 4, 8):
        per_dp[dp] = _run_child(dp)
    base = per_dp[1]["imgs_per_s"]
    scaling = {dp: m["imgs_per_s"] / max(base, 1e-9)
               for dp, m in per_dp.items()}
    return {
        "mode": "weak scaling (fixed per-device batch "
                f"{PER_DEVICE_BATCH}, smoke geometry, 3 steps)",
        "host_cores": cores,
        "imgs_per_s": {dp: m["imgs_per_s"] for dp, m in per_dp.items()},
        "iter_wall_ms": {dp: m["iter_wall_ms"] for dp, m in per_dp.items()},
        "scaling_vs_dp1": scaling,
        "scaling_dp4_over_dp1": scaling[4],
        # the dp degree this host can actually parallelize (dp threads
        # beyond the core count just time-slice)
        "scaling_at_host_core_dp": scaling.get(
            max(d for d in per_dp if d <= cores), scaling[1]),
        # dp cannot beat the physical core count on a shared-memory host
        "efficiency_vs_core_ceiling": {
            dp: scaling[dp] / max(min(dp, cores), 1)
            for dp in per_dp},
        "parity": {dp: m["parity"] for dp, m in per_dp.items()},
        "energy_headline_mj_per_iter": {
            dp: m["energy"]["mj_per_iter_with_ema"]
            for dp, m in per_dp.items() if "energy" in m},
        "padded_rows": {dp: m["padded_rows"] for dp, m in per_dp.items()},
    }


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
