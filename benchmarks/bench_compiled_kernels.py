"""BENCH — Compiled-path kernels: reference vs fused vs autotuned blocks + int8 FFN.

The fused-attention trajectory benches run at small interpret-friendly
geometry; this bench makes the PERFORMANCE claim at the geometry the
serving paths actually run (64x64 latents -> T=4096 self-attention rows,
Tk=77 text keys, 4096-row GEGLU FFN) and is explicit about what machine
made it: every record carries ``backend`` and ``interpreted``, so the
regression gate can tell an interpret-mode trajectory (CPU CI — the
committed numbers) from a compiled claim (TPU/GPU, where ``interpreted``
is false and the Pallas kernels execute natively).

Three routes per attention geometry, timed with donation + warmup and
the shared min-of-k convention (``benchmarks.timing``):

* ``reference``      — materializing XLA path (the stats oracle)
* ``fused_default``  — blocked Pallas kernel, ``KernelPolicy`` defaults
* ``fused_tuned``    — same kernel, blocks from the committed autotune
  table (``kernels.autotune``); ``tuned_vs_default_speedup`` is the
  number the autotuner has to defend.  The PSSA/TIPS integer statistics
  must not move with routing or block shape: at engine geometry that is
  the bit-identical contract (tests/test_autotune.py pins it), while at
  this geometry's 134M stochastic softmax samples a handful of
  probabilities land within an ulp of the 2^-13 prune threshold (the
  normalizer's summation order differs per block size), so the
  self-attention counter claim here is BOUNDED knife-edge drift with
  the raw mismatch counts in the record.

The FFN section runs the DBSC integer matmul both ways —
``quant_path="model"`` (int32 simulation) vs ``"int8"`` (real int8 x
int8 -> int32 ``lax.dot_general``) — and pins the accumulators
bit-identical.  The int8 wall is reported honestly: it maps to MXU /
dp4a integer units on accelerators, while CPU XLA may simulate it
SLOWER than f32; the claim here is exactness + the routing existing,
not a CPU speedup.
"""
from __future__ import annotations

import functools
import time

# full geometry: smoke-model channels at the top resolution
SELF_GEOMS = ((1, 8, 4096, 40, 64),)
CROSS_GEOMS = ((1, 8, 1024, 40, 77), (1, 8, 4096, 40, 77))
FFN_GEOM = (4096, 320, 1280)                 # (rows, c, dff)

SMOKE_SELF = ((1, 2, 256, 32, 16),)
SMOKE_CROSS = ((1, 2, 256, 32, 77),)
SMOKE_FFN = (256, 64, 128)


def _donated_wall(op, make_args, *, donate, reps):
    """Min-of-k wall of ``op`` with donated, freshly-staged operands.

    Donation lets the compiled path reuse operand buffers for outputs
    (the serving posture) — which also means a timed call CONSUMES its
    operands, so each repetition stages fresh device copies outside the
    clock; ``benchmarks.timing.min_over`` keeps the min-of-k convention.
    """
    import jax

    from benchmarks.timing import min_over

    fn = jax.jit(op, donate_argnums=donate)
    jax.block_until_ready(fn(*make_args()))            # compile + warm up

    def sample():
        args = make_args()
        jax.block_until_ready(args)
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        return time.perf_counter() - t0

    return min_over(reps, sample)


def run(smoke: bool = False) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.core.attention                        # noqa: F401  (cycle)
    from repro.kernels import autotune
    from repro.kernels.bitslice_matmul.ops import bitslice_matmul
    from repro.kernels.dispatch import KernelPolicy
    from repro.kernels.pssa_attention.ops import pssa_attention
    from repro.kernels.cross_attention_tips.ops import cross_attention_cas
    from repro.kernels.runtime import default_interpret

    reps = 1 if smoke else 2
    threshold = 1.0 / 8192.0
    defaults = KernelPolicy()
    backend = jax.default_backend()
    interpreted = default_interpret()

    def tuned_or_default(op, geom, names):
        won = autotune.lookup(op, geom) or {}
        return {n: won.get(n, getattr(defaults, n)) for n in names}

    # ---- self-attention: reference vs fused-default vs fused-tuned ---
    self_attn = {}
    for geom in (SMOKE_SELF if smoke else SELF_GEOMS):
        b, h, t, d, patch = geom
        arrs = [np.random.default_rng(i).standard_normal(
            (b, h, t, d), dtype=np.float32) for i in range(3)]
        make_args = lambda: tuple(jnp.array(a) for a in arrs)

        default_blk = {"attn_block_q": defaults.attn_block_q,
                       "attn_block_k": defaults.attn_block_k}
        tuned_blk = tuned_or_default("self_attention", geom, default_blk)

        def attn_op(bq, bk, use_kernel=True):
            return functools.partial(
                pssa_attention, threshold=threshold, patch=patch,
                use_kernel=use_kernel, bq=bq, bk=bk)

        walls = {
            "reference_wall_s": _donated_wall(
                attn_op(128, 128, use_kernel=False), make_args,
                donate=(0, 1, 2), reps=reps),
            "fused_default_wall_s": _donated_wall(
                attn_op(default_blk["attn_block_q"],
                        default_blk["attn_block_k"]), make_args,
                donate=(0, 1, 2), reps=reps),
            "fused_tuned_wall_s": _donated_wall(
                attn_op(tuned_blk["attn_block_q"],
                        tuned_blk["attn_block_k"]), make_args,
                donate=(0, 1, 2), reps=reps),
        }
        # counters (surviving-score nnz + patch-XOR popcount): at engine
        # geometry these are bit-identical across routing and block
        # shape (tests/test_autotune.py pins it); at this many-sample
        # geometry a handful of softmax probabilities land within an ulp
        # of the 2^-13 prune threshold, and the normalizer's summation
        # order differs per block size — so the full-geometry claim is
        # BOUNDED knife-edge drift (a few rows, +-1..2 counts), reported
        # with the raw mismatch numbers
        outs = {name: f(*make_args()) for name, f in [
            ("reference", attn_op(128, 128, use_kernel=False)),
            ("default", attn_op(default_blk["attn_block_q"],
                                default_blk["attn_block_k"])),
            ("tuned", attn_op(tuned_blk["attn_block_q"],
                              tuned_blk["attn_block_k"]))]}
        rows = b * h * t
        mismatch = max(
            int(jnp.sum(outs["reference"][i] != o[i]))
            for o in (outs["default"], outs["tuned"]) for i in (1, 2))
        max_diff = max(
            int(jnp.max(jnp.abs(outs["reference"][i] - o[i])))
            for o in (outs["default"], outs["tuned"]) for i in (1, 2))
        counters_ok = mismatch <= max(1, rows // 1000) and max_diff <= 4
        self_attn[f"t={t}"] = {
            "geom": list(geom),
            **walls,
            "default_blocks": default_blk,
            "tuned_blocks": tuned_blk,
            "tuned_vs_default_speedup": walls["fused_default_wall_s"]
            / max(walls["fused_tuned_wall_s"], 1e-9),
            "fused_tuned_vs_reference_speedup": walls["reference_wall_s"]
            / max(walls["fused_tuned_wall_s"], 1e-9),
            "counter_mismatch_rows": mismatch,
            "counter_max_abs_diff": max_diff,
            "counters_knife_edge_bounded": bool(counters_ok),
        }

    # ---- cross-attention ---------------------------------------------
    cross_attn = {}
    for geom in (SMOKE_CROSS if smoke else CROSS_GEOMS):
        b, h, tq, d, tk = geom
        rng = np.random.default_rng(7)
        qa = rng.standard_normal((b, h, tq, d), dtype=np.float32)
        ka = rng.standard_normal((b, h, tk, d), dtype=np.float32)
        va = rng.standard_normal((b, h, tk, d), dtype=np.float32)
        make_args = lambda: (jnp.array(qa), jnp.array(ka), jnp.array(va))

        default_blk = {"cross_block_q": defaults.cross_block_q}
        tuned_blk = tuned_or_default("cross_attention", geom, default_blk)

        def cross_op(bq, use_kernel=True):
            return functools.partial(cross_attention_cas,
                                     use_kernel=use_kernel, bq=bq)

        walls = {
            "reference_wall_s": _donated_wall(
                cross_op(128, use_kernel=False), make_args,
                donate=(0, 1, 2), reps=reps),
            "fused_default_wall_s": _donated_wall(
                cross_op(default_blk["cross_block_q"]), make_args,
                donate=(0, 1, 2), reps=reps),
            "fused_tuned_wall_s": _donated_wall(
                cross_op(tuned_blk["cross_block_q"]), make_args,
                donate=(0, 1, 2), reps=reps),
        }
        # the TIPS contract (DESIGN.md §7): the head-averaged CAS feeds
        # ``important <=> cas < threshold`` and THAT mask must not move
        # with routing or block shape (raw per-head CAS floats may differ
        # in final ulps between the online-softmax kernel and the
        # materializing reference; the decision integers may not)
        tips_thr = 0.05                      # PrecisionPolicy.fixed()
        masks = {name: jnp.mean(f(*make_args())[1], axis=1) < tips_thr
                 for name, f in [
                     ("reference", cross_op(128, use_kernel=False)),
                     ("default", cross_op(default_blk["cross_block_q"])),
                     ("tuned", cross_op(tuned_blk["cross_block_q"]))]}
        cas_ok = (jnp.array_equal(masks["reference"], masks["default"])
                  and jnp.array_equal(masks["reference"], masks["tuned"]))
        cross_attn[f"tq={tq}"] = {
            "geom": list(geom),
            **walls,
            "default_blocks": default_blk,
            "tuned_blocks": tuned_blk,
            "tuned_vs_default_speedup": walls["fused_default_wall_s"]
            / max(walls["fused_tuned_wall_s"], 1e-9),
            "fused_tuned_vs_reference_speedup": walls["reference_wall_s"]
            / max(walls["fused_tuned_wall_s"], 1e-9),
            "tips_mask_bit_identical": bool(cas_ok),
        }

    # ---- FFN int8 datapath -------------------------------------------
    rows, c, dff = SMOKE_FFN if smoke else FFN_GEOM
    rng = np.random.default_rng(11)
    xa = rng.standard_normal((rows, c), dtype=np.float32)
    wa = (rng.standard_normal((c, 2 * dff), dtype=np.float32)
          / np.sqrt(c)).astype(np.float32)
    imp = rng.random(rows) < 0.5
    w_dev = jnp.array(wa)                    # weights stay resident
    make_x = lambda: (jnp.array(xa),)

    def ffn_op(quant_path):
        return functools.partial(bitslice_matmul, w=w_dev,
                                 important=jnp.array(imp),
                                 use_kernel=False, quant_path=quant_path)

    model_wall = _donated_wall(ffn_op("model"), make_x, donate=(0,),
                               reps=reps)
    int8_wall = _donated_wall(ffn_op("int8"), make_x, donate=(0,),
                              reps=reps)
    acc_model = ffn_op("model")(*make_x())
    acc_int8 = ffn_op("int8")(*make_x())
    ffn = {
        "geom": {"rows": rows, "c": c, "dff": dff,
                 "important_ratio": float(np.mean(imp))},
        "model_wall_s": model_wall,
        "int8_wall_s": int8_wall,
        "int8_vs_model_speedup": model_wall / max(int8_wall, 1e-9),
        "int8_bit_identical": bool(jnp.array_equal(acc_model, acc_int8)),
    }

    tuned_wins = all(
        rec["tuned_vs_default_speedup"] >= 1.0
        for section in (self_attn, cross_attn) for rec in section.values())
    exact = (all(r["counters_knife_edge_bounded"]
                 for r in self_attn.values())
             and all(r["tips_mask_bit_identical"]
                     for r in cross_attn.values())
             and ffn["int8_bit_identical"])

    return {
        "backend": backend,
        "interpreted": bool(interpreted),
        "smoke": bool(smoke),
        "reps": reps,
        "table_entries": len(autotune.load_table()["entries"]),
        "self_attention": self_attn,
        "cross_attention": cross_attn,
        "ffn_int8": ffn,
        "tuned_beats_default": bool(tuned_wins),
        "exactness_bit_identical": bool(exact),
        "meets_target": bool(tuned_wins and exact),
    }


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny geometry, 1 rep (CI wiring check)")
    args = ap.parse_args()
    print(json.dumps(run(smoke=args.smoke), indent=2))
