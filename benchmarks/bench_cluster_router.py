"""BENCH — Cluster router: replica scaling, SLO degradation, ledger identity.

Three claims from DESIGN.md §13, measured on one engine config:

1. **Replica sweep** {1, 2, 4}: the same saturating trace served by N
   slot-state replicas behind occupancy routing.  Records p50/p95
   enqueue->image latency and steps-normalized goodput per replica
   count, plus the invariant that matters: the MERGED integer ledger
   (``pipeline.energy_report_cluster``) is bit-identical at every
   replica count AND to the same requests served one-shot.

2. **Overload**: a burst larger than the whole cluster's slots, with a
   round-denominated SLO.  Degrade-don't-queue admission serves late
   requests at a lower bank tier; the queueing baseline (the positive
   control) serves everyone at the requested tier, late.  Round
   arithmetic makes both attainments DETERMINISTIC — the committed
   numbers reproduce exactly on any machine.

3. **Streaming previews**: progressive preview decode every K rounds;
   time-to-first-pixel (first preview latency) lands well before the
   finished image.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def run() -> dict:
    import jax
    import jax.numpy as jnp

    from repro.diffusion.engine import DiffusionEngine
    from repro.diffusion.pipeline import PipelineConfig, energy_report_multi
    from repro.diffusion.sampler import DDIMConfig
    from repro.diffusion.solvers import SamplerPolicy
    from repro.launch.router import ClusterRouter, RouterSLO
    from repro.launch.scheduler import make_requests

    steps = 5
    n_requests = 12
    slots = 2
    replica_counts = (1, 2, 4)

    cfg = PipelineConfig.smoke()
    cfg = dataclasses.replace(
        cfg,
        ddim=DDIMConfig(num_inference_steps=steps, guidance_scale=1.0,
                        tips_active_iters=max(1, steps * 20 // 25)))
    eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))

    # ---- 1. replica sweep (saturating trace: whole queue at t=0) -------
    sweep = {}
    energies = {}
    compile_s = 0.0
    request_sets = {}
    for r in replica_counts:
        router = ClusterRouter(eng, r, slots)
        compile_s += router.warmup()   # shared executables: ~free after 1
        reqs = make_requests(cfg, n_requests, seed=7)
        m = router.run(reqs, ledger=True)
        m.pop("states")
        assert m["dropped"] == 0
        energies[r] = m["energy"]
        request_sets[r] = reqs
        sweep[f"replicas_{r}"] = {
            "latency_s": m["latency_s"],
            "queue_wait_s": m["queue_wait_s"],
            "goodput_imgs_per_s": m["goodput_imgs_per_s"],
            "goodput_steps_per_s": m["goodput_steps_per_s"],
            "makespan_s": m["makespan_s"],
            "mean_occupancy": m["mean_occupancy"],
            "rounds": m["rounds"],
        }
    ledger_bit_identical_across_replicas = all(
        energies[r] == energies[replica_counts[0]] for r in replica_counts)
    images_bit_identical_across_replicas = all(
        np.array_equal(a.image, b.image)
        for r in replica_counts[1:]
        for a, b in zip(request_sets[replica_counts[0]], request_sets[r]))

    # one-shot oracle at the slot batch width (the bit-identity contract
    # is per batch signature)
    fetched = []
    reqs0 = request_sets[replica_counts[0]]
    images_bit_identical_vs_one_shot = True
    for i in range(0, n_requests, slots):
        chunk = reqs0[i:i + slots]
        out = eng.generate(
            jnp.concatenate([q.tokens for q in chunk], axis=0), None,
            latents=jnp.concatenate([q.latents for q in chunk], axis=0))
        arr = np.asarray(out.images)
        images_bit_identical_vs_one_shot &= all(
            np.array_equal(arr[j], q.image) for j, q in enumerate(chunk))
        fetched.append(out.stats.ledger_fetch())
    one_shot_energy = {k: float(v) for k, v in
                       energy_report_multi(cfg, fetched).summary().items()}
    energy_bit_identical_vs_one_shot = (
        energies[replica_counts[0]] == one_shot_energy)

    # ---- 2. overload: degrade-don't-queue vs queueing ------------------
    bank = (SamplerPolicy.parse("ddim,steps=4"),
            SamplerPolicy.parse("ddim,steps=2"))
    deadline = 6

    def overload_requests():
        reqs = make_requests(cfg, 6, seed=7, bank=bank)
        for q in reqs:                 # everyone asks the expensive tier
            q.policy_index = 0
            q.tier = bank[0].label()
        return reqs

    def overload_run(degrade):
        router = ClusterRouter(
            eng, 1, slots, bank=bank,
            slo=RouterSLO(deadline_steps=deadline, degrade=degrade))
        router.warmup()
        reqs = overload_requests()
        m = router.run(reqs, ledger=True)
        m.pop("states")
        assert m["dropped"] == 0
        return {
            "slo_attainment": m["slo"]["attainment"],
            "slo_met": m["slo"]["met"],
            "finish_rounds": sorted(q.finish_round - q.arrival_round
                                    for q in reqs),
            "degraded_per_tier": m.get("degraded_per_tier", {}),
            "per_policy_images": [e["images"]
                                  for e in m["energy"]["per_policy"]],
            "latency_s": m["latency_s"],
        }

    degrade = overload_run(True)
    queue = overload_run(False)
    degradation_beats_queueing = (degrade["slo_attainment"]
                                  > queue["slo_attainment"])

    # ---- 3. streaming previews (time-to-first-pixel) -------------------
    router = ClusterRouter(eng, 2, slots, preview_every=2)
    router.warmup()
    reqs = make_requests(cfg, 8, seed=7)
    m = router.run(reqs, ledger=False)
    m.pop("states")
    firsts = [q.first_preview_s - q.arrival_s for q in reqs
              if q.first_preview_s is not None]
    preview = {
        "every": 2,
        "decodes": m["events"]["preview"],
        "requests_previewed": len(firsts),
        "first_preview_latency_s": float(np.mean(firsts)),
        "finished_latency_s": m["latency_s"]["mean"],
        "ttfp_improvement": m["latency_s"]["mean"]
        / max(float(np.mean(firsts)), 1e-9),
    }

    meets_target = bool(
        ledger_bit_identical_across_replicas
        and images_bit_identical_across_replicas
        and energy_bit_identical_vs_one_shot
        and images_bit_identical_vs_one_shot
        and degradation_beats_queueing)
    return {
        "config": {"steps": steps, "requests": n_requests,
                   "slots_per_replica": slots,
                   "replica_counts": list(replica_counts),
                   "latent": cfg.unet.latent_size},
        "compile_s": compile_s,
        "replica_sweep": sweep,
        "ledger_bit_identical_across_replicas":
            ledger_bit_identical_across_replicas,
        "images_bit_identical_across_replicas":
            images_bit_identical_across_replicas,
        "energy_bit_identical_vs_one_shot":
            energy_bit_identical_vs_one_shot,
        "images_bit_identical_vs_one_shot":
            images_bit_identical_vs_one_shot,
        "energy_headline_mj_per_iter":
            energies[replica_counts[0]]["mj_per_iter_with_ema"],
        "overload": {
            "bank": [p.label() for p in bank],
            "deadline_steps": deadline,
            "degrade": degrade,
            "queue_baseline": queue,
            "degradation_beats_queueing": degradation_beats_queueing,
        },
        "preview": preview,
        "meets_target": meets_target,
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))
