"""BENCH — jitted engine vs the seed Python-loop pipeline (smoke config).

Three measured paths on identical geometry/params/inputs:

  * ``seed_loop``   — the seed repo's serving path, faithfully
    reconstructed: 25 Python-level dispatches per image, TWO jitted UNet
    calls per step under classifier-free guidance, and PSSA accounting
    through the seed's materializing ``compress_stats_reference``
    (``UNetConfig.pssa_stats_reference=True``).  This is the PR-over-PR
    trajectory baseline.
  * ``python_loop`` — the same dispatch model with THIS PR's fused stats
    counters (isolates the dispatch-model win from the stats-hot-path win).
  * ``engine``      — one ``jax.jit`` of encode -> ``lax.scan`` sampler ->
    decode, with cond+uncond fused into ONE batched UNet call per step and
    fused stats counters.

Emits ``benchmarks/results/bench_engine.json`` with imgs/s, per-iteration
wall time, and the speedups — the first point of the perf trajectory (PR
acceptance: engine >= 1.5x the seed loop's imgs/s).  Also cross-checks that
the full-geometry energy headline computed from the engine's STACKED stats
pytree matches the one from the Python loop's per-step stats list.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.diffusion.engine import DiffusionEngine
from repro.diffusion.pipeline import (PipelineConfig, StableDiffusionPipeline,
                                      energy_report)
from repro.diffusion.sampler import DDIMConfig


def _bench_config(steps: int, guidance: float,
                  seed_stats: bool = False) -> PipelineConfig:
    cfg = PipelineConfig.smoke()
    return dataclasses.replace(
        cfg,
        unet=dataclasses.replace(cfg.unet,
                                 pssa_stats_reference=seed_stats),
        ddim=DDIMConfig(num_inference_steps=steps, guidance_scale=guidance,
                        tips_active_iters=max(1, steps * 20 // 25)))


def _time_python_loop(pipe, toks, uncond, key, reps: int):
    pipe.generate(toks, key, uncond_tokens=uncond)          # warmup/compile
    best = float("inf")
    stats = None
    for r in range(reps):                 # min-of-reps: scheduler-noise-free
        t0 = time.perf_counter()
        img, stats = pipe.generate(toks, jax.random.fold_in(key, r),
                                   uncond_tokens=uncond)
        jax.block_until_ready(img)
        best = min(best, time.perf_counter() - t0)
    return best, stats


def _time_engine(eng, toks, uncond, key, reps: int):
    eng.generate(toks, key, uncond_tokens=uncond)           # warmup/compile
    best = float("inf")
    out = None
    for r in range(reps):                 # min-of-reps: scheduler-noise-free
        out = eng.generate(toks, jax.random.fold_in(key, r),
                           uncond_tokens=uncond)
        best = min(best, eng.last_wall_s)
    return best, out.stats


def _path_metrics(wall_s: float, batch: int, steps: int,
                  dispatches: int) -> dict:
    return {
        "wall_s_per_call": wall_s,
        "imgs_per_s": batch / wall_s,
        "iter_wall_ms": 1e3 * wall_s / steps,
        "unet_dispatches_per_image": dispatches,
    }


def run(steps: int = 25, batch: int = 2, guidance: float = 7.5,
        reps: int = 3) -> dict:
    """Defaults pin the PAPER's operating point: 25 UNet iterations with
    classifier-free guidance.  (Short step counts understate the engine —
    the once-per-image text-encode/VAE-decode constant dominates.)"""
    key = jax.random.PRNGKey(0)
    cfg = _bench_config(steps, guidance)
    cfg_seed = _bench_config(steps, guidance, seed_stats=True)

    pipe_seed = StableDiffusionPipeline(cfg_seed, key=key)
    pipe = StableDiffusionPipeline(cfg, key=key)
    eng = DiffusionEngine(cfg, key=key)

    toks = jax.random.randint(jax.random.PRNGKey(1), (batch, cfg.text.max_len),
                              0, cfg.text.vocab_size)
    uncond = (jnp.zeros_like(toks) if guidance != 1.0 else None)
    kgen = jax.random.PRNGKey(2)
    per_img_dispatch = steps * (2 if guidance != 1.0 else 1)

    seed_s, _ = _time_python_loop(pipe_seed, toks, uncond, kgen, reps)
    loop_s, loop_stats = _time_python_loop(pipe, toks, uncond, kgen, reps)
    eng_s, eng_stats = _time_engine(eng, toks, uncond, kgen, reps)

    # energy-headline parity: stacked pytree vs per-step stats list
    rep_loop = energy_report(cfg, loop_stats).summary()
    rep_eng = energy_report(cfg, eng_stats).summary()
    headline_drift = max(
        abs(rep_loop["total_ema_reduction"] - rep_eng["total_ema_reduction"]),
        abs(rep_loop["mj_per_iter_with_ema"] - rep_eng["mj_per_iter_with_ema"])
        / max(abs(rep_loop["mj_per_iter_with_ema"]), 1e-9))

    return {
        "config": {"steps": steps, "batch": batch, "guidance": guidance,
                   "reps": reps, "latent": cfg.unet.latent_size},
        "seed_loop": _path_metrics(seed_s, batch, steps, per_img_dispatch),
        "python_loop": _path_metrics(loop_s, batch, steps, per_img_dispatch),
        "engine": {**_path_metrics(eng_s, batch, steps, 0),
                   "note": "one fused XLA computation per call"},
        "speedup_vs_seed_loop": seed_s / eng_s,
        "speedup_vs_current_loop": loop_s / eng_s,
        "meets_1p5x_target": bool(seed_s / eng_s >= 1.5),
        "energy_headline": {
            "from_stacked_stats": {
                "total_ema_reduction": rep_eng["total_ema_reduction"],
                "mj_per_iter_with_ema": rep_eng["mj_per_iter_with_ema"],
            },
            "from_python_loop_stats": {
                "total_ema_reduction": rep_loop["total_ema_reduction"],
                "mj_per_iter_with_ema": rep_loop["mj_per_iter_with_ema"],
            },
            "max_relative_drift": headline_drift,
        },
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
