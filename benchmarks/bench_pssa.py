"""Paper Fig. 5 — PSSA compression vs baseline / RLE / global CSR.

Measures, at the TRUE BK-SDM self-attention resolutions (64/32/16 -> patch
sizes 64/32/16), the SAS EMA bytes under four schemes:

  baseline   — dense SAS, INT12
  RLE        — pruned values + zero-run-length index stream
  CSR        — pruned values + one global CSR index
  PSSA       — pruned values + patch-XOR'd, per-patch local CSR index

Every scheme gets the dense-bypass a real DMA engine would use (one mode bit:
store dense when "compression" expands — which happens at the small
resolutions where the fixed threshold prunes nothing).

Calibration: the smoke UNet is untrained, so attention-score statistics come
from ``synthetic_sas`` (spatially-local, peaked rows).  One scalar —
sharpness — is bisected so the T=4096 pruned density sits at the paper's
operating point (the density where its 61.2 % SAS EMA cut is arithmetically
reachable, ~1/3); everything downstream (XOR win, index sizes, per-scheme
deltas, total-EMA cut) is *measured*, not assumed.
"""
from __future__ import annotations

import jax

import jax.numpy as jnp

from benchmarks.synthetic_sas import synthetic_sas
from repro.core import pssa
from repro.diffusion import ledger as L
from repro.diffusion.unet import BK_SDM_TINY
from repro.kernels import dispatch
from repro.kernels.dispatch import KernelPolicy

POINTS = [(64, 8), (32, 8), (16, 8)]       # (resolution, heads)
TARGET_DENSITY_64 = 1.0 / 3.0


def calibrate_sharpness(key, target=TARGET_DENSITY_64, lo=0.2, hi=3.0,
                        iters=8) -> float:
    """Bisect sharpness so pruned density at res 64 hits ``target``."""
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        sas = synthetic_sas(key, 64, heads=2, sharpness=mid)
        st = pssa.compress_stats(sas, patch=64)
        d = float(st.nnz / st.total)
        if d > target:
            lo = mid          # too dense -> sharpen
        else:
            hi = mid
    return 0.5 * (lo + hi)


def measure(sharpness: float, seed: int = 0,
            policy: KernelPolicy = KernelPolicy.fused()):
    """-> (per-res stats, aggregate bytes per scheme with dense-bypass).

    The PSXU payload — the packed XOR bitmap a DMA engine would actually
    move — is generated through ``dispatch.patch_bitmap`` per the kernel
    policy, and its popcounts are cross-checked against the byte-accounting
    counters (``payload_counter_parity``): the payload and the ledger must
    describe the same bits.
    """
    rows = {}
    agg = {"baseline": 0.0, "rle": 0.0, "csr": 0.0, "pssa": 0.0,
           "idx_rle": 0.0, "idx_csr": 0.0, "idx_pssa": 0.0}
    payload_parity = True
    for res, heads in POINTS:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), res)
        sas = synthetic_sas(key, res, heads=heads, sharpness=sharpness)
        patch = BK_SDM_TINY.patch_size(res)
        st = pssa.compress_stats(sas, patch=patch)
        rows[res] = st
        _, counts = dispatch.patch_bitmap(policy, sas, patch,
                                          pssa.DEFAULT_THRESHOLD)
        payload_parity &= (int(jnp.sum(counts))
                           == int(float(st.bitmap_ones_xor)))
        dense = float(st.bytes_baseline)
        agg["baseline"] += dense
        agg["rle"] += min(dense, float(st.bytes_values + st.bytes_index_rle))
        agg["csr"] += min(dense,
                          float(st.bytes_values + st.bytes_index_csr_global))
        agg["pssa"] += min(dense, float(st.bytes_pssa_total))
        agg["idx_rle"] += float(st.bytes_index_rle)
        agg["idx_csr"] += float(st.bytes_index_csr_global)
        agg["idx_pssa"] += float(st.bytes_index_pssa)
    agg["payload_counter_parity"] = payload_parity
    return rows, agg


def run() -> dict:
    sharp = calibrate_sharpness(jax.random.PRNGKey(42))
    rows, agg = measure(sharp)
    sas_ratio = {res: min(1.0, float(st.bytes_pssa_total
                                     / st.bytes_baseline))
                 for res, st in rows.items()}

    base_rep = L.iteration_report(BK_SDM_TINY, L.LedgerOptions())
    opt_rep = L.iteration_report(
        BK_SDM_TINY, L.LedgerOptions(pssa=True, sas_ratio=sas_ratio))

    return {
        "calibrated_sharpness": sharp,
        "payload_counter_parity": agg["payload_counter_parity"],
        "density_by_res": {res: float(st.nnz / st.total)
                           for res, st in rows.items()},
        "sas_ratio_by_res": sas_ratio,
        "sas_ema_reduction_vs_baseline": 1 - agg["pssa"] / agg["baseline"],
        "sas_ema_reduction_vs_rle": 1 - agg["pssa"] / agg["rle"],
        "sas_ema_reduction_vs_csr": 1 - agg["pssa"] / agg["csr"],
        "index_reduction_vs_rle": 1 - agg["idx_pssa"] / agg["idx_rle"],
        "index_reduction_vs_csr": 1 - agg["idx_pssa"] / agg["idx_csr"],
        "total_ema_reduction": 1 - (opt_rep.ema_bytes_total
                                    / base_rep.ema_bytes_total),
        "paper": {"sas_vs_baseline": 0.612, "sas_vs_rle": 0.467,
                  "sas_vs_csr": 0.385, "idx_vs_rle": 0.836,
                  "idx_vs_csr": 0.795, "total_ema": 0.378},
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
