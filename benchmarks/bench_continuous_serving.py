"""BENCH — Continuous batching vs fixed micro-batching under bursty traffic.

A request arriving while a fixed micro-batch is mid-scan waits the batch's
whole generation before it can start, so its enqueue->image latency
approaches 2x the generation time.  The slot-based continuous scheduler
(DESIGN.md §8) admits it at the next STEP boundary instead.  This bench
drives the SAME bursty arrival trace through both schedulers on the same
engine config and records enqueue->image latency percentiles, goodput, and
the two bit-identity contracts (per-request images; energy headline from
the integer accumulator vs the one-shot batch aggregation).

The burst gap is calibrated against the measured one-shot generation wall
so the trace stresses the same regime on any machine: bursts land
mid-generation for the fixed scheduler while the queue stays deep enough
that continuous slots run near-full occupancy.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def run() -> dict:
    import jax

    from repro.diffusion.engine import DiffusionEngine
    from repro.diffusion.pipeline import PipelineConfig
    from repro.diffusion.sampler import DDIMConfig
    from repro.launch.scheduler import (ContinuousScheduler,
                                        FixedBatchScheduler, apply_trace,
                                        bursty_trace, make_requests)

    steps = 5
    n_requests = 16
    slots = 4
    burst = 2

    # paper-default thresholds: the committed headline must be
    # reproducible on ANY machine (the bench-regression gate compares it
    # exactly), so the bench runs the saturation-stable operating point;
    # the knife-edge input-sensitivity proofs live in
    # tests/test_continuous.py where reference and candidate run on the
    # same host
    cfg = PipelineConfig.smoke()
    cfg = dataclasses.replace(
        cfg,
        ddim=DDIMConfig(num_inference_steps=steps, guidance_scale=1.0,
                        tips_active_iters=max(1, steps * 20 // 25)))

    eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
    cont = ContinuousScheduler(eng, num_slots=slots)
    fixed = FixedBatchScheduler(eng, micro_batch=slots)
    compile_s = cont.warmup() + fixed.warmup()

    # calibrate: one generation's wall at the serving batch size
    out = eng.generate(
        jax.random.randint(jax.random.PRNGKey(1),
                           (slots, cfg.text.max_len), 0,
                           cfg.text.vocab_size), jax.random.PRNGKey(2))
    del out
    gen_wall = eng.last_wall_s
    # half-batch bursts spaced just under one generation: every burst
    # leaves the fixed scheduler short of a full batch, so each request
    # pays wait-to-fill (up to a full gap) on top of wait-for-engine and
    # the generation itself; the slot runtime admits the same burst at
    # the next step boundary and its tail service is a fraction of a
    # generation, so it wins BOTH tail latency and makespan-goodput
    gap_s = 0.9 * gen_wall

    def fresh_requests():
        reqs = make_requests(cfg, n_requests, seed=7)
        return apply_trace(reqs, bursty_trace(n_requests, burst, gap_s))

    reqs_fixed = fresh_requests()
    m_fixed = fixed.run(reqs_fixed, ledger=True)
    reqs_cont = fresh_requests()
    m_cont = cont.run(reqs_cont, ledger=True)
    m_cont.pop("state")

    images_bit_identical = all(
        np.array_equal(rc.image, rf.image)
        for rc, rf in zip(reqs_cont, reqs_fixed))
    stats_bit_identical = (m_cont["energy"] == m_fixed["energy"])

    def view(m):
        return {
            "latency_s": m["latency_s"],
            "queue_wait_s": m["queue_wait_s"],
            "goodput_imgs_per_s": m["goodput_imgs_per_s"],
            "makespan_s": m["makespan_s"],
        }

    p95_fixed = m_fixed["latency_s"]["p95"]
    p95_cont = m_cont["latency_s"]["p95"]
    goodput_ratio = (m_cont["goodput_imgs_per_s"]
                     / m_fixed["goodput_imgs_per_s"])
    return {
        "config": {"steps": steps, "requests": n_requests, "slots": slots,
                   "micro_batch": slots, "burst": burst,
                   "latent": cfg.unet.latent_size},
        "trace": {"kind": "bursty", "burst": burst, "gap_s": gap_s,
                  "gen_wall_s": gen_wall},
        "compile_s": compile_s,
        "fixed_micro_batch": view(m_fixed),
        "continuous": {**view(m_cont),
                       "mean_occupancy": m_cont["mean_occupancy"],
                       "engine_steps": m_cont["engine_steps"]},
        "p95_latency_improvement": p95_fixed / max(p95_cont, 1e-9),
        "p50_latency_improvement": (m_fixed["latency_s"]["p50"]
                                    / max(m_cont["latency_s"]["p50"], 1e-9)),
        "goodput_ratio_vs_fixed": goodput_ratio,
        "images_bit_identical": images_bit_identical,
        "stats_bit_identical": stats_bit_identical,
        "energy_headline_mj_per_iter": {
            "continuous": m_cont["energy"]["mj_per_iter_with_ema"],
            "fixed": m_fixed["energy"]["mj_per_iter_with_ema"],
        },
        "meets_target": bool(p95_fixed / max(p95_cont, 1e-9) > 1.0
                             and goodput_ratio >= 0.97
                             and images_bit_identical
                             and stats_bit_identical),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
