"""BENCH — DiT serving: UNet-vs-DiT throughput + energy behind one contract.

The denoiser contract (DESIGN.md §11) makes the model family a config
choice: the DiT denoiser serves through the SAME engine, kernel dispatch
table, quality tiers and banked integer ledger as the UNet.  This bench
pins that claim with numbers:

  * UNet-vs-DiT imgs/s and modeled mJ/image at MATCHED parameter count —
    the DiT depth is chosen (via ``abstract_params``, no allocation) so
    its smoke geometry lands closest to the UNet smoke parameter count,
    making the throughput/energy comparison a family comparison rather
    than a size comparison;
  * ``dit_counters_bit_identical`` — the DiT PSSA/TIPS integer counters
    are bit-identical across ``reference`` and ``fused`` kernel routing
    (the same §4/§5 contract the UNet carries);
  * ``dit_banked_ledger_bit_identical`` — a mixed-tier DiT slot trace
    produces a bit-identical banked energy summary across slot counts
    {2, 4} (occupancy-invariant integer accumulation, §8/§10 on the new
    family).
"""
from __future__ import annotations

import dataclasses

import numpy as np

STEPS = 5
N_REQUESTS = 4
DIT_DEPTH_SWEEP = range(1, 17)


def _param_count(den) -> int:
    import jax
    return int(sum(np.prod(l.shape)
                   for l in jax.tree_util.tree_leaves(den.abstract_params())))


def run() -> dict:
    import jax
    import jax.numpy as jnp

    from repro.diffusion import solvers
    from repro.diffusion.denoiser import make_denoiser
    from repro.diffusion.dit import DiTConfig
    from repro.diffusion.engine import DiffusionEngine
    from repro.diffusion.pipeline import PipelineConfig, energy_report
    from repro.diffusion.sampler import DDIMConfig
    from repro.kernels.dispatch import KernelPolicy
    from repro.launch.scheduler import ContinuousScheduler, make_requests

    base = PipelineConfig.smoke()
    ddim = DDIMConfig(num_inference_steps=STEPS, guidance_scale=1.0,
                      tips_active_iters=max(1, STEPS * 20 // 25))

    # ---- match DiT size to the UNet smoke parameter count ----
    unet_params = _param_count(make_denoiser(base.unet))
    dit_smoke = DiTConfig().smoke()
    depth = min(DIT_DEPTH_SWEEP, key=lambda d: abs(
        _param_count(make_denoiser(
            dataclasses.replace(dit_smoke, depth=d))) - unet_params))
    dit_cfg = dataclasses.replace(dit_smoke, depth=depth)

    model_cfgs = {"unet": base.unet, "dit": dit_cfg}
    families: dict = {}
    engines: dict = {}
    for fam, mcfg in model_cfgs.items():
        cfg = dataclasses.replace(base, unet=mcfg, ddim=ddim)
        eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
        engines[fam] = (cfg, eng)
        toks = jax.random.randint(jax.random.PRNGKey(1),
                                  (2, cfg.text.max_len), 0,
                                  cfg.text.vocab_size)
        lat0 = np.asarray(eng.init_latents(2, jax.random.PRNGKey(2)))
        out = eng.generate(toks, None, latents=jnp.asarray(lat0))
        # min-of-3 on the compiled executable (see bench_phase_sampling)
        wall = min(
            (eng.generate(toks, None, latents=jnp.asarray(lat0)),
             eng.last_wall_s)[1] for _ in range(3))
        rep = energy_report(cfg, out.stats)
        families[fam] = {
            "params": _param_count(eng.denoiser),
            "latent": mcfg.latent_size,
            "attn_layers": len(eng.denoiser.layer_order()),
            "wall_s": wall,
            "imgs_per_s": 2.0 / max(wall, 1e-9),
            "energy": {
                "mj_per_iter_with_ema": rep.mj_per_iter_with_ema,
                "mj_per_image": rep.mj_per_iter_with_ema * STEPS,
            },
        }
    families["dit"]["depth_matched"] = depth

    # ---- contract: DiT counters bit-identical across kernel routing ----
    counters = {}
    for routing in ("reference", "fused"):
        cfg = dataclasses.replace(
            base, ddim=ddim, unet=dataclasses.replace(
                dit_cfg, kernel_policy=getattr(KernelPolicy, routing)()))
        eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(3),
                                  (1, cfg.text.max_len), 0,
                                  cfg.text.vocab_size)
        out = eng.generate(toks, jax.random.PRNGKey(4))
        # the contract leaf set (tests/test_denoiser_contract.py): all
        # PSSAStats fields + folded TIPS low_precision_ratio; raw cas
        # floats are fp-tolerance-only across the blocked softmax
        counters[routing] = (
            [np.asarray(x) for p in out.stats.pssa for x in p]
            + [np.asarray(t.low_precision_ratio) for t in out.stats.tips])
    dit_counters_bit_identical = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(counters["reference"], counters["fused"]))

    # ---- contract: banked DiT ledger bit-identical across slot counts ----
    dit_pipe, dit_eng = engines["dit"]
    bank = (solvers.SamplerPolicy(solver="dpm2m", num_steps=4,
                                  name="draft"),
            solvers.SamplerPolicy(solver="ddim", num_steps=STEPS,
                                  name="quality"))
    energies = {}
    compile_s = 0.0
    for slots in (2, 4):
        sched = ContinuousScheduler(dit_eng, num_slots=slots, bank=bank)
        compile_s += sched.warmup()
        m = sched.run(make_requests(dit_pipe, N_REQUESTS, seed=11,
                                    bank=bank), ledger=True)
        m.pop("state")
        energies[slots] = m["energy"]
    dit_banked_ledger_bit_identical = (energies[2] == energies[4])

    return {
        "config": {"steps": STEPS, "requests": N_REQUESTS,
                   "bank": [p.describe() for p in bank]},
        "compile_s": compile_s,
        "families": families,
        "comparison": {
            "param_ratio_dit_over_unet": (families["dit"]["params"]
                                          / families["unet"]["params"]),
            "imgs_per_s_ratio_dit_over_unet": (
                families["dit"]["imgs_per_s"]
                / families["unet"]["imgs_per_s"]),
            "mj_per_image_dit_over_unet": (
                families["dit"]["energy"]["mj_per_image"]
                / families["unet"]["energy"]["mj_per_image"]),
        },
        "banked_ledger": {"energy": energies[2]},
        "dit_counters_bit_identical": bool(dit_counters_bit_identical),
        "dit_banked_ledger_bit_identical": bool(
            dit_banked_ledger_bit_identical),
        "meets_target": bool(dit_counters_bit_identical
                             and dit_banked_ledger_bit_identical),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
