"""BENCH — fused (Pallas) self-attention path vs the materializing reference.

Three measurements per geometry, all on identical inputs:

  * ``peak_temp_bytes`` — XLA's compiled peak temp-buffer size for one
    self-attention layer (``memory_analysis()``).  This is the number the
    refactor moves: the reference path materializes the (B, H, T, T) score
    matrix (O(T^2) residency), the fused path streams K blocks and keeps
    only O(T * block) alive.  Exact on any backend, no timers involved.
  * wall time of the jitted layer, fused vs reference (min-of-reps).  NOTE
    on CPU the fused numbers run Pallas INTERPRET mode — a correctness rig
    with per-block Python dispatch — so wall time is expected to LOSE on
    CPU and is recorded for trajectory only; on TPU the same call compiles
    (``interpret`` auto-selects; see kernels.runtime).
  * engine imgs/s with the reference vs fused ``KernelPolicy`` at smoke
    geometry — the end-to-end serving view of the same switch, plus the
    stats-parity cross-check (PSSA counters must be bit-identical).

Emits ``benchmarks/results/bench_fused_attention.json``.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.timing import min_wall_s
from repro.core.attention import (self_attention_pssa,
                                  self_attention_pssa_fused)
from repro.core.policies import ServePolicies
from repro.diffusion.engine import DiffusionEngine
from repro.diffusion.pipeline import PipelineConfig
from repro.kernels.dispatch import KernelPolicy
from repro.kernels.runtime import default_interpret

GEOMS = [  # (batch, heads, T, d, patch) — smoke-scale self-attention layers
    (1, 4, 256, 32, 16),
    (2, 4, 1024, 32, 32),
]


def _layer_fns(patch):
    ref = jax.jit(lambda q, k, v: self_attention_pssa(q, k, v, patch=patch))
    fused = jax.jit(lambda q, k, v: self_attention_pssa_fused(
        q, k, v, patch=patch))
    return {"reference": ref, "fused": fused}


def _layer_record(b, h, t, d, patch, reps):
    q, k, v = (jax.random.normal(jax.random.PRNGKey(i), (b, h, t, d))
               for i in range(3))
    rec = {"geometry": {"batch": b, "heads": h, "tokens": t, "head_dim": d,
                        "patch": patch},
           "sas_bytes_if_materialized": b * h * t * t * 4}
    outs = {}
    for name, fn in _layer_fns(patch).items():
        comp = fn.lower(q, k, v).compile()
        mem = comp.memory_analysis()
        rec[name] = {
            "peak_temp_bytes": int(mem.temp_size_in_bytes),
            "wall_s": min_wall_s(fn, q, k, v, reps=reps),
        }
        outs[name] = fn(q, k, v)
    rec["peak_temp_reduction"] = 1.0 - (
        rec["fused"]["peak_temp_bytes"]
        / max(rec["reference"]["peak_temp_bytes"], 1))
    rec["wall_speedup_fused"] = (rec["reference"]["wall_s"]
                                 / rec["fused"]["wall_s"])
    # stats-parity cross-check rides along with every benchmark run
    rec["stats_bit_identical"] = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(outs["reference"].stats, outs["fused"].stats))
    return rec


def _engine_record(steps, batch, reps):
    cfg = PipelineConfig.smoke()
    import dataclasses
    from repro.diffusion.sampler import DDIMConfig
    cfg = dataclasses.replace(cfg, ddim=DDIMConfig(
        num_inference_steps=steps, guidance_scale=1.0,
        tips_active_iters=max(1, steps * 20 // 25)))
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (batch, cfg.text.max_len), 0,
                              cfg.text.vocab_size)
    key = jax.random.PRNGKey(0)
    rec = {"steps": steps, "batch": batch}
    stats = {}
    for name, policy in [("reference", KernelPolicy.reference()),
                         ("fused", KernelPolicy.fused())]:
        eng = DiffusionEngine(cfg, key=key,
                              policies=ServePolicies(kernels=policy))
        eng.generate(toks, jax.random.PRNGKey(2))          # compile
        best = float("inf")
        for r in range(reps):
            out = eng.generate(toks, jax.random.fold_in(key, r))
            best = min(best, eng.last_wall_s)
        stats[name] = out.stats
        rec[name] = {"wall_s_per_call": best, "imgs_per_s": batch / best}
    rec["imgs_per_s_ratio_fused"] = (rec["fused"]["imgs_per_s"]
                                     / rec["reference"]["imgs_per_s"])
    rec["stats_bit_identical"] = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for sa, sb in zip(stats["reference"].pssa, stats["fused"].pssa)
        for a, b in zip(sa, sb))
    return rec


def run(reps: int = 3, engine_steps: int = 5, engine_batch: int = 1) -> dict:
    return {
        "backend": jax.default_backend(),
        "pallas_interpret": default_interpret(),
        "note": ("wall times on CPU run the fused path in Pallas interpret "
                 "mode (correctness rig, expected slower); peak_temp_bytes "
                 "is the backend-independent metric the fused path moves"),
        "layers": [_layer_record(*g, reps) for g in GEOMS],
        "engine_smoke": _engine_record(engine_steps, engine_batch, reps),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
