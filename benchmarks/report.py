"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from records."""
from __future__ import annotations

import json
import os

from benchmarks.roofline import load_records, roofline_row

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def dryrun_table(mesh: str) -> str:
    lines = [
        "| arch | shape | status | FLOPs/dev | bytes/dev | coll(w)/dev | "
        "args GB/dev | temp GB/dev | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), r in sorted(load_records(mesh).items()):
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | skipped (full-attn @524k) "
                         "| — | — | — | — | — | — |")
            continue
        e = r["extrapolated"]
        m = r["memory_analysis"]
        lines.append(
            f"| {arch} | {shape} | ok | {e['flops']:.2e} | "
            f"{e['bytes_accessed']:.2e} | "
            f"{e['collective_bytes']['weighted']:.2e} | "
            f"{m['argument_size_in_bytes'] / 1e9:.2f} | "
            f"{m['temp_size_in_bytes'] / 1e9:.2f} | {r['compile_s']:.1f} |")
    return "\n".join(lines)


def roofline_table(mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful | kind | roofline |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    rows = []
    for (arch, shape), rec in sorted(load_records(mesh).items()):
        row = roofline_row(rec)
        if row:
            rows.append(row)
    rows.sort(key=lambda r: r["roofline_fraction"])
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_kind']} | {r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def variant_rows():
    """All __variant perf records + their baselines, as dicts."""
    out = []
    for name in sorted(os.listdir(RESULTS)):
        if name.startswith("dryrun_") and "__" in name:
            out.append(json.load(open(os.path.join(RESULTS, name))))
        if name.startswith("baseline_dryrun_"):
            r = json.load(open(os.path.join(RESULTS, name)))
            r["variant"] = "BASELINE"
            out.append(r)
    return out


if __name__ == "__main__":
    print("## Dry-run 16x16\n")
    print(dryrun_table("16x16"))
    print("\n## Dry-run 2x16x16\n")
    print(dryrun_table("2x16x16"))
    print("\n## Roofline (16x16)\n")
    print(roofline_table())
