"""Paper Table I — energy per iteration + throughput of the full system.

Composes the measured mechanism numbers (PSSA compression ratios from
bench_pssa's calibrated SAS statistics, TIPS per-iteration ratios from
bench_tips' mechanism run) into the 25-iteration generation ledger:

  * 28.6 mJ/iter  (EMA excluded  — compute datapath with TIPS + DBSC)
  * 213.3 mJ/iter (EMA included  — + LPDDR traffic after PSSA)
  * 34.6 % EMA-included energy reduction vs the unoptimized datapath
  * 3.84 TOPS peak / 225.6 mW average -> iteration wall-time sanity check
"""
from __future__ import annotations

from benchmarks import bench_pssa, bench_tips
from repro.core.energy import (AVG_POWER_MW, PEAK_TOPS, iter_time_s, report)
from repro.diffusion import ledger as L
from repro.diffusion.unet import BK_SDM_TINY


def run() -> dict:
    # measured inputs from the mechanism benchmarks
    sharp = bench_pssa.calibrate_sharpness(
        __import__("jax").random.PRNGKey(42))
    rows, _ = bench_pssa.measure(sharp)
    sas_ratio = {res: min(1.0, float(st.bytes_pssa_total / st.bytes_baseline))
                 for res, st in rows.items()}
    tips_mech = bench_tips.mechanism_run()
    ratios = tips_mech["ratios_per_iter"]

    # 25-iteration ledgers
    opt_iters = [L.LedgerOptions(pssa=True, tips=r > 0, sas_ratio=sas_ratio,
                                 tips_low_ratio=r) for r in ratios]
    base_iters = [L.LedgerOptions()] * len(ratios)
    opt = L.generation_report(BK_SDM_TINY, opt_iters)
    base = L.generation_report(BK_SDM_TINY, base_iters)
    n = len(ratios)

    macs = sum(l.macs_high + l.macs_low
               for l in L.unet_ledger(BK_SDM_TINY)) / 1e9
    # on-chip power check: compute energy over the full-utilization
    # iteration time should land near the paper's 225.6 mW average
    t_iter = iter_time_s(macs * 1e9, utilization=1.0)

    return {
        "mj_per_iter_compute": opt.compute_energy_mj / n,
        "mj_per_iter_with_ema": opt.total_mj / n,
        "mj_per_iter_compute_baseline": base.compute_energy_mj / n,
        "mj_per_iter_with_ema_baseline": base.total_mj / n,
        "ema_included_reduction": 1 - opt.total_mj / base.total_mj,
        "gmacs_per_iter": macs,
        "iter_time_s_at_peak_tops": t_iter,
        "avg_power_mw_implied": (opt.compute_energy_mj / n) / t_iter,
        "hw": {"peak_tops": PEAK_TOPS, "avg_power_mw": AVG_POWER_MW},
        "paper": {"mj_per_iter_compute": 28.6, "mj_per_iter_with_ema": 213.3,
                  "ema_included_reduction": 0.346},
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
