"""Shared min-of-k block-until-ready wall-clock helpers (bench-facing).

Every bench times jitted callables the same way: compile/warm up OUTSIDE
the clock, then take the MINIMUM of k block-until-ready repetitions.
This module is the one import point the previously-duplicated
``_time``/``_timed`` helpers collapse into; the implementation lives in
``repro.kernels.runtime`` so the block autotuner (``kernels.autotune``,
which runs without the bench tree on the path) shares it byte for byte.

  ``timed(fn, *args, reps=3, warmup=1)``  -> (last output, min wall s)
  ``min_wall_s(fn, *args, reps=3)``       -> min wall s only
  ``min_over(reps, sample)``              -> min of self-clocked samples
"""
from __future__ import annotations

from repro.kernels.runtime import min_over, min_wall_s, timed  # noqa: F401
