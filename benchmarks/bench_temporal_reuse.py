"""BENCH — Temporal patch reuse: threshold sweep + img2img edit-trace speedup.

Two traces over the SIGE-style incremental denoiser (DESIGN.md §9):

* **t2i / temporal** — the jitted engine runs the scanned DDIM loop with
  the previous step's activations as the reuse reference, swept over the
  patch-delta threshold.  Threshold 0 forces every patch active, so its
  images must be BIT-IDENTICAL to the dense engine (the flag the
  regression gate pins); larger thresholds report the realized per-
  iteration reuse ratio from the integer counters and the modeled EMA
  that ratio implies (transformer-stage traffic scales with the computed
  fraction; CNN/other stages stay dense).
* **edit / img2img** — a base generation records its per-step activation
  caches (``sample_scan_reuse(record_caches=True)``); an edited latent
  (localized window perturbation) then re-denoises against those caches
  with a SUB-1.0 static gather capacity, so the attention/FFN stages
  really run on ~6% of the patch rows.  Measured: active-patch fraction
  from the counters and the dense-vs-reuse step wall-clock (interpret-
  mode CPU proxy, same convention as the fused-attention benches).

Geometry: smoke channels at latent 32 — 1024 tokens at the top
resolution, where the materializing reference attention dominates the
step, which is the regime the gather/scatter pays off in.
"""
from __future__ import annotations

import dataclasses

from benchmarks.timing import timed as _timed


def run() -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.reuse import ReusePolicy, reuse_cache_zeros
    from repro.diffusion.engine import DiffusionEngine
    from repro.diffusion.pipeline import (PipelineConfig, energy_report,
                                          aggregated_reuse_ratios_per_iter)
    from repro.diffusion.sampler import (DDIMConfig, sample_scan,
                                         sample_scan_reuse)
    from repro.diffusion.unet import init_unet_params, unet_forward

    steps = 3
    batch = 2

    cfg = PipelineConfig.smoke()
    cfg = dataclasses.replace(
        cfg,
        ddim=DDIMConfig(num_inference_steps=steps, guidance_scale=1.0,
                        tips_active_iters=max(1, steps * 20 // 25)))
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (batch, cfg.text.max_len), 0,
                              cfg.text.vocab_size)
    lat0 = None  # drawn per engine from the same key -> identical inputs

    # ---- t2i temporal trace: engine threshold sweep ------------------
    eng_dense = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
    out_dense = eng_dense.generate(toks, jax.random.PRNGKey(2))
    dense_wall = eng_dense.last_wall_s
    dense_img = np.asarray(out_dense.images)
    rep_dense = energy_report(cfg, out_dense.stats)
    stages = rep_dense.optimized.ema_bytes_by_stage
    xform = sum(stages.get(s, 0.0)
                for s in ("self_attn", "cross_attn", "ffn"))
    other = rep_dense.optimized.ema_bytes_total - xform

    sweep = []
    # smoke-geometry latents move a lot per DDIM step, so the small
    # thresholds realize no reuse (honest zeros); 1.0 shows the counter
    # machinery engaging on the temporal path
    for thr in (0.0, 0.05, 0.2, 1.0):
        eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0),
                              reuse_policy=ReusePolicy.temporal(
                                  threshold=thr))
        out = eng.generate(toks, jax.random.PRNGKey(2))
        ratios = aggregated_reuse_ratios_per_iter(cfg, [out.stats])
        mean_reuse = sum(ratios) / len(ratios)
        # modeled EMA: transformer traffic scales with the computed
        # fraction, everything else stays dense — integer-counter inputs,
        # so the number is machine-independent
        modeled = (other + xform * (1.0 - mean_reuse)) / steps / 1e9
        sweep.append({
            "threshold": thr,
            "step_wall_ms": 1e3 * eng.last_wall_s / steps,
            "reuse_ratio_per_iter": [float(r) for r in ratios],
            "modeled_ema_gb_per_iter": modeled,
            "images_equal_dense": bool(np.array_equal(
                np.asarray(out.images), dense_img)),
        })
    t2i_bit_identical = sweep[0]["images_equal_dense"]

    # ---- edit / img2img trace (sampler level, latent 32) -------------
    ucfg = dataclasses.replace(cfg.unet, latent_size=32)
    params = init_unet_params(jax.random.PRNGKey(3), ucfg)
    ctx = jax.random.normal(jax.random.PRNGKey(4),
                            (1, ucfg.text_len, ucfg.context_dim))
    s = ucfg.latent_size
    base_lat = jax.random.normal(jax.random.PRNGKey(5),
                                 (1, s, s, ucfg.in_channels))
    # localized edit: one 8x8 window re-noised
    edit_lat = base_lat.at[:, 4:12, 4:12, :].set(
        jax.random.normal(jax.random.PRNGKey(6),
                          (1, 8, 8, ucfg.in_channels)))

    def apply_for(uc):
        def unet_apply(lt, tv, cx, act, **kw):
            return unet_forward(params, lt, tv, cx, uc,
                                tips_active=act, **kw)
        return unet_apply

    record_cfg = dataclasses.replace(
        ucfg, reuse_policy=ReusePolicy.temporal(threshold=0.0))
    base_out, _, base_caches = jax.jit(
        lambda l: sample_scan_reuse(
            apply_for(record_cfg), l, ctx, None, cfg.ddim,
            reuse_cache=reuse_cache_zeros(record_cfg, 1, use_cfg=False),
            record_caches=True))(base_lat)
    jax.block_until_ready(base_out)

    dense_fn = jax.jit(
        lambda l: sample_scan(apply_for(ucfg), l, ctx, None, cfg.ddim))
    (dense_lat_out, _), dense_step_wall = _timed(dense_fn, edit_lat)

    # exactness control: thr=0 / cap=1 edit run == dense on the same input
    exact_cfg = dataclasses.replace(
        ucfg, reuse_policy=ReusePolicy.edit(threshold=0.0, capacity=1.0))
    exact_out, _ = jax.jit(
        lambda l: sample_scan_reuse(apply_for(exact_cfg), l, ctx, None,
                                    cfg.ddim, base_caches=base_caches)
    )(edit_lat)
    edit_bit_identical = bool(jnp.array_equal(exact_out, dense_lat_out))

    edit_cfg = dataclasses.replace(
        ucfg, reuse_policy=ReusePolicy.edit(threshold=0.05,
                                            capacity=0.0625))
    reuse_fn = jax.jit(
        lambda l: sample_scan_reuse(apply_for(edit_cfg), l, ctx, None,
                                    cfg.ddim, base_caches=base_caches))
    (reuse_lat_out, reuse_stats), reuse_step_wall = _timed(reuse_fn,
                                                           edit_lat)
    comp = sum(int(jnp.sum(c.computed)) for c in reuse_stats.reuse)
    tot = sum(int(jnp.sum(c.total)) for c in reuse_stats.reuse)
    active_fraction = comp / max(tot, 1)
    speedup = dense_step_wall / max(reuse_step_wall, 1e-9)

    return {
        "config": {"steps": steps, "batch": batch,
                   "t2i_latent": cfg.unet.latent_size,
                   "edit_latent": ucfg.latent_size,
                   "edit_capacity": 0.0625},
        "t2i": {
            "dense_step_wall_ms": 1e3 * dense_wall / steps,
            "threshold_sweep": sweep,
        },
        "edit": {
            "dense_step_wall_ms": 1e3 * dense_step_wall / steps,
            "reuse_step_wall_ms": 1e3 * reuse_step_wall / steps,
            "step_speedup": speedup,
            "active_patch_fraction": active_fraction,
            "edit_window_differs": bool(
                not jnp.array_equal(reuse_lat_out, base_out)),
        },
        "t2i_thr0_bit_identical": bool(t2i_bit_identical),
        "edit_thr0_bit_identical": edit_bit_identical,
        "meets_target": bool(t2i_bit_identical and edit_bit_identical
                             and active_fraction <= 0.10
                             and speedup >= 2.0),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
