"""BENCH — fused (Pallas) cross-attention TIPS path vs materializing reference.

Per geometry, on identical inputs:

  * ``peak_temp_bytes`` — XLA's compiled peak temp-buffer size for one
    cross-attention layer (``memory_analysis()``).  The reference
    materializes the (B, H, Tq, Tk) probability tensor just to read its
    CLS column; the fused path streams query blocks against the (small)
    text-key stripe, so only O(bq * Tk) probabilities are ever alive.
    Exact on any backend, no timers involved.
  * wall time of the jitted layer, fused vs reference (min-of-reps).  On
    CPU the fused path runs Pallas INTERPRET mode — a correctness rig
    with per-block Python dispatch — so wall time is recorded for
    trajectory only; on TPU the same call compiles (interpret
    auto-selects; see kernels.runtime).
  * the precision-decision parity cross-check: importance mask and
    low-precision ratio bit-identical, CAS within ulps (DESIGN.md §7) —
    under both the fixed and the adaptive spotting policy.

Emits ``benchmarks/results/bench_fused_cross_attention.json``.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.timing import min_wall_s
from repro.core.attention import (cross_attention_tips,
                                  cross_attention_tips_fused)
from repro.core.precision import PrecisionPolicy
from repro.kernels.runtime import default_interpret

GEOMS = [  # (batch, heads, Tq, d, Tk) — pixel queries x CLIP text keys
    (2, 8, 1024, 40, 77),      # full-geometry 32x32 block
    (1, 8, 4096, 40, 77),      # full-geometry 64x64 block (EMA-dominant)
]

POLICIES = {
    "fixed": PrecisionPolicy.fixed(),
    "adaptive": PrecisionPolicy.adaptive(),
}


def _layer_fns(policy):
    ref = jax.jit(lambda q, k, v: cross_attention_tips(
        q, k, v, precision=policy))
    fused = jax.jit(lambda q, k, v: cross_attention_tips_fused(
        q, k, v, precision=policy))
    return {"reference": ref, "fused": fused}


def _layer_record(b, h, tq, d, tk, policy_name, reps):
    policy = POLICIES[policy_name]
    q, k, v = (jax.random.normal(jax.random.PRNGKey(i), shape)
               for i, shape in enumerate([(b, h, tq, d), (b, h, tk, d),
                                          (b, h, tk, d)]))
    rec = {"geometry": {"batch": b, "heads": h, "queries": tq, "head_dim": d,
                        "text_len": tk},
           "policy": policy_name,
           "probs_bytes_if_materialized": b * h * tq * tk * 4}
    outs = {}
    for name, fn in _layer_fns(policy).items():
        comp = fn.lower(q, k, v).compile()
        mem = comp.memory_analysis()
        rec[name] = {
            "peak_temp_bytes": int(mem.temp_size_in_bytes),
            "wall_s": min_wall_s(fn, q, k, v, reps=reps),
        }
        outs[name] = fn(q, k, v)
    rec["peak_temp_reduction"] = 1.0 - (
        rec["fused"]["peak_temp_bytes"]
        / max(rec["reference"]["peak_temp_bytes"], 1))
    rec["wall_speedup_fused"] = (rec["reference"]["wall_s"]
                                 / rec["fused"]["wall_s"])
    r, f = outs["reference"].tips_result, outs["fused"].tips_result
    rec["mask_bit_identical"] = bool(np.array_equal(
        np.asarray(r.important), np.asarray(f.important)))
    rec["low_ratio_bit_identical"] = bool(np.array_equal(
        np.asarray(r.low_precision_ratio),
        np.asarray(f.low_precision_ratio)))
    rec["cas_max_abs_diff"] = float(np.max(np.abs(
        np.asarray(r.cas) - np.asarray(f.cas))))
    rec["realized_low_ratio"] = float(np.asarray(r.low_precision_ratio))
    return rec


def run(reps: int = 3) -> dict:
    return {
        "backend": jax.default_backend(),
        "pallas_interpret": default_interpret(),
        "note": ("wall times on CPU run the fused path in Pallas interpret "
                 "mode (correctness rig, expected slower); peak_temp_bytes "
                 "is the backend-independent metric the fused path moves"),
        "layers": [_layer_record(*g, policy_name=pn, reps=reps)
                   for g in GEOMS for pn in POLICIES],
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
