"""Realistic synthetic self-attention scores for the PSSA benchmarks.

The smoke UNet is untrained, so its attention rows are near-uniform — a
trained SD UNet's self-attention is *peaked* (few large scores per row) and
*spatially local* (adjacent image rows attend similarly; paper Fig. 3(a)).
This generator reproduces both properties at the true BK-SDM resolutions so
Fig. 5's compression numbers can be measured at full scale (T = 4096) without
pretrained weights:

  * a smooth 2-D feature field gives queries/keys with spatial locality
    (neighbouring pixels have similar embeddings);
  * a sharpness (inverse-temperature) factor controls how peaked the softmax
    rows are — calibrated so the pruned-SAS density matches the operating
    point where the paper's PSSA EMA reduction (~60 %) is achievable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _smooth_field(key, res: int, channels: int, base: int = 2,
                  octaves: int = 3):
    """Multi-octave smooth random field (H, W, C) — image-like locality.

    ``base`` sets the coarsest octave's grid: a small base gives LONG-range
    correlation (attention spread over big image regions), which is what a
    trained SD UNet shows at 64x64 (objects span many latent pixels)."""
    out = jnp.zeros((res, res, channels))
    for o in range(octaves):
        r = min(res, base << o)
        k = jax.random.fold_in(key, o)
        coarse = jax.random.normal(k, (r, r, channels))
        up = jax.image.resize(coarse, (res, res, channels), "bilinear")
        out = out + up / (2.0 ** o)
    return out


def synthetic_sas(key, res: int, heads: int = 8, head_dim: int = 40,
                  sharpness: float = 0.5, base: int = 2):
    """Peaked, spatially-local SAS (heads, T, T) at feature-map ``res``."""
    feat = _smooth_field(key, res, heads * head_dim, base=base)
    t = res * res
    qk = feat.reshape(t, heads, head_dim).transpose(1, 0, 2)
    qk = qk / jnp.linalg.norm(qk, axis=-1, keepdims=True)
    scores = jnp.einsum("hqd,hkd->hqk", qk, qk) * sharpness * head_dim ** 0.5
    return jax.nn.softmax(scores, axis=-1)
