"""Paper Fig. 9(c) + §IV-B — DBSC bit-slice core on the FFN workload.

Reports:
  * FFN energy-efficiency gain of INT12/INT6 mixed precision vs the all-
    INT12 baseline at the measured TIPS ratio (paper: +43.0 % at 44.8 %);
  * bit-exactness of the Pallas kernel vs the integer oracle on an
    FFN-shaped workload (both stationary dataflows);
  * numerical error of the full quantized datapath vs float (the quality
    cost that buys the energy), per precision mix;
  * per-slice MAC accounting (how many int7x8 slice-MACs each mode costs —
    the quantity the PE-energy model charges).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.energy import MAC_PJ, ffn_energy_gain
from repro.kernels.bitslice_matmul.kernel import bitslice_matmul_kernel
from repro.kernels.bitslice_matmul.ops import bitslice_matmul
from repro.kernels.bitslice_matmul.ref import bitslice_matmul_ref

# FFN-shaped workload: one GEGLU up-proj tile at the res-16 stage (C=1280)
M, K, N = 256, 1280, 1280


def run(low_ratio: float = 0.448) -> dict:
    key = jax.random.PRNGKey(0)
    x = jax.nn.relu(jax.random.normal(key, (M, K)))          # post-GN acts
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N)) / K ** 0.5
    important = jax.random.uniform(jax.random.fold_in(key, 2),
                                   (M,)) >= low_ratio

    # --- exactness: kernel vs integer oracle, both dataflows ---
    qx = quant.quantize_act(x)
    hi, lo = quant.bitslice_split(qx.values)
    qw = quant.quantize_weight(w)
    prec = important.astype(jnp.int32)[:, None]
    exact = {}
    for df in ("weight_stationary", "input_stationary"):
        out = bitslice_matmul_kernel(hi, lo, qw.values, prec, dataflow=df)
        ref = bitslice_matmul_ref(hi, lo, qw.values, prec)
        exact[df] = bool(jnp.all(out == ref))

    # --- numerical error of the datapath vs float ---
    y_float = x @ w
    err = {}
    for name, imp in [("all_int12", None), ("mixed_tips", important),
                      ("all_int6", jnp.zeros((M,), bool))]:
        y = bitslice_matmul(x, w, important=imp)
        err[name] = float(jnp.linalg.norm(y - y_float)
                          / jnp.linalg.norm(y_float))

    # --- slice-MAC accounting + energy model ---
    macs = M * K * N
    high_rows = float(jnp.mean(important.astype(jnp.float32)))
    slice_macs_baseline = 2 * macs                    # two int7x8 per MAC
    slice_macs_dbsc = macs * (2 * high_rows + 1 * (1 - high_rows))
    gain_measured_mix = ffn_energy_gain(1 - high_rows)

    return {
        "kernel_exact_vs_oracle": exact,
        "datapath_rel_error": err,
        "high_precision_row_fraction": high_rows,
        "slice_macs_baseline": slice_macs_baseline,
        "slice_macs_dbsc": slice_macs_dbsc,
        "slice_mac_reduction": 1 - slice_macs_dbsc / slice_macs_baseline,
        "ffn_energy_gain": gain_measured_mix,
        "mac_pj_table": MAC_PJ,
        "paper": {"ffn_energy_gain": 0.43, "low_ratio": 0.448},
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
