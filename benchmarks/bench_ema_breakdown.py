"""Paper Fig. 1(b) / §I — EMA + compute breakdown of one UNet iteration.

Baseline (INT12 act / INT8 weight, no compression), full BK-SDM-Tiny
geometry.  Paper numbers: 1.9 GB EMA/iter; transformer stage 87.0 % of EMA;
self-attention 78.2 % of transformer EMA; SAS alone 61.8 % of total EMA;
FFN 42.5 % of transformer-stage computation.
"""
from __future__ import annotations

from repro.diffusion import ledger as L
from repro.diffusion.unet import BK_SDM_TINY


def run() -> dict:
    rep = L.iteration_report(BK_SDM_TINY, L.LedgerOptions())
    led = L.unet_ledger(BK_SDM_TINY, L.LedgerOptions())
    tx_stages = ("self_attn", "cross_attn", "ffn")
    tx_ema = sum(rep.ema_bytes_by_stage.get(s, 0.0) for s in tx_stages)
    sa_ema = rep.ema_bytes_by_stage.get("self_attn", 0.0)

    tx_macs = sum(l.macs_high + l.macs_low for l in led
                  if l.stage in tx_stages)
    ffn_macs = sum(l.macs_high + l.macs_low for l in led
                   if l.stage == "ffn")
    cnn_macs = sum(l.macs_high + l.macs_low for l in led
                   if l.stage == "cnn")

    return {
        "ema_gb_per_iter": rep.ema_bytes_total / 1e9,
        "transformer_ema_fraction": tx_ema / rep.ema_bytes_total,
        "self_attn_fraction_of_transformer_ema": sa_ema / tx_ema,
        "sas_fraction_of_total_ema": rep.sas_fraction,
        "ffn_fraction_of_transformer_macs": ffn_macs / tx_macs,
        "cnn_fraction_of_total_macs": cnn_macs / (tx_macs + cnn_macs),
        "total_gmacs_per_iter": (tx_macs + cnn_macs) / 1e9,
        "ema_by_stage_gb": {k: v / 1e9
                            for k, v in rep.ema_bytes_by_stage.items()},
        "paper": {"ema_gb_per_iter": 1.9, "transformer_ema_fraction": 0.870,
                  "self_attn_fraction_of_transformer_ema": 0.782,
                  "sas_fraction_of_total_ema": 0.618,
                  "ffn_fraction_of_transformer_macs": 0.425},
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
