"""Paper Fig. 9(b) — TIPS low-precision ratio per UNet iteration.

Two measurements:

1. *Mechanism at the paper's operating point.*  Synthetic cross-attention
   rows with text-relevance structure (a smooth relevance field over the
   64x64 latent — prompt-related regions put their softmax mass on text
   tokens, so their CLS score is small).  The fixed CAS threshold splits
   pixels; the per-iteration schedule (20 of 25 active) turns the per-iter
   ratio into the workload fraction.  Paper: 44.8 % of FFN workload at INT6.

2. *End-to-end measurement* on the (untrained) smoke pipeline — validates
   the plumbing (per-iteration ratios collected by the sampler, zero in the
   last 5 iterations), not the trained-model ratio.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.synthetic_sas import _smooth_field
from repro.core import tips
from repro.core.energy import ffn_energy_gain


def synthetic_cross_attention(key, res: int = 64, text_len: int = 77,
                              heads: int = 8, relevance_scale: float = 3.0,
                              unimportant_frac: float = 0.56):
    """(heads, T, text_len) softmax rows over [CLS, text...] keys.

    The paper's premise (§IV-A): pixels tied to the prompt put their softmax
    mass on text tokens (small CAS); pixels NOT tied to the prompt dump
    their attention on the CLS token — the attention-sink behaviour — so
    their CAS is large.  ``unimportant_frac`` sets how much of the image is
    background (the paper measures ~56 % per active iteration -> 44.8 % of
    the 25-iteration workload)."""
    rel = _smooth_field(key, res, 1, base=2)[..., 0].reshape(-1)  # (T,)
    rel = rel - jnp.quantile(rel, unimportant_frac)   # >0 <=> prompt-related
    t = res * res
    k2 = jax.random.fold_in(key, 1)
    base = jax.random.normal(k2, (heads, t, text_len)) * 0.5
    boost = jnp.zeros((heads, t, text_len))
    # related pixels: mass onto text tokens
    boost = boost.at[:, :, 1:].add(
        relevance_scale * jax.nn.relu(rel)[None, :, None])
    # background pixels: mass onto the CLS sink (step + graded component —
    # even weakly-background pixels sink noticeably in a trained model)
    sink = jnp.where(rel < 0, 1.0, 0.0) + jax.nn.relu(-rel)
    boost = boost.at[:, :, 0].add(relevance_scale * sink[None, :])
    return jax.nn.softmax(base + boost, axis=-1)


def mechanism_run(threshold: float = 0.05, iters: int = 25,
                  active: int = 20, seed: int = 0) -> dict:
    ratios = []
    for i in range(iters):
        if i >= active:
            ratios.append(0.0)
            continue
        key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        probs = synthetic_cross_attention(key)
        r = tips.spot(probs, threshold)
        ratios.append(float(r.low_precision_ratio))
    frac = float(tips.workload_low_precision_fraction(
        jnp.asarray(ratios), active, iters))
    return {"ratios_per_iter": ratios, "workload_low_fraction": frac,
            "ffn_energy_gain_at_fraction": float(ffn_energy_gain(frac)),
            "paper": {"workload_low_fraction": 0.448,
                      "ffn_energy_gain": 0.43}}


def pipeline_run() -> dict:
    """Plumbing check on the reduced pipeline (untrained weights)."""
    from repro.diffusion.pipeline import (PipelineConfig,
                                          StableDiffusionPipeline)
    cfg = PipelineConfig.smoke()
    pipe = StableDiffusionPipeline(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, cfg.text.max_len),
                              0, cfg.text.vocab_size)
    _, stats = pipe.generate(toks, jax.random.PRNGKey(2))
    ratios = [pipe.measured_tips_ratio(s) for s in stats]
    # the workload fraction follows THIS run's DDIM schedule (3 steps, 2
    # active on smoke) — not the paper's hardcoded 20/25 operating point
    frac = float(tips.workload_low_precision_fraction(
        jnp.asarray(ratios), ddim=cfg.ddim))
    return {"ratios_per_iter": ratios,
            "workload_low_fraction": frac,
            "active_iters": cfg.ddim.tips_active_iters,
            "n_iters": cfg.ddim.num_inference_steps}


def run() -> dict:
    out = {"mechanism": mechanism_run()}
    out["pipeline_smoke"] = pipeline_run()
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
