"""End-to-end text-to-image generation (the paper's Fig. 1(a) flow).

Runs the reduced-geometry pipeline on CPU — text encode -> 25 DDIM UNet
iterations (PSSA pruning + TIPS mixed precision live) -> VAE decode — then
feeds the measured compression/precision statistics into the full
BK-SDM-Tiny ledger and prints the Table-I-style energy summary.

Run:  PYTHONPATH=src python examples/generate_image.py [--steps 5]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.diffusion.pipeline import PipelineConfig, StableDiffusionPipeline
from repro.diffusion.sampler import DDIMConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=5,
                    help="DDIM iterations (paper: 25; CPU demo default 5)")
    ap.add_argument("--guidance", type=float, default=1.0)
    args = ap.parse_args()

    cfg = PipelineConfig.smoke()
    cfg = PipelineConfig(
        unet=cfg.unet, text=cfg.text, vae=cfg.vae,
        ddim=DDIMConfig(num_inference_steps=args.steps,
                        guidance_scale=args.guidance,
                        tips_active_iters=max(1, args.steps * 20 // 25)))
    print(f"pipeline: latent {cfg.unet.latent_size}^2, "
          f"{args.steps} DDIM steps, guidance {args.guidance}")

    pipe = StableDiffusionPipeline(cfg, key=jax.random.PRNGKey(0))
    # "a toy raccoon standing on a pile of broccoli" — tokens are synthetic
    # (no tokenizer offline); semantics don't affect the energy evaluation.
    prompt = jax.random.randint(jax.random.PRNGKey(7),
                                (1, cfg.text.max_len), 0,
                                cfg.text.vocab_size)
    t0 = time.time()
    image, stats = pipe.generate(prompt, jax.random.PRNGKey(1))
    print(f"generated image {image.shape} in {time.time() - t0:.1f}s, "
          f"range [{float(image.min()):.2f}, {float(image.max()):.2f}]")
    img8 = np.asarray((image[0] * 0.5 + 0.5) * 255, dtype=np.uint8)
    np.save("/tmp/generated_image.npy", img8)
    print("saved /tmp/generated_image.npy")

    rep = pipe.energy_report(stats)
    print("\nfull-geometry (BK-SDM-Tiny) energy ledger:")
    for k, v in rep.summary().items():
        print(f"  {k:42s} {v:10.4f}")


if __name__ == "__main__":
    main()
