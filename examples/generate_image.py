"""End-to-end text-to-image generation (the paper's Fig. 1(a) flow).

Runs the reduced-geometry path on CPU — text encode -> DDIM UNet iterations
(PSSA pruning + TIPS mixed precision live) -> VAE decode — then feeds the
measured compression/precision statistics into the full BK-SDM-Tiny ledger
and prints the Table-I-style energy summary.

Default path is the fully-jitted ``DiffusionEngine`` (one XLA computation:
scanned sampler, fused-CFG batched UNet, stacked stats pytree); pass
``--python-loop`` for the seed-style per-step dispatch loop.  Both feed the
same ledger.

Run:  PYTHONPATH=src python examples/generate_image.py [--steps 5]
          [--model unet|dit] [--solver dpm2m,steps=12] [--solver balanced]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.diffusion.engine import DiffusionEngine
from repro.diffusion.pipeline import StableDiffusionPipeline, energy_report


def main():
    from repro.launch.cli import (add_policy_args, config_from_args,
                                  policies_from_args)

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=5,
                    help="DDIM iterations (paper: 25; CPU demo default 5)")
    ap.add_argument("--guidance", type=float, default=1.0)
    ap.add_argument("--python-loop", action="store_true",
                    help="seed-style per-step dispatch instead of the "
                         "jitted engine")
    # the policy surface (--model/--kernels/--tips/--reuse/--solver) is
    # the SAME wiring serve_diffusion and the cluster router register —
    # one ServePolicies bundle behind every CLI (DESIGN.md §13)
    add_policy_args(ap, tiers=False)
    args = ap.parse_args()

    from repro.diffusion.solvers import TIERS

    if args.solver and args.python_loop:
        ap.error("--solver needs the jitted engine (the seed-style "
                 "python loop has no SamplerPolicy runtime)")
    policies = policies_from_args(args)
    policy = policies.sampler
    if policy is not None and "steps=" not in args.solver \
            and args.solver not in TIERS:
        policy = dataclasses.replace(policy, num_steps=args.steps)
    cfg = config_from_args(args, policies=policies)
    n_steps = policy.num_steps if policy is not None else args.steps
    sampler_desc = (f"{policy.solver} x{policy.num_steps}"
                    + (" (phased)" if policy.phases else "")
                    if policy is not None else f"ddim x{args.steps}")
    print(f"pipeline: model {args.model}, latent {cfg.unet.latent_size}^2, "
          f"sampler {sampler_desc}, guidance {args.guidance}, "
          f"{'python loop' if args.python_loop else 'jitted engine'}, "
          f"kernels {args.kernels}, tips {args.tips}")

    # "a toy raccoon standing on a pile of broccoli" — tokens are synthetic
    # (no tokenizer offline); semantics don't affect the energy evaluation.
    prompt = jax.random.randint(jax.random.PRNGKey(7),
                                (1, cfg.text.max_len), 0,
                                cfg.text.vocab_size)
    uncond = (jnp.zeros_like(prompt) if args.guidance != 1.0 else None)

    t0 = time.time()
    if args.python_loop:
        pipe = StableDiffusionPipeline(cfg, key=jax.random.PRNGKey(0))
        image, stats = pipe.generate(prompt, jax.random.PRNGKey(1),
                                     uncond_tokens=uncond)
        jax.block_until_ready(image)
    else:
        eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
        out = eng.generate(prompt, jax.random.PRNGKey(1),
                           uncond_tokens=uncond, sampler_policy=policy)
        image, stats = out.images, out.stats
    wall = time.time() - t0
    print(f"generated image {image.shape} in {wall:.1f}s "
          f"({1e3 * wall / n_steps:.0f} ms/iter incl. compile), "
          f"range [{float(image.min()):.2f}, {float(image.max()):.2f}]")
    img8 = np.asarray((image[0] * 0.5 + 0.5) * 255, dtype=np.uint8)
    np.save("/tmp/generated_image.npy", img8)
    print("saved /tmp/generated_image.npy")

    rep = energy_report(cfg, stats, sampler_policy=policy)
    geometry = "BK-SDM-Tiny" if args.model == "unet" else "DiT-S/2"
    print(f"\nfull-geometry ({geometry}, family={args.model}) "
          f"energy ledger:")
    for k, v in rep.summary().items():
        print(f"  {k:42s} {v:10.4f}")
    if policy is not None:
        print(f"  {'mj_per_image (x' + str(n_steps) + ' steps)':42s} "
              f"{rep.mj_per_iter_with_ema * n_steps:10.4f}")


if __name__ == "__main__":
    main()
