"""Batched serving demo: prefill + decode loop with the paper's features.

A small GQA model serves a batch of requests: prefill builds the KV cache,
then tokens decode step by step.  TIPS (sink-CAS mixed precision) is live in
the FFN; the DBSC bit-slice kernel path is demonstrated on the final FFN
projection of the last step (interpret mode — TPU is the target).

Run:  PYTHONPATH=src python examples/serve_lm.py [--new-tokens 16]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch).smoke()
    max_seq = args.prompt_len + args.new_tokens
    print(f"serving {cfg.name} (smoke geometry), batch={args.batch}, "
          f"prompt={args.prompt_len}, decode={args.new_tokens}")

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    # --- prefill ---
    t0 = time.time()
    logits, cache = T.prefill(params, cfg, None, tokens=prompts)
    # grow the cache to max_seq (dense/moe stacked layout)
    if cfg.family in ("dense", "moe"):
        pad = args.new_tokens
        cache = {k: jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                 for k, v in cache.items()}
    print(f"prefill: {time.time() - t0:.2f}s, cache "
          f"{jax.tree.reduce(lambda a, b: a + b, jax.tree.map(lambda x: x.size * x.dtype.itemsize, cache)) / 1e6:.1f} MB")

    # --- decode loop (greedy) ---
    step_fn = jax.jit(
        lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg, None))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        logits, cache = step_fn(params, cache, tok, pos)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        generated.append(tok)
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decoded {out.shape[1]} tokens x {args.batch} seqs in {dt:.2f}s "
          f"({args.batch * out.shape[1] / max(dt, 1e-9):.1f} tok/s)")
    print("sample token ids:", out[0, :10].tolist())

    # --- DBSC kernel path on one FFN tile (the serving datapath) ---
    from repro.kernels.bitslice_matmul.ops import bitslice_matmul
    lp0 = jax.tree.map(lambda a: a[0], params["layers"])
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(2),
                                      (args.batch, cfg.d_model)))
    imp = jnp.arange(args.batch) % 2 == 0       # TIPS mask stand-in
    y = bitslice_matmul(x, lp0["w_up"].astype(jnp.float32), important=imp)
    print(f"DBSC bit-slice FFN tile: {y.shape}, "
          f"finite={bool(jnp.all(jnp.isfinite(y)))}")


if __name__ == "__main__":
    main()
