"""Quickstart: the paper's three mechanisms in 60 seconds (pure CPU).

  1. PSSA  — prune + patch-XOR + local-CSR compress a self-attention score
             matrix; print the byte ledger.
  2. TIPS  — spot important tokens from cross-attention CAS; quantize an
             activation tensor INT12/INT6 by the mask.
  3. DBSC  — run the bit-slice Pallas kernel (interpret mode) on the mixed-
             precision matmul and check it against the integer oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import pssa, quant, tips
from repro.core.attention import cross_attention_tips
from repro.kernels.bitslice_matmul.ops import bitslice_matmul


def main():
    key = jax.random.PRNGKey(0)

    # --- 1. PSSA ----------------------------------------------------------
    print("== PSSA: self-attention score compression ==")
    scores = jax.nn.softmax(
        jax.random.normal(key, (8, 256, 256)) * 3.0, axis=-1)
    st = pssa.compress_stats(scores, patch=32)
    print(f"  dense SAS:      {float(st.bytes_baseline):>12.0f} B")
    print(f"  PSSA payload:   {float(st.bytes_pssa_total):>12.0f} B "
          f"({float(pssa.ema_reduction(st)) * 100:.1f} % EMA cut)")
    rec = pssa.compress_decompress(scores, patch=32)
    assert bool(jnp.all(rec == pssa.prune(scores))), "lossless!"
    print("  round-trip lossless: OK")

    # --- 2. TIPS -----------------------------------------------------------
    print("== TIPS: text-based important pixel spotting ==")
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, 64, 32))
    kt = jax.random.normal(jax.random.fold_in(key, 2), (1, 8, 16, 32))
    out = cross_attention_tips(q, kt, kt, threshold=0.06)
    r = out.tips_result
    print(f"  low-precision token ratio: "
          f"{float(r.low_precision_ratio) * 100:.1f} %")
    x = jax.nn.relu(jax.random.normal(jax.random.fold_in(key, 3), (1, 64, 32)))
    xq = tips.apply_precision_mask(x, r.important)
    print(f"  masked-quant max err: {float(jnp.max(jnp.abs(xq - x))):.4f}")

    # --- 3. DBSC ------------------------------------------------------------
    print("== DBSC: bit-slice mixed-precision matmul (Pallas) ==")
    xm = jax.nn.relu(jax.random.normal(jax.random.fold_in(key, 4), (64, 128)))
    w = jax.random.normal(jax.random.fold_in(key, 5), (128, 64))
    imp = jnp.arange(64) % 2 == 0
    y_kernel = bitslice_matmul(xm, w, important=imp, use_kernel=True)
    y_ref = bitslice_matmul(xm, w, important=imp, use_kernel=False)
    print(f"  kernel vs oracle max diff: "
          f"{float(jnp.max(jnp.abs(y_kernel - y_ref))):.2e}")
    rel = float(jnp.linalg.norm(y_kernel - xm @ w) / jnp.linalg.norm(xm @ w))
    print(f"  datapath vs float rel err: {rel:.4f}")
    print("done.")


if __name__ == "__main__":
    main()
