"""End-to-end training driver: ~100M-param LM, a few hundred steps on CPU.

Exercises the production path end to end: deterministic sharded data
pipeline -> train step (remat + optional gradient compression) ->
fault-tolerant checkpointing (kill it mid-run and relaunch: it resumes).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
      PYTHONPATH=src python examples/train_lm.py --steps 300   # resumes
"""
import argparse

import jax

from repro.configs import get_arch
from repro.data import SyntheticLMDataset
from repro.launch.model_flops import param_count
from repro.optim import AdamW, linear_warmup_cosine
from repro.train import TrainConfig, Trainer


def make_100m_config():
    """llama3-family config scaled to ~100M params (CPU-trainable)."""
    return get_arch("llama3-8b").scaled(
        name="llama3-100m",
        num_layers=12,
        d_model=512,
        num_heads=8,
        num_kv_heads=4,
        head_dim=64,
        d_ff=2048,
        vocab_size=50304,
        tips=False, pssa=False,          # vanilla training numerics
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()

    cfg = make_100m_config()
    print(f"arch {cfg.name}: {param_count(cfg) / 1e6:.1f} M params")

    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=args.seq,
                            global_batch=args.batch, seed=0)
    opt = AdamW(lr=linear_warmup_cosine(3e-4, warmup=20,
                                        total_steps=max(args.steps, 21)))
    tc = TrainConfig(steps=args.steps, checkpoint_every=50, log_every=10,
                     checkpoint_dir=args.ckpt_dir,
                     grad_compression=args.grad_compression)
    trainer = Trainer(cfg, ds, opt, tc)
    state, history = trainer.run(key=jax.random.PRNGKey(0))
    first, last = history[0][1], history[-1][1]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
