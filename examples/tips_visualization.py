"""Fig. 9(a) analogue: 2-D visualization of TIPS-spotted important pixels.

The paper compares the binary importance map (white = important = INT12)
with the generated image to show TIPS tracks prompt relevance.  Without
pretrained weights the relevance field is synthetic (bench_tips's
generator), so this demo validates the same property the figure shows: the
spotted map recovers the prompt-relevance structure planted in the
cross-attention scores.

Run:  PYTHONPATH=src:. python examples/tips_visualization.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_tips import synthetic_cross_attention
from repro.core import tips


def ascii_map(mask2d, width=64):
    chars = np.where(np.asarray(mask2d), "#", ".")
    return "\n".join("".join(row) for row in chars)


def main():
    res = 64
    key = jax.random.PRNGKey(7)
    probs = synthetic_cross_attention(key, res=res)
    r = tips.spot(probs, threshold=0.05)
    mask = np.asarray(r.important).reshape(res, res)

    print(f"important-pixel ratio: {mask.mean() * 100:.1f} % "
          f"(low-precision: {float(r.low_precision_ratio) * 100:.1f} %)")
    # the planted relevance field is smooth -> the spotted map must be
    # spatially coherent, not salt-and-pepper: neighbour agreement >> 50 %
    agree_h = (mask[:, 1:] == mask[:, :-1]).mean()
    agree_v = (mask[1:, :] == mask[:-1, :]).mean()
    print(f"spatial coherence: horizontal {agree_h * 100:.1f} %, "
          f"vertical {agree_v * 100:.1f} %")
    assert agree_h > 0.85 and agree_v > 0.85, "map should be region-like"

    print("\nTIPS importance map (64x64, # = important = INT12):")
    print(ascii_map(mask[::2, ::1]))       # halve rows for terminal aspect


if __name__ == "__main__":
    main()
