"""TIPS + quantization unit/property tests (paper §IV)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import quant, tips


# ----------------------------------------------------------------------------
# Quantization primitives
# ----------------------------------------------------------------------------
@given(seed=st.integers(0, 2 ** 16), bits=st.sampled_from([6, 8, 12]))
@settings(max_examples=30, deadline=None)
def test_act_quant_error_bound(seed, bits):
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(seed), (64, 32)))
    q = quant.quantize_act(x, bits)
    err = jnp.max(jnp.abs(quant.dequantize(q) - x))
    assert float(err) <= float(q.scale) * 0.5 + 1e-6


@given(seed=st.integers(0, 2 ** 16))
@settings(max_examples=30, deadline=None)
def test_bitslice_split_merge_exact(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 4096, (100,)), jnp.int32)
    hi, lo = quant.bitslice_split(x)
    assert int(jnp.max(hi)) <= 63 and int(jnp.max(lo)) <= 63  # int7-safe
    np.testing.assert_array_equal(np.asarray(quant.bitslice_merge(hi, lo)),
                                  np.asarray(x))


def test_quantized_matmul_reference_close():
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(0), (32, 64)))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    y = quant.quantized_matmul_reference(x, w)
    rel = jnp.max(jnp.abs(y - x @ w)) / jnp.max(jnp.abs(x @ w))
    assert float(rel) < 0.02  # INT12/INT8 is tight


def test_mixed_precision_int6_grid():
    """INT6 rows live on the 64x coarser grid of the SAME scale (paper:
    the SIMD core re-quantizes from one cross-attention output)."""
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(2), (8, 16))) * 3
    imp = jnp.array([True, False] * 4)
    q = quant.mixed_precision_quantize(x, imp)
    vals = np.asarray(q.values)
    assert (vals[1::2] % 64 == 0).all()       # INT6 rows: low 6 bits zero
    qfull = quant.quantize_act(x, quant.ACT_BITS_HIGH)
    np.testing.assert_array_equal(vals[0::2], np.asarray(qfull.values)[0::2])


# ----------------------------------------------------------------------------
# TIPS spotting
# ----------------------------------------------------------------------------
def test_spot_inverse_cas_tas_relation():
    """Small CAS <=> large TAS (softmax row property the paper relies on)."""
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(3), (2, 4, 64, 8)) * 2, -1)
    r = tips.spot(probs, threshold=0.1)
    cas = np.asarray(r.cas)
    tas = 1.0 - cas                       # row sums to 1
    important = np.asarray(r.important)
    assert (tas[important] > tas[~important].mean()).mean() > 0.9


def test_spot_threshold_monotonic():
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(4), (1, 2, 128, 16)) * 2, -1)
    r_lo = tips.spot(probs, threshold=0.02)
    r_hi = tips.spot(probs, threshold=0.5)
    # higher threshold -> more tokens important -> lower low-precision ratio
    assert float(r_hi.low_precision_ratio) <= float(r_lo.low_precision_ratio)


def test_adaptive_threshold_hits_target():
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(5), (1, 8, 4096, 77)) * 2, -1)
    r = tips.spot(probs, threshold=1.0)   # all important -> get CAS
    thr = tips.adaptive_threshold(r.cas, target_low_ratio=0.448)
    r2 = tips.spot(probs, threshold=float(thr))
    assert float(r2.low_precision_ratio) == pytest.approx(0.448, abs=0.02)


def test_tips_schedule_20_of_25():
    active = [bool(tips.tips_schedule(jnp.asarray(i))) for i in range(25)]
    assert sum(active) == 20 and not any(active[20:])


def test_workload_fraction_matches_paper_shape():
    # per-iteration ratios like Fig. 9(b): ~0.56 while active, 0 after
    ratios = jnp.array([0.56] * 20 + [0.0] * 5)
    frac = tips.workload_low_precision_fraction(ratios)
    assert float(frac) == pytest.approx(0.448, abs=1e-6)


def test_apply_precision_mask_important_rows_change_less():
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(6), (2, 64, 32)))
    imp = jnp.zeros((2, 64), bool).at[:, :32].set(True)
    y = tips.apply_precision_mask(x, imp)
    err_imp = float(jnp.abs(y - x)[:, :32].mean())
    err_unimp = float(jnp.abs(y - x)[:, 32:].mean())
    assert err_imp < err_unimp


def test_apply_precision_mask_inactive_is_high_precision():
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(7), (2, 16, 8)))
    imp = jnp.zeros((2, 16), bool)
    y_active = tips.apply_precision_mask(x, imp, active=True)
    y_inactive = tips.apply_precision_mask(x, imp, active=False)
    assert float(jnp.abs(y_inactive - x).mean()) \
        < float(jnp.abs(y_active - x).mean())
