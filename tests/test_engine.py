"""Jitted-engine refactor tests: stats pytree, fused CFG, scan parity.

The contract under test (DESIGN.md §3):

  * ``UNetStats`` is a registered pytree whose layer order is derived from
    config and whose leaves flow through ``lax.scan`` as stacked arrays;
  * one fused [cond | uncond] UNet call equals two separate calls;
  * the scanned sampler reproduces the Python-loop seed implementation —
    latents AND per-iteration stats — on the smoke config;
  * ``energy_report`` produces identical headline numbers from the stacked
    stats pytree and from the per-step list.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pssa
from repro.diffusion.engine import DiffusionEngine
from repro.diffusion.pipeline import (PipelineConfig, StableDiffusionPipeline,
                                      energy_report)
from repro.diffusion.sampler import (DDIMConfig, cfg_batch, guided_eps,
                                     sample, sample_scan)
from repro.diffusion.stats import UNetStats, attn_layer_order
from repro.diffusion.unet import UNetConfig, init_unet_params, unet_forward


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = PipelineConfig.smoke()
    key = jax.random.PRNGKey(42)
    pipe = StableDiffusionPipeline(cfg, key=key)
    eng = DiffusionEngine(cfg, key=key)   # same key -> identical params
    return cfg, pipe, eng


def _toks(cfg, batch=1, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed),
                              (batch, cfg.text.max_len), 0,
                              cfg.text.vocab_size)


# ----------------------------------------------------------------------------
# Stats pytree
# ----------------------------------------------------------------------------
def test_layer_order_matches_forward_traversal(smoke_setup):
    cfg, pipe, _ = smoke_setup
    order = attn_layer_order(cfg.unet)
    assert [k.name for k in order] == [
        "down0.0@16", "down1.0@8", "down2.0@4",
        "up1.0@4", "up1.1@4", "up2.0@8", "up2.1@8",
        "up3.0@16", "up3.1@16"]
    s = cfg.unet.latent_size
    lat = jax.random.normal(jax.random.PRNGKey(0), (1, s, s, 4))
    ctx = jax.random.normal(jax.random.PRNGKey(1),
                            (1, cfg.unet.text_len, cfg.unet.context_dim))
    _, stats = unet_forward(pipe.unet_params, lat, jnp.array([500]), ctx,
                            cfg.unet)
    assert stats.layers == order


def test_unet_stats_is_scan_compatible_pytree(smoke_setup):
    cfg, pipe, _ = smoke_setup
    s = cfg.unet.latent_size
    lat = jax.random.normal(jax.random.PRNGKey(0), (1, s, s, 4))
    ctx = jax.random.normal(jax.random.PRNGKey(1),
                            (1, cfg.unet.text_len, cfg.unet.context_dim))
    _, stats = unet_forward(pipe.unet_params, lat, jnp.array([500]), ctx,
                            cfg.unet)
    # round-trips flatten/unflatten with static layer keys in the treedef
    leaves, treedef = jax.tree_util.tree_flatten(stats)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.layers == stats.layers
    # a stacked pytree indexes back to per-step views
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.stack([x, x]), stats)
    assert stacked.num_steps == 2
    per_step = stacked.step(0)
    np.testing.assert_allclose(np.asarray(per_step.pssa[0].nnz),
                               np.asarray(stats.pssa[0].nnz))
    # legacy dict view preserved
    d = stats.as_dict()
    assert set(d) == {"pssa", "tips"}
    assert len(d["pssa"]) == len(stats)


# ----------------------------------------------------------------------------
# Fused CFG
# ----------------------------------------------------------------------------
def test_fused_cfg_matches_two_call_path(smoke_setup):
    cfg, pipe, _ = smoke_setup
    ucfg = cfg.unet
    s = ucfg.latent_size
    lat = jax.random.normal(jax.random.PRNGKey(3), (2, s, s, 4))
    ctx = jax.random.normal(jax.random.PRNGKey(4),
                            (2, ucfg.text_len, ucfg.context_dim))
    unc = jax.random.normal(jax.random.PRNGKey(5),
                            (2, ucfg.text_len, ucfg.context_dim))
    tvec = jnp.full((2,), 500, jnp.int32)

    eps_c, stats_c = unet_forward(pipe.unet_params, lat, tvec, ctx, ucfg)
    eps_u, _ = unet_forward(pipe.unet_params, lat, tvec, unc, ucfg)
    two_call = eps_u + 7.5 * (eps_c - eps_u)

    lat2, ctx2 = cfg_batch(lat, ctx, unc)
    eps_f, stats_f = unet_forward(pipe.unet_params, lat2,
                                  jnp.full((4,), 500, jnp.int32), ctx2, ucfg,
                                  stats_rows=2)
    fused = guided_eps(eps_f, 7.5)

    np.testing.assert_allclose(np.asarray(fused), np.asarray(two_call),
                               rtol=1e-4, atol=1e-4)

    # prefix-deduplicated variant (the engine's path): latents carry only
    # the cond half; the shared prefix runs once — exact equality per half
    eps_d, _ = unet_forward(pipe.unet_params, lat, tvec, ctx2, ucfg,
                            stats_rows=2, cfg_dup=True)
    eps_dc, eps_du = jnp.split(eps_d, 2, axis=0)
    np.testing.assert_allclose(np.asarray(eps_dc), np.asarray(eps_c),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(eps_du), np.asarray(eps_u),
                               rtol=1e-5, atol=1e-5)
    # stats from the fused call restricted to cond rows == cond-call stats.
    # Scores within one ulp of the prune threshold can flip between the
    # batched and unbatched einsum, so counters get a few counts of slack.
    for a, b in zip(stats_f.pssa, stats_c.pssa):
        np.testing.assert_allclose(np.asarray(a.nnz), np.asarray(b.nnz),
                                   atol=16)
        np.testing.assert_allclose(np.asarray(a.bytes_pssa_total),
                                   np.asarray(b.bytes_pssa_total),
                                   rtol=1e-3)
    for a, b in zip(stats_f.tips, stats_c.tips):
        np.testing.assert_allclose(np.asarray(a.low_precision_ratio),
                                   np.asarray(b.low_precision_ratio),
                                   atol=0.02)
        assert a.important.shape == b.important.shape   # cond rows only


# ----------------------------------------------------------------------------
# Scanned sampler vs Python loop
# ----------------------------------------------------------------------------
def test_scan_sampler_matches_python_loop(smoke_setup):
    cfg, pipe, _ = smoke_setup
    s = cfg.unet.latent_size
    lat = jax.random.normal(jax.random.PRNGKey(9), (1, s, s, 4))
    ctx = pipe._encode(_toks(cfg))

    def unet_apply(l, t, c, act, stats_rows=None):
        return unet_forward(pipe.unet_params, l, t, c, cfg.unet,
                            tips_active=act, stats_rows=stats_rows)

    lat_loop, stats_loop = sample(unet_apply, lat, ctx, None, cfg.ddim,
                                  collect_stats=True)
    lat_scan, stacked = sample_scan(unet_apply, lat, ctx, None, cfg.ddim)

    # eager loop vs scanned-jit execution reassociates fp ops
    np.testing.assert_allclose(np.asarray(lat_scan), np.asarray(lat_loop),
                               rtol=2e-3, atol=2e-3)
    assert stacked.num_steps == cfg.ddim.num_inference_steps
    for i, st in enumerate(stacked.unstack()):
        ref = stats_loop[i]
        for a, b in zip(st.pssa, ref.pssa):
            # threshold-knife-edge scores may flip between eager and
            # scanned execution; allow a few counts of slack
            np.testing.assert_allclose(np.asarray(a.nnz),
                                       np.asarray(b.nnz), atol=16)
            np.testing.assert_allclose(np.asarray(a.bytes_pssa_total),
                                       np.asarray(b.bytes_pssa_total),
                                       rtol=1e-3)
        for a, b in zip(st.tips, ref.tips):
            np.testing.assert_allclose(np.asarray(a.low_precision_ratio),
                                       np.asarray(b.low_precision_ratio),
                                       atol=0.02)


def test_engine_end_to_end_and_energy_report_parity(smoke_setup):
    cfg, pipe, eng = smoke_setup
    toks = _toks(cfg)
    img_loop, stats_loop = pipe.generate(toks, jax.random.PRNGKey(2))
    out = eng.generate(toks, jax.random.PRNGKey(2))

    assert out.images.shape == img_loop.shape
    assert bool(jnp.all(jnp.isfinite(out.images)))
    np.testing.assert_allclose(np.asarray(out.images),
                               np.asarray(img_loop), rtol=1e-3, atol=1e-3)

    rep_list = energy_report(cfg, stats_loop).summary()
    rep_stacked = energy_report(cfg, out.stats).summary()
    for k in rep_list:
        assert rep_stacked[k] == pytest.approx(rep_list[k], rel=1e-3), k


def test_engine_cfg_trajectory_close_to_two_call_loop(smoke_setup):
    cfg0, _, _ = smoke_setup
    cfg = dataclasses.replace(cfg0, ddim=dataclasses.replace(
        cfg0.ddim, guidance_scale=7.5))
    key = jax.random.PRNGKey(7)
    pipe = StableDiffusionPipeline(cfg, key=key)
    eng = DiffusionEngine(cfg, key=key)
    toks, un = _toks(cfg), jnp.zeros_like(_toks(cfg))
    s = cfg.unet.latent_size
    lat = jax.random.normal(jax.random.PRNGKey(8), (1, s, s, 4))

    ctx, uc = pipe._encode(toks), pipe._encode(un)
    lat_loop, _ = sample(pipe._unet, lat, ctx, uc, cfg.ddim)
    out = eng.generate(toks, None, uncond_tokens=un, latents=lat.copy())
    # prefix dedup makes the fused step per-row identical to the two-call
    # step; residual drift is jit-vs-eager fp reassociation only
    np.testing.assert_allclose(np.asarray(out.latents),
                               np.asarray(lat_loop), rtol=2e-3, atol=2e-3)


def test_engine_caches_compiled_signatures(smoke_setup):
    cfg, _, eng = smoke_setup
    eng.generate(_toks(cfg, batch=1), jax.random.PRNGKey(0))
    n = len(eng._compiled)
    eng.generate(_toks(cfg, batch=1, seed=3), jax.random.PRNGKey(1))
    assert len(eng._compiled) == n          # same signature -> cached
    eng.generate(_toks(cfg, batch=2), jax.random.PRNGKey(2))
    assert len(eng._compiled) == n + 1      # new batch -> new executable


# ----------------------------------------------------------------------------
# PSSA byte-counter precision (satellite fix)
# ----------------------------------------------------------------------------
def test_compress_stats_integer_exact_at_full_geometry():
    """The static byte terms must be exact where float32 would round."""
    # full-geometry SAS with heads folded in: 8 * 4096 * 4096 = 134M elems
    lead, tq, tk, patch = 8, 4096, 4096, 64
    exact = pssa.exact_byte_counts(nnz=2 ** 24 + 1, ones_xor=2 ** 24 + 3,
                                   lead=lead, tq=tq, tk=tk, patch=patch)
    assert exact["total"] == lead * tq * tk                  # exact int
    assert exact["bytes_baseline"] == lead * tq * tk * 12 / 8
    # float32 cannot represent odd integers above 2^24 — the exact path must
    # not inherit that rounding
    f32_nnz = float(np.float32(2 ** 24 + 1))
    assert f32_nnz != 2 ** 24 + 1
    assert exact["bytes_values"] == (2 ** 24 + 1) * 12 / 8


def test_compress_stats_fused_matches_reference_oracle():
    key = jax.random.PRNGKey(0)
    sas = jax.nn.softmax(jax.random.normal(key, (3, 2, 64, 64)) * 4.0, -1)
    fast = pssa.compress_stats(sas, patch=16)
    ref = pssa.compress_stats_reference(sas, patch=16)
    for f, r in zip(fast, ref):
        np.testing.assert_allclose(np.asarray(f), np.asarray(r))


def test_compress_stats_counters_accumulate_in_integers():
    sas = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32)) * 4.0, -1)
    st = pssa.compress_stats(sas, patch=16)
    # counters are whole numbers (integer accumulation, float storage)
    assert float(st.nnz) == int(float(st.nnz))
    assert float(st.bitmap_ones_xor) == int(float(st.bitmap_ones_xor))
    exact = pssa.exact_byte_counts(int(float(st.nnz)),
                                   int(float(st.bitmap_ones_xor)),
                                   lead=2, tq=32, tk=32, patch=16)
    assert float(st.bytes_pssa_total) == pytest.approx(
        exact["bytes_values"] + exact["bytes_index_pssa"], rel=1e-6)
