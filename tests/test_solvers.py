"""SamplerPolicy runtime (repro.diffusion.solvers) — DESIGN.md §10.

Pure-python coverage of the policy/bank/table layer (parsing, phase
math, TIPS scheduling, coefficient tables) plus the two exactness
contracts on the smoke engine:

* a single-policy ``(ddim, cfg-steps)`` bank is bit-identical to the
  policy-free legacy engine (one-shot), including a neutral phase
  schedule (all scales 1.0, tips matching the legacy window);
* a mixed-tier slot batch produces per-request images bit-identical to
  one-shot runs of each request's own policy under the same bank AND
  the same batch signature (request tiled to the slot count — the
  structural-identity oracle: XLA specializes codegen per traced
  program and batch size, so parity is defined at matching shapes).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion import solvers
from repro.diffusion.engine import DiffusionEngine
from repro.diffusion.pipeline import PipelineConfig
from repro.diffusion.sampler import alphas_cumprod
from repro.diffusion.solvers import (PLMS_WEIGHTS, SOLVER_ID, TIERS,
                                     PhaseSchedule, SamplerPolicy, as_bank)
from repro.launch.scheduler import ContinuousScheduler, make_requests


# ----------------------------------------------------------------------------
# policy / schedule parsing and identity
# ----------------------------------------------------------------------------
def test_policy_parse_round_trips():
    assert SamplerPolicy.parse("draft") == TIERS["draft"]
    assert SamplerPolicy.parse("balanced").num_steps == 12
    # a bare solver keeps the default budget (tiers carry their own)
    assert SamplerPolicy.parse("dpm2m") == SamplerPolicy.dpm2m(25)
    p = SamplerPolicy.parse("dpm2m,steps=10,phases=detail_guard")
    assert (p.solver, p.num_steps) == ("dpm2m", 10)
    assert p.phases == PhaseSchedule.detail_guard()
    p = SamplerPolicy.parse("solver=plms,steps=6,name=fast")
    assert (p.solver, p.num_steps, p.name) == ("plms", 6, "fast")
    # ';' separates phase-schedule items inside the policy spec
    p = SamplerPolicy.parse("ddim,phases=boundaries=0.3:0.6;pssa=2:2:1")
    assert p.phases.boundaries == (0.3, 0.6)
    assert p.phases.pssa_scale == (2.0, 2.0, 1.0)


def test_policy_validation_errors():
    with pytest.raises(ValueError, match="solver"):
        SamplerPolicy(solver="euler")
    with pytest.raises(ValueError, match="num_steps"):
        SamplerPolicy(num_steps=0)
    with pytest.raises(ValueError, match="tier"):
        SamplerPolicy.tier("ultra")
    with pytest.raises(ValueError, match="unknown key"):
        SamplerPolicy.parse("ddim,foo=1")
    with pytest.raises(ValueError, match="empty"):
        as_bank(())
    with pytest.raises(TypeError, match="SamplerPolicy"):
        as_bank(("ddim",))


def test_policy_name_excluded_from_identity():
    a = SamplerPolicy.dpm2m(8, name="draft")
    b = SamplerPolicy.dpm2m(8, name="renamed")
    assert a == b and hash(a) == hash(b)
    assert a.label() == "draft" and a.key() == "dpm2m-8"


def test_phase_schedule_parse_and_phase_of():
    ph = PhaseSchedule.parse("boundaries=0.3:0.6,tips=on:on:off,pssa=2:2:1")
    assert ph.boundaries == (0.3, 0.6)
    assert ph.tips_on == (True, True, False)
    assert ph.schedules_pssa and not ph.schedules_reuse
    assert not PhaseSchedule().schedules_pssa
    # ceil-based phase boundaries: 3 steps at (0.3, 0.6) -> one per phase
    assert [ph.phase_of(i, 3) for i in range(3)] == [0, 1, 2]
    # default (0.4, 0.8) over 25 steps: 10 / 10 / 5
    d = PhaseSchedule()
    counts = [0, 0, 0]
    for i in range(25):
        counts[d.phase_of(i, 25)] += 1
    assert counts == [10, 10, 5]
    with pytest.raises(ValueError, match="boundaries"):
        PhaseSchedule(boundaries=(0.8, 0.4))
    with pytest.raises(ValueError, match="> 0"):
        PhaseSchedule(pssa_scale=(1.0, 0.0, 1.0))


def test_tips_active_schedule(cfg):
    ddim_cfg = cfg.ddim
    # budget == config steps: EXACTLY the legacy i < tips_active_iters
    legacy = tuple(i < ddim_cfg.tips_active_iters
                   for i in range(ddim_cfg.num_inference_steps))
    pol = SamplerPolicy.ddim(ddim_cfg.num_inference_steps)
    assert solvers.tips_active_schedule(pol, ddim_cfg) == legacy
    # other budgets scale the operating point (never fully off)
    sched = solvers.tips_active_schedule(SamplerPolicy.dpm2m(6), ddim_cfg)
    assert len(sched) == 6 and sched[0] and not sched[-1]
    assert sum(sched) == max(1, 6 * ddim_cfg.tips_active_iters
                             // ddim_cfg.num_inference_steps)
    # phases override the window entirely
    ph = PhaseSchedule(boundaries=(0.3, 0.6), tips_on=(False, True, False))
    sched = solvers.tips_active_schedule(
        SamplerPolicy.ddim(3, phases=ph), ddim_cfg)
    assert sched == (False, True, False)


def test_bank_views():
    bank = as_bank((SamplerPolicy.ddim(3), SamplerPolicy.dpm2m(4),
                    SamplerPolicy.plms(2)))
    assert solvers.bank_max_steps(bank) == 4
    assert solvers.bank_history(bank) == 3        # plms worst case
    # single policy normalizes to a 1-bank
    assert as_bank(SamplerPolicy.ddim(3)) == (SamplerPolicy.ddim(3),)
    # unscheduled bank: no override lanes live
    assert solvers.bank_schedules(bank) == (False, False, False)
    guarded = as_bank((SamplerPolicy.ddim(
        3, phases=PhaseSchedule.detail_guard()),))
    assert solvers.bank_schedules(guarded) == (True, False, True)


def test_plms_weights_are_adams_bashforth():
    # every warmup order integrates a constant exactly: weights sum to 1
    for row in PLMS_WEIGHTS:
        assert abs(sum(row) - 1.0) < 1e-12


def test_solver_tables_ddim_columns(cfg):
    ddim_cfg = cfg.ddim
    bank = (SamplerPolicy.ddim(3), SamplerPolicy.dpm2m(2))
    tab = solvers.solver_tables(bank, ddim_cfg)
    n_max = solvers.bank_max_steps(bank)
    assert tab.t.shape == (2, n_max)
    acp = np.asarray(alphas_cumprod(ddim_cfg))
    # row 0: the legacy descending timestep ladder + its acp gathers
    step = ddim_cfg.num_train_steps // 3
    ts = np.arange(2, -1, -1) * step
    assert np.array_equal(np.asarray(tab.t[0]), ts)
    assert np.array_equal(np.asarray(tab.a_t[0]), acp[ts])
    # final boundary lands on alpha_prev = 1.0 (t_prev < 0)
    assert float(tab.a_prev[0, 2]) == 1.0
    # short-budget rows pad by repeating the final step (never read:
    # per-row step indices are clipped to the row's budget)
    assert float(tab.t[1, 1]) == float(tab.t[1, 2])
    assert np.array_equal(np.asarray(tab.budget), [3, 2])
    assert np.array_equal(np.asarray(tab.solver),
                          [SOLVER_ID["ddim"], SOLVER_ID["dpm2m"]])
    # tips column mirrors tips_active_schedule per row
    want = solvers.tips_active_schedule(bank[0], ddim_cfg)
    assert tuple(bool(v) for v in np.asarray(tab.tips[0, :3])) == want


# ----------------------------------------------------------------------------
# engine exactness contracts (smoke geometry, 3 steps)
# ----------------------------------------------------------------------------
@pytest.fixture(scope="module")
def cfg():
    return PipelineConfig.smoke()


@pytest.fixture(scope="module")
def eng(cfg):
    return DiffusionEngine(cfg, key=jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def legacy_out(eng, cfg):
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, cfg.text.max_len),
                              0, cfg.text.vocab_size)
    lat = np.asarray(eng.init_latents(2, jax.random.PRNGKey(2)))
    out = eng.generate(toks, None, latents=jnp.array(lat))
    return toks, lat, np.asarray(out.images)


def test_single_policy_ddim_bank_matches_legacy(eng, cfg, legacy_out):
    toks, lat, legacy_images = legacy_out
    pol = SamplerPolicy.ddim(cfg.ddim.num_inference_steps)
    out = eng.generate(toks, None, latents=jnp.array(lat),
                       sampler_policy=pol)
    assert np.array_equal(legacy_images, np.asarray(out.images))


def test_neutral_phase_schedule_matches_legacy(eng, cfg, legacy_out):
    toks, lat, legacy_images = legacy_out
    # one step per phase; tips_on reproducing the legacy 2-of-3 window;
    # all threshold scales 1.0 -> no override lane goes live, and the
    # banked trace must reproduce the legacy program bit-for-bit
    assert cfg.ddim.num_inference_steps == 3
    assert cfg.ddim.tips_active_iters == 2
    ph = PhaseSchedule(boundaries=(0.3, 0.6), tips_on=(True, True, False))
    pol = SamplerPolicy.ddim(3, phases=ph)
    out = eng.generate(toks, None, latents=jnp.array(lat),
                       sampler_policy=pol)
    assert np.array_equal(legacy_images, np.asarray(out.images))


def test_generate_rejects_policy_outside_bank(eng, cfg):
    toks = jnp.zeros((1, cfg.text.max_len), jnp.int32)
    with pytest.raises(ValueError, match="bank"):
        eng.generate(toks, jax.random.PRNGKey(0),
                     sampler_policy=SamplerPolicy.ddim(3),
                     sampler_bank=(SamplerPolicy.dpm2m(2),))


def test_mixed_bank_slot_trace_bit_identical(eng, cfg):
    num_slots = 2
    bank = (SamplerPolicy.ddim(3, name="quality"),
            SamplerPolicy.dpm2m(4, name="draft"))
    reqs = make_requests(cfg, 3, seed=5, bank=bank)
    sched = ContinuousScheduler(eng, num_slots=num_slots, bank=bank)
    metrics = sched.run(reqs, ledger=True)
    state = metrics.pop("state")

    for r in reqs:
        pol = bank[r.policy_index]
        # the §10 oracle: one-shot under the SAME bank, policy_id a
        # runtime operand, request tiled to the slot-batch signature
        out = eng.generate(jnp.tile(r.tokens, (num_slots, 1)), None,
                           latents=jnp.tile(jnp.array(r.latents),
                                            (num_slots, 1, 1, 1)),
                           sampler_policy=pol, sampler_bank=bank)
        assert np.array_equal(r.image, np.asarray(out.images[0])), \
            f"request {r.rid} ({pol.key()}) diverged from its one-shot run"

    # banked ledger: bucket p*N+i holds policy p's step-i counters; a
    # short-budget policy leaves its tail buckets untouched
    n_max = solvers.bank_max_steps(bank)
    rows = np.asarray(state.accum.rows)
    assert rows.shape == (len(bank) * n_max,)
    per_policy = [sum(r.policy_index == p for r in reqs)
                  for p in range(len(bank))]
    for p, pol in enumerate(bank):
        seg = rows[p * n_max:(p + 1) * n_max]
        assert list(seg[:pol.num_steps]) == [per_policy[p]] * pol.num_steps
        assert not seg[pol.num_steps:].any()
    assert rows.sum() == sum(bank[r.policy_index].num_steps for r in reqs)
