"""Cluster-router tests — multi-replica serving, DESIGN.md §13.

The contract under test:

  * replicas are independent ``SlotState``s through ONE engine's cached
    executables — per-request images are bit-identical to the one-shot
    engine at any replica count, and the MERGED integer ledger
    (``pipeline.merge_ledger_accums``) yields an energy headline
    bit-identical across replica counts, routing decisions and admission
    orders;
  * admission is FIFO into the least-occupied replica;
  * under overload with a ``RouterSLO``, requests DEGRADE to a lower
    bank tier instead of queueing — deterministically, in round
    arithmetic — and that beats the queueing baseline on SLO attainment
    (the positive control);
  * streaming previews decode in-flight latents between steps;
  * the router never drops a request.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.precision import PrecisionPolicy
from repro.diffusion.engine import DiffusionEngine
from repro.diffusion.pipeline import (PipelineConfig,
                                      energy_report_cluster,
                                      energy_report_multi,
                                      merge_ledger_accums)
from repro.diffusion.solvers import SamplerPolicy
from repro.launch.router import ClusterRouter, RouterSLO
from repro.launch.scheduler import make_requests


@pytest.fixture(scope="module")
def cfg():
    # knife-edge thresholds (modern spelling) keep every ledger counter
    # input-sensitive — see tests/test_continuous.py
    base = PipelineConfig.smoke()
    t = base.unet.latent_size ** 2
    return dataclasses.replace(
        base,
        unet=dataclasses.replace(
            base.unet, pssa_threshold=1.0 / t,
            precision=PrecisionPolicy(threshold=1.0 / base.unet.text_len)),
        ddim=dataclasses.replace(base.ddim, num_inference_steps=3,
                                 tips_active_iters=2))


@pytest.fixture(scope="module")
def eng(cfg):
    return DiffusionEngine(cfg, key=jax.random.PRNGKey(0))


BANK = (SamplerPolicy.parse("ddim,steps=4"),
        SamplerPolicy.parse("ddim,steps=2"))


def _serve(eng, replicas, slots, n=6, bank=None, slo=None,
           preview_every=0, seed=7):
    router = ClusterRouter(eng, replicas, slots, bank=bank, slo=slo,
                           preview_every=preview_every)
    reqs = make_requests(eng.cfg, n, seed=seed, bank=router.bank)
    metrics = router.run(reqs, ledger=True)
    return metrics, reqs


def test_bit_identical_across_replica_counts_and_vs_one_shot(cfg, eng):
    m1, reqs1 = _serve(eng, replicas=1, slots=2)
    m2, reqs2 = _serve(eng, replicas=2, slots=2)
    assert m1["dropped"] == 0 and m2["dropped"] == 0
    # images: replica count is a pure scheduling change
    for a, b in zip(reqs1, reqs2):
        assert np.array_equal(a.image, b.image), a.rid
    # merged ledger: bit-identical energy headline at any replica count
    assert m1["energy"] == m2["energy"]
    accums1 = [st.accum for st in m1["states"]]
    accums2 = [st.accum for st in m2["states"]]
    merged1, merged2 = (merge_ledger_accums(a) for a in (accums1, accums2))
    for f in ("nnz", "ones_xor", "imp", "rows"):
        assert (getattr(merged1, f) == getattr(merged2, f)).all(), f
    # ... and to the SAME requests served one-shot (extends the slot
    # oracle of tests/test_continuous.py to the router).  One-shot
    # batches match the slot width — the bit-identity contract is per
    # batch signature (a batch-1 UNet call is a different XLA program)
    import jax.numpy as jnp

    fetched = []
    for i in range(0, len(reqs1), 2):
        chunk = reqs1[i:i + 2]
        out = eng.generate(
            jnp.concatenate([r.tokens for r in chunk], axis=0), None,
            latents=jnp.concatenate([r.latents for r in chunk], axis=0))
        arr = np.asarray(out.images)
        for j, r in enumerate(chunk):
            assert np.array_equal(arr[j], r.image), r.rid
        fetched.append(out.stats.ledger_fetch())
    rep_oneshot = energy_report_multi(cfg, fetched)
    assert m1["energy"] == {k: float(v)
                            for k, v in rep_oneshot.summary().items()}


def test_fifo_admission_into_least_occupied_replica(cfg, eng):
    router = ClusterRouter(eng, replicas=2, slots_per_replica=2)
    reqs = make_requests(cfg, 6, seed=11)
    admitted = [ev for ev in router.stream(reqs) if ev["event"] == "admitted"]
    # FIFO: admission follows arrival (= rid) order
    assert [ev["rid"] for ev in admitted] == sorted(r.rid for r in reqs)
    # least-occupancy routing: the first wave alternates replicas
    assert [ev["replica"] for ev in admitted[:4]] == [0, 1, 0, 1]
    assert all(r.replica is not None for r in reqs)


def test_overload_degrades_instead_of_queueing(cfg, eng):
    """The worked overload example: deterministic round arithmetic."""
    def overload_requests():
        reqs = make_requests(cfg, 6, seed=7, bank=BANK)
        for r in reqs:           # everyone asks for the expensive tier
            r.policy_index = 0
            r.tier = BANK[0].label()
        return reqs

    router = ClusterRouter(eng, replicas=1, slots_per_replica=2,
                           bank=BANK,
                           slo=RouterSLO(deadline_steps=6, degrade=True))
    reqs = overload_requests()
    m = router.run(reqs, ledger=True)
    assert m["dropped"] == 0
    assert sorted(r.finish_round - r.arrival_round for r in reqs) \
        == [4, 4, 6, 6, 8, 8]
    assert m["slo"]["met"] == 4
    assert m["degraded_requests"] == 4
    assert m["degraded_per_tier"] == {BANK[0].label(): 4}
    # the two non-degraded requests kept the expensive tier
    assert sum(r.tier == BANK[0].label() for r in reqs) == 2
    assert sum(r.tier == BANK[1].label() for r in reqs) == 4
    # ledger stays clean: banked per-policy image counts match service
    per_policy = m["energy"]["per_policy"]
    assert [e["images"] for e in per_policy] == [2, 4]
    assert m["energy"]["images"] == 6

    # positive control: queueing instead (degrade=False) misses the SLO
    router_q = ClusterRouter(eng, replicas=1, slots_per_replica=2,
                             bank=BANK,
                             slo=RouterSLO(deadline_steps=6,
                                           degrade=False))
    reqs_q = overload_requests()
    m_q = router_q.run(reqs_q, ledger=False)
    assert m_q["dropped"] == 0
    assert sorted(r.finish_round - r.arrival_round for r in reqs_q) \
        == [4, 4, 8, 8, 12, 12]
    assert m_q["slo"]["met"] == 2
    assert m_q.get("degraded_requests", 0) == 0
    # FIFO survives overload in both modes
    for rr in (reqs, reqs_q):
        assert [r.rid for r in sorted(rr, key=lambda r: r.admitted_s)] \
            == [r.rid for r in rr]
    assert m["slo"]["attainment"] > m_q["slo"]["attainment"]


def test_streaming_previews(cfg, eng):
    router = ClusterRouter(eng, replicas=1, slots_per_replica=2,
                           preview_every=1)
    reqs = make_requests(cfg, 2, seed=3)
    events = list(router.stream(reqs))
    previews = [ev for ev in events if ev["event"] == "preview"]
    # steps=3, previews every round: each request previews mid-flight
    assert previews and sum(r.previews for r in reqs) == len(previews)
    for r in reqs:
        assert r.previews >= 1
        assert r.first_preview_s is not None
        assert r.first_preview_s <= r.finished_s
    for ev in previews:
        assert ev["image"].shape == reqs[0].image.shape
        assert 0 < ev["step"] < cfg.ddim.num_inference_steps
    # event stream is complete and ordered per request
    for r in reqs:
        kinds = [ev["event"] for ev in events if ev["rid"] == r.rid]
        assert kinds[0] == "admitted" and kinds[-1] == "finished"


def test_merge_ledger_accums_sums_and_guards():
    from repro.diffusion.stats import LedgerAccum

    a = LedgerAccum.zeros(3, 4)
    b = dataclasses.replace(a, nnz=a.nnz + 2, rows=a.rows + 1)
    c = dataclasses.replace(a, nnz=a.nnz + 5)
    merged = merge_ledger_accums([b, c])
    assert (merged.nnz == 7).all()
    assert (merged.rows == 1).all()
    assert (merged.imp == 0).all()
    # exact/associative integer addition: merge order cannot matter
    swapped = merge_ledger_accums([c, b])
    assert (merged.nnz == swapped.nnz).all()
    with pytest.raises(ValueError, match="no accumulators"):
        merge_ledger_accums([])
    with pytest.raises(ValueError, match="mismatched"):
        merge_ledger_accums([a, LedgerAccum.zeros(2, 4)])


def test_router_guards(cfg, eng):
    with pytest.raises(ValueError, match="replicas"):
        ClusterRouter(eng, 0, 2)
    with pytest.raises(ValueError, match="bank"):
        ClusterRouter(eng, 1, 2, slo=RouterSLO(deadline_steps=4))
    with pytest.raises(ValueError, match="engines"):
        ClusterRouter(eng, 2, 2, engines=[eng])
    # a bank-less router refuses banked requests, like the scheduler
    router = ClusterRouter(eng, 1, 2)
    reqs = make_requests(cfg, 2, seed=5)
    reqs[1].policy_index = 1
    with pytest.raises(ValueError, match="policy_index"):
        list(router.stream(reqs))
