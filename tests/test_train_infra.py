"""Training-infrastructure tests: checkpoint/restart (fault tolerance),
data determinism/sharding, optimizer, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import get_arch
from repro.data import SyntheticLMDataset, shard_assignment
from repro.optim import AdamW
from repro.optim.compression import (compress_gradients,
                                     decompress_gradients,
                                     error_feedback_update)
from repro.train import TrainConfig, Trainer


@pytest.fixture()
def smoke_cfg():
    return get_arch("llama3-8b").smoke().scaled(vocab_size=128)


def _dataset(cfg):
    return SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=16,
                              global_batch=4, seed=7)


# ----------------------------------------------------------------------------
# Checkpoint store
# ----------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 5, tree, meta={"x": 1})
    assert latest_step(str(tmp_path)) == 5
    out, meta = load_checkpoint(str(tmp_path), 5, tree)
    assert meta == {"x": 1}
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(10.0))
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomicity_ignores_partial(tmp_path):
    tree = {"a": jnp.arange(4.0)}
    save_checkpoint(str(tmp_path), 1, tree)
    # simulate a crash mid-write: .tmp dir without manifest
    os.makedirs(tmp_path / "step_00000002.tmp-999")
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_gc_keeps_last_3(tmp_path):
    tree = {"a": jnp.zeros(2)}
    for s in range(1, 6):
        save_checkpoint(str(tmp_path), s, tree, keep=3)
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4, 5]


# ----------------------------------------------------------------------------
# Fault-tolerant trainer
# ----------------------------------------------------------------------------
def test_trainer_resume_bit_exact(tmp_path, smoke_cfg):
    """Kill at step 6, resume, final params == uninterrupted run."""
    tc = lambda: TrainConfig(steps=10, checkpoint_every=3, log_every=100,
                             checkpoint_dir=str(tmp_path / "ckpt"))
    ds = _dataset(smoke_cfg)
    opt = AdamW(lr=1e-3)

    class Boom(RuntimeError):
        pass

    def killer(step):
        if step == 7:
            raise Boom()

    t1 = Trainer(smoke_cfg, ds, opt, tc(), failure_hook=killer)
    with pytest.raises(Boom):
        t1.run(key=jax.random.PRNGKey(0))
    # node comes back: fresh Trainer object, auto-resume from step 6
    t2 = Trainer(smoke_cfg, ds, opt, tc())
    state_resumed, _ = t2.run(key=jax.random.PRNGKey(0))

    import shutil
    shutil.rmtree(tmp_path / "ckpt")
    t3 = Trainer(smoke_cfg, ds, opt, tc())
    state_clean, _ = t3.run(key=jax.random.PRNGKey(0))

    for a, b in zip(jax.tree.leaves(state_resumed[0]),
                    jax.tree.leaves(state_clean[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_loss_decreases(smoke_cfg, tmp_path):
    ds = _dataset(smoke_cfg)
    tc = TrainConfig(steps=30, checkpoint_every=1000, log_every=5,
                     checkpoint_dir=str(tmp_path / "c2"))
    t = Trainer(smoke_cfg, ds, AdamW(lr=3e-3), tc)
    _, history = t.run(resume=False)
    assert history[-1][1] < history[0][1]


# ----------------------------------------------------------------------------
# Data pipeline
# ----------------------------------------------------------------------------
def test_data_pure_in_seed_step():
    ds = SyntheticLMDataset(vocab_size=100, seq_len=8, global_batch=4, seed=3)
    b1 = ds.batch_at(12)
    b2 = ds.batch_at(12)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = ds.batch_at(13)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_shard_assignment_partitions_exactly():
    for gb, hosts in [(256, 7), (32, 32), (100, 9)]:
        rows = []
        for h in range(hosts):
            lo, hi = shard_assignment(gb, h, hosts)
            rows.extend(range(lo, hi))
        assert rows == list(range(gb))


def test_straggler_takeover_same_rows():
    """ANY host can regenerate another host's shard (pure seed/step)."""
    ds = SyntheticLMDataset(vocab_size=100, seq_len=8, global_batch=8, seed=1)
    full = ds.batch_at(3)["tokens"]
    part = ds.batch_at(3, host=1, num_hosts=4)["tokens"]
    lo, hi = shard_assignment(8, 1, 4)
    np.testing.assert_array_equal(np.asarray(part), np.asarray(full[lo:hi]))


# ----------------------------------------------------------------------------
# Optimizer + gradient compression
# ----------------------------------------------------------------------------
def test_adamw_decreases_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1.0


def test_grad_clip_bounds_update():
    opt = AdamW(lr=1.0, grad_clip=1e-3, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    _, _, gnorm = opt.update({"w": jnp.full((4,), 1e6)}, state, params)
    assert float(gnorm) > 1.0  # reported norm is pre-clip


def test_compression_roundtrip_error_feedback():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (256,))}
    res = None
    total_err = []
    # with error feedback, accumulated mean error -> 0 over steps
    carried = jax.tree.map(jnp.zeros_like, g)
    res = jax.tree.map(jnp.zeros_like, g)
    for _ in range(20):
        deq, res = error_feedback_update(g, res)
        carried = jax.tree.map(lambda c, d: c + d, carried, deq)
    target = jax.tree.map(lambda x: 20.0 * x, g)
    rel = float(jnp.linalg.norm(carried["w"] - target["w"])
                / jnp.linalg.norm(target["w"]))
    assert rel < 0.01


def test_compression_wire_format_int8():
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (64,))}
    qtree, _ = compress_gradients(g)
    q, scale = qtree["w"]
    assert q.dtype == jnp.int8
    deq = decompress_gradients(qtree)
    assert float(jnp.max(jnp.abs(deq["w"] - g["w"]))) <= float(scale) * 0.51
