"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device (the 512-device override is dryrun-only)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def smoke_mesh():
    from repro.launch.mesh import make_smoke_mesh
    return make_smoke_mesh()
