"""System-level tests: dry-run machinery, HLO collective parsing, FLOP
counting, energy model, sharded execution on fake devices (subprocess)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.core import energy
from repro.launch.dryrun import collective_bytes_from_hlo, pick_microbatches
from repro.launch.flops import flops_of_callable

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ----------------------------------------------------------------------------
# Collective parsing
# ----------------------------------------------------------------------------
def test_collective_bytes_parser():
    hlo = textwrap.dedent("""
      %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
      %ag = bf16[64]{0} all-gather(bf16[16]{0} %y), dimensions={0}
      %rs.1 = f32[32]{0} reduce-scatter(f32[128]{0} %z), dimensions={0}
      %cp = u8[100]{0} collective-permute-start(u8[100]{0} %w)
    """)
    out = collective_bytes_from_hlo(hlo)
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["all-gather"] == 64 * 2
    # reduce-scatter counted at OPERAND size (ring streams the full payload)
    assert out["reduce-scatter"] == 128 * 4
    assert out["collective-permute"] == 100
    assert out["counts"]["all-reduce"] == 1
    # ring AR weighted 2x
    assert out["weighted"] == pytest.approx(
        2 * 128 * 256 * 4 + 128 + 512 + 100)


def test_collective_parser_ignores_noncollective():
    out = collective_bytes_from_hlo("%m = f32[8,8] dot(%a, %b)")
    assert out["total"] == 0


# ----------------------------------------------------------------------------
# FLOP counter (loop-aware jaxpr walk)
# ----------------------------------------------------------------------------
def test_flops_matmul_exact():
    f = lambda a, b: a @ b
    n = flops_of_callable(f, jax.ShapeDtypeStruct((8, 16), jnp.float32),
                          jax.ShapeDtypeStruct((16, 4), jnp.float32))
    assert n == 2 * 8 * 16 * 4


def test_flops_scan_multiplies_by_length():
    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out
    n = flops_of_callable(f, jax.ShapeDtypeStruct((4, 4), jnp.float32))
    assert n == 7 * 2 * 4 * 4 * 4


def test_flops_remat_counts_recompute():
    def f(x):
        g = jax.checkpoint(lambda y: (y @ y).sum())
        return jax.grad(g)(x)
    n = flops_of_callable(f, jax.ShapeDtypeStruct((4, 4), jnp.float32))
    # fwd + recompute-fwd + bwd(2 matmuls) = 4 matmuls >= 3 matmuls
    assert n >= 3 * 2 * 4 ** 3


def test_pick_microbatches_divides():
    for gb, dp, seq in [(256, 16, 4096), (32, 16, 32768), (100, 10, 1000)]:
        m = pick_microbatches(gb, dp, seq)
        assert gb % m == 0 and (gb // m) % dp == 0


# ----------------------------------------------------------------------------
# Energy model
# ----------------------------------------------------------------------------
def test_energy_report_aggregation():
    layers = [
        energy.LayerTraffic("a", "cnn", weight_bytes=10, act_in_bytes=20,
                            act_out_bytes=30, macs_high=1e6),
        energy.LayerTraffic("b", "self_attn", sas_bytes=100, macs_high=2e6),
    ]
    rep = energy.report(layers)
    assert rep.ema_bytes_total == 160
    assert rep.sas_fraction == pytest.approx(100 / 160)
    assert rep.stage_fraction("cnn") == pytest.approx(60 / 160)
    assert rep.compute_energy_mj == pytest.approx(
        3e6 * energy.MAC_PJ["int12x8"] * 1e-9)


def test_ffn_energy_gain_matches_paper():
    """Paper Fig. 9(c): +43 % FFN energy efficiency at 44.8 % INT6 rows."""
    gain = energy.ffn_energy_gain(0.448)
    assert gain == pytest.approx(0.43, abs=0.02)


def test_dram_constant_calibration():
    """156 pJ/B was derived from (213.3 - 28.6 mJ) / (1.9 GB * 0.622)."""
    ema_opt = 1.9e9 * (1 - 0.378)
    adder_mj = ema_opt * energy.DRAM_PJ_PER_BYTE * 1e-9
    assert adder_mj == pytest.approx(213.3 - 28.6, rel=0.01)


# ----------------------------------------------------------------------------
# Sharded execution on fake devices (subprocess: needs its own XLA_FLAGS)
# ----------------------------------------------------------------------------
_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_arch
from repro.launch.mesh import use_mesh
from repro.models import transformer as T
from repro.models.layers import ShardCtx

mesh = jax.make_mesh((2, 4), ("data", "model"))
# vanilla numerics: TIPS/PSSA fake-quant amplifies bf16 reduction-order
# noise across shardings; exactness is only expected feature-off
cfg = get_arch("%(arch)s").smoke().scaled(
    num_kv_heads=4 if "%(family)s" != "ssm" else 0, tips=False, pssa=False)
params = T.init_params(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)

# unsharded reference
ref, _, _ = T.forward(params, cfg, None, tokens=toks, remat=False)

ctx = ShardCtx(mesh=mesh, dp_axes=("data",))
specs = T.param_specs(cfg, 4)
ns = lambda s: NamedSharding(mesh, s)
# use_mesh: jax.set_mesh on jax >= 0.6, the Mesh context manager below it
with use_mesh(mesh):
    psh = jax.tree.map(lambda s: ns(s), specs, is_leaf=lambda x: isinstance(x, P))
    sp = jax.device_put(params, psh)
    st = jax.device_put(toks, ns(P("data", None)))
    out, _, _ = jax.jit(lambda p, t: T.forward(p, cfg, ctx, tokens=t,
                                               remat=False))(sp, st)
a = np.asarray(ref, np.float32)
b = np.asarray(out, np.float32)
# mean-relative: bf16 reduction-order noise can flip a handful of discrete
# routing decisions (MoE top-k ties), which blows up the max-norm while the
# distributions stay equal; the mean norm is the equivalence criterion
rel = np.abs(a - b).mean() / (np.abs(a).mean() + 1e-9)
assert rel < %(tol)s, f"mean-relative divergence {rel}"
print("SHARDED_OK")
"""


@pytest.mark.parametrize("arch,family,tol",
                         [("llama3-8b", "dense", "2e-2"),
                          ("qwen2-moe-a2.7b", "moe", "5e-2"),
                          ("mamba2-130m", "ssm", "2e-2")])
def test_sharded_forward_matches_single_device(arch, family, tol):
    """2x4 fake-device mesh forward == single-device forward (numerics)."""
    script = _SHARD_SCRIPT % {"arch": arch, "family": family, "tol": tol}
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "SHARDED_OK" in r.stdout, r.stdout + r.stderr


# ----------------------------------------------------------------------------
# Dry-run records (consumes what the background matrix produced)
# ----------------------------------------------------------------------------
RESULTS = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                       "results")


def test_existing_dryrun_records_are_ok():
    if not os.path.isdir(RESULTS):
        pytest.skip("no dry-run results yet")
    recs = [json.load(open(os.path.join(RESULTS, n)))
            for n in os.listdir(RESULTS) if n.startswith("dryrun_")]
    if not recs:
        pytest.skip("no dry-run results yet")
    bad = [r for r in recs if r.get("status") == "error"]
    assert not bad, [(r["arch"], r["shape"], r["mesh"], r["error"])
                     for r in bad]
    for r in recs:
        if r["status"] != "ok":
            continue
        assert r["flops"] > 0
        assert r["bytes_accessed"] > 0
        assert r["extrapolated"]["flops"] >= r["flops"] * 0.5
