"""Pallas kernel tests: shape/dtype sweeps, allclose vs the pure-jnp oracle.

All kernels run interpret=True (CPU container; TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import pssa, quant
from repro.kernels.bitslice_matmul.kernel import bitslice_matmul_kernel
from repro.kernels.bitslice_matmul.ops import bitslice_matmul
from repro.kernels.bitslice_matmul.ref import bitslice_matmul_ref
from repro.kernels.patch_bitmap.kernel import patch_bitmap_kernel
from repro.kernels.patch_bitmap.ref import patch_bitmap_ref
from repro.kernels.pssa_attention.kernel import pssa_attention_kernel
from repro.kernels.pssa_attention.ref import pssa_attention_ref


# ----------------------------------------------------------------------------
# DBSC bit-slice matmul
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (128, 256, 256)])
@pytest.mark.parametrize("dataflow", ["weight_stationary",
                                      "input_stationary"])
def test_bitslice_kernel_exact_vs_ref(m, k, n, dataflow):
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.integers(0, 4096, (m, k)), jnp.int32)
    hi, lo = quant.bitslice_split(vals)
    w = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int32)
    prec = jnp.asarray(rng.integers(0, 2, (m, 1)), jnp.int32)
    out = bitslice_matmul_kernel(hi, lo, w, prec, dataflow=dataflow)
    ref = bitslice_matmul_ref(hi, lo, w, prec)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("bm,bn,bk", [(64, 64, 64), (128, 128, 64),
                                      (64, 128, 128)])
def test_bitslice_kernel_block_shape_sweep(bm, bn, bk):
    rng = np.random.default_rng(1)
    m, k, n = 256, 256, 256
    vals = jnp.asarray(rng.integers(0, 4096, (m, k)), jnp.int32)
    hi, lo = quant.bitslice_split(vals)
    w = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int32)
    prec = jnp.ones((m, 1), jnp.int32)
    out = bitslice_matmul_kernel(hi, lo, w, prec, bm=bm, bn=bn, bk=bk)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(bitslice_matmul_ref(hi, lo, w, prec)))


@given(seed=st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_bitslice_int6_rows_skip_low_slice(seed):
    """prec=0 rows must equal the hi-slice-only product (the silicon skips
    the low-slice pass entirely for INT6 rows)."""
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.integers(0, 4096, (128, 128)), jnp.int32)
    hi, lo = quant.bitslice_split(vals)
    w = jnp.asarray(rng.integers(-128, 128, (128, 128)), jnp.int32)
    prec = jnp.zeros((128, 1), jnp.int32)
    out = bitslice_matmul_kernel(hi, lo, w, prec)
    expect = (jnp.matmul(hi, w, preferred_element_type=jnp.int32) << 6)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("m,k,n", [(100, 96, 40), (7, 130, 129)])
def test_bitslice_op_ragged_shapes(m, k, n):
    """ops.py pads ragged shapes to the 128-multiple grid."""
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(2), (m, k)))
    w = jax.random.normal(jax.random.PRNGKey(3), (k, n))
    y = bitslice_matmul(x, w)
    rel = jnp.max(jnp.abs(y - x @ w)) / (jnp.max(jnp.abs(x @ w)) + 1e-9)
    assert float(rel) < 0.02


def test_bitslice_op_kernel_matches_ref_path():
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(4), (64, 64)))
    w = jax.random.normal(jax.random.PRNGKey(5), (64, 64))
    imp = jnp.arange(64) % 2 == 0
    yk = bitslice_matmul(x, w, important=imp, use_kernel=True)
    yr = bitslice_matmul(x, w, important=imp, use_kernel=False)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-6)


# ----------------------------------------------------------------------------
# PSSA attention kernel
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("bh,t,d", [(2, 256, 64), (4, 512, 32),
                                    (1, 1024, 128)])
def test_pssa_attention_matches_ref(bh, t, d):
    k = jax.random.PRNGKey(0)
    q, kk, v = (jax.random.normal(jax.random.PRNGKey(i), (bh, t, d))
                for i in range(3))
    out, nnz = pssa_attention_kernel(q, kk, v, threshold=1.0 / 1024.0)
    oref, nref = pssa_attention_ref(q, kk, v, threshold=1.0 / 1024.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(nnz), np.asarray(nref))


@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 256), (256, 128)])
def test_pssa_attention_block_sweep(bq, bk):
    q = jax.random.normal(jax.random.PRNGKey(1), (2, 512, 64))
    out, nnz = pssa_attention_kernel(q, q, q, threshold=1.0 / 1024.0,
                                     bq=bq, bk=bk)
    oref, nref = pssa_attention_ref(q, q, q, threshold=1.0 / 1024.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(nnz), np.asarray(nref))


def test_pssa_attention_zero_threshold_is_exact_softmax():
    q = jax.random.normal(jax.random.PRNGKey(2), (2, 256, 64))
    out, nnz = pssa_attention_kernel(q, q, q, threshold=0.0)
    probs = jax.nn.softmax(
        jnp.einsum("bqd,bkd->bqk", q, q) / jnp.sqrt(64.0), -1)
    oref = jnp.einsum("bqk,bkd->bqd", probs, q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oref),
                               rtol=2e-5, atol=2e-5)
    assert (np.asarray(nnz) == 256).all()


# ----------------------------------------------------------------------------
# PSXU patch-bitmap kernel
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("patch", [16, 32, 64])
@pytest.mark.parametrize("rows,tk", [(64, 256), (128, 1024), (256, 64)])
def test_patch_bitmap_matches_ref(patch, rows, tk):
    if tk % patch:
        pytest.skip("patch must divide Tk")
    sas = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(0), (rows, tk)) * 3, -1)
    packed, counts = patch_bitmap_kernel(sas, patch=patch,
                                         threshold=1.0 / 1024.0)
    pref, cref = patch_bitmap_ref(sas, patch=patch, threshold=1.0 / 1024.0)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(pref))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(cref))


def test_patch_bitmap_counts_match_core_pssa():
    """Kernel popcounts == core.pssa patch_xor ones (two implementations)."""
    sas = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(1), (128, 512)) * 4, -1)
    _, counts = patch_bitmap_kernel(sas, patch=32, threshold=1.0 / 1024.0)
    bm = pssa.bitmap(pssa.prune(sas, 1.0 / 1024.0))
    xbm = pssa.patch_xor(bm, 32)
    cref = jnp.sum(xbm.reshape(128, 512 // 32, 32).astype(jnp.int32), -1)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(cref))


def test_patch_bitmap_pack_unpack_roundtrip():
    sas = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(2), (64, 128)) * 4, -1)
    packed, _ = patch_bitmap_kernel(sas, patch=32, threshold=1.0 / 1024.0)
    # unpack the uint32 words back to bits
    bits = (packed[..., None] >> jnp.arange(32, dtype=jnp.uint32)) & 1
    bits = bits.reshape(64, 128).astype(bool)
    bm = pssa.bitmap(pssa.prune(sas, 1.0 / 1024.0))
    np.testing.assert_array_equal(np.asarray(bits),
                                  np.asarray(pssa.patch_xor(bm, 32)))
