"""Temporal patch reuse (SIGE-style incremental denoising) — DESIGN.md §9.

The contract under test:

  * threshold 0 (or a fully-changed input) forces every patch active, and
    the gather -> compute -> scatter path is then BIT-IDENTICAL to the
    dense UNet — eps, images, AND the integer ledger counters — across
    reference|kernel delta routing, the scanned sampler, fused-CFG, and
    the slot engine;
  * the patch-delta kernel matches its reference bit-for-bit (max/abs
    commute exactly with blocking);
  * a corrupted cache row at a full-reuse threshold CHANGES the output
    (positive control: the parity tests can detect a stale-cache leak);
  * cache lifecycle: a fresh cache is all-invalid (first step dense), an
    admitted slot's row is invalidated (no reuse across occupants);
  * realized-reuse counters are integers, masked like every other ledger
    bucket, and identical across slot counts;
  * ``ReusePolicy`` guards: capacity bounds, engine temporal-path
    capacity==1.0, parse round-trips.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.reuse import (LayerReuseCache, ReuseCache, ReusePolicy,
                              reuse_cache_zeros)
from repro.diffusion.engine import DiffusionEngine
from repro.diffusion.pipeline import (PipelineConfig,
                                      aggregated_reuse_ratios_per_iter,
                                      reuse_ratios_from_accum)
from repro.diffusion.sampler import (DDIMConfig, sample_scan,
                                     sample_scan_reuse)
from repro.diffusion.unet import UNetConfig, init_unet_params, unet_forward
from repro.kernels.dispatch import KernelPolicy
from repro.kernels.patch_reuse import ops as reuse_ops
from repro.kernels.patch_reuse.ref import patch_delta_ref


@pytest.fixture(scope="module")
def ucfg():
    return UNetConfig().smoke()


@pytest.fixture(scope="module")
def params(ucfg):
    return init_unet_params(jax.random.PRNGKey(0), ucfg)


@pytest.fixture(scope="module")
def inputs(ucfg):
    lat = jax.random.normal(jax.random.PRNGKey(1),
                            (2, ucfg.latent_size, ucfg.latent_size,
                             ucfg.in_channels))
    ctx = jax.random.normal(jax.random.PRNGKey(2),
                            (2, ucfg.text_len, ucfg.context_dim))
    un = jax.random.normal(jax.random.PRNGKey(3),
                           (2, ucfg.text_len, ucfg.context_dim))
    t = jnp.array([500, 500])
    return lat, ctx, un, t


def with_reuse(ucfg, **kw):
    return dataclasses.replace(
        ucfg, reuse_policy=ReusePolicy.temporal(**kw))


# ---------------------------------------------------------------------------
# ReusePolicy surface
# ---------------------------------------------------------------------------
class TestPolicy:
    def test_presets_and_parse(self):
        assert not ReusePolicy.off().enabled
        assert ReusePolicy.parse("temporal").enabled
        assert ReusePolicy.parse("edit").capacity < 1.0
        p = ReusePolicy.parse("temporal,threshold=0.1")
        assert p.threshold == 0.1 and p.capacity == 1.0
        assert isinstance(hash(p), int)          # hashable (jit cache key)

    def test_validation(self):
        with pytest.raises(ValueError):
            ReusePolicy(threshold=-1.0)
        with pytest.raises(ValueError):
            ReusePolicy(capacity=0.0)
        with pytest.raises(ValueError):
            ReusePolicy(capacity=1.5)

    def test_cap_patches(self):
        p = ReusePolicy(enabled=True, capacity=0.0625)
        assert p.cap_patches(32) == 2
        assert p.cap_patches(4) == 1             # floor at one patch
        assert ReusePolicy(enabled=True).cap_patches(7) == 7

    def test_engine_rejects_sub_one_capacity(self):
        cfg = PipelineConfig.smoke()
        with pytest.raises(ValueError, match="capacity"):
            DiffusionEngine(cfg, reuse_policy=ReusePolicy.edit())

    def test_window_patch_mask(self):
        from repro.core.reuse import window_patch_mask
        # full-frame window: every patch active at every resolution
        assert all(window_patch_mask((0, 0, 8, 8), 8, 4, 8))
        assert all(window_patch_mask((0, 0, 8, 8), 4, 4, 8))
        # a 2x2 window in an 8x8 latent at resolution 8, patch=4 tokens
        # (half-row patches): rows 2-3 touch patches 4..7 -> exactly the
        # two left-half patches of those rows are active
        mask = window_patch_mask((2, 0, 2, 2), 8, 4, 8)
        assert len(mask) == 16
        assert [i for i, a in enumerate(mask) if a] == [4, 6]
        # downscaled resolution rounds the window OUTWARD (conservative:
        # boundary tokens always covered, never missed)
        # (2,2,3,3) in 8px spans rows [1, 2.5) at res 4 -> rows 1-2 of
        # the 4 row-patches active, first and last rows untouched
        lo = window_patch_mask((2, 2, 3, 3), 4, 4, 8)
        assert lo == (False, True, True, False)
        # a priori mask is a static tuple of python bools (trace-time
        # constant — what lets the edit engine skip the delta kernel)
        assert all(isinstance(a, bool) for a in mask)


# ---------------------------------------------------------------------------
# Kernel parity
# ---------------------------------------------------------------------------
class TestPatchDeltaKernel:
    @pytest.mark.parametrize("tokens,patch", [(64, 16), (80, 16), (24, 8)])
    def test_kernel_matches_reference(self, tokens, patch):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, tokens, 12))
        r = jax.random.normal(jax.random.PRNGKey(1), (2, tokens, 12))
        ref = patch_delta_ref(x, r, patch)
        for pol in (KernelPolicy(reuse="kernel"),
                    KernelPolicy(reuse="kernel", reuse_block_patches=3)):
            from repro.kernels import dispatch
            d, changed = dispatch.patch_delta(pol, x, r, patch=patch,
                                              threshold=0.5)
            assert jnp.array_equal(d, ref)       # max/abs commute exactly
            assert jnp.array_equal(changed, ref >= 0.5)

    def test_threshold_zero_all_active(self):
        from repro.kernels import dispatch
        x = jnp.zeros((1, 32, 4))
        _, changed = dispatch.patch_delta(KernelPolicy(), x, x,
                                          patch=16, threshold=0.0)
        assert bool(jnp.all(changed))            # delta 0 >= 0

    def test_plan_all_active_is_identity(self):
        active = jnp.ones((3, 8), bool)
        order, gate = reuse_ops.reuse_plan(active, 8)
        assert jnp.array_equal(order,
                               jnp.broadcast_to(jnp.arange(8), (3, 8)))
        assert bool(jnp.all(gate))

    def test_scatter_gated_rows_keep_base(self):
        base = jnp.arange(12, dtype=jnp.float32).reshape(1, 6, 2)
        rows = jnp.array([[0, 3]])
        vals = jnp.full((1, 2, 2), -1.0)
        gate = jnp.array([[True, False]])
        out = reuse_ops.scatter_rows(base, rows, vals, gate)
        assert jnp.array_equal(out[0, 0], jnp.array([-1.0, -1.0]))
        assert jnp.array_equal(out[0, 3], base[0, 3])   # gated off


# ---------------------------------------------------------------------------
# UNet-level exactness (the tentpole contract)
# ---------------------------------------------------------------------------
class TestUNetParity:
    @pytest.mark.parametrize("kernels", ["reference", "fused"])
    def test_thr0_bit_identical_and_counters(self, ucfg, params, inputs,
                                             kernels):
        lat, ctx, _, t = inputs
        kp = KernelPolicy.parse(kernels)
        base = dataclasses.replace(ucfg, kernel_policy=kp)
        eps_d, st_d = unet_forward(params, lat, t, ctx, base)
        rcfg = with_reuse(base, threshold=0.0)
        cache = reuse_cache_zeros(rcfg, 2, use_cfg=False)
        eps_r, st_r, cache2 = unet_forward(params, lat, t, ctx, rcfg,
                                           reuse_cache=cache)
        assert jnp.array_equal(eps_d, eps_r)
        # second step against a VALID cache, same threshold: still dense
        eps_r2, st_r2, _ = unet_forward(params, lat, t, ctx, rcfg,
                                        reuse_cache=cache2)
        assert jnp.array_equal(eps_d, eps_r2)
        # ledger counters bit-identical to the dense run
        for a, b in zip(st_d.pssa, st_r.pssa):
            assert jnp.array_equal(a.nnz, b.nnz)
            assert jnp.array_equal(a.bitmap_ones_xor, b.bitmap_ones_xor)
        # realized-reuse counters: everything computed
        for c in st_r2.reuse:
            assert c.computed.dtype == jnp.int32
            assert jnp.array_equal(c.computed, c.total)

    def test_fully_changed_input_is_dense(self, ucfg, params, inputs):
        """A large threshold with a COMPLETELY different input: every
        patch trips the delta, so the output is exactly dense."""
        lat, ctx, _, t = inputs
        rcfg = with_reuse(ucfg, threshold=0.05)
        cache = reuse_cache_zeros(rcfg, 2, use_cfg=False)
        _, _, cache2 = unet_forward(params, lat, t, ctx, rcfg,
                                    reuse_cache=cache)
        lat2 = lat + 100.0                       # every patch changes
        eps_d, _ = unet_forward(params, lat2, t, ctx, ucfg)
        eps_r, st_r, _ = unet_forward(params, lat2, t, ctx, rcfg,
                                      reuse_cache=cache2)
        assert jnp.array_equal(eps_d, eps_r)
        for c in st_r.reuse:
            assert jnp.array_equal(c.computed, c.total)

    def test_full_reuse_replays_cache(self, ucfg, params, inputs):
        lat, ctx, _, t = inputs
        eps_d, _ = unet_forward(params, lat, t, ctx, ucfg)
        rcfg = with_reuse(ucfg, threshold=1e9)
        cache = reuse_cache_zeros(rcfg, 2, use_cfg=False)
        _, _, cache2 = unet_forward(params, lat, t, ctx, rcfg,
                                    reuse_cache=cache)
        eps_f, st_f, _ = unet_forward(params, lat, t, ctx, rcfg,
                                      reuse_cache=cache2)
        assert jnp.array_equal(eps_f, eps_d)     # same input -> same eps
        assert sum(int(jnp.sum(c.computed)) for c in st_f.reuse) == 0

    def test_stale_cache_leak_detected(self, ucfg, params, inputs):
        """POSITIVE CONTROL: corrupt one cached activation row at a
        full-reuse threshold — the output must move.  Proves the parity
        assertions above would catch a scatter that read stale rows."""
        lat, ctx, _, t = inputs
        rcfg = with_reuse(ucfg, threshold=1e9)
        cache = reuse_cache_zeros(rcfg, 2, use_cfg=False)
        eps_clean, _, cache2 = unet_forward(params, lat, t, ctx, rcfg,
                                            reuse_cache=cache)
        bad_layers = list(cache2.layers)
        l0 = bad_layers[0]
        bad_layers[0] = LayerReuseCache(
            ref=l0.ref, sa=l0.sa.at[0].add(10.0), ca=l0.ca, ffn=l0.ffn)
        bad = ReuseCache(valid=cache2.valid, layers=tuple(bad_layers))
        eps_bad, _, _ = unet_forward(params, lat, t, ctx, rcfg,
                                     reuse_cache=bad)
        assert not jnp.array_equal(eps_clean, eps_bad)

    def test_invalid_row_forces_dense(self, ucfg, params, inputs):
        """Row invalidation overrides even a full-reuse threshold."""
        lat, ctx, _, t = inputs
        rcfg = with_reuse(ucfg, threshold=1e9)
        cache = reuse_cache_zeros(rcfg, 2, use_cfg=False)
        _, _, cache2 = unet_forward(params, lat, t, ctx, rcfg,
                                    reuse_cache=cache)
        inv = cache2.invalidate_row(1)
        _, st, _ = unet_forward(params, lat, t, ctx, rcfg,
                                reuse_cache=inv)
        for c in st.reuse:
            assert int(c.computed[0]) == 0               # row 0 reuses
            assert int(c.computed[1]) == int(c.total[1])  # row 1 dense

    def test_cfg_dup_parity(self, ucfg, params, inputs):
        lat, ctx, un, t = inputs
        ctx_f = jnp.concatenate([ctx, un], axis=0)
        eps_d, _ = unet_forward(params, lat, t, ctx_f, ucfg,
                                stats_rows=2, cfg_dup=True)
        rcfg = with_reuse(ucfg, threshold=0.0)
        cache = reuse_cache_zeros(rcfg, 2, use_cfg=True)
        eps_r, _, cache2 = unet_forward(params, lat, t, ctx_f, rcfg,
                                        stats_rows=2, cfg_dup=True,
                                        reuse_cache=cache)
        assert jnp.array_equal(eps_d, eps_r)
        eps_r2, _, _ = unet_forward(params, lat, t, ctx_f, rcfg,
                                    stats_rows=2, cfg_dup=True,
                                    reuse_cache=cache2)
        assert jnp.array_equal(eps_d, eps_r2)


# ---------------------------------------------------------------------------
# Sampler: temporal scan carry + img2img edit mode
# ---------------------------------------------------------------------------
class TestSampler:
    @pytest.fixture(scope="class")
    def scfg(self):
        return DDIMConfig(num_inference_steps=3, guidance_scale=7.5,
                          tips_active_iters=2)

    def apply(self, params, ucfg):
        def unet_apply(l, t, c, a, **kw):
            return unet_forward(params, l, t, c, ucfg, tips_active=a,
                                **kw)
        return unet_apply

    def test_scan_thr0_parity_and_record(self, ucfg, params, inputs, scfg):
        lat, ctx, un, _ = inputs
        lat_d, _ = sample_scan(self.apply(params, ucfg), lat, ctx, un,
                               scfg)
        rcfg = with_reuse(ucfg, threshold=0.0)
        cache = reuse_cache_zeros(rcfg, 2, use_cfg=True)
        lat_r, stats, caches = sample_scan_reuse(
            self.apply(params, rcfg), lat, ctx, un, scfg,
            reuse_cache=cache, record_caches=True)
        assert jnp.array_equal(lat_d, lat_r)
        # recorded stack: leading axis = iterations
        assert jax.tree_util.tree_leaves(caches)[0].shape[0] == 3

    def test_edit_mode_exact_and_bounded(self, ucfg, params, inputs, scfg):
        lat, ctx, un, _ = inputs
        rcfg = with_reuse(ucfg, threshold=0.0)
        cache = reuse_cache_zeros(rcfg, 2, use_cfg=True)
        lat_b, _, caches = sample_scan_reuse(
            self.apply(params, rcfg), lat, ctx, un, scfg,
            reuse_cache=cache, record_caches=True)
        # edit run on the SAME input at sub-1.0 capacity: full reuse,
        # replays the base trajectory exactly
        ecfg = dataclasses.replace(
            ucfg, reuse_policy=ReusePolicy.edit(threshold=0.05,
                                                capacity=0.25))
        lat_e, st = sample_scan_reuse(self.apply(params, ecfg), lat, ctx,
                                      un, scfg, base_caches=caches)
        assert jnp.array_equal(lat_e, lat_b)
        assert sum(int(jnp.sum(c.computed)) for c in st.reuse) == 0
        # perturbed input diverges, and computed stays under the static cap
        lat2 = lat.at[:, :4, :4, :].add(3.0)
        lat_e2, st2 = sample_scan_reuse(self.apply(params, ecfg), lat2,
                                        ctx, un, scfg, base_caches=caches)
        assert not jnp.array_equal(lat_e2, lat_b)
        for c in st2.reuse:
            assert bool(jnp.all(c.computed <= c.total))

    def test_exactly_one_cache_source(self, ucfg, params, inputs, scfg):
        lat, ctx, un, _ = inputs
        with pytest.raises(ValueError, match="exactly one"):
            sample_scan_reuse(self.apply(params, ucfg), lat, ctx, un,
                              scfg)


# ---------------------------------------------------------------------------
# Engine + slots: lifecycle, masking, ratio helpers
# ---------------------------------------------------------------------------
class TestEngine:
    @pytest.fixture(scope="class")
    def cfg(self):
        cfg = PipelineConfig.smoke()
        return dataclasses.replace(cfg, ddim=dataclasses.replace(
            cfg.ddim, num_inference_steps=3, guidance_scale=7.5,
            tips_active_iters=2))

    @pytest.fixture(scope="class")
    def toks(self, cfg):
        return jax.random.randint(jax.random.PRNGKey(9),
                                  (2, cfg.text.max_len), 0,
                                  cfg.text.vocab_size)

    def test_one_shot_thr0_bit_identical(self, cfg, toks):
        un = jnp.zeros_like(toks)
        eng_d = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
        eng_r = DiffusionEngine(cfg, key=jax.random.PRNGKey(0),
                                reuse_policy=ReusePolicy.temporal(
                                    threshold=0.0))
        lat0 = eng_d.init_latents(2, jax.random.PRNGKey(7))
        out_d = eng_d.generate(toks, None, uncond_tokens=un,
                               latents=lat0)
        out_r = eng_r.generate(toks, None, uncond_tokens=un,
                               latents=eng_r.init_latents(
                                   2, jax.random.PRNGKey(7)))
        assert jnp.array_equal(out_d.images, out_r.images)
        # dense trajectories report zero reuse
        assert aggregated_reuse_ratios_per_iter(cfg, [out_d.stats]) \
            == [0.0, 0.0, 0.0]

    def test_slot_parity_and_counters_across_slot_counts(self, cfg, toks):
        un = jnp.zeros_like(toks)
        eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0),
                              reuse_policy=ReusePolicy.temporal(
                                  threshold=1.0))
        lat0 = eng.init_latents(2, jax.random.PRNGKey(7))

        def run(num_slots):
            st = eng.init_slots(num_slots)
            for i in range(2):
                st = eng.admit(st, i, toks[i:i + 1], None,
                               uncond_tokens=un[i:i + 1],
                               latents=lat0[i:i + 1])
            for _ in range(cfg.ddim.num_inference_steps):
                st = eng.slot_step(st)
            return st

        st2, st4 = run(2), run(4)
        assert jnp.array_equal(st2.latents, st4.latents[:2])
        # reuse buckets are integer counters: slot count cannot move them
        assert jnp.array_equal(st2.accum.reuse_computed,
                               st4.accum.reuse_computed)
        assert jnp.array_equal(st2.accum.reuse_total,
                               st4.accum.reuse_total)
        r = reuse_ratios_from_accum(cfg, st2.accum)
        assert r[0] == 0.0                       # first step: invalid cache
        assert all(0.0 <= x <= 1.0 for x in r)

    def test_admit_invalidates_previous_occupant(self, cfg, toks):
        un = jnp.zeros_like(toks)
        eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0),
                              reuse_policy=ReusePolicy.temporal(
                                  threshold=1e9))
        st = eng.init_slots(1)
        st = eng.admit(st, 0, toks[:1], jax.random.PRNGKey(1),
                       uncond_tokens=un[:1])
        st = eng.slot_step(st)
        assert bool(st.reuse_cache.valid[0])     # cache valid after a step
        st = eng.retire(st, [0])
        st = eng.admit(st, 0, toks[1:], jax.random.PRNGKey(2),
                       uncond_tokens=un[1:])
        assert not bool(st.reuse_cache.valid[0])  # invalidated on admit
        # the new occupant's first step is dense despite threshold=1e9
        comp0 = int(jnp.sum(st.accum.reuse_computed[0]))
        tot0 = int(jnp.sum(st.accum.reuse_total[0]))
        st = eng.slot_step(st)
        d_comp = int(jnp.sum(st.accum.reuse_computed[0])) - comp0
        d_tot = int(jnp.sum(st.accum.reuse_total[0])) - tot0
        assert d_tot > 0 and d_comp == d_tot
