"""Continuous-batching (slot-state) serving tests — DESIGN.md §8.

The contract under test:

  * images served through the slot runtime are BIT-IDENTICAL per request
    to the one-shot engine at the same per-request latents — interleaving
    requests at heterogeneous step indices in one batched UNet call is a
    pure scheduling change;
  * the drained ``LedgerAccum`` yields an energy headline bit-identical
    to the same requests served one-shot, at ANY slot count, admission
    order, or occupancy pattern (integer-counter exactness), with
    knife-edge thresholds keeping every counter input-sensitive;
  * the active-slot mask is what guarantees that: un-masking it (the
    positive control) lets the unoccupied rows' garbage move the headline;
  * admission/retirement swap rows without retracing the step executable;
  * the CFG contract carries over (fused cond+uncond per step).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion.engine import DiffusionEngine
from repro.diffusion.pipeline import (PipelineConfig,
                                      energy_report_from_accum,
                                      energy_report_multi)
from repro.diffusion.stats import LedgerAccum
from repro.launch.scheduler import (ContinuousScheduler,
                                    FixedBatchScheduler, apply_trace,
                                    bursty_trace, make_requests,
                                    poisson_trace)


def knife_edge(cfg):
    """Thresholds at the actual smoke-model score scale.

    The untrained model's near-uniform softmax rows saturate the counters
    at the paper operating point (nothing pruned, nothing spotted) — both
    sides of every equality would be trivially equal.  ~1/T and
    ~1/text_len make every counter input-sensitive, so the positive
    controls below have teeth.
    """
    t = cfg.unet.latent_size ** 2
    return dataclasses.replace(cfg, unet=dataclasses.replace(
        cfg.unet, pssa_threshold=1.0 / t,
        tips_threshold=1.0 / cfg.unet.text_len))


@pytest.fixture(scope="module")
def cfg():
    return knife_edge(PipelineConfig.smoke())


@pytest.fixture(scope="module")
def eng(cfg):
    return DiffusionEngine(cfg, key=jax.random.PRNGKey(0))


def _requests(cfg, n, seed=7):
    return make_requests(cfg, n, seed=seed)


def _drain(eng, requests, num_slots, order=None):
    """Drive requests through the slot runtime; returns (state, images).

    ``order`` permutes admission (arrival order); default request order.
    All requests are available immediately — occupancy varies naturally
    as slots drain at the end of the queue.
    """
    queue = list(order if order is not None else range(len(requests)))
    owner = {}
    images = {}
    state = eng.init_slots(num_slots)

    def fill(state):
        for s in range(num_slots):
            if s not in owner and queue:
                r = requests[queue.pop(0)]
                state = eng.admit(state, s, r.tokens, None,
                                  uncond_tokens=r.uncond_tokens,
                                  latents=r.latents)
                owner[s] = r
        return state

    state = fill(state)
    while owner:
        state = eng.slot_step(state)
        done = eng.finished_slots(state)
        if done:
            decoded = np.asarray(jax.device_get(
                eng.decode_slots(state, done)))
            for j, s in enumerate(done):
                images[owner.pop(s).rid] = decoded[j]
            state = eng.retire(state, done)
            state = fill(state)
    return state, images


def _one_shot(eng, requests, batch):
    """Oracle: the same requests through plain ``generate`` calls."""
    images, stats = {}, []
    for i in range(0, len(requests), batch):
        chunk = requests[i:i + batch]
        toks = jnp.concatenate([r.tokens for r in chunk], axis=0)
        lats = jnp.concatenate([r.latents for r in chunk], axis=0)
        uncond = (jnp.concatenate([r.uncond_tokens for r in chunk], axis=0)
                  if chunk[0].uncond_tokens is not None else None)
        out = eng.generate(toks, None, uncond_tokens=uncond, latents=lats)
        arr = np.asarray(out.images)
        for j, r in enumerate(chunk):
            images[r.rid] = arr[j]
        stats.append(out.stats)
    return images, stats


# ----------------------------------------------------------------------------
# Image bit-identity
# ----------------------------------------------------------------------------
def test_images_bit_identical_to_one_shot(cfg, eng):
    reqs = _requests(cfg, 4)
    ref, _ = _one_shot(eng, reqs, batch=2)
    _, imgs = _drain(eng, reqs, num_slots=2)
    for r in reqs:
        np.testing.assert_array_equal(imgs[r.rid], ref[r.rid],
                                      err_msg=f"request {r.rid}")


def test_images_bit_identical_under_cfg(cfg):
    cfg_g = dataclasses.replace(cfg, ddim=dataclasses.replace(
        cfg.ddim, guidance_scale=7.5))
    eng = DiffusionEngine(cfg_g, key=jax.random.PRNGKey(0))
    reqs = make_requests(cfg_g, 4)
    assert reqs[0].uncond_tokens is not None    # CFG requests carry uncond
    ref, _ = _one_shot(eng, reqs, batch=2)
    _, imgs = _drain(eng, reqs, num_slots=2)
    for r in reqs:
        np.testing.assert_array_equal(imgs[r.rid], ref[r.rid],
                                      err_msg=f"request {r.rid}")


# ----------------------------------------------------------------------------
# Ledger bit-identity across slot counts / occupancy patterns
# ----------------------------------------------------------------------------
def test_ledger_bit_identical_across_slot_counts(cfg, eng):
    reqs = _requests(cfg, 4)
    _, stats = _one_shot(eng, reqs, batch=4)
    ref = energy_report_multi(cfg, stats).summary()
    for slots in (2, 3, 4):
        state, _ = _drain(eng, reqs, num_slots=slots)
        rep = energy_report_from_accum(cfg, state.accum).summary()
        assert rep == ref, f"slots={slots}"
        # every request executed every iteration exactly once
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(state.accum.rows)), len(reqs))


def test_ledger_bit_identical_across_occupancy_patterns(cfg, eng):
    """Admission order staggers which slots sit at which step index —
    the aggregated headline must not move."""
    reqs = _requests(cfg, 5)                 # odd count: uneven drain
    state_a, _ = _drain(eng, reqs, num_slots=2)
    state_b, _ = _drain(eng, reqs, num_slots=3, order=[4, 2, 0, 3, 1])
    rep_a = energy_report_from_accum(cfg, state_a.accum).summary()
    rep_b = energy_report_from_accum(cfg, state_b.accum).summary()
    assert rep_a == rep_b


def test_ledger_headline_is_input_sensitive(cfg, eng):
    """Positive control for the equality above: at knife-edge thresholds a
    different request set MUST move the integer counters."""
    state_a, _ = _drain(eng, _requests(cfg, 4, seed=7), num_slots=2)
    state_b, _ = _drain(eng, _requests(cfg, 4, seed=23), num_slots=2)
    assert not np.array_equal(
        np.asarray(jax.device_get(state_a.accum.nnz)),
        np.asarray(jax.device_get(state_b.accum.nnz)))
    rep_a = energy_report_from_accum(cfg, state_a.accum).summary()
    rep_b = energy_report_from_accum(cfg, state_b.accum).summary()
    assert rep_a != rep_b


def test_unmasked_garbage_moves_the_headline(cfg, monkeypatch):
    """Positive control for the active-slot mask: scatter WITHOUT the mask
    and the unoccupied rows' garbage lands in the ledger buckets."""
    reqs = _requests(cfg, 2)
    eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
    state_good, _ = _drain(eng, reqs, num_slots=4)   # 2 slots always empty

    orig = LedgerAccum.scatter
    monkeypatch.setattr(
        LedgerAccum, "scatter",
        lambda self, step_idx, active, ss:
            orig(self, step_idx, jnp.ones_like(active), ss))
    eng_bad = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
    state_bad, _ = _drain(eng_bad, reqs, num_slots=4)
    assert not np.array_equal(
        np.asarray(jax.device_get(state_good.accum.nnz)),
        np.asarray(jax.device_get(state_bad.accum.nnz)))
    # the mask is also what keeps the per-iteration row counts honest
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(state_good.accum.rows)), 2)
    assert int(np.asarray(jax.device_get(state_bad.accum.rows))[0]) > 2


# ----------------------------------------------------------------------------
# Slot mechanics
# ----------------------------------------------------------------------------
def test_step_executable_compiles_once_per_signature(cfg):
    eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
    reqs = _requests(cfg, 5)
    _drain(eng, reqs, num_slots=2)           # occupancy varies over the run
    assert len(eng._slot_compiled) == 1      # one step executable reused
    _drain(eng, reqs, num_slots=3)
    assert len(eng._slot_compiled) == 2      # new slot count retraces


def test_step_counts_and_occupancy(cfg, eng):
    """2 slots x 4 requests x 3 steps: full occupancy, 6 steps total."""
    n_steps = cfg.ddim.num_inference_steps
    reqs = _requests(cfg, 4)
    state, imgs = _drain(eng, reqs, num_slots=2)
    assert len(imgs) == 4
    rows = np.asarray(jax.device_get(state.accum.rows))
    assert rows.sum() == 4 * n_steps         # every request, every step
    assert not bool(np.asarray(jax.device_get(state.active)).any())


def test_admit_cfg_contract(cfg, eng):
    state = eng.init_slots(2)
    toks = jnp.zeros((1, cfg.text.max_len), jnp.int32)
    with pytest.raises(ValueError, match="guidance_scale == 1.0"):
        eng.admit(state, 0, toks, jax.random.PRNGKey(0),
                  uncond_tokens=toks)
    cfg_g = dataclasses.replace(cfg, ddim=dataclasses.replace(
        cfg.ddim, guidance_scale=7.5))
    eng_g = DiffusionEngine(cfg_g, key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="requires classifier-free"):
        eng_g.admit(eng_g.init_slots(2), 0, toks, jax.random.PRNGKey(0))
    # a non-CFG state from another engine cannot take a CFG admit
    with pytest.raises(ValueError, match="slot state CFG mode"):
        eng_g.admit(state, 0, toks, jax.random.PRNGKey(0),
                    uncond_tokens=toks)


def test_init_slots_guards(cfg, smoke_mesh):
    eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0), mesh=smoke_mesh)
    with pytest.raises(ValueError, match="single-device"):
        eng.init_slots(2)
    eng2 = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="num_slots"):
        eng2.init_slots(0)


# ----------------------------------------------------------------------------
# Schedulers
# ----------------------------------------------------------------------------
def test_scheduler_continuous_matches_fixed_batch_bitwise(cfg):
    """Same trace through both schedulers: identical images AND identical
    energy headline (the continuous accumulator vs the one-shot batch
    stats aggregation)."""
    eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
    reqs_c = make_requests(cfg, 4)
    reqs_f = make_requests(cfg, 4)
    cont = ContinuousScheduler(eng, num_slots=2)
    cont.warmup()
    m_c = cont.run(reqs_c, ledger=True)
    m_c.pop("state")
    fixed = FixedBatchScheduler(eng, micro_batch=2)
    m_f = fixed.run(reqs_f, ledger=True)
    for rc, rf in zip(reqs_c, reqs_f):
        np.testing.assert_array_equal(rc.image, rf.image,
                                      err_msg=f"request {rc.rid}")
    assert m_c["energy"] == m_f["energy"]
    assert m_c["tips_low_ratio_per_iter"] == m_f["tips_low_ratio_per_iter"]
    assert m_c["latency_s"]["p95"] > 0 and m_f["latency_s"]["p95"] > 0


def test_scheduler_respects_arrival_gating(cfg):
    """A request arriving after the makespan-so-far cannot be admitted
    before its arrival time."""
    eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
    reqs = make_requests(cfg, 3)
    apply_trace(reqs, [0.0, 0.0, 0.35])
    cont = ContinuousScheduler(eng, num_slots=2)
    cont.warmup()
    cont.run(reqs)
    late = reqs[2]
    assert late.admitted_s >= 0.35
    assert late.finished_s > late.admitted_s
    assert all(r.image is not None for r in reqs)


def test_traces_are_deterministic():
    assert bursty_trace(6, 2, 0.5) == [0.0, 0.0, 0.5, 0.5, 1.0, 1.0]
    assert poisson_trace(5, 4.0, seed=3) == poisson_trace(5, 4.0, seed=3)
    assert poisson_trace(5, 4.0, seed=3) != poisson_trace(5, 4.0, seed=4)
