"""Denoiser-contract parity tests — DESIGN.md §11.

The contract under test, for BOTH registered families (``unet``, ``dit``):

  * ``make_denoiser`` resolves the family from the config type alone,
    the handle is frozen/hashable (it joins executable-cache keys), and
    ``layer_order`` matches the stats traversal the forward emits;
  * the PSSA/TIPS integer counters are BIT-IDENTICAL across
    ``reference`` and ``fused`` kernel routing at the default operating
    point — the fused Pallas path is an execution strategy, not a
    different computation (same contract bench_fused_attention pins);
  * the scanned engine reproduces the Python-loop pipeline on the same
    parameters (scan-vs-loop latents parity);
  * images served through the slot runtime are bit-identical to the
    one-shot engine, and the drained ``LedgerAccum`` headline equals the
    one-shot energy report (the §8 oracle, now family-generic);
  * knife-edge thresholds keep every counter input-sensitive (positive
    control: a different request set MUST move the counters, so the
    equalities above cannot pass vacuously).

Everything here drives the UNMODIFIED engine/sampler/stats/scheduler
spine — a family only plugs in via ``repro.diffusion.denoiser``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.precision import PrecisionPolicy
from repro.diffusion.denoiser import FAMILIES, family_of, make_denoiser
from repro.diffusion.dit import DiTConfig
from repro.diffusion.engine import DiffusionEngine
from repro.diffusion.pipeline import (PipelineConfig,
                                      StableDiffusionPipeline,
                                      energy_report_from_accum,
                                      energy_report_multi)
from repro.diffusion.stats import attn_layer_order
from repro.kernels.dispatch import KernelPolicy
from repro.launch.scheduler import make_requests


def _family_cfg(family: str) -> PipelineConfig:
    """Smoke pipeline for one family at the default operating point."""
    cfg = PipelineConfig.smoke()
    if family == "dit":
        cfg = dataclasses.replace(cfg, unet=DiTConfig().smoke())
    return cfg


def _knife_edge(cfg: PipelineConfig) -> PipelineConfig:
    """Thresholds at the actual smoke-model score scale.

    The untrained smoke models' near-uniform softmax rows saturate the
    counters at the paper operating point; ~1/T and ~1/text_len make
    every counter input-sensitive (same rationale as
    tests/test_continuous.py) so the slot-oracle equality below has
    teeth.  Knife-edge scores sit within fp noise of the threshold, so
    these configs pin SINGLE-routing contracts; the cross-routing
    bit-identity contract is defined at the default operating point
    (margins above fp reassociation — same as bench_fused_attention).
    """
    t = cfg.unet.attn_resolutions()[0] ** 2
    return dataclasses.replace(cfg, unet=dataclasses.replace(
        cfg.unet,
        pssa_threshold=1.0 / t,
        precision=PrecisionPolicy.fixed(
            threshold=1.0 / cfg.unet.text_len)))


@pytest.fixture(scope="module", params=FAMILIES)
def family(request):
    return request.param


@pytest.fixture(scope="module")
def cfg(family):
    return _family_cfg(family)


@pytest.fixture(scope="module")
def knife(cfg):
    return _knife_edge(cfg)


@pytest.fixture(scope="module")
def eng(knife):
    return DiffusionEngine(knife, key=jax.random.PRNGKey(0))


def _toks(cfg, batch=1, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed),
                              (batch, cfg.text.max_len), 0,
                              cfg.text.vocab_size)


def _counters(stats):
    """The counter leaves whose bit-identity we pin across routing.

    All PSSAStats fields plus the folded TIPS ``low_precision_ratio`` —
    the same set tests/test_dispatch.py pins.  The raw per-query ``cas``
    floats are NOT in the contract: the fused kernel's blocked softmax
    reassociates their reduction (they agree to fp tolerance only), and
    nothing downstream consumes them un-thresholded.
    """
    leaves = [jnp.asarray(x) for p in stats.pssa for x in p]
    leaves += [jnp.asarray(t.low_precision_ratio) for t in stats.tips]
    return leaves


# ----------------------------------------------------------------------------
# The handle itself
# ----------------------------------------------------------------------------
def test_make_denoiser_resolves_family(family, cfg):
    den = make_denoiser(cfg.unet)
    assert den.family == family == family_of(cfg.unet)
    assert den.cfg is cfg.unet
    # frozen/hashable: the handle can join executable-cache keys
    assert {den: 1}[make_denoiser(cfg.unet)] == 1
    # the canonical stats traversal comes from the config hook
    assert den.layer_order() == attn_layer_order(cfg.unet)
    assert len(den.layer_order()) > 0


def test_family_of_rejects_unknown_configs():
    with pytest.raises(TypeError):
        family_of(object())


def test_abstract_params_match_init(cfg):
    den = make_denoiser(cfg.unet)
    concrete = den.init_params(jax.random.PRNGKey(3))
    abstract = den.abstract_params()
    c_leaves = jax.tree_util.tree_leaves(concrete)
    a_leaves = jax.tree_util.tree_leaves(abstract)
    assert len(c_leaves) == len(a_leaves)
    for c, a in zip(c_leaves, a_leaves):
        assert c.shape == a.shape and c.dtype == a.dtype


# ----------------------------------------------------------------------------
# Kernel-routing bit-identity (reference | fused)
# ----------------------------------------------------------------------------
def test_counters_bit_identical_across_kernel_routing(cfg):
    outs = {}
    for routing in ("reference", "fused"):
        c = dataclasses.replace(cfg, unet=dataclasses.replace(
            cfg.unet,
            kernel_policy=getattr(KernelPolicy, routing)()))
        e = DiffusionEngine(c, key=jax.random.PRNGKey(0))
        outs[routing] = e.generate(_toks(cfg), jax.random.PRNGKey(2))
    ref = _counters(outs["reference"].stats)
    fus = _counters(outs["fused"].stats)
    assert len(ref) == len(fus)
    for a, b in zip(ref, fus):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------------------
# Scan-vs-loop parity
# ----------------------------------------------------------------------------
def test_scan_engine_matches_python_loop_pipeline(cfg):
    pipe = StableDiffusionPipeline(cfg, key=jax.random.PRNGKey(0))
    e = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))  # same params
    toks = _toks(cfg)
    img_loop, _ = pipe.generate(toks, jax.random.PRNGKey(2))
    out = e.generate(toks, jax.random.PRNGKey(2))
    assert out.images.shape == img_loop.shape
    assert bool(jnp.all(jnp.isfinite(out.images)))
    # eager loop vs scanned-jit execution reassociates fp ops
    np.testing.assert_allclose(np.asarray(out.images),
                               np.asarray(img_loop), rtol=1e-3, atol=1e-3)


# ----------------------------------------------------------------------------
# Slot-vs-one-shot oracle (images + banked ledger)
# ----------------------------------------------------------------------------
def _drain(eng, requests, num_slots):
    """Serve all requests through the slot runtime; (state, images)."""
    queue = list(range(len(requests)))
    owner, images = {}, {}
    state = eng.init_slots(num_slots)

    def fill(state):
        for s in range(num_slots):
            if s not in owner and queue:
                r = requests[queue.pop(0)]
                state = eng.admit(state, s, r.tokens, None,
                                  uncond_tokens=r.uncond_tokens,
                                  latents=r.latents)
                owner[s] = r
        return state

    state = fill(state)
    while owner:
        state = eng.slot_step(state)
        done = eng.finished_slots(state)
        if done:
            decoded = np.asarray(jax.device_get(
                eng.decode_slots(state, done)))
            for j, s in enumerate(done):
                images[owner.pop(s).rid] = decoded[j]
            state = eng.retire(state, done)
            state = fill(state)
    return state, images


def test_slot_runtime_matches_one_shot_oracle(knife):
    cfg_g = dataclasses.replace(knife, ddim=dataclasses.replace(
        knife.ddim, guidance_scale=7.5))      # CFG rows exercise cfg_dup
    e = DiffusionEngine(cfg_g, key=jax.random.PRNGKey(0))
    reqs = make_requests(cfg_g, 4)
    assert reqs[0].uncond_tokens is not None

    # one-shot oracle: one generate call over all four requests
    toks = jnp.concatenate([r.tokens for r in reqs], axis=0)
    lats = jnp.concatenate([r.latents for r in reqs], axis=0)
    uncond = jnp.concatenate([r.uncond_tokens for r in reqs], axis=0)
    out = e.generate(toks, None, uncond_tokens=uncond, latents=lats)
    ref_imgs = np.asarray(out.images)
    ref_rep = energy_report_multi(cfg_g, [out.stats]).summary()

    state, imgs = _drain(e, reqs, num_slots=3)   # uneven drain at the tail
    for j, r in enumerate(reqs):
        np.testing.assert_array_equal(imgs[r.rid], ref_imgs[j],
                                      err_msg=f"request {r.rid}")
    rep = energy_report_from_accum(cfg_g, state.accum).summary()
    assert rep == ref_rep


# ----------------------------------------------------------------------------
# Positive control: the knife edge keeps the counters input-sensitive
# ----------------------------------------------------------------------------
def test_knife_edge_counters_are_input_sensitive(knife, eng):
    a = eng.generate(_toks(knife, seed=7), jax.random.PRNGKey(2))
    b = eng.generate(_toks(knife, seed=23), jax.random.PRNGKey(3))
    nnz_a = np.concatenate(
        [np.asarray(p.nnz).ravel() for p in a.stats.pssa])
    nnz_b = np.concatenate(
        [np.asarray(p.nnz).ravel() for p in b.stats.pssa])
    assert not np.array_equal(nnz_a, nnz_b)
