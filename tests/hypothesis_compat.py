"""Import hypothesis, or stub it so modules still collect without it.

The tier-1 container does not ship ``hypothesis`` (it is declared in
``requirements-test.txt`` / the ``test`` extra for CI and dev machines).
Importing it unguarded made four test modules ERROR at collection and took
the whole suite down with ``-x``.  This shim keeps the property tests as
first-class hypothesis tests when the library is present, and degrades them
to individually-skipped tests — without hiding the modules' plain unit
tests — when it is not.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """Answers any strategy constructor with a placeholder."""

        def __getattr__(self, name):
            def strategy(*_args, **_kwargs):
                return None
            return strategy

    st = _StrategyStub()
