"""PSSA unit + property tests (paper §III)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import pssa


def _softmax_rows(key, shape, temp=3.0):
    return jax.nn.softmax(jax.random.normal(key, shape) * temp, axis=-1)


# ----------------------------------------------------------------------------
# Lossless round trip (the compression must be exact on the pruned SAS)
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("patch", [16, 32, 64])
@pytest.mark.parametrize("shape", [(64, 64), (2, 128, 128), (2, 2, 64, 128)])
def test_compress_decompress_lossless(patch, shape):
    if shape[-1] % patch:
        pytest.skip("patch must divide Tk")
    sas = _softmax_rows(jax.random.PRNGKey(0), shape)
    rec = pssa.compress_decompress(sas, patch)
    np.testing.assert_array_equal(np.asarray(rec),
                                  np.asarray(pssa.prune(sas)))


@given(patch_log=st.integers(0, 2), seed=st.integers(0, 2 ** 16),
       temp=st.floats(0.5, 8.0))
@settings(max_examples=25, deadline=None)
def test_xor_unxor_roundtrip_property(patch_log, seed, temp):
    """patch_unxor(patch_xor(b)) == b for any bitmap (hypothesis sweep)."""
    patch = 16 << patch_log
    sas = _softmax_rows(jax.random.PRNGKey(seed), (32, 64), temp)
    bm = pssa.bitmap(pssa.prune(sas))
    rec = pssa.patch_unxor(pssa.patch_xor(bm, patch), patch)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(bm))


# ----------------------------------------------------------------------------
# Mechanism: XOR of similar adjacent patches increases bitmap sparsity
# ----------------------------------------------------------------------------
def test_xor_reduces_ones_for_similar_patches():
    """Adjacent-row similarity (the paper's Fig. 3(a) premise) must make the
    XOR'd bitmap sparser than the raw bitmap."""
    key = jax.random.PRNGKey(1)
    w = 64
    base = jax.random.normal(key, (w, w)) * 3.0
    # adjacent patches similar: each patch = base + small noise
    patches = [base + 0.1 * jax.random.normal(jax.random.PRNGKey(i), (w, w))
               for i in range(4)]
    sas = jax.nn.softmax(jnp.concatenate(patches, axis=-1), axis=-1)
    bm = pssa.bitmap(pssa.prune(sas))
    xbm = pssa.patch_xor(bm, w)
    assert int(jnp.sum(xbm)) < int(jnp.sum(bm))


def test_xor_no_benefit_for_independent_patches():
    """Independent patches: XOR ~doubles-ish the ones — documents the
    failure mode the paper's locality argument avoids."""
    sas = _softmax_rows(jax.random.PRNGKey(2), (64, 256), temp=4.0)
    bm = pssa.bitmap(pssa.prune(sas))
    xbm = pssa.patch_xor(bm, 64)
    # not a win (allow equality noise)
    assert int(jnp.sum(xbm)) >= int(jnp.sum(bm)) * 0.9


# ----------------------------------------------------------------------------
# Byte accounting
# ----------------------------------------------------------------------------
def test_compress_stats_bytes_exact():
    sas = _softmax_rows(jax.random.PRNGKey(3), (128, 128), temp=5.0)
    st_ = pssa.compress_stats(sas, patch=32)
    bm = pssa.bitmap(pssa.prune(sas))
    assert float(st_.nnz) == float(jnp.sum(bm))
    assert float(st_.total) == 128 * 128
    assert float(st_.bytes_baseline) == 128 * 128 * 1.5
    assert float(st_.bytes_values) == float(jnp.sum(bm)) * 1.5
    # PSSA total = values + index
    assert float(st_.bytes_pssa_total) == pytest.approx(
        float(st_.bytes_values) + float(st_.bytes_index_pssa))


def test_local_csr_beats_global_csr_on_sparse_similar():
    """Paper claim: local per-patch CSR beats global CSR (index overhead)."""
    w = 64
    base = jax.random.normal(jax.random.PRNGKey(4), (w, w)) * 5.0
    patches = [base + 0.05 * jax.random.normal(jax.random.PRNGKey(10 + i),
                                               (w, w)) for i in range(8)]
    sas = jax.nn.softmax(jnp.concatenate(patches, axis=-1), axis=-1)
    st_ = pssa.compress_stats(sas, patch=w)
    assert float(st_.bytes_index_pssa) < float(st_.bytes_index_csr_global)


def test_prune_threshold_semantics():
    tau = pssa.DEFAULT_THRESHOLD
    sas = jnp.array([[tau / 2, 0.5, tau, 2 * tau]])
    out = pssa.prune(sas)
    np.testing.assert_array_equal(
        np.asarray(out != 0), [[False, True, True, True]])


@given(seed=st.integers(0, 2 ** 16))
@settings(max_examples=20, deadline=None)
def test_ema_reduction_bounded(seed):
    sas = _softmax_rows(jax.random.PRNGKey(seed), (64, 64), temp=6.0)
    st_ = pssa.compress_stats(sas, patch=16)
    red = float(pssa.ema_reduction(st_))
    assert red <= 1.0  # can be negative for dense SAS (honest accounting)
