"""Fused SSD Pallas kernel: shape sweeps + allclose vs oracle + model parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan.kernel import ssd_scan_kernel
from repro.kernels.ssd_scan.ops import ssd_scan_fused
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models import ssm as SSM


def _inputs(key, bh, t, p, n):
    x = jax.random.normal(key, (bh, t, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (bh, t)))
    B = jax.random.normal(jax.random.fold_in(key, 2), (bh, t, n)) * 0.3
    C = jax.random.normal(jax.random.fold_in(key, 3), (bh, t, n)) * 0.3
    return x, -dt, B, C        # dA = dt * A with A = -1


@pytest.mark.parametrize("bh,t,p,n", [(2, 128, 16, 32), (4, 256, 64, 128),
                                      (1, 512, 32, 16)])
@pytest.mark.parametrize("chunk", [32, 64, 128])
def test_kernel_matches_sequential_oracle(bh, t, p, n, chunk):
    x, dA, B, C = _inputs(jax.random.PRNGKey(0), bh, t, p, n)
    y, s = ssd_scan_kernel(x, dA, B, C, chunk=chunk)
    yr, sr = ssd_scan_ref(x, dA, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=2e-4, atol=2e-4)


def test_fused_op_matches_model_ssd_scan():
    """The model's chunked jnp SSD and the fused kernel agree."""
    b, t, h, p, n = 2, 128, 3, 16, 8
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (b, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, t, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.1)
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, t, n)) * 0.3
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, t, n)) * 0.3

    y_model, s_model = SSM.ssd_scan(x.astype(jnp.float32), dt, A,
                                    B.astype(jnp.float32),
                                    C.astype(jnp.float32), chunk=32)
    y_fused, s_fused = ssd_scan_fused(x, dt, A, B, C, chunk=32)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_model),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(s_fused), np.asarray(s_model),
                               rtol=5e-3, atol=5e-3)


def test_kernel_state_carry_across_chunks():
    """Chunk boundaries must be invisible: chunk=T vs chunk=T/4 identical."""
    x, dA, B, C = _inputs(jax.random.PRNGKey(2), 2, 256, 16, 16)
    y1, s1 = ssd_scan_kernel(x, dA, B, C, chunk=256)
    y2, s2 = ssd_scan_kernel(x, dA, B, C, chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_kernel_decode_consistency():
    """Full-sequence kernel output at the last step == running the O(1)
    recurrent decode over the sequence (SSD duality)."""
    x, dA, B, C = _inputs(jax.random.PRNGKey(3), 1, 64, 8, 8)
    y, s = ssd_scan_kernel(x, dA, B, C, chunk=32)
    # sequential decode
    state = jnp.zeros((8, 8))
    for i in range(64):
        state = jnp.exp(dA[0, i]) * state + jnp.outer(x[0, i], B[0, i])
    np.testing.assert_allclose(np.asarray(s[0]), np.asarray(state),
                               rtol=2e-4, atol=2e-4)
