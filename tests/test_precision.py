"""PrecisionPolicy + fused cross-attention TIPS tests (DESIGN.md §7).

The contract under test:

  * ``PrecisionPolicy`` is the single source of TIPS/DBSC precision truth:
    it selects fixed vs per-sample adaptive spotting, extends the FFN mask
    to the second matmul (``ffn_mid``), parses from the ``--tips`` CLI
    spec, and participates in the engine's executable-cache key (a policy
    change retraces);
  * the fused cross-attention path — blocked Pallas kernel, CAS side
    output — produces outputs within fp tolerance of the materializing
    reference and precision DECISIONS that are BIT-IDENTICAL: the
    importance mask, the low-precision ratio, and every ledger term
    derived from them.  The raw CAS is ulp-identical (the reference is
    not bitwise stable against itself across jit contexts, so bitwise
    equality is defined on the threshold decisions, which only flip on
    exact fp ties — same empirical contract as the PSSA counter equality
    of DESIGN.md §5);
  * no (…, Tq, Tk_text) probability tensor is materialized anywhere on
    the fused path (asserted on the jaxpr, with a positive control);
  * ``quantize_act`` scales from the positive range only (unsigned
    datapath) — negatives can't inflate the INT12/INT6 grid.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision as P
from repro.core import quant, tips
from repro.core.attention import (cross_attention_tips,
                                  cross_attention_tips_fused)
from repro.core.precision import PrecisionPolicy, spot_cas
from repro.diffusion import ledger as L
from repro.diffusion.engine import DiffusionEngine
from repro.diffusion.pipeline import PipelineConfig, energy_report
from repro.diffusion.sampler import sample_scan
from repro.diffusion.unet import init_unet_params, unet_forward
from repro.kernels import dispatch
from repro.kernels.dispatch import KernelPolicy

from test_dispatch import _avals_in

FIXED_KNIFE = PrecisionPolicy(threshold=1.0 / 8)   # near the smoke CAS mean
ADAPTIVE = PrecisionPolicy.adaptive()

CROSS_FUSED = KernelPolicy(cross_attention="fused")


def _ca_inputs(b=2, h=4, tq=64, d=16, tk=8, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, h, tq, d))
    k = jax.random.normal(ks[1], (b, h, tk, d))
    v = jax.random.normal(ks[2], (b, h, tk, d))
    return q, k, v


def _assert_decisions_bit_equal(a: tips.TIPSResult, b: tips.TIPSResult):
    """Mask + low ratio exactly equal; CAS within ulps (see module doc)."""
    np.testing.assert_array_equal(np.asarray(a.important),
                                  np.asarray(b.important))
    np.testing.assert_array_equal(np.asarray(a.low_precision_ratio),
                                  np.asarray(b.low_precision_ratio))
    np.testing.assert_allclose(np.asarray(a.cas), np.asarray(b.cas),
                               rtol=0, atol=5e-7)


# ----------------------------------------------------------------------------
# PrecisionPolicy
# ----------------------------------------------------------------------------
def test_policy_presets_parse_and_validate():
    assert PrecisionPolicy.fixed() == PrecisionPolicy()
    assert PrecisionPolicy.adaptive().spotting == "adaptive"
    pol = PrecisionPolicy.parse("adaptive,target=0.5,mid=true")
    assert (pol.spotting, pol.target_low_ratio, pol.ffn_mid) == \
        ("adaptive", 0.5, True)
    assert PrecisionPolicy.parse("fixed") == PrecisionPolicy()
    assert PrecisionPolicy.parse("threshold=0.02").threshold == 0.02
    assert PrecisionPolicy.parse("cls=1").cls_index == 1
    with pytest.raises(ValueError):
        PrecisionPolicy.parse("warp=9")
    with pytest.raises(ValueError):
        PrecisionPolicy.parse("bogus")
    with pytest.raises(ValueError):
        PrecisionPolicy.parse("mid=maybe")
    with pytest.raises(ValueError):
        PrecisionPolicy(spotting="nope")
    with pytest.raises(ValueError):
        PrecisionPolicy(target_low_ratio=1.5)
    with pytest.raises(ValueError):       # CAS cut is a probability
        PrecisionPolicy(threshold=-0.05)
    desc = PrecisionPolicy.adaptive().describe()
    assert desc["spotting"] == "adaptive" and "ffn_mid" in desc


def test_spot_cas_fixed_matches_tips_spot():
    """Fixed spotting on head-averaged CAS == the seed's ``tips.spot``."""
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(3), (2, 4, 64, 8)) * 2, -1)
    seed = tips.spot(probs, threshold=0.1)
    cas = jnp.mean(probs[..., :, 0], axis=-2)
    new = spot_cas(cas, PrecisionPolicy(threshold=0.1))
    np.testing.assert_array_equal(np.asarray(new.important),
                                  np.asarray(seed.important))
    np.testing.assert_array_equal(np.asarray(new.cas), np.asarray(seed.cas))
    np.testing.assert_array_equal(np.asarray(new.low_precision_ratio),
                                  np.asarray(seed.low_precision_ratio))


def test_adaptive_spotting_realizes_target_per_sample():
    cas = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(4), (4, 256)), -1)
    res = spot_cas(cas, PrecisionPolicy.adaptive(0.448))
    per_sample = 1.0 - np.asarray(res.important).mean(axis=-1)
    assert np.allclose(per_sample, 0.448, atol=0.02)        # every sample
    # per-sample quantile => batch composition can't change a sample's map
    half = spot_cas(cas[:2], PrecisionPolicy.adaptive(0.448))
    np.testing.assert_array_equal(np.asarray(res.important[:2]),
                                  np.asarray(half.important))


# ----------------------------------------------------------------------------
# Fused cross-attention parity (op level)
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("geom", [(2, 4, 64, 16, 8), (1, 4, 256, 8, 12),
                                  (2, 8, 100, 40, 77)])
@pytest.mark.parametrize("policy", [FIXED_KNIFE, ADAPTIVE],
                         ids=["fixed", "adaptive"])
def test_cross_attention_fused_matches_reference(geom, policy):
    q, k, v = _ca_inputs(*geom)
    ref = cross_attention_tips(q, k, v, precision=policy)
    fused = cross_attention_tips_fused(q, k, v, precision=policy)
    np.testing.assert_allclose(np.asarray(fused.out), np.asarray(ref.out),
                               rtol=2e-5, atol=2e-5)
    _assert_decisions_bit_equal(fused.tips_result, ref.tips_result)
    np.testing.assert_array_equal(np.asarray(fused.important_full),
                                  np.asarray(ref.important_full))


def test_cross_attention_fused_stats_rows_matches_cond_only_call():
    q, k, v = _ca_inputs(b=4)
    full = cross_attention_tips_fused(q, k, v, precision=ADAPTIVE,
                                      stats_rows=2)
    cond = cross_attention_tips_fused(q[:2], k[:2], v[:2],
                                      precision=ADAPTIVE)
    _assert_decisions_bit_equal(full.tips_result, cond.tips_result)
    # the FFN mask still covers the full batch
    assert full.important_full.shape[0] == 4


def test_cross_attention_fused_under_vmap():
    q, k, v = _ca_inputs(b=3, h=2, tq=64, d=16, tk=8)
    fn = lambda a, b, c: cross_attention_tips_fused(
        a[None], b[None], c[None], precision=FIXED_KNIFE)
    mapped = jax.vmap(fn)(q, k, v)
    for i in range(q.shape[0]):
        one = fn(q[i], k[i], v[i])
        np.testing.assert_allclose(np.asarray(mapped.out[i]),
                                   np.asarray(one.out),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_array_equal(
            np.asarray(mapped.tips_result.important[i]),
            np.asarray(one.tips_result.important))


# ----------------------------------------------------------------------------
# Through the UNet / sampler / engine
# ----------------------------------------------------------------------------
@pytest.fixture(scope="module")
def smoke_setup():
    cfg = PipelineConfig.smoke()
    params = init_unet_params(jax.random.PRNGKey(42), cfg.unet)
    return cfg, params


def _unet_io(cfg, batch=1):
    s = cfg.unet.latent_size
    lat = jax.random.normal(jax.random.PRNGKey(0), (batch, s, s, 4))
    ctx = jax.random.normal(jax.random.PRNGKey(1),
                            (batch, cfg.unet.text_len, cfg.unet.context_dim))
    return lat, ctx


@pytest.mark.parametrize("policy", [FIXED_KNIFE, ADAPTIVE],
                         ids=["fixed", "adaptive"])
def test_unet_forward_cross_fused_parity(smoke_setup, policy):
    """cross_attention=fused alone: TIPS decisions bit-equal, PSSA
    untouched (the self-attention path is identical)."""
    cfg, params = smoke_setup
    lat, ctx = _unet_io(cfg)
    tvec = jnp.array([500])
    u_ref = dataclasses.replace(cfg.unet, precision=policy)
    u_fused = dataclasses.replace(u_ref, kernel_policy=CROSS_FUSED)
    eps_r, st_r = unet_forward(params, lat, tvec, ctx, u_ref)
    eps_f, st_f = unet_forward(params, lat, tvec, ctx, u_fused)
    np.testing.assert_allclose(np.asarray(eps_f), np.asarray(eps_r),
                               rtol=1e-3, atol=1e-3)
    for a, b in zip(st_f.tips, st_r.tips):
        _assert_decisions_bit_equal(a, b)
    for a, b in zip(st_f.pssa, st_r.pssa):
        for name, x, y in zip(a._fields, a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"PSSAStats.{name}")


@pytest.mark.parametrize("policy", [FIXED_KNIFE, ADAPTIVE],
                         ids=["fixed", "adaptive"])
def test_sample_scan_cross_fused_parity(smoke_setup, policy):
    cfg, params = smoke_setup
    lat, ctx = _unet_io(cfg)

    def apply(ucfg):
        def unet_apply(l, t, c, act, stats_rows=None, cfg_dup=False):
            return unet_forward(params, l, t, c, ucfg, tips_active=act,
                                stats_rows=stats_rows, cfg_dup=cfg_dup)
        return unet_apply

    u_ref = dataclasses.replace(cfg.unet, precision=policy)
    u_fused = dataclasses.replace(u_ref, kernel_policy=CROSS_FUSED)
    lat_r, st_r = sample_scan(apply(u_ref), lat, ctx, None, cfg.ddim)
    lat_f, st_f = sample_scan(apply(u_fused), lat, ctx, None, cfg.ddim)
    np.testing.assert_allclose(np.asarray(lat_f), np.asarray(lat_r),
                               rtol=2e-3, atol=2e-3)
    for a, b in zip(st_f.tips, st_r.tips):      # stacked across all steps
        np.testing.assert_array_equal(np.asarray(a.important),
                                      np.asarray(b.important))
        np.testing.assert_array_equal(np.asarray(a.low_precision_ratio),
                                      np.asarray(b.low_precision_ratio))


def test_engine_fused_cfg_adaptive_parity(smoke_setup):
    """Fused cross-attention composes with fused-CFG prefix dedup under an
    adaptive policy: cond-half TIPS accounting and the energy headline are
    bit-identical to the reference routing."""
    cfg, _ = smoke_setup
    cfg = dataclasses.replace(cfg, ddim=dataclasses.replace(
        cfg.ddim, guidance_scale=7.5))
    key = jax.random.PRNGKey(7)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, cfg.text.max_len),
                              0, cfg.text.vocab_size)
    un = jnp.zeros_like(toks)
    s = cfg.unet.latent_size
    lat0 = jax.random.normal(jax.random.PRNGKey(2), (1, s, s, 4))
    eng_r = DiffusionEngine(cfg, key=key, precision_policy=ADAPTIVE)
    eng_f = DiffusionEngine(cfg, key=key, precision_policy=ADAPTIVE,
                            kernel_policy=CROSS_FUSED)
    out_r = eng_r.generate(toks, None, uncond_tokens=un, latents=lat0.copy())
    out_f = eng_f.generate(toks, None, uncond_tokens=un, latents=lat0.copy())
    np.testing.assert_allclose(np.asarray(out_f.latents),
                               np.asarray(out_r.latents),
                               rtol=2e-2, atol=2e-2)
    for a, b in zip(out_f.stats.tips, out_r.stats.tips):
        np.testing.assert_array_equal(np.asarray(a.important),
                                      np.asarray(b.important))
        np.testing.assert_array_equal(np.asarray(a.low_precision_ratio),
                                      np.asarray(b.low_precision_ratio))
    rep_r = energy_report(eng_r.cfg, out_r.stats).summary()
    rep_f = energy_report(eng_f.cfg, out_f.stats).summary()
    assert rep_f == rep_r


def test_engine_cache_retraces_on_precision_change(smoke_setup):
    cfg, _ = smoke_setup
    eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, cfg.text.max_len),
                              0, cfg.text.vocab_size)
    eng.generate(toks, jax.random.PRNGKey(2))
    assert len(eng._compiled) == 1
    assert list(eng._compiled)[0][3] is None    # mesh slot stays position 3
    eng.generate(toks, jax.random.PRNGKey(3))
    assert len(eng._compiled) == 1              # same policy: cached
    eng.set_precision(PrecisionPolicy.adaptive())
    out = eng.generate(toks, jax.random.PRNGKey(4))
    assert len(eng._compiled) == 2              # policy change: retraced
    # adaptive spotting realizes its target on the new executable
    low = float(np.asarray(out.stats.tips[0].low_precision_ratio)[0])
    assert low == pytest.approx(0.448, abs=0.05)


def test_effective_precision_folds_legacy_threshold(smoke_setup):
    cfg, _ = smoke_setup
    u = dataclasses.replace(cfg.unet, tips_threshold=0.125)
    assert u.effective_precision().threshold == 0.125
    # an explicitly-set policy wins over the legacy knob
    u2 = dataclasses.replace(u, precision=PrecisionPolicy(threshold=0.3))
    assert u2.effective_precision().threshold == 0.3
    u3 = dataclasses.replace(u, precision=PrecisionPolicy.adaptive())
    assert u3.effective_precision().spotting == "adaptive"


# ----------------------------------------------------------------------------
# The point of the kernel: no (…, Tq, Tk_text) probs on the fused path
# ----------------------------------------------------------------------------
def _materializes_probs(cfg_unet, params, tq, tk):
    lat = jax.random.normal(jax.random.PRNGKey(0),
                            (1, cfg_unet.latent_size,
                             cfg_unet.latent_size, 4))
    ctx = jax.random.normal(jax.random.PRNGKey(1),
                            (1, cfg_unet.text_len, cfg_unet.context_dim))
    jaxpr = jax.make_jaxpr(
        lambda p, l, c: unet_forward(p, l, jnp.array([500]), c, cfg_unet))(
        params, lat, ctx)
    return any(getattr(a, "shape", ())[-2:] == (tq, tk)
               for a in _avals_in(jaxpr))


def test_no_probs_materialized_on_fused_cross_path():
    # text_len=12 de-aliases Tk from the smoke head dims (8/16): only a
    # cross-attention probability tensor can end in (T, 12)
    ucfg = dataclasses.replace(PipelineConfig.smoke().unet, text_len=12)
    params = init_unet_params(jax.random.PRNGKey(42), ucfg)
    t_big = ucfg.latent_size ** 2          # largest cross-attention Tq
    # positive control: the reference path DOES materialize (…, T, 12)
    assert _materializes_probs(ucfg, params, t_big, 12)
    fused = dataclasses.replace(ucfg, kernel_policy=CROSS_FUSED)
    assert not _materializes_probs(fused, params, t_big, 12)


# ----------------------------------------------------------------------------
# ffn_mid: second-matmul TIPS coverage
# ----------------------------------------------------------------------------
def _ffn_weights(c=32, dff=64, seed=5):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    s = 1.0 / np.sqrt(c)
    return {
        "ff_geglu": {"w": jax.random.uniform(ks[0], (c, 2 * dff),
                                             jnp.float32, -s, s),
                     "b": jnp.zeros((2 * dff,))},
        "ff_out": {"w": jax.random.uniform(ks[1], (dff, c),
                                           jnp.float32, -s, s),
                   "b": jnp.zeros((c,))},
    }


def test_ffn_mid_coverage_dbsc_matches_reference():
    hn = jax.random.normal(jax.random.PRNGKey(6), (2, 64, 32))
    p = _ffn_weights()
    imp = jnp.zeros((2, 64), bool).at[:, :32].set(True)
    mid_on = PrecisionPolicy(ffn_mid=True)
    ref = dispatch.ffn_geglu(KernelPolicy(), hn, p, imp, precision=mid_on)
    dbsc = dispatch.ffn_geglu(KernelPolicy(ffn="dbsc"), hn, p, imp,
                              precision=mid_on)
    # DBSC quantizes weights to INT8 on top of the activation grid
    rel = float(jnp.max(jnp.abs(dbsc - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 0.05


@pytest.mark.parametrize("ffn", ["reference", "dbsc"])
def test_ffn_mid_changes_only_unimportant_rows(ffn):
    hn = jax.random.normal(jax.random.PRNGKey(7), (1, 64, 32))
    p = _ffn_weights()
    pol = KernelPolicy(ffn=ffn)
    imp_half = jnp.zeros((1, 64), bool).at[:, :32].set(True)
    off = dispatch.ffn_geglu(pol, hn, p, imp_half,
                             precision=PrecisionPolicy(ffn_mid=False))
    on = dispatch.ffn_geglu(pol, hn, p, imp_half,
                            precision=PrecisionPolicy(ffn_mid=True))
    assert not np.allclose(np.asarray(off), np.asarray(on))
    if ffn == "dbsc":
        # the DBSC second matmul quantizes mid at INT12 regardless; with
        # every row important the mid mask is exactly that — a no-op
        # (on the float reference ffn_mid=True additionally INT12
        # round-trips the mid activations, so no such identity holds)
        imp_all = jnp.ones((1, 64), bool)
        off_all = dispatch.ffn_geglu(pol, hn, p, imp_all,
                                     precision=PrecisionPolicy(ffn_mid=False))
        on_all = dispatch.ffn_geglu(pol, hn, p, imp_all,
                                    precision=PrecisionPolicy(ffn_mid=True))
        np.testing.assert_array_equal(np.asarray(off_all),
                                      np.asarray(on_all))


def test_ledger_tips_mid_macs_split():
    """tips_mid=False: only the up projection (2/3 of FFN MACs) splits."""
    from repro.diffusion.unet import BK_SDM_TINY
    base = sum(l.macs_high for l in L.unet_ledger(BK_SDM_TINY)
               if l.stage == "ffn")
    led = L.unet_ledger(BK_SDM_TINY, L.LedgerOptions(
        tips=True, tips_low_ratio=0.448, tips_mid=False))
    hi = sum(l.macs_high for l in led if l.stage == "ffn")
    lo = sum(l.macs_low for l in led if l.stage == "ffn")
    assert lo == pytest.approx(base * 0.448 * (2.0 / 3.0), rel=1e-6)
    assert hi + lo == pytest.approx(base, rel=1e-12)    # MAC conservation
    # tips_mid=True (default) keeps the paper's whole-FFN split
    led_mid = L.unet_ledger(BK_SDM_TINY, L.LedgerOptions(
        tips=True, tips_low_ratio=0.448))
    lo_mid = sum(l.macs_low for l in led_mid if l.stage == "ffn")
    assert lo_mid == pytest.approx(base * 0.448, rel=1e-6)


def test_energy_report_respects_ffn_mid(smoke_setup):
    """More mask coverage -> more INT6 MACs -> lower compute energy."""
    cfg, params = smoke_setup
    lat, ctx = _unet_io(cfg)
    _, stats = unet_forward(params, lat, jnp.array([500]), ctx, cfg.unet)
    stats_list = [stats] * cfg.ddim.num_inference_steps
    cfg_off = dataclasses.replace(cfg, unet=dataclasses.replace(
        cfg.unet, precision=PrecisionPolicy(ffn_mid=False)))
    cfg_on = dataclasses.replace(cfg, unet=dataclasses.replace(
        cfg.unet, precision=PrecisionPolicy(ffn_mid=True)))
    rep_off = energy_report(cfg_off, stats_list)
    rep_on = energy_report(cfg_on, stats_list)
    assert rep_on.optimized.compute_energy_mj \
        < rep_off.optimized.compute_energy_mj


# ----------------------------------------------------------------------------
# quantize_act: unsigned datapath scale
# ----------------------------------------------------------------------------
def test_quantize_act_scale_ignores_negative_range():
    """Large negative pre-activations used to inflate the scale 8x; the
    unsigned grid must span the positive range only."""
    pos = jnp.linspace(0.0, 1.0, 64)
    neg = -8.0 * jnp.ones((64,))
    x = jnp.concatenate([pos, neg])
    q = quant.quantize_act(x, quant.ACT_BITS_HIGH)
    new_scale = 1.0 / quant.ACT_HIGH_MAX
    assert float(q.scale) == pytest.approx(new_scale, rel=1e-6)
    # round-trip error on the representable (positive) half is bounded by
    # the IMPROVED scale — 8x tighter than the seed's |x|-based scale
    err = float(jnp.max(jnp.abs(quant.dequantize(q)[:64] - pos)))
    old_scale = 8.0 / quant.ACT_HIGH_MAX
    assert err <= new_scale * 0.5 + 1e-7
    assert err < old_scale * 0.5                 # pins the improvement
    # negatives clip to zero — the unsigned datapath's semantics
    np.testing.assert_array_equal(np.asarray(q.values[64:]),
                                  np.zeros(64, np.int32))


def test_apply_precision_mask_scale_ignores_negative_range():
    """Per-sample TIPS quantization grid spans the positive range only."""
    x = jnp.concatenate([jnp.linspace(0.0, 1.0, 32)[None, :, None],
                         -5.0 * jnp.ones((1, 32, 1))], axis=1)
    imp = jnp.ones((1, 64), bool)
    y = tips.apply_precision_mask(x, imp)
    err = float(jnp.max(jnp.abs(y[:, :32] - x[:, :32])))
    assert err <= (1.0 / quant.ACT_HIGH_MAX) * 0.5 + 1e-7
