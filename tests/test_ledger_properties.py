"""Hypothesis property tests on the energy-ledger invariants."""
import jax.numpy as jnp
import pytest

from hypothesis_compat import given, settings, st

from repro.core import energy
from repro.core.tips import workload_low_precision_fraction
from repro.diffusion import ledger as L
from repro.diffusion.unet import BK_SDM_TINY, UNetConfig


@given(ratio=st.floats(0.0, 1.0))
@settings(max_examples=30, deadline=None)
def test_pssa_monotone_in_sas_ratio(ratio):
    """Total EMA is monotone in the SAS compression ratio, and never above
    the uncompressed baseline."""
    base = L.iteration_report(BK_SDM_TINY, L.LedgerOptions())
    opt = L.iteration_report(BK_SDM_TINY, L.LedgerOptions(
        pssa=True, sas_ratio={64: ratio, 32: ratio, 16: ratio}))
    assert opt.ema_bytes_total <= base.ema_bytes_total + 1e-6
    # exact linearity in the SELF-attention SAS share (PSSA does not touch
    # the cross-attention score traffic — paper §III is self-attention only)
    self_sas = sum(l.sas_bytes for l in L.unet_ledger(BK_SDM_TINY)
                   if l.stage == "self_attn")
    expect = base.ema_bytes_total - self_sas * (1.0 - ratio)
    assert opt.ema_bytes_total == pytest.approx(expect, rel=1e-9)


@given(low=st.floats(0.0, 1.0))
@settings(max_examples=30, deadline=None)
def test_tips_energy_monotone_in_low_ratio(low):
    rep = L.iteration_report(BK_SDM_TINY,
                             L.LedgerOptions(tips=True, tips_low_ratio=low))
    base = L.iteration_report(BK_SDM_TINY, L.LedgerOptions())
    assert rep.compute_energy_mj <= base.compute_energy_mj + 1e-9
    # MAC conservation: high + low == baseline total FFN MACs
    led = L.unet_ledger(BK_SDM_TINY,
                        L.LedgerOptions(tips=True, tips_low_ratio=low))
    led0 = L.unet_ledger(BK_SDM_TINY)
    ffn = sum(l.macs_high + l.macs_low for l in led if l.stage == "ffn")
    ffn0 = sum(l.macs_high for l in led0 if l.stage == "ffn")
    assert ffn == pytest.approx(ffn0, rel=1e-12)


@given(batch=st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_ledger_linear_in_batch(batch):
    r1 = L.iteration_report(BK_SDM_TINY, L.LedgerOptions(batch=1))
    rb = L.iteration_report(BK_SDM_TINY, L.LedgerOptions(batch=batch))
    # activations & SAS scale with batch; weights don't -> strictly between
    assert rb.ema_bytes_total <= batch * r1.ema_bytes_total + 1e-6
    assert rb.ema_bytes_total >= r1.ema_bytes_total - 1e-6


@given(active=st.integers(0, 25))
@settings(max_examples=26, deadline=None)
def test_workload_fraction_linear_in_schedule(active):
    ratios = jnp.array([0.5] * active + [0.0] * (25 - active))
    frac = float(workload_low_precision_fraction(ratios, active, 25))
    assert frac == pytest.approx(0.5 * active / 25, abs=1e-6)


def test_ledger_geometry_consistency_with_unet_params():
    """Ledger weight bytes == the real UNet parameter count (INT8 = 1 B) —
    the analytic walk and the actual module must describe the same model."""
    import jax
    from repro.diffusion.unet import abstract_unet_params
    led = L.unet_ledger(BK_SDM_TINY)
    w_bytes = sum(l.weight_bytes for l in led)
    aparams = abstract_unet_params(BK_SDM_TINY)
    n_params = sum(x.size for x in jax.tree.leaves(aparams))
    # ledger skips tiny biases/norm scales/time-MLP; agree within 4 %
    assert w_bytes == pytest.approx(n_params, rel=0.04)
