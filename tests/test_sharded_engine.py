"""Data-parallel mesh serving tests (DESIGN.md §6).

The contract under test:

  * a dp=1 mesh (``make_smoke_mesh``) is a pure placement change: images
    AND every stats leaf are bit-identical to the unsharded engine;
  * ``stats_rows`` masks padded tail rows out of the accounting at the
    source — garbage in the padded rows cannot move a single counter;
  * the executable cache is keyed on the mesh signature, so re-placing an
    engine (elastic resize) retraces instead of reusing stale executables;
  * the CFG contract raises on guidance/uncond mismatches instead of
    silently disabling guidance;
  * the serving front-end aggregates the energy ledger across ALL
    micro-batches with padded rows masked;
  * dp>1 execution on fake host devices (subprocess, own XLA_FLAGS)
    keeps integer PSSA counters bit-equal to the unsharded engine.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion.engine import DiffusionEngine
from repro.diffusion.pipeline import (PipelineConfig, energy_report,
                                      energy_report_multi)
from repro.launch.mesh import make_smoke_mesh, mesh_signature
from repro.launch import serve_diffusion as S

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def smoke_cfg():
    return PipelineConfig.smoke()


def _toks(cfg, batch=1, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed),
                              (batch, cfg.text.max_len), 0,
                              cfg.text.vocab_size)


def _lat(cfg, batch, seed=3):
    s = cfg.unet.latent_size
    return jax.random.normal(jax.random.PRNGKey(seed),
                             (batch, s, s, cfg.unet.in_channels))


def _assert_stats_equal(a, b):
    ab, bb = a.as_dict(), b.as_dict()
    for name, st in ab["pssa"].items():
        for f, x, y in zip(st._fields, st, bb["pssa"][name]):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"{name}.{f}")
    for name, tr in ab["tips"].items():
        np.testing.assert_array_equal(
            np.asarray(tr.low_precision_ratio),
            np.asarray(bb["tips"][name].low_precision_ratio),
            err_msg=f"{name}.low_precision_ratio")


# ----------------------------------------------------------------------------
# dp=1 mesh bit-parity
# ----------------------------------------------------------------------------
def test_dp1_mesh_bit_parity(smoke_cfg, smoke_mesh):
    cfg = smoke_cfg
    key = jax.random.PRNGKey(42)
    toks, lat = _toks(cfg, batch=2), _lat(cfg, 2)
    ref = DiffusionEngine(cfg, key=key).generate(toks, None,
                                                 latents=lat.copy())
    shd = DiffusionEngine(cfg, key=key, mesh=smoke_mesh).generate(
        toks, None, latents=lat.copy())
    np.testing.assert_array_equal(np.asarray(ref.images),
                                  np.asarray(shd.images))
    np.testing.assert_array_equal(np.asarray(ref.latents),
                                  np.asarray(shd.latents))
    _assert_stats_equal(ref.stats, shd.stats)


# ----------------------------------------------------------------------------
# Padded-row masking
# ----------------------------------------------------------------------------
def test_stats_rows_masks_padded_rows_exactly(smoke_cfg):
    """Same executable, same valid rows, different garbage in the padded
    tail -> EXACTLY the same stats (and valid-row images)."""
    # knife-edge thresholds: the untrained smoke model's near-uniform
    # softmax rows saturate the counters at the paper operating point
    # (~1/T vs 2^-13 prunes nothing; CAS vs 0.05 spots nothing), which
    # would make BOTH sides of this test trivially equal.  Thresholds at
    # the actual score scale (1/T, 1/text_len) make every counter
    # input-sensitive, so the positive control below has teeth.
    t = smoke_cfg.unet.latent_size ** 2
    cfg = dataclasses.replace(smoke_cfg, unet=dataclasses.replace(
        smoke_cfg.unet, pssa_threshold=1.0 / t,
        tips_threshold=1.0 / smoke_cfg.unet.text_len))
    eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
    valid = 2
    toks = _toks(cfg, batch=4, seed=1)
    lat = _lat(cfg, 4)
    toks_b = jnp.concatenate([toks[:valid], _toks(cfg, 2, seed=9)], axis=0)
    lat_b = jnp.concatenate([lat[:valid], _lat(cfg, 2, seed=11)], axis=0)

    out_a = eng.generate(toks, None, latents=lat.copy(), stats_rows=valid)
    out_b = eng.generate(toks_b, None, latents=lat_b, stats_rows=valid)
    _assert_stats_equal(out_a.stats, out_b.stats)
    np.testing.assert_array_equal(np.asarray(out_a.images[:valid]),
                                  np.asarray(out_b.images[:valid]))
    # positive control: WITHOUT the mask the garbage rows leak into stats
    out_c = eng.generate(toks, None, latents=lat.copy())
    out_d = eng.generate(toks_b, None,
                         latents=jnp.concatenate(
                             [lat[:valid], _lat(cfg, 2, seed=11)], axis=0))
    nnz_c = np.asarray([np.asarray(s.nnz) for s in out_c.stats.pssa])
    nnz_d = np.asarray([np.asarray(s.nnz) for s in out_d.stats.pssa])
    assert not np.array_equal(nnz_c, nnz_d)


def test_stats_rows_restricts_tips_rows(smoke_cfg):
    eng = DiffusionEngine(smoke_cfg, key=jax.random.PRNGKey(0))
    out = eng.generate(_toks(smoke_cfg, batch=4), jax.random.PRNGKey(2),
                       stats_rows=3)
    # stacked leaves: (num_steps, rows, Tq) — accounting covers 3 rows only
    assert out.stats.tips[0].important.shape[1] == 3


def test_stats_rows_out_of_range_raises(smoke_cfg):
    eng = DiffusionEngine(smoke_cfg, key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="stats_rows"):
        eng.generate(_toks(smoke_cfg, batch=2), jax.random.PRNGKey(0),
                     stats_rows=3)


# ----------------------------------------------------------------------------
# Executable-cache keying
# ----------------------------------------------------------------------------
def test_executable_cache_keys_on_mesh_signature(smoke_cfg, smoke_mesh):
    cfg = smoke_cfg
    eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
    eng.generate(_toks(cfg, batch=1), jax.random.PRNGKey(0))
    assert len(eng._compiled) == 1
    assert list(eng._compiled)[0][3] is None          # unsharded signature

    eng.place_on_mesh(smoke_mesh)
    eng.generate(_toks(cfg, batch=1), jax.random.PRNGKey(1))
    assert len(eng._compiled) == 2                    # retraced, not reused
    sig = mesh_signature(smoke_mesh)
    assert any(k[3] == sig for k in eng._compiled)

    eng.generate(_toks(cfg, batch=1, seed=5), jax.random.PRNGKey(2))
    assert len(eng._compiled) == 2                    # same signature: cached

    # distinct stats_rows is a distinct executable (static slice)
    eng.generate(_toks(cfg, batch=2), jax.random.PRNGKey(3))
    eng.generate(_toks(cfg, batch=2), jax.random.PRNGKey(4), stats_rows=1)
    assert len(eng._compiled) == 4


def test_mesh_signature_identity(smoke_mesh):
    assert mesh_signature(None) is None
    assert mesh_signature(smoke_mesh) == mesh_signature(make_smoke_mesh())
    names, sizes, devs = mesh_signature(smoke_mesh)
    assert names == ("data", "model") and sizes == (1, 1)


# ----------------------------------------------------------------------------
# CFG contract
# ----------------------------------------------------------------------------
def test_generate_raises_on_guidance_without_uncond(smoke_cfg):
    cfg = dataclasses.replace(smoke_cfg, ddim=dataclasses.replace(
        smoke_cfg.ddim, guidance_scale=7.5))
    eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="guidance_scale=7.5.*uncond"):
        eng.generate(_toks(cfg), jax.random.PRNGKey(0))


def test_generate_raises_on_uncond_without_guidance(smoke_cfg):
    eng = DiffusionEngine(smoke_cfg, key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="guidance_scale == 1.0"):
        eng.generate(_toks(smoke_cfg), jax.random.PRNGKey(0),
                     uncond_tokens=jnp.zeros_like(_toks(smoke_cfg)))


def test_warmup_respects_cfg_contract(smoke_cfg):
    cfg = dataclasses.replace(smoke_cfg, ddim=dataclasses.replace(
        smoke_cfg.ddim, guidance_scale=7.5))
    eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="uncond"):
        eng.warmup(1, use_cfg=False)      # config wants CFG; refuse
    eng2 = DiffusionEngine(smoke_cfg, key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="guidance_scale == 1.0"):
        eng2.warmup(1, use_cfg=True)      # config forbids CFG; refuse


def test_mesh_batch_divisibility_message(smoke_cfg, smoke_mesh):
    # dp=1 divides everything; fake a dp-2 engine to hit the guard
    eng = DiffusionEngine(smoke_cfg, key=jax.random.PRNGKey(0),
                          mesh=smoke_mesh)
    eng.dp_size = 2
    with pytest.raises(ValueError, match="multiple of the data-parallel"):
        eng.generate(_toks(smoke_cfg, batch=3), jax.random.PRNGKey(0))


# ----------------------------------------------------------------------------
# Serving: padded-tail ledger aggregation
# ----------------------------------------------------------------------------
def test_energy_report_multi_matches_single_batch(smoke_cfg):
    """Splitting one 3-row batch into 2+1 calls (the second padded to 2
    with stats_rows=1) gives the same aggregate report, up to the usual
    batch-tiling reassociation tolerance."""
    cfg = smoke_cfg
    eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
    toks, lat = _toks(cfg, batch=3), _lat(cfg, 3)

    ref = eng.generate(toks, None, latents=lat.copy())
    rep_ref = energy_report(cfg, ref.stats).summary()

    a = eng.generate(toks[:2], None, latents=lat[:2])
    toks_pad = jnp.concatenate([toks[2:], toks[2:]], axis=0)
    lat_pad = jnp.concatenate([lat[2:], lat[2:]], axis=0)
    b = eng.generate(toks_pad, None, latents=lat_pad, stats_rows=1)
    rep_multi = energy_report_multi(cfg, [a.stats, b.stats]).summary()
    for k in rep_ref:
        assert rep_multi[k] == pytest.approx(rep_ref[k], rel=1e-3), k

    # single-entry aggregation is exactly energy_report
    rep_one = energy_report_multi(cfg, [ref.stats]).summary()
    for k in rep_ref:
        assert rep_one[k] == pytest.approx(rep_ref[k], rel=1e-12), k


def test_serve_aggregates_ledger_and_masks_padding(smoke_cfg):
    reqs = S.synthetic_requests(smoke_cfg, 3)
    m = S.serve(smoke_cfg, reqs, micro_batch=2, ledger=True)
    assert m["requests"] == 3 and m["engine_calls"] == 2
    assert m["padded_rows"] == 1
    assert "energy" in m and m["energy"]["mj_per_iter_with_ema"] > 0
    # the run's 3-step schedule (2 active), not the paper's 20/25
    assert 0.0 <= m["tips_workload_low_fraction"] <= 2.0 / 3.0 + 1e-6


def test_serve_rounds_micro_batch_up_to_dp(smoke_cfg, smoke_mesh):
    reqs = S.synthetic_requests(smoke_cfg, 2)
    m = S.serve(smoke_cfg, reqs, micro_batch=2, mesh=smoke_mesh)
    assert m["mesh"] == {"dp": 1, "shape": {"data": 1, "model": 1},
                         "devices": 1}
    assert m["micro_batch"] == 2 and m["imgs_per_s"] > 0


# ----------------------------------------------------------------------------
# dp>1 on fake host devices (subprocess: needs its own XLA_FLAGS)
# ----------------------------------------------------------------------------
_DP_SCRIPT = r"""
from repro.launch.mesh import simulate_host_devices
simulate_host_devices(4)
import jax, jax.numpy as jnp, numpy as np
from repro.diffusion.engine import DiffusionEngine
from repro.diffusion.pipeline import PipelineConfig
from repro.launch.mesh import make_data_mesh

cfg = PipelineConfig.smoke()
key = jax.random.PRNGKey(42)
toks = jax.random.randint(jax.random.PRNGKey(7), (4, cfg.text.max_len), 0,
                          cfg.text.vocab_size)
s = cfg.unet.latent_size
lat = jax.random.normal(jax.random.PRNGKey(3), (4, s, s,
                                                cfg.unet.in_channels))
ref = DiffusionEngine(cfg, key=key).generate(toks, None, latents=lat.copy())
shd = DiffusionEngine(cfg, key=key, mesh=make_data_mesh(4)).generate(
    toks, None, latents=lat.copy())
# integer PSSA counters: bit-equal across placements (ledger drift-free)
for a, b in zip(ref.stats.pssa, shd.stats.pssa):
    assert np.array_equal(np.asarray(a.nnz), np.asarray(b.nnz))
    assert np.array_equal(np.asarray(a.bitmap_ones_xor),
                          np.asarray(b.bitmap_ones_xor))
# images: tight float agreement (XLA tiles per-shard batches differently,
# so bit-exactness across dp>1 placements is not an XLA guarantee)
d = float(np.abs(np.asarray(ref.images) - np.asarray(shd.images)).max())
assert d < 1e-4, d
assert len(jax.devices()) == 4
print("DP4_OK maxdiff", d)
"""


def test_dp4_fake_devices_counters_bit_equal():
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    r = subprocess.run([sys.executable, "-c", _DP_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "DP4_OK" in r.stdout, r.stdout + r.stderr
