"""Autotune + int8-datapath tests: table contract, block invariance, parity.

The contracts under test (DESIGN.md §12):

  * the autotune table key is a strict round-trip of (backend, op,
    geometry) in the dispatch layer's canonical field order; unknown
    geometries fall back to the policy's default blocks silently, while
    a PRESENT table that is malformed or version-stale raises a loud
    ``AutotuneTableError`` (a quietly ignored table would masquerade as
    a tuning regression);
  * block sizes are a pure wall-clock lever: PSSA/TIPS integer counters,
    images and the energy headline are bit-identical across tuned block
    configurations, including ragged non-block-multiple geometry;
  * ``KernelPolicy.ffn_quant="int8"`` routes the DBSC integer matmuls
    through real int8 x int8 -> int32 ``lax.dot_general`` with
    accumulators bit-identical to the modeled path (same integers,
    PE-shaped execution), so images and the energy ledger do not move;
    vs the FLOAT reference FFN the int8 image is only bounded (different
    scale semantics: per-sample fake-quant + f32 accumulation).
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.attention  # noqa: F401  (resolves the ops<->core cycle)
from repro.kernels import autotune, dispatch
from repro.kernels.autotune import AutotuneTableError
from repro.kernels.bitslice_matmul.ops import bitslice_matmul
from repro.kernels.bitslice_matmul.ref import (bitslice_matmul_int8,
                                               bitslice_matmul_ref)
from repro.kernels.dispatch import KernelPolicy
from repro.kernels.pssa_attention.ops import pssa_attention
from repro.kernels.patch_bitmap.ops import patch_bitmap
from repro.kernels.patch_reuse.ops import patch_delta


@pytest.fixture(autouse=True)
def _fresh_table_cache():
    autotune.clear_cache()
    yield
    autotune.clear_cache()


def _write_table(tmp_path, table):
    path = tmp_path / "table.json"
    path.write_text(json.dumps(table))
    return str(path)


# ----------------------------------------------------------------------------
# Key round-trip + table validation
# ----------------------------------------------------------------------------
GEOMS = {
    "self_attention": (1, 8, 4096, 40, 64),
    "cross_attention": (1, 8, 1024, 40, 77),
    "bitmap": (4096, 4096, 64),
    "reuse": (1, 4096, 320, 64),
}


@pytest.mark.parametrize("op", sorted(GEOMS))
def test_key_round_trip(op):
    geom = GEOMS[op]
    key = autotune.make_key("cpu", op, geom)
    assert autotune.parse_key(key) == ("cpu", op, geom)
    # the key is the dispatch-table convention: backend/op/f=v,...
    backend, opname, dims = key.split("/")
    assert (backend, opname) == ("cpu", op)
    assert all("=" in part for part in dims.split(","))


@pytest.mark.parametrize("bad", [
    "cpu/self_attention",                                   # no geometry
    "cpu/unknown_op/b=1,h=8,t=64,d=8,patch=16",             # unknown op
    "cpu/self_attention/b=1,h=8,t=64,d=8",                  # missing field
    "cpu/self_attention/t=64,b=1,h=8,d=8,patch=16",         # wrong order
    "cpu/self_attention/b=1,h=8,t=sixty,d=8,patch=16",      # non-int
])
def test_parse_key_rejects_malformed(bad):
    with pytest.raises(AutotuneTableError):
        autotune.parse_key(bad)


def test_missing_table_is_empty_and_lookup_falls_back(tmp_path):
    # a missing file is a valid empty table (fresh checkout, exotic
    # backend): lookup returns None and dispatch keeps policy defaults
    path = str(tmp_path / "nope.json")
    assert autotune.load_table(path)["entries"] == {}
    assert autotune.lookup("self_attention", (1, 1, 64, 8, 16),
                           path=path) is None
    # unknown geometry in a REAL table also falls back to None
    assert autotune.lookup("self_attention", (9, 9, 144, 9, 9)) is None


def test_stale_version_rejected_loudly(tmp_path):
    path = _write_table(tmp_path, {"version": autotune.AUTOTUNE_VERSION + 1,
                                   "entries": {}})
    with pytest.raises(AutotuneTableError, match="version"):
        autotune.load_table(path)


def test_malformed_json_rejected_loudly(tmp_path):
    path = tmp_path / "broken.json"
    path.write_text("{not json")
    with pytest.raises(AutotuneTableError, match="not valid JSON"):
        autotune.load_table(str(path))


@pytest.mark.parametrize("entries,match", [
    ({"cpu/self_attention/b=1,h=8,t=64,d=8,patch=16":
      {"bogus_knob": 128}}, "unknown knob"),
    ({"cpu/self_attention/b=1,h=8,t=64,d=8,patch=16":
      {"attn_block_q": "big"}}, "positive int"),
    ({"cpu/self_attention/b=1,h=8,t=64,d=8,patch=16":
      {"attn_block_q": 0}}, "positive int"),
    ({"cpu/self_attention/b=1,h=8,t=64,d=8,patch=16": {}}, "knob"),
    ({"cpu/self_attention/b=1,t=64": {"attn_block_q": 64}}, "fields"),
])
def test_bad_entries_rejected_loudly(tmp_path, entries, match):
    path = _write_table(tmp_path, {"version": autotune.AUTOTUNE_VERSION,
                                   "entries": entries})
    with pytest.raises(AutotuneTableError, match=match):
        autotune.load_table(path)


def test_lookup_hits_and_dispatch_blocks(tmp_path, monkeypatch):
    geom = (1, 2, 64, 8, 16)
    key = autotune.make_key(jax.default_backend(), "self_attention", geom)
    path = _write_table(tmp_path, {
        "version": autotune.AUTOTUNE_VERSION,
        "entries": {key: {"attn_block_q": 64, "attn_block_k": 32}}})
    monkeypatch.setattr(autotune, "DEFAULT_TABLE_PATH", path)

    assert autotune.lookup("self_attention", geom) == {
        "attn_block_q": 64, "attn_block_k": 32}
    # dispatch resolution: tuned policy takes the table's winner, the
    # untuned policy (and unknown geometries) keep the field defaults
    tuned = KernelPolicy.autotuned()
    assert dispatch._blocks(tuned, "self_attention", geom) == {
        "attn_block_q": 64, "attn_block_k": 32}
    assert dispatch._blocks(KernelPolicy.fused(), "self_attention",
                            geom) == {"attn_block_q": 128,
                                      "attn_block_k": 128}
    assert dispatch._blocks(tuned, "self_attention", (1, 2, 128, 8, 16)) \
        == {"attn_block_q": 128, "attn_block_k": 128}


def test_committed_table_is_valid():
    # the repo ships a generated table: it must load (validation is
    # load-time) and its entries must parse back to known ops
    table = autotune.load_table()
    assert table["version"] == autotune.AUTOTUNE_VERSION
    assert table["entries"], "committed table should not be empty"
    for key in table["entries"]:
        backend, op, geom = autotune.parse_key(key)
        assert op in autotune._OPS


def test_tune_smoke_produces_valid_loadable_table(tmp_path):
    # end-to-end: sweep tiny geometries for two cheap families, save,
    # reload through the validating loader, and hit an entry
    geoms = {"bitmap": ((64, 64, 16),), "reuse": ((1, 64, 8, 8),)}
    table = autotune.tune(geoms, reps=1, verbose=False)
    assert len(table["entries"]) == 2
    path = autotune.save_table(table, str(tmp_path / "t.json"))
    loaded = autotune.load_table(path)
    won = autotune.lookup("bitmap", (64, 64, 16), path=path)
    assert won and set(won) == {"bitmap_block_rows"}
    assert loaded["generated_on"]["backend"] == jax.default_backend()


# ----------------------------------------------------------------------------
# Block invariance: counters/outputs identical across tuned block sizes
# ----------------------------------------------------------------------------
def _qkv(b=1, h=2, t=96, d=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return tuple(jax.random.normal(k, (b, h, t, d)) for k in ks)


def test_pssa_counters_bit_identical_across_blocks():
    # t=96 is the ragged knife edge: not a multiple of 64-block configs,
    # so the pad-and-slice path is exercised on both q and k axes
    q, k, v = _qkv(t=96)
    thr = 1.0 / 1024.0
    outs = [pssa_attention(q, k, v, threshold=thr, patch=16,
                           bq=bq, bk=bk, interpret=True)
            for bq, bk in [(128, 128), (64, 32), (96, 48), (32, 64)]]
    base = outs[0]
    for out in outs[1:]:
        np.testing.assert_array_equal(np.asarray(base[1]),
                                      np.asarray(out[1]))  # nnz counter
        np.testing.assert_array_equal(np.asarray(base[2]),
                                      np.asarray(out[2]))  # popcount
        np.testing.assert_allclose(np.asarray(base[0]), np.asarray(out[0]),
                                   rtol=1e-5, atol=1e-5)


def test_bitmap_and_reuse_bit_identical_across_blocks():
    sas = jax.random.uniform(jax.random.PRNGKey(0), (3, 5, 96, 96)) * 2e-3
    base = patch_bitmap(sas, 16, 1e-3, br=64, interpret=True)
    for br in (8, 24, 96, 256):
        got = patch_bitmap(sas, 16, 1e-3, br=br, interpret=True)
        np.testing.assert_array_equal(np.asarray(base[0]),
                                      np.asarray(got[0]))
        np.testing.assert_array_equal(np.asarray(base[1]),
                                      np.asarray(got[1]))

    x = jax.random.normal(jax.random.PRNGKey(1), (2, 96, 8))
    x_ref = x + 1e-3 * jax.random.normal(jax.random.PRNGKey(2), (2, 96, 8))
    d0, a0 = patch_delta(x, x_ref, patch=16, threshold=1e-3, bp=8,
                         interpret=True)
    for bp in (1, 3, 6):             # 96/16 = 6 patches -> ragged plans
        d, a = patch_delta(x, x_ref, patch=16, threshold=1e-3, bp=bp,
                           interpret=True)
        np.testing.assert_array_equal(np.asarray(a0), np.asarray(a))
        np.testing.assert_array_equal(np.asarray(d0), np.asarray(d))


def test_autotune_probe_hooks_cover_knobs():
    # every family advertises knobs that are real KernelPolicy fields and
    # produces candidates whose keys match exactly
    for op, (modname, _) in autotune._OPS.items():
        mod = autotune._op_module(op)
        assert mod.AUTOTUNE_KNOBS == autotune._op_knobs(op)
        geom = {"self_attention": (1, 2, 64, 8, 16),
                "cross_attention": (1, 2, 64, 8, 77),
                "bitmap": (64, 64, 16),
                "reuse": (1, 64, 8, 8)}[op]
        cands = mod.autotune_candidates(geom)
        assert cands
        for blocks in cands:
            assert set(blocks) == set(mod.AUTOTUNE_KNOBS)
            for name in blocks:
                assert hasattr(KernelPolicy(), name)


# ----------------------------------------------------------------------------
# Policy surface: autotuned preset, parse, describe
# ----------------------------------------------------------------------------
def test_autotuned_preset_parse_and_describe():
    pol = KernelPolicy.autotuned()
    assert pol.tuned and pol.self_attention == "fused"
    assert KernelPolicy.parse("autotuned") == pol
    # autotuned differs from fused ONLY by the tuned bit
    assert dataclasses.replace(pol, tuned=False) == KernelPolicy.fused()

    spec = KernelPolicy.parse("ffn=dbsc,ffn_quant=int8,tuned=true")
    assert spec.ffn == "dbsc" and spec.ffn_quant == "int8" and spec.tuned
    desc = spec.describe()
    assert desc["tuned"] is True and desc["ffn_quant"] == "int8"

    with pytest.raises(ValueError, match="ffn_quant"):
        KernelPolicy(ffn_quant="int4")
    with pytest.raises(ValueError, match="tuned"):
        KernelPolicy.parse("tuned=maybe")


# ----------------------------------------------------------------------------
# int8 dot_general datapath
# ----------------------------------------------------------------------------
def test_int8_accumulators_bitwise_vs_model():
    rng = np.random.default_rng(0)
    x = jnp.array(rng.standard_normal((96, 40), dtype=np.float32))
    w = jnp.array(rng.standard_normal((40, 56), dtype=np.float32))
    imp = jnp.array(rng.random(96) < 0.5)
    for important in (None, imp):
        ref = bitslice_matmul(x, w, important=important, use_kernel=False)
        kern = bitslice_matmul(x, w, important=important, use_kernel=True,
                               interpret=True)
        i8 = bitslice_matmul(x, w, important=important, quant_path="int8")
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(i8))
        np.testing.assert_array_equal(np.asarray(kern), np.asarray(i8))
    with pytest.raises(ValueError, match="quant_path"):
        bitslice_matmul(x, w, quant_path="int4")


def test_int8_operands_are_really_int8():
    # the point of the path is the operand dtype XLA sees: int8 inputs,
    # int32 accumulator (hardware integer units), not widened casts
    hi = jnp.full((8, 16), 63, jnp.int32)
    lo = jnp.full((8, 16), 63, jnp.int32)
    w = jnp.full((16, 4), -128, jnp.int32)
    prec = jnp.ones((8, 1), jnp.int32)
    jaxpr = jax.make_jaxpr(bitslice_matmul_int8)(hi, lo, w, prec)
    dots = [e for e in jaxpr.jaxpr.eqns if e.primitive.name == "dot_general"]
    assert len(dots) == 2
    for eqn in dots:
        assert all(v.aval.dtype == jnp.int8 for v in eqn.invars)
        assert eqn.outvars[0].aval.dtype == jnp.int32
    # worst-case magnitudes round-trip exactly
    np.testing.assert_array_equal(
        np.asarray(bitslice_matmul_int8(hi, lo, w, prec)),
        np.asarray(bitslice_matmul_ref(hi, lo, w, prec)))


# ----------------------------------------------------------------------------
# Engine-level: routing moves nothing but wall-clock
# ----------------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine_outputs():
    from repro.diffusion.engine import DiffusionEngine
    from repro.diffusion.pipeline import PipelineConfig, energy_report
    from repro.diffusion.sampler import DDIMConfig

    cfg = PipelineConfig.smoke()
    cfg = dataclasses.replace(
        cfg, ddim=DDIMConfig(num_inference_steps=2, guidance_scale=1.0,
                             tips_active_iters=1))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, cfg.text.max_len),
                              0, cfg.text.vocab_size)
    outs = {}
    for name, pol in [
            ("reference", KernelPolicy.reference()),
            ("fused", KernelPolicy.fused()),
            ("autotuned", KernelPolicy.autotuned()),
            ("dbsc_model", KernelPolicy.parse("ffn=dbsc")),
            ("dbsc_int8", KernelPolicy.parse("ffn=dbsc,ffn_quant=int8"))]:
        eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0),
                              kernel_policy=pol)
        out = eng.generate(toks, jax.random.PRNGKey(2))
        outs[name] = (np.asarray(out.images),
                      energy_report(cfg, out.stats).summary())
    return outs


def test_engine_bit_identical_across_ffn_quant(engine_outputs):
    # int8 vs modeled DBSC: same integers -> same image, same ledger
    img_model, rep_model = engine_outputs["dbsc_model"]
    img_int8, rep_int8 = engine_outputs["dbsc_int8"]
    np.testing.assert_array_equal(img_int8, img_model)
    assert rep_int8 == rep_model


def test_engine_bit_identical_across_tuned_blocks(engine_outputs):
    # autotuned == fused routing with (possibly) different blocks: block
    # shape is a pure wall-clock lever — image and ledger are pinned
    img_fused, rep_fused = engine_outputs["fused"]
    img_tuned, rep_tuned = engine_outputs["autotuned"]
    np.testing.assert_array_equal(img_tuned, img_fused)
    assert rep_tuned == rep_fused


def test_engine_energy_headline_identical_across_all_policies(
        engine_outputs):
    # integer-counter exactness: the mJ/iter headline never moves with
    # kernel routing, block shape or the int8 datapath
    base = engine_outputs["reference"][1]
    for name, (_, rep) in engine_outputs.items():
        assert rep["mj_per_iter_with_ema"] \
            == base["mj_per_iter_with_ema"], name


def test_engine_int8_image_bounded_vs_float_reference(engine_outputs):
    # vs the FLOAT reference FFN the int8 image is only BOUNDED: the
    # reference fake-quantizes on per-sample scales and accumulates in
    # f32, the DBSC path quantizes on one shared scale and accumulates
    # integers — different numerics, same model (pinned here so the
    # bound is part of the contract, not a hope)
    img_ref = engine_outputs["reference"][0]
    img_int8 = engine_outputs["dbsc_int8"][0]
    rel = (np.linalg.norm(img_int8.astype(np.float64)
                          - img_ref.astype(np.float64))
           / max(np.linalg.norm(img_ref.astype(np.float64)), 1e-12))
    assert rel < 0.05, rel
