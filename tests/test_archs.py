"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, assert output shapes + no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_arch
from repro.models import transformer as T
from repro.optim import AdamW
from repro.train import make_train_step

BATCH, SEQ = 2, 32


def _batch(cfg, key):
    if cfg.embedding_input:
        return {"embeds": jax.random.normal(key, (BATCH, SEQ, cfg.d_model),
                                            jnp.bfloat16),
                "labels": jax.random.randint(key, (BATCH, SEQ), 0,
                                             cfg.vocab_size)}
    toks = jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_no_nan(arch):
    cfg = get_arch(arch).smoke()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux, _ = T.forward(params, cfg, None,
                               tokens=b.get("tokens"),
                               embeds=b.get("embeds"))
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_no_nan(arch):
    cfg = get_arch(arch).smoke()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, None, opt))
    state = (params, opt.init(params), jnp.zeros(()))
    state, metrics = step(state, _batch(cfg, jax.random.PRNGKey(1)))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda p0, p1: float(jnp.sum(jnp.abs(
            p0.astype(jnp.float32) - p1.astype(jnp.float32)))),
            params, state[0]))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_step_matches_cache_semantics(arch):
    """decode_step produces finite logits and updates the cache in place."""
    cfg = get_arch(arch).smoke()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    cache = T.init_cache(cfg, batch=BATCH, max_seq=16)
    tok = jax.random.randint(jax.random.PRNGKey(2), (BATCH, 1), 0,
                             cfg.vocab_size)
    logits, new_cache = T.decode_step(params, cache, tok,
                                      jnp.asarray(0, jnp.int32), cfg, None)
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure preserved
    jax.tree.map(lambda a, b: None
                 if a.shape == b.shape else pytest.fail("cache shape"),
                 cache, new_cache)


@pytest.mark.parametrize("arch,tol", [
    ("llama3-8b", 2e-2),
    # mamba2 runs the full sequence through the CHUNKED SSD path but decodes
    # through the O(1) f32 recurrence — two mathematically-equal programs
    # whose summation orders differ everywhere (quadratic intra-chunk einsum
    # vs state update; shifted-add causal conv vs window einsum).  With
    # bf16 activations that reassociation costs ~1 bf16 ulp per layer at the
    # hidden-state magnitude (|h| ~ 4 -> ulp = 2^-8 * 2^2 = 0.03125); the
    # measured logit drift is 0.031-0.033 over the 2 smoke layers, and an
    # all-f32 intra-chunk run still drifts 0.027 (so this is activation-
    # dtype rounding, not the bf16 einsum operands; root-caused in PR 3).
    # 6e-2 = two bf16 ulps at |h|=4 of headroom; a real divergence bug (like
    # a mis-rolled conv window) shows up at O(1), far above it.
    ("mamba2-130m", 6e-2),
    ("hymba-1.5b", 2e-2),
])
def test_prefill_then_decode_consistent(arch, tol):
    """Greedy continuation: prefill cache + decode next token == running
    forward on the extended sequence (teacher forcing)."""
    # vanilla path: TIPS fake-quant uses a full-tensor scale in prefill but a
    # per-step scale in decode, so exact consistency holds with features off
    cfg = get_arch(arch).smoke().scaled(tips=False, pssa=False)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                              cfg.vocab_size)

    logits_full, _, _ = T.forward(params, cfg, None, tokens=toks,
                                  remat=False)
    # prefill on the first 7, decode token 7, compare its logits to full fwd
    if cfg.family == "hybrid":
        pytest.skip("hybrid ring-buffer cache needs full-seq prefill shapes")
    logits_p, cache = T.prefill(params, cfg, None, tokens=toks[:, :7])
    # pad cache seq axis to 8 for the dense path
    if cfg.family in ("dense", "moe"):
        pad = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0)))
        cache = {"k": pad(cache["k"]), "v": pad(cache["v"])}
    logits_d, _ = T.decode_step(params, cache, toks[:, 7:8],
                                jnp.asarray(7, jnp.int32), cfg, None)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(logits_full[:, 7]),
                               rtol=tol, atol=tol)


def test_moe_router_balance_aux_positive():
    cfg = get_arch("qwen2-moe-a2.7b").smoke()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    _, aux, _ = T.forward(params, cfg, None, tokens=toks)
    assert float(aux) >= 1.0 - 1e-3    # e * sum(me*ce) >= 1 by Cauchy-Schwarz


def test_pssa_pruning_changes_attention():
    """cfg.pssa threshold actually prunes (different logits vs pssa=False)."""
    cfg = get_arch("llama3-8b").smoke().scaled(pssa=True,
                                               pssa_threshold=0.2)
    cfg_off = cfg.scaled(pssa=False)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                              cfg.vocab_size)
    lg_on, _, _ = T.forward(params, cfg, None, tokens=toks)
    lg_off, _, _ = T.forward(params, cfg_off, None, tokens=toks)
    assert float(jnp.max(jnp.abs(lg_on - lg_off))) > 0


def test_hymba_global_vs_swa_layers():
    cfg = get_arch("hymba-1.5b").smoke()
    assert cfg.sliding_window == 16
    # smoke seq 32 > window 16 -> banded mask actually matters
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                              cfg.vocab_size)
    logits, _, _ = T.forward(params, cfg, None, tokens=toks)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_moe_capacity_drops_tokens():
    """Capacity-factor semantics: a tight cap drops overflow tokens (their
    combine weight is zero), a generous cap keeps everything."""
    from repro.models import moe as MOE
    cfg = get_arch("qwen2-moe-a2.7b").smoke()
    p = MOE.init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y_full, _ = MOE.moe_ffn(x, p, cfg, None, capacity_factor=16.0)
    y_tight, _ = MOE.moe_ffn(x, p, cfg, None, capacity_factor=0.25)
    # tight capacity changes outputs (tokens were dropped)
    assert float(jnp.max(jnp.abs(y_full - y_tight))) > 0
    # and dropped-token rows fall back to the shared-expert path only
    assert bool(jnp.all(jnp.isfinite(y_tight)))
