"""Diffusion pipeline tests: UNet, sampler, full text->image, ledger."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.energy import report
from repro.diffusion import ledger as L
from repro.diffusion.pipeline import PipelineConfig, StableDiffusionPipeline
from repro.diffusion.sampler import (DDIMConfig, alphas_cumprod, ddim_step,
                                     timestep_schedule)
from repro.diffusion.text_encoder import (TextEncoderConfig, encode_text,
                                          init_text_encoder_params)
from repro.diffusion.unet import (BK_SDM_TINY, UNetConfig,
                                  abstract_unet_params, init_unet_params,
                                  unet_forward)
from repro.diffusion.vae import VAEConfig, decode, init_vae_params


@pytest.fixture(scope="module")
def smoke_unet():
    cfg = UNetConfig().smoke()
    params = init_unet_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_unet_forward_shapes(smoke_unet):
    cfg, params = smoke_unet
    s = cfg.latent_size
    lat = jax.random.normal(jax.random.PRNGKey(1), (2, s, s, 4))
    ctx = jax.random.normal(jax.random.PRNGKey(2),
                            (2, cfg.text_len, cfg.context_dim))
    eps, stats = unet_forward(params, lat, jnp.array([10, 500]), ctx, cfg)
    assert eps.shape == lat.shape
    assert bool(jnp.all(jnp.isfinite(eps)))
    # 9 transformer blocks in the BK-SDM layout (3 down + 6 up)
    assert len(stats) == 9
    d = stats.as_dict()                      # legacy string-keyed view
    assert len(d["pssa"]) == 9
    assert len(d["tips"]) == 9
    assert "down0.0@16" in d["pssa"]


def test_unet_full_geometry_shapes_abstract():
    """Full BK-SDM-Tiny geometry type-checks end-to-end (eval_shape only —
    no 1.3 GW of CPU matmuls)."""
    cfg = BK_SDM_TINY
    aparams = abstract_unet_params(cfg)
    out = jax.eval_shape(
        lambda p, l, t, c: unet_forward(p, l, t, c, cfg)[0],
        aparams,
        jax.ShapeDtypeStruct((1, 64, 64, 4), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.int32),
        jax.ShapeDtypeStruct((1, 77, 768), jnp.float32))
    assert out.shape == (1, 64, 64, 4)


def test_unet_tips_active_flag_changes_ffn(smoke_unet):
    cfg, params = smoke_unet
    s = cfg.latent_size
    lat = jax.random.normal(jax.random.PRNGKey(3), (1, s, s, 4))
    ctx = jax.random.normal(jax.random.PRNGKey(4),
                            (1, cfg.text_len, cfg.context_dim))
    e_on, _ = unet_forward(params, lat, jnp.array([500]), ctx, cfg,
                           tips_active=True)
    e_off, _ = unet_forward(params, lat, jnp.array([500]), ctx, cfg,
                            tips_active=False)
    assert float(jnp.max(jnp.abs(e_on - e_off))) > 0


def test_text_encoder_cls_first():
    cfg = TextEncoderConfig().smoke()
    params = init_text_encoder_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, cfg.max_len), 0,
                              cfg.vocab_size)
    ctx = encode_text(params, toks, cfg)
    assert ctx.shape == (2, cfg.max_len, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(ctx)))


def test_vae_decode_8x_upsample():
    cfg = VAEConfig().smoke()
    params = init_vae_params(jax.random.PRNGKey(0), cfg)
    img = decode(params, jax.random.normal(jax.random.PRNGKey(1),
                                           (1, 8, 8, 4)), cfg)
    assert img.shape == (1, 64, 64, 3)
    assert float(jnp.max(jnp.abs(img))) <= 1.0


def test_ddim_step_reconstructs_x0_at_last_step():
    cfg = DDIMConfig()
    acp = alphas_cumprod(cfg)
    x0 = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 4, 4))
    eps = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 4, 4))
    t = 40
    xt = jnp.sqrt(acp[t]) * x0 + jnp.sqrt(1 - acp[t]) * eps
    # with the true eps, stepping to t_prev<0 recovers x0 exactly
    x_prev = ddim_step(xt, eps, t, -1, acp)
    np.testing.assert_allclose(np.asarray(x_prev), np.asarray(x0),
                               rtol=1e-4, atol=1e-4)


def test_timestep_schedule_descending_25():
    ts = timestep_schedule(DDIMConfig())
    assert len(ts) == 25 and int(ts[-1]) == 0
    assert (np.diff(np.asarray(ts)) < 0).all()


def test_pipeline_end_to_end_smoke():
    cfg = PipelineConfig.smoke()
    pipe = StableDiffusionPipeline(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, cfg.text.max_len),
                              0, cfg.text.vocab_size)
    img, stats = pipe.generate(toks, jax.random.PRNGKey(2))
    assert img.shape[-1] == 3
    assert bool(jnp.all(jnp.isfinite(img)))
    assert len(stats) == cfg.ddim.num_inference_steps
    rep = pipe.energy_report(stats)
    s = rep.summary()
    # paper-shape assertions on the full-geometry BASELINE ledger
    assert s["ema_gb_per_iter_baseline"] == pytest.approx(1.9, rel=0.1)
    assert s["sas_fraction_of_ema_baseline"] == pytest.approx(0.618,
                                                              abs=0.08)
    assert s["transformer_ema_fraction_baseline"] > 0.75


# ----------------------------------------------------------------------------
# Ledger arithmetic
# ----------------------------------------------------------------------------
def test_ledger_baseline_matches_paper_operating_point():
    rep = L.iteration_report(BK_SDM_TINY, L.LedgerOptions())
    gb = rep.ema_bytes_total / 1e9
    assert gb == pytest.approx(1.9, rel=0.1)                 # 1.9 GB/iter
    assert rep.sas_fraction == pytest.approx(0.618, abs=0.08)  # 61.8 %
    tx = rep.stage_fraction("self_attn", "cross_attn", "ffn")
    assert tx == pytest.approx(0.87, abs=0.08)               # 87.0 %


def test_ledger_pssa_reduces_total_ema_378():
    base = L.iteration_report(BK_SDM_TINY, L.LedgerOptions())
    opt = L.iteration_report(
        BK_SDM_TINY, L.LedgerOptions(pssa=True))   # paper-default SAS ratio
    red = 1.0 - opt.ema_bytes_total / base.ema_bytes_total
    assert red == pytest.approx(0.378, abs=0.06)             # 37.8 %


def test_ledger_tips_low_ratio_cuts_high_macs():
    base = L.iteration_report(BK_SDM_TINY, L.LedgerOptions())
    opt = L.iteration_report(BK_SDM_TINY,
                             L.LedgerOptions(tips=True, tips_low_ratio=0.448))
    ffn_base = sum(l.macs_high for l in L.unet_ledger(BK_SDM_TINY)
                   if l.stage == "ffn")
    led = L.unet_ledger(BK_SDM_TINY,
                        L.LedgerOptions(tips=True, tips_low_ratio=0.448))
    hi = sum(l.macs_high for l in led if l.stage == "ffn")
    lo = sum(l.macs_low for l in led if l.stage == "ffn")
    assert hi == pytest.approx(ffn_base * 0.552, rel=1e-6)
    assert lo == pytest.approx(ffn_base * 0.448, rel=1e-6)
    assert opt.compute_energy_mj < base.compute_energy_mj


def test_ledger_ffn_is_dominant_transformer_compute():
    """Fig. 1(b): FFN ~42.5 % of transformer-stage computation."""
    led = L.unet_ledger(BK_SDM_TINY)
    tx = [l for l in led if l.stage in ("self_attn", "cross_attn", "ffn")]
    ffn = sum(l.macs_high for l in tx if l.stage == "ffn")
    tot = sum(l.macs_high for l in tx)
    assert ffn / tot == pytest.approx(0.425, abs=0.1)


def test_ledger_cnn_transformer_compute_split():
    """Fig. 1(b): CNN and transformer split compute 'in similar proportion'."""
    led = L.unet_ledger(BK_SDM_TINY)
    cnn = sum(l.macs_high for l in led if l.stage == "cnn")
    tx = sum(l.macs_high for l in led if l.stage != "cnn")
    assert 0.25 < cnn / (cnn + tx) < 0.75
