"""ServePolicies bundle tests — the unified serving-policy API (§13).

The api_redesign contract: one frozen/hashable ``ServePolicies`` bundle
is the single policy component of the engine's executable-cache keys,
and every legacy spelling — per-policy engine kwargs, ``UNetConfig``
fold-in knobs — normalizes onto the SAME bundle: identical cache keys
(old and new call sites share executables), bit-identical images and
ledgers, plus a ``repro legacy:``-prefixed DeprecationWarning naming the
modern spelling.
"""
import dataclasses
import json

import jax
import pytest

from repro.core.policies import (LEGACY_WARNING_PREFIX, ServePolicies)
from repro.core.precision import PrecisionPolicy
from repro.core.reuse import ReusePolicy
from repro.diffusion.engine import DiffusionEngine
from repro.diffusion.pipeline import PipelineConfig, energy_report
from repro.diffusion.solvers import TIERS, SamplerPolicy
from repro.kernels.dispatch import KernelPolicy


@pytest.fixture(scope="module")
def cfg():
    return PipelineConfig.smoke()


# -- bundle semantics ------------------------------------------------------

def test_parse_describe_round_trip():
    specs = dict(kernels="fused", tips="adaptive,target=0.5",
                 reuse="temporal,threshold=0.1",
                 tiers=["draft", "balanced"])
    pol = ServePolicies.parse(**specs)
    d = pol.describe()
    json.dumps(d)  # JSON-clean for serving metrics / bench records
    assert d["kernels"] == KernelPolicy.parse("fused").describe()
    assert d["precision"]["spotting"] == "adaptive"
    assert d["precision"]["target_low_ratio"] == 0.5
    assert d["reuse"]["enabled"] and d["reuse"]["threshold"] == 0.1
    assert d["sampler"] is None
    assert len(d["bank"]) == 2
    # the same specs reconstruct an EQUAL (and hash-equal) bundle
    again = ServePolicies.parse(**specs)
    assert again == pol and hash(again) == hash(pol)
    assert again.key() == pol.key()


def test_parse_defaults_are_default_bundle():
    assert ServePolicies.parse() == ServePolicies()
    assert ServePolicies().key() == (KernelPolicy(), PrecisionPolicy(),
                                     ReusePolicy(), None, None)


def test_parse_solver_and_tiers_exclusive():
    with pytest.raises(ValueError, match="exclusive"):
        ServePolicies.parse(solver="draft", tiers=["draft", "quality"])


def test_sampler_must_be_bank_entry():
    bank = (TIERS["draft"], TIERS["quality"])
    ok = ServePolicies(sampler=TIERS["draft"], bank=bank)
    assert ok.sampler in ok.bank
    with pytest.raises(ValueError, match="not an entry"):
        ServePolicies(sampler=TIERS["balanced"], bank=bank)


def test_with_sampling_keeps_other_axes():
    pol = ServePolicies.parse(kernels="fused", tips="adaptive")
    pol2 = pol.with_sampling(sampler=TIERS["draft"],
                             bank=(TIERS["draft"],))
    assert pol2.kernels == pol.kernels
    assert pol2.precision == pol.precision
    assert pol2.sampler == TIERS["draft"]
    assert pol.sampler is None  # frozen: original untouched


def test_apply_installs_axes_on_config(cfg):
    pol = ServePolicies.parse(kernels="fused", tips="adaptive",
                              reuse="temporal")
    cfg2 = pol.apply(cfg)
    assert cfg2.unet.kernel_policy == pol.kernels
    assert cfg2.unet.precision == pol.precision
    assert cfg2.unet.reuse_policy == pol.reuse
    assert cfg.unet.kernel_policy != pol.kernels  # original untouched


# -- legacy aliases: warnings ---------------------------------------------

def test_legacy_config_knobs_warn(cfg):
    with pytest.warns(DeprecationWarning,
                      match="^" + LEGACY_WARNING_PREFIX):
        dataclasses.replace(cfg.unet, use_dbsc_kernel=True)
    with pytest.warns(DeprecationWarning,
                      match="^" + LEGACY_WARNING_PREFIX):
        dataclasses.replace(cfg.unet, tips_threshold=0.1)


def test_legacy_engine_kwargs_warn(cfg):
    with pytest.warns(DeprecationWarning,
                      match="^" + LEGACY_WARNING_PREFIX):
        DiffusionEngine(cfg, key=jax.random.PRNGKey(0),
                        kernel_policy=KernelPolicy.parse("reference"))


def test_legacy_kwargs_exclusive_with_policies(cfg):
    with pytest.raises(ValueError, match="not both"):
        DiffusionEngine(cfg, key=jax.random.PRNGKey(0),
                        policies=ServePolicies(),
                        precision_policy=PrecisionPolicy())


# -- legacy aliases: identical cache keys ---------------------------------

def _key_of(eng):
    return eng._cache_key(2, False, None, None, None)


def test_legacy_config_knobs_share_cache_key(cfg):
    with pytest.warns(DeprecationWarning):
        legacy_unet = dataclasses.replace(cfg.unet, use_dbsc_kernel=True,
                                          tips_threshold=0.1)
    legacy_cfg = dataclasses.replace(cfg, unet=legacy_unet)
    modern = ServePolicies(
        kernels=KernelPolicy(ffn="dbsc"),
        precision=PrecisionPolicy(threshold=0.1))
    key = jax.random.PRNGKey(0)
    eng_legacy = DiffusionEngine(legacy_cfg, key=key)
    eng_modern = DiffusionEngine(cfg, key=key, policies=modern)
    assert _key_of(eng_legacy) == _key_of(eng_modern)
    assert eng_legacy.policies == eng_modern.policies == modern


def test_legacy_engine_kwargs_share_cache_key(cfg):
    key = jax.random.PRNGKey(0)
    with pytest.warns(DeprecationWarning):
        eng_legacy = DiffusionEngine(
            cfg, key=key,
            kernel_policy=KernelPolicy.parse("reference"),
            precision_policy=PrecisionPolicy.parse("adaptive"))
    eng_modern = DiffusionEngine(
        cfg, key=key,
        policies=ServePolicies(kernels=KernelPolicy.parse("reference"),
                               precision=PrecisionPolicy.parse("adaptive")))
    assert _key_of(eng_legacy) == _key_of(eng_modern)
    # and the sampler axes fold per call through the same bundle
    pol = SamplerPolicy.parse("draft")
    assert (eng_legacy._cache_key(1, False, None, pol, None)
            == eng_modern._cache_key(1, False, None, pol, None))


def test_bundle_is_single_cache_key_component(cfg):
    eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0))
    k = eng._cache_key(2, True, None, None, None)
    # positions 0-3 stay load-bearing (batch, use_cfg, stats_rows, mesh);
    # position 4 is the ONE policy component — a ServePolicies key tuple
    assert k[:4] == (2, True, None, None)
    assert k[4] == eng.policies
    assert k[4].key() == ServePolicies.from_config(cfg.unet).key()


# -- legacy aliases: bit-identical images and ledgers ---------------------

def test_legacy_and_modern_spellings_bit_identical(cfg):
    steps = 3
    small = dataclasses.replace(
        cfg, ddim=dataclasses.replace(cfg.ddim, num_inference_steps=steps,
                                      tips_active_iters=2))
    with pytest.warns(DeprecationWarning):
        legacy_cfg = dataclasses.replace(
            small, unet=dataclasses.replace(small.unet,
                                            tips_threshold=0.02))
    modern = ServePolicies(precision=PrecisionPolicy(threshold=0.02))
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(jax.random.PRNGKey(7),
                              (2, small.text.max_len), 0,
                              small.text.vocab_size)
    out_legacy = DiffusionEngine(legacy_cfg, key=key).generate(
        toks, jax.random.PRNGKey(1))
    out_modern = DiffusionEngine(small, key=key, policies=modern).generate(
        toks, jax.random.PRNGKey(1))
    assert (out_legacy.images == out_modern.images).all()
    rep_legacy = energy_report(legacy_cfg, out_legacy.stats)
    rep_modern = energy_report(small, out_modern.stats)
    assert rep_legacy.summary() == rep_modern.summary()


# -- shared CLI wiring -----------------------------------------------------

def test_cli_wiring_round_trips_policies():
    import argparse

    from repro.launch.cli import add_policy_args, policies_from_args

    ap = argparse.ArgumentParser()
    add_policy_args(ap)
    args = ap.parse_args(["--kernels", "fused", "--tips", "adaptive",
                          "--reuse", "temporal", "--tiers", "draft",
                          "balanced"])
    pol = policies_from_args(args)
    assert pol == ServePolicies.parse(kernels="fused", tips="adaptive",
                                      reuse="temporal",
                                      tiers=["draft", "balanced"])


def test_cli_wiring_clamps_serving_reuse_capacity():
    import argparse

    from repro.launch.cli import add_policy_args, policies_from_args

    ap = argparse.ArgumentParser()
    add_policy_args(ap)
    args = ap.parse_args(["--reuse", "edit"])
    pol = policies_from_args(args)
    assert pol.reuse.enabled and pol.reuse.capacity == 1.0
    raw = policies_from_args(args, clamp_reuse_capacity=False)
    assert raw.reuse.capacity < 1.0


def test_both_clis_consume_shared_wiring():
    """The two CLIs and the router register flags through launch.cli."""
    import ast
    import pathlib

    root = pathlib.Path(__file__).resolve().parents[1]
    for rel in ("src/repro/launch/serve_diffusion.py",
                "examples/generate_image.py",
                "src/repro/launch/router.py"):
        src = (root / rel).read_text()
        assert "add_policy_args" in src, rel
        tree = ast.parse(src)
        dupes = [n.value for n in ast.walk(tree)
                 if isinstance(n, ast.Constant)
                 and n.value in ("--kernels", "--tips", "--solver")]
        assert not dupes, f"{rel} re-registers shared policy flags {dupes}"
