"""Tests for the §Perf framework features (TP-fold, int8 KV, grouped GQA,
model-flops accounting)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_arch
from repro.launch.model_flops import (model_bytes_decode, model_flops,
                                      param_count)
from repro.models import transformer as T
from repro.models.layers import KV_INT8_SCALE, ShardCtx, _kv_load, _kv_store


# ----------------------------------------------------------------------------
# TP-fold
# ----------------------------------------------------------------------------
def test_tp_fold_policy():
    from repro.launch.dryrun import choose_tp_fold
    assert choose_tp_fold(get_arch("mamba2-130m"), SHAPES["train_4k"])
    assert not choose_tp_fold(get_arch("yi-34b"), SHAPES["train_4k"])
    assert not choose_tp_fold(get_arch("qwen2-moe-a2.7b"),
                              SHAPES["train_4k"])        # MoE keeps EP/TP
    assert not choose_tp_fold(get_arch("mamba2-130m"),
                              SHAPES["decode_32k"])      # decode keeps TP


def test_shardctx_tp_substitution(smoke_mesh):
    ctx = ShardCtx(mesh=smoke_mesh, dp_axes=("data",), tp_axis=None)
    x = jnp.zeros((4, 8))
    y = ctx.cs(x, "data", "model")       # 'model' must rewrite to None
    assert y.shape == x.shape
    assert ctx.tp_size == 1
    ctx2 = ShardCtx(mesh=smoke_mesh, dp_axes=("data",))
    assert ctx2.tp_size == smoke_mesh.shape["model"]


def test_tp_fold_forward_matches_tp(smoke_mesh):
    """tp_axis=None produces the same math as tp_axis='model' on 1 device."""
    cfg = get_arch("mamba2-130m").smoke()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    ctx_tp = ShardCtx(mesh=smoke_mesh, dp_axes=("data",))
    ctx_fold = ShardCtx(mesh=smoke_mesh, dp_axes=("data", "model"),
                        tp_axis=None)
    a, _, _ = T.forward(params, cfg, ctx_tp, tokens=toks, remat=False)
    b, _, _ = T.forward(params, cfg, ctx_fold, tokens=toks, remat=False)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=1e-5)


# ----------------------------------------------------------------------------
# int8 KV cache
# ----------------------------------------------------------------------------
def test_kv_int8_store_load_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 2, 8),
                          jnp.bfloat16) * 2
    q = _kv_store(x, jnp.int8)
    assert q.dtype == jnp.int8
    y = _kv_load(q)
    assert float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                 - x.astype(jnp.float32)))) \
        <= KV_INT8_SCALE * 0.51 + 0.02   # grid error + bf16 input error


def test_kv_int8_decode_close_to_bf16():
    cfg = get_arch("llama3-8b").smoke().scaled(tips=False, pssa=False)
    cfg8 = cfg.scaled(kv_cache_dtype="int8")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 1), 0,
                              cfg.vocab_size)
    c16 = T.init_cache(cfg, 2, 8)
    c8 = T.init_cache(cfg8, 2, 8)
    assert c8["k"].dtype == jnp.int8
    l16, _ = T.decode_step(params, c16, toks, jnp.asarray(0), cfg, None)
    l8, _ = T.decode_step(params, c8, toks, jnp.asarray(0), cfg8, None)
    rel = float(jnp.max(jnp.abs(l8 - l16))
                / (jnp.max(jnp.abs(l16)) + 1e-9))
    assert rel < 0.05


# ----------------------------------------------------------------------------
# grouped GQA == repeat-based reference
# ----------------------------------------------------------------------------
def test_grouped_gqa_matches_repeat_reference():
    from repro.models import layers as L
    cfg = get_arch("llama3-8b").smoke().scaled(pssa=False, tips=False)
    p = L.init_attn_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    out, sink, (k, v) = L.gqa_attention(x, p, cfg, None, pos)

    # independent repeat-based reference
    b, t = 2, 16
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("btd,dk->btk", x, p["wq"]).reshape(b, t, h, hd)
    kk = jnp.einsum("btd,dk->btk", x, p["wk"]).reshape(b, t, kv, hd)
    vv = jnp.einsum("btd,dk->btk", x, p["wv"]).reshape(b, t, kv, hd)
    q = L.apply_rope(q, pos, cfg.rotary_pct, cfg.rope_theta)
    kk = L.apply_rope(kk, pos, cfg.rotary_pct, cfg.rope_theta)
    kf = jnp.repeat(kk, h // kv, axis=2)
    vf = jnp.repeat(vv, h // kv, axis=2)
    s = jnp.einsum("bthd,bshd->bhts", q, kf) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bhts,bshd->bthd", pr, vf).reshape(b, t, h * hd)
    ref = jnp.einsum("btk,kd->btd", ref, p["wo"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    # sink CAS equals head-mean attention to token 0
    np.testing.assert_allclose(np.asarray(sink),
                               np.asarray(jnp.mean(pr[..., 0], axis=1)),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------------------------
# model-flops accounting
# ----------------------------------------------------------------------------
def test_param_count_matches_init():
    for arch in ("llama3-8b", "mamba2-130m", "qwen2-moe-a2.7b",
                 "hymba-1.5b"):
        cfg = get_arch(arch).smoke()
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(x.size for x in jax.tree.leaves(params))
        assert param_count(cfg) == pytest.approx(actual, rel=1e-6), arch


def test_active_params_lt_total_for_moe():
    cfg = get_arch("qwen2-moe-a2.7b")
    assert param_count(cfg, active_only=True) < param_count(cfg)


def test_model_flops_kinds():
    cfg = get_arch("llama3-8b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    dc = model_flops(cfg, SHAPES["decode_32k"])
    assert tr > pf > dc > 0
    assert model_bytes_decode(cfg, SHAPES["decode_32k"]) > 0


def test_mamba_forward_fused_kernel_path():
    """cfg.use_ssd_kernel routes through the Pallas kernel with matching
    numerics (bf16-vs-f32 path tolerance)."""
    cfg = get_arch("mamba2-130m").smoke()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                              cfg.vocab_size)
    a, _, _ = T.forward(params, cfg, None, tokens=toks, remat=False)
    b, _, _ = T.forward(params, cfg.scaled(use_ssd_kernel=True), None,
                        tokens=toks, remat=False)
    rel = float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-9))
    assert rel < 2e-2


def test_elastic_mesh_from_live_devices():
    from repro.launch.mesh import make_elastic_mesh
    mesh = make_elastic_mesh(tp_size=16)
    assert mesh.devices.size == len(jax.devices())
    assert set(mesh.axis_names) == {"data", "model"}
