"""Kernel-dispatch layer tests: fused-path parity + the stats contract.

The contract under test (DESIGN.md §5):

  * ``KernelPolicy`` routes each hot-path op (self-attention, FFN, bitmap)
    to its reference or Pallas implementation; interpret auto-selects from
    the backend so the same policy is TPU-real and CPU-testable;
  * the fused self-attention path — blocked Pallas kernel, kernel-side
    PSSA counters — produces outputs within fp tolerance of the
    materializing reference and ``PSSAStats`` that are BIT-IDENTICAL
    (equal integer counters through the shared byte arithmetic), under
    plain calls, ``vmap``, and inside the scanned sampler;
  * no (B, H, T, T) score matrix is materialized anywhere on the fused
    path (asserted on the jaxpr);
  * the ops' pad-and-slice block handling is exact for non-block-multiple
    geometries (no degenerate block fallback).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pssa
from repro.core.attention import (self_attention_pssa,
                                  self_attention_pssa_fused)
from repro.diffusion.engine import DiffusionEngine
from repro.diffusion.pipeline import PipelineConfig, energy_report
from repro.diffusion.sampler import sample_scan
from repro.diffusion.stats import UNetStats
from repro.diffusion.unet import init_unet_params, unet_forward
from repro.kernels import dispatch
from repro.kernels.dispatch import KernelPolicy
from repro.kernels.patch_bitmap.ops import patch_bitmap
from repro.kernels.pssa_attention.ops import pssa_attention
from repro.kernels.runtime import default_interpret, resolve_interpret

THRESH = 1.0 / 1024.0


def _qkv(b=2, h=4, t=64, d=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return tuple(jax.random.normal(k, (b, h, t, d)) for k in ks)


def _assert_stats_bit_equal(a: pssa.PSSAStats, b: pssa.PSSAStats):
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"PSSAStats.{name}")


# ----------------------------------------------------------------------------
# KernelPolicy
# ----------------------------------------------------------------------------
def test_policy_presets_and_parse():
    assert KernelPolicy.reference() == KernelPolicy()
    fused = KernelPolicy.fused()
    assert fused.self_attention == "fused" and fused.bitmap == "kernel"
    assert KernelPolicy.parse("fused") == fused
    pol = KernelPolicy.parse("self_attention=fused,ffn=dbsc,interpret=true")
    assert (pol.self_attention, pol.ffn, pol.interpret) == \
        ("fused", "dbsc", True)
    assert KernelPolicy.parse("interpret=auto").interpret is None
    with pytest.raises(ValueError):
        KernelPolicy.parse("self_attention=nope")
    with pytest.raises(ValueError):
        KernelPolicy.parse("warp_drive=fused")
    with pytest.raises(ValueError):
        KernelPolicy.parse("interpret=yes")
    with pytest.raises(ValueError):
        KernelPolicy(ffn="nope")


def test_interpret_auto_selects_from_backend(monkeypatch):
    # Pallas has a real lowering on TPU (Mosaic) AND GPU (triton-pallas):
    # interpret must resolve False on both and True only where nothing
    # compiles (CPU — this container).  The earlier mapping treated TPU
    # as the only compiling backend, which forced interpret mode — and
    # ``KernelPolicy.auto()``'s reference routing — on GPU.
    from repro.kernels import runtime

    assert default_interpret()        # this container is CPU-only
    assert resolve_interpret(None) == default_interpret()
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    assert KernelPolicy().resolve_interpret() == default_interpret()
    desc = KernelPolicy.fused().describe()
    assert desc["interpret"] == "auto"
    assert desc["interpret_resolved"] == default_interpret()

    # the full backend -> interpret mapping, including the two names
    # jax has used for the CUDA platform and ROCm
    for backend, expect in [("cpu", True), ("tpu", False), ("gpu", False),
                            ("cuda", False), ("rocm", False)]:
        monkeypatch.setattr(runtime.jax, "default_backend",
                            lambda b=backend: b)
        assert runtime.default_interpret() is expect, backend
        assert runtime.resolve_interpret(None) is expect, backend
        # explicit values always win over the backend
        assert runtime.resolve_interpret(True) is True
        assert runtime.resolve_interpret(False) is False


def test_dispatch_table_covers_policy_choices():
    for op, impls in dispatch.DISPATCH_TABLE.items():
        assert set(impls) == set(dispatch._CHOICES[op])
    ops = {row["op"] for row in dispatch.support_matrix()}
    assert ops == set(dispatch.DISPATCH_TABLE)


# ----------------------------------------------------------------------------
# Fused self-attention parity (op level)
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("t,patch", [(64, 16), (256, 32)])
def test_fused_attention_matches_reference(t, patch):
    q, k, v = _qkv(t=t)
    ref = self_attention_pssa(q, k, v, patch=patch, threshold=THRESH)
    fused = self_attention_pssa_fused(q, k, v, patch=patch, threshold=THRESH)
    np.testing.assert_allclose(np.asarray(fused.out), np.asarray(ref.out),
                               rtol=2e-5, atol=2e-5)
    _assert_stats_bit_equal(fused.stats, ref.stats)


def test_fused_attention_stats_rows_matches_cond_only_call():
    q, k, v = _qkv(b=4, t=64)
    fused = self_attention_pssa_fused(q, k, v, patch=16, threshold=THRESH,
                                      stats_rows=2)
    cond = self_attention_pssa_fused(q[:2], k[:2], v[:2], patch=16,
                                     threshold=THRESH)
    _assert_stats_bit_equal(fused.stats, cond.stats)


def test_fused_attention_under_vmap():
    """The Pallas op must batch (pallas_call has a batching rule): vmap
    over a leading axis == a Python loop over the same slices."""
    q, k, v = _qkv(b=3, h=2, t=64)
    fn = lambda a, b, c: self_attention_pssa_fused(
        a[None], b[None], c[None], patch=16, threshold=THRESH)
    mapped = jax.vmap(fn)(q, k, v)
    for i in range(q.shape[0]):
        one = fn(q[i], k[i], v[i])
        np.testing.assert_allclose(np.asarray(mapped.out[i]),
                                   np.asarray(one.out),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_array_equal(np.asarray(mapped.stats.nnz[i]),
                                      np.asarray(one.stats.nnz))
        np.testing.assert_array_equal(
            np.asarray(mapped.stats.bitmap_ones_xor[i]),
            np.asarray(one.stats.bitmap_ones_xor))


def test_dispatch_downgrades_oracle_and_unpruned_to_reference():
    """reference_stats / prune_scores=False definitionally materialize; the
    fused policy must silently route them to the reference implementation
    rather than change semantics."""
    q, k, v = _qkv(t=64)
    pol = KernelPolicy.fused()
    ref = self_attention_pssa(q, k, v, patch=16, threshold=THRESH,
                              prune_scores=False)
    out = dispatch.self_attention(pol, q, k, v, patch=16, threshold=THRESH,
                                  prune_scores=False)
    np.testing.assert_array_equal(np.asarray(out.out), np.asarray(ref.out))
    oracle = dispatch.self_attention(pol, q, k, v, patch=16,
                                     threshold=THRESH, reference_stats=True)
    ref_o = self_attention_pssa(q, k, v, patch=16, threshold=THRESH,
                                reference_stats=True)
    _assert_stats_bit_equal(oracle.stats, ref_o.stats)


# ----------------------------------------------------------------------------
# Pad-and-slice block handling (no degenerate fallback)
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("t", [144, 320])
def test_pssa_attention_op_non_power_of_two_t(t):
    """Non-power-of-two T used to collapse the block fallback to 1-wide
    blocks; now the op pads to the block multiple and masks — exact."""
    q, k, v = _qkv(b=1, h=2, t=t, d=8, seed=3)
    out_k, nnz_k, xor_k = pssa_attention(q, k, v, THRESH, patch=16,
                                         use_kernel=True)
    out_r, nnz_r, xor_r = pssa_attention(q, k, v, THRESH, patch=16,
                                         use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(nnz_k), np.asarray(nnz_r))
    np.testing.assert_array_equal(np.asarray(xor_k), np.asarray(xor_r))


@pytest.mark.parametrize("rows", [100, 7])
def test_patch_bitmap_op_ragged_rows(rows):
    sas = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(0), (rows, 128)) * 4, -1)
    pk, ck = patch_bitmap(sas, 32, THRESH, use_kernel=True)
    pr, cr = patch_bitmap(sas, 32, THRESH, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))


# ----------------------------------------------------------------------------
# patch_bitmap popcounts drive the exact byte accounting
# ----------------------------------------------------------------------------
def test_patch_bitmap_counts_match_exact_byte_counts():
    """Kernel popcounts summed == the integer counters behind
    ``compress_stats``; ``pssa.exact_byte_counts`` closes the loop."""
    lead, tq, tk, patch = 2, 64, 128, 32
    sas = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(1), (lead, tq, tk)) * 4, -1)
    pol = KernelPolicy.fused()
    _, counts = dispatch.patch_bitmap(pol, sas, patch, THRESH)
    ones_xor = int(jnp.sum(counts))
    nnz = int(jnp.sum(pssa.bitmap(pssa.prune(sas, THRESH))))
    exact = pssa.exact_byte_counts(nnz, ones_xor, lead=lead, tq=tq, tk=tk,
                                   patch=patch)
    st = pssa.compress_stats(sas, patch, THRESH)
    assert float(st.bytes_index_pssa) == exact["bytes_index_pssa"]
    assert float(st.bytes_values) == exact["bytes_values"]
    assert float(st.bytes_pssa_total) == (exact["bytes_values"]
                                          + exact["bytes_index_pssa"])


# ----------------------------------------------------------------------------
# Fused policy through the UNet / sampler / engine
# ----------------------------------------------------------------------------
@pytest.fixture(scope="module")
def smoke_pair():
    cfg = PipelineConfig.smoke()
    cfg_fused = dataclasses.replace(
        cfg, unet=dataclasses.replace(cfg.unet,
                                      kernel_policy=KernelPolicy.fused()))
    params = init_unet_params(jax.random.PRNGKey(42), cfg.unet)
    return cfg, cfg_fused, params


def _unet_io(cfg, batch=1):
    s = cfg.unet.latent_size
    lat = jax.random.normal(jax.random.PRNGKey(0), (batch, s, s, 4))
    ctx = jax.random.normal(jax.random.PRNGKey(1),
                            (batch, cfg.unet.text_len, cfg.unet.context_dim))
    return lat, ctx


def test_fused_unet_forward_parity(smoke_pair):
    cfg, cfg_fused, params = smoke_pair
    lat, ctx = _unet_io(cfg)
    tvec = jnp.array([500])
    eps_r, st_r = unet_forward(params, lat, tvec, ctx, cfg.unet)
    eps_f, st_f = unet_forward(params, lat, tvec, ctx, cfg_fused.unet)
    # the fused preset swaps BOTH attentions (self + cross); each adds
    # ulp-level blocked-vs-einsum drift that the conv/norm stack amplifies
    np.testing.assert_allclose(np.asarray(eps_f), np.asarray(eps_r),
                               rtol=1e-3, atol=1e-3)
    assert st_f.layers == st_r.layers
    for a, b in zip(st_f.pssa, st_r.pssa):
        _assert_stats_bit_equal(a, b)
    for a, b in zip(st_f.tips, st_r.tips):      # TIPS path is untouched
        np.testing.assert_array_equal(np.asarray(a.low_precision_ratio),
                                      np.asarray(b.low_precision_ratio))


def test_fused_sample_scan_parity(smoke_pair):
    cfg, cfg_fused, params = smoke_pair
    lat, ctx = _unet_io(cfg)

    def apply(ucfg):
        def unet_apply(l, t, c, act, stats_rows=None, cfg_dup=False):
            return unet_forward(params, l, t, c, ucfg, tips_active=act,
                                stats_rows=stats_rows, cfg_dup=cfg_dup)
        return unet_apply

    lat_r, st_r = sample_scan(apply(cfg.unet), lat, ctx, None, cfg.ddim)
    lat_f, st_f = sample_scan(apply(cfg_fused.unet), lat, ctx, None,
                              cfg.ddim)
    np.testing.assert_allclose(np.asarray(lat_f), np.asarray(lat_r),
                               rtol=2e-3, atol=2e-3)
    assert isinstance(st_f, UNetStats)
    assert st_f.num_steps == cfg.ddim.num_inference_steps
    for a, b in zip(st_f.pssa, st_r.pssa):      # stacked across all steps
        _assert_stats_bit_equal(a, b)


def test_engine_fused_policy_end_to_end(smoke_pair):
    cfg, _, _ = smoke_pair
    key = jax.random.PRNGKey(7)
    eng_r = DiffusionEngine(cfg, key=key)
    eng_f = DiffusionEngine(cfg, key=key, kernel_policy=KernelPolicy.fused())
    assert eng_f.cfg.unet.kernel_policy == KernelPolicy.fused()
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, cfg.text.max_len),
                              0, cfg.text.vocab_size)
    s = cfg.unet.latent_size
    lat0 = jax.random.normal(jax.random.PRNGKey(2), (1, s, s, 4))
    out_r = eng_r.generate(toks, None, latents=lat0.copy())
    out_f = eng_f.generate(toks, None, latents=lat0.copy())
    np.testing.assert_allclose(np.asarray(out_f.latents),
                               np.asarray(out_r.latents),
                               rtol=2e-3, atol=2e-3)
    # the stats contract: PSSA accounting is bit-identical across policies,
    # so the energy-ledger headline is drift-free
    for a, b in zip(out_f.stats.pssa, out_r.stats.pssa):
        _assert_stats_bit_equal(a, b)
    rep_r = energy_report(cfg, out_r.stats).summary()
    rep_f = energy_report(eng_f.cfg, out_f.stats).summary()
    assert rep_f == rep_r


def test_engine_fused_policy_under_cfg(smoke_pair):
    """Fused kernels compose with fused-CFG prefix dedup (cfg_dup +
    stats_rows): cond-half accounting stays bit-identical to reference."""
    cfg, _, _ = smoke_pair
    cfg = dataclasses.replace(cfg, ddim=dataclasses.replace(
        cfg.ddim, guidance_scale=7.5))
    key = jax.random.PRNGKey(7)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, cfg.text.max_len),
                              0, cfg.text.vocab_size)
    un = jnp.zeros_like(toks)
    s = cfg.unet.latent_size
    lat0 = jax.random.normal(jax.random.PRNGKey(2), (1, s, s, 4))
    out_r = DiffusionEngine(cfg, key=key).generate(
        toks, None, uncond_tokens=un, latents=lat0.copy())
    out_f = DiffusionEngine(cfg, key=key,
                            kernel_policy=KernelPolicy.fused()).generate(
        toks, None, uncond_tokens=un, latents=lat0.copy())
    # guidance_scale amplifies per-step kernel-vs-reference fp drift ~7.5x
    np.testing.assert_allclose(np.asarray(out_f.latents),
                               np.asarray(out_r.latents),
                               rtol=2e-2, atol=2e-2)
    for a, b in zip(out_f.stats.pssa, out_r.stats.pssa):
        _assert_stats_bit_equal(a, b)


# ----------------------------------------------------------------------------
# The point of the refactor: the SAS never exists on the fused path
# ----------------------------------------------------------------------------
def _avals_in(jaxpr):
    """All output avals in a (closed) jaxpr, recursing into sub-jaxprs."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            yield var.aval
        for val in eqn.params.values():
            for sub in jax.tree_util.tree_leaves(
                    val, is_leaf=lambda x: hasattr(x, "eqns")
                    or hasattr(x, "jaxpr")):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    yield from _avals_in(sub)


def _materializes_sas(cfg_unet, params, t_big):
    lat = jax.random.normal(jax.random.PRNGKey(0),
                            (1, cfg_unet.latent_size,
                             cfg_unet.latent_size, 4))
    ctx = jax.random.normal(jax.random.PRNGKey(1),
                            (1, cfg_unet.text_len, cfg_unet.context_dim))
    jaxpr = jax.make_jaxpr(
        lambda p, l, c: unet_forward(p, l, jnp.array([500]), c, cfg_unet))(
        params, lat, ctx)
    return any(getattr(a, "shape", ())[-2:] == (t_big, t_big)
               for a in _avals_in(jaxpr))


def test_no_sas_materialized_on_fused_path():
    # ffn_mult=2 de-aliases the GEGLU hidden width from T (at smoke
    # defaults 2*4*32 == 256 == T, so a benign FFN activation would trip
    # the (T, T) probe); with it, only a score matrix can end in (T, T).
    ucfg = dataclasses.replace(PipelineConfig.smoke().unet, ffn_mult=2)
    params = init_unet_params(jax.random.PRNGKey(42), ucfg)
    t_big = ucfg.latent_size ** 2          # largest self-attention T
    # positive control: the reference path DOES materialize the (.., T, T)
    # score matrix — if this fails the probe is broken, not the model
    assert _materializes_sas(ucfg, params, t_big)
    fused = dataclasses.replace(ucfg, kernel_policy=KernelPolicy.fused())
    assert not _materializes_sas(fused, params, t_big)
