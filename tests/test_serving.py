"""Serving-helper + benchmark-registry coverage (previously untested).

``micro_batches`` is the padding/accounting keystone of the fixed
micro-batch front-end — its ``valid`` counts drive both imgs/s and the
``stats_rows`` ledger masking, so exactness here is load-bearing.  The
``benchmarks/run.py`` registry is what CI and the bench-regression gate
drive; every entry must resolve and unknown names must error cleanly.
"""
import os
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import serve_diffusion as S

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ----------------------------------------------------------------------------
# micro_batches: tail padding + valid-count exactness
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("n,batch", [(1, 1), (1, 4), (3, 2), (4, 2),
                                     (5, 4), (7, 3), (8, 8)])
def test_micro_batches_exact(n, batch):
    reqs = jnp.arange(n * 5).reshape(n, 5)
    out = S.micro_batches(reqs, batch)
    # valid counts partition the request count exactly
    assert sum(v for _, v in out) == n
    assert len(out) == -(-n // batch)
    rebuilt = jnp.concatenate([chunk[:v] for chunk, v in out], axis=0)
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(reqs))
    for chunk, valid in out:
        assert chunk.shape == (batch,) + reqs.shape[1:]   # fixed signature
        assert 1 <= valid <= batch
        # padded rows repeat the chunk's FIRST request row
        for j in range(valid, batch):
            np.testing.assert_array_equal(np.asarray(chunk[j]),
                                          np.asarray(chunk[0]))
    # only the LAST chunk may be padded
    for chunk, valid in out[:-1]:
        assert valid == batch


def test_micro_batches_empty_requests():
    out = S.micro_batches(jnp.zeros((0, 5), jnp.int32), 4)
    assert out == []


# ----------------------------------------------------------------------------
# benchmarks/run.py registry
# ----------------------------------------------------------------------------
@pytest.fixture(scope="module")
def run_mod():
    sys.path.insert(0, ROOT)
    try:
        import benchmarks.run as R
        return R
    finally:
        sys.path.remove(ROOT)


def test_listing_covers_every_registry_entry(run_mod):
    listing = run_mod.bench_listing()
    for name in run_mod.BENCHES:
        assert name in listing, name


def test_every_bench_module_resolves(run_mod):
    """Each registry entry points at an importable module file with a
    docstring summary and a ``run`` callable (``--only`` contract)."""
    sys.path.insert(0, ROOT)
    try:
        for name, modname in run_mod.BENCHES.items():
            path = os.path.join(ROOT, "benchmarks",
                                modname.rsplit(".", 1)[1] + ".py")
            assert os.path.exists(path), path
            assert run_mod._summary_line(modname), modname
            assert callable(run_mod._runner(name)), name
    finally:
        sys.path.remove(ROOT)


def test_unknown_only_name_errors_cleanly(run_mod, monkeypatch, capsys):
    monkeypatch.setattr(sys, "argv",
                        ["run.py", "--only", "definitely_not_a_bench"])
    with pytest.raises(SystemExit) as e:
        run_mod.main()
    assert e.value.code == 2                       # argparse error exit
    err = capsys.readouterr().err
    assert "definitely_not_a_bench" in err


def test_list_flag_prints_listing_and_exits_zero(run_mod, monkeypatch,
                                                 capsys):
    monkeypatch.setattr(sys, "argv", ["run.py", "--list"])
    with pytest.raises(SystemExit) as e:
        run_mod.main()
    assert e.value.code == 0
    out = capsys.readouterr().out
    for name in run_mod.BENCHES:
        assert name in out


# ----------------------------------------------------------------------------
# bench-regression gate: comparison logic (the CI job re-runs the real
# benches; here the classifier itself is pinned on synthetic records)
# ----------------------------------------------------------------------------
@pytest.fixture(scope="module")
def check_mod():
    sys.path.insert(0, ROOT)
    try:
        import benchmarks.check_regression as C
        return C
    finally:
        sys.path.remove(ROOT)


def test_regression_classifier_passes_identical(check_mod):
    rec = {"stats_bit_identical": True,
           "energy_headline": {"mj": 343.58149848883204},
           "wall_s_per_call": 1.5, "note": "free text"}
    assert check_mod.compare_records("x", rec, rec) == []


def test_regression_classifier_hard_fails_on_bit_flag(check_mod):
    a = {"stats_bit_identical": True}
    b = {"stats_bit_identical": False}
    probs = check_mod.compare_records("x", a, b)
    assert probs and "stats_bit_identical" in probs[0]


def test_regression_classifier_hard_fails_on_headline_drift(check_mod):
    a = {"energy": {"mj_per_iter_with_ema": 343.5}}
    b = {"energy": {"mj_per_iter_with_ema": 343.6}}
    assert check_mod.compare_records("x", a, b)
    # ... while wall-clock drift inside the band is tolerated
    a = {"serve_wall_s": 1.0}
    b = {"serve_wall_s": 2.5}
    assert check_mod.compare_records("x", a, b, wall_tolerance=4.0) == []
    assert check_mod.compare_records("x", a, b, wall_tolerance=2.0)


def test_regression_classifier_fails_on_structure_drift(check_mod):
    a = {"energy": {"mj_per_iter_with_ema": 1.0}}
    assert check_mod.compare_records("x", a, {})
    assert check_mod.compare_records("x", {}, a)
