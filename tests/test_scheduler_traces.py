"""Continuous-scheduler trace edge cases (launch/scheduler.py).

The bursty/steady traces the benches drive are well-behaved; the edges a
serving deployment actually hits are pinned here:

  * zero-arrival window — every request lands at t=0 (burst gap 0): the
    admit loop must fill all slots immediately and drain without a sleep
    deadlock;
  * single-request trace — one request, many slots: latency metrics and
    percentile math must survive n=1, and the empty-slot majority must
    not pollute the ledger;
  * burst larger than the slot count — the admit loop wraps: the
    overflow requests queue and enter freed slots across retirement
    boundaries, completing in arrival order without a drop.

Plus the img2img request builder (``make_edit_requests``): one shared
base latent, per-request localized edits, same Request surface.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion.engine import DiffusionEngine
from repro.diffusion.pipeline import PipelineConfig
from repro.diffusion.solvers import SamplerPolicy, bank_max_steps
from repro.launch.scheduler import (ContinuousScheduler, apply_trace,
                                    bursty_trace, make_edit_requests,
                                    make_requests)


@pytest.fixture(scope="module")
def cfg():
    cfg = PipelineConfig.smoke()
    return dataclasses.replace(cfg, ddim=dataclasses.replace(
        cfg.ddim, num_inference_steps=2, tips_active_iters=1))


@pytest.fixture(scope="module")
def eng(cfg):
    return DiffusionEngine(cfg, key=jax.random.PRNGKey(0))


def test_zero_arrival_window(cfg, eng):
    """All requests at t=0: slots fill instantly, the run drains."""
    sched = ContinuousScheduler(eng, num_slots=2)
    reqs = make_requests(cfg, 4, seed=3)
    apply_trace(reqs, bursty_trace(4, burst=4, gap_s=0.0))
    assert all(r.arrival_s == 0.0 for r in reqs)
    m = sched.run(reqs, ledger=False)
    m.pop("state")
    assert m["requests"] == 4
    assert all(r.image is not None for r in reqs)
    assert all(r.queue_s >= 0.0 for r in reqs)
    # 4 requests x 2 steps on 2 slots: exactly 4 engine steps
    assert m["engine_steps"] == 4
    assert m["mean_occupancy"] == 1.0


def test_single_request_trace(cfg, eng):
    """n=1 must survive the percentile math and keep slots clean."""
    sched = ContinuousScheduler(eng, num_slots=3)
    reqs = make_requests(cfg, 1, seed=4)
    m = sched.run(reqs, ledger=True)
    state = m.pop("state")
    assert m["requests"] == 1
    assert m["latency_s"]["p50"] == m["latency_s"]["p95"] \
        == m["latency_s"]["max"]
    assert reqs[0].image is not None
    # only the one occupied slot stepped: occupancy 1/3 per step
    assert m["engine_steps"] == cfg.ddim.num_inference_steps
    assert m["mean_occupancy"] == pytest.approx(1.0 / 3.0)
    # empty slots contributed nothing to the ledger
    assert int(jnp.sum(state.accum.rows)) \
        == cfg.ddim.num_inference_steps
    # the ledger block carries the reuse ratios (zeros: reuse off)
    assert m["reuse_ratio_per_iter"] == [0.0, 0.0]


def test_burst_larger_than_slot_count(cfg, eng):
    """Admit-loop wraparound: a 5-burst into 2 slots queues the overflow
    and completes everything in arrival order."""
    sched = ContinuousScheduler(eng, num_slots=2)
    reqs = make_requests(cfg, 5, seed=5)
    apply_trace(reqs, bursty_trace(5, burst=5, gap_s=0.0))
    m = sched.run(reqs, ledger=False)
    m.pop("state")
    assert all(r.image is not None for r in reqs)
    # FIFO admission: earlier rids never admitted after later ones
    admits = [r.admitted_s for r in reqs]
    assert admits == sorted(admits)
    # pairs (r0,r1), (r2,r3) take 2 steps each; r4 runs its 2 steps
    # alone in the wrapped slot: 6 engine steps
    assert m["engine_steps"] == 6
    # per-request images identical to the one-shot engine at the same
    # batch signature (wraparound does not leak rows across occupants)
    one = eng.generate(
        jnp.concatenate([reqs[0].tokens, reqs[1].tokens], axis=0), None,
        latents=jnp.concatenate([reqs[0].latents, reqs[1].latents],
                                axis=0))
    ref = np.asarray(jax.device_get(one.images))
    for i in (0, 1):
        np.testing.assert_array_equal(reqs[i].image, ref[i],
                                      err_msg=f"request {i}")


def test_mixed_tier_trace_metrics_and_ledger(cfg, eng):
    """Heterogeneous step budgets: per-tier percentile math, the
    steps-normalized goodput, and ledger cleanliness when short-budget
    rows retire early (their tail buckets must stay untouched)."""
    bank = (SamplerPolicy.dpm2m(2, name="draft"),
            SamplerPolicy.ddim(3, name="quality"))
    sched = ContinuousScheduler(eng, num_slots=2, bank=bank)
    reqs = make_requests(cfg, 4, seed=7, bank=bank)
    m = sched.run(reqs, ledger=True)
    state = m.pop("state")

    assert all(r.image is not None for r in reqs)
    # round-robin tiers: balanced populations, n=2 percentile math holds
    assert m["per_tier"]["draft"]["requests"] == 2
    assert m["per_tier"]["quality"]["requests"] == 2
    for t in ("draft", "quality"):
        lat = m["per_tier"][t]["latency_s"]
        assert 0.0 <= lat["p50"] <= lat["p95"] <= lat["max"]
    # steps-normalized goodput: total denoising steps / makespan
    total_steps = sum(bank[r.policy_index].num_steps for r in reqs)
    assert total_steps == 10
    assert m["goodput_steps_per_s"] \
        == pytest.approx(total_steps / m["makespan_s"])
    assert [b["name"] for b in m["bank"]] == ["draft", "quality"]

    # banked ledger: bucket p*N+i holds policy p's step-i row counts;
    # the draft tier's early retirement leaves its step-2 bucket empty
    n_max = bank_max_steps(bank)
    rows = np.asarray(state.accum.rows)
    assert rows.shape == (len(bank) * n_max,)
    for p, pol in enumerate(bank):
        seg = rows[p * n_max:(p + 1) * n_max]
        assert list(seg[:pol.num_steps]) == [2] * pol.num_steps
        assert not seg[pol.num_steps:].any()
    assert rows.sum() == total_steps
    # banked energy + phase breakdown rode along
    assert m["energy"] and m["phase_breakdown"]


def test_admit_after_retire_reuses_row_in_banked_state(cfg, eng):
    """A freed slot row re-admitted mid-trace under a multistep solver:
    the re-admission must reset the row's step counter and solver
    history, so the second occupant's image is bit-identical to its own
    one-shot run (same batch signature: B=1 oracle for 1 slot)."""
    bank = (SamplerPolicy.plms(3, name="fast"),)
    sched = ContinuousScheduler(eng, num_slots=1, bank=bank)
    reqs = make_requests(cfg, 2, seed=8, bank=bank)
    m = sched.run(reqs, ledger=False)
    m.pop("state")
    # both occupants of the single row, sequentially: 3 + 3 steps
    assert m["engine_steps"] == 6
    for r in reqs:
        out = eng.generate(r.tokens, None,
                           latents=jnp.array(r.latents),
                           sampler_policy=bank[0], sampler_bank=bank)
        np.testing.assert_array_equal(
            r.image, np.asarray(jax.device_get(out.images[0])),
            err_msg=f"request {r.rid} (row re-use leaked state)")


def test_make_edit_requests_shape(cfg):
    reqs = make_edit_requests(cfg, 3, seed=6, edit_fraction=0.25)
    assert len(reqs) == 3
    s = cfg.unet.latent_size
    w = max(1, int(round(0.25 * s)))
    lats = [np.asarray(r.latents) for r in reqs]
    for lat in lats:
        assert lat.shape == (1, s, s, cfg.unet.in_channels)
    # requests share a base latent: pairwise differences are confined to
    # the union of two edit windows — far fewer than half the pixels
    diff = np.any(lats[0] != lats[1], axis=-1)
    assert 0 < diff.sum() <= 2 * w * w
    # deterministic per seed
    again = make_edit_requests(cfg, 3, seed=6, edit_fraction=0.25)
    assert np.array_equal(lats[0], np.asarray(again[0].latents))
    # distinct from the t2i builder's independent draws
    t2i = make_requests(cfg, 2, seed=6)
    d = np.any(np.asarray(t2i[0].latents) != np.asarray(t2i[1].latents),
               axis=-1)
    assert d.sum() > diff.sum()
