"""Core: the paper's contribution — PSSA, TIPS, DBSC quant, energy model."""
from repro.core import attention, energy, pssa, quant, tips  # noqa: F401
