"""Quantization primitives for the SD-processor reproduction.

The paper's datapath is A:INT12(unsigned) / W:INT8(signed), with TIPS
dropping selected activations to INT6.  The DBSC splits the 12-bit unsigned
activation into two *signed 7-bit* slices (6 magnitude bits + sign each):

    x (uint12)  =  x_hi * 2**6 + x_lo,   x_hi, x_lo in [0, 63]  -> int7 ok

On TPU we *simulate* integer arithmetic: values are held in int32 (exact for
these widths) and fake-quant round-trips are used where the surrounding model
runs in floating point.  The energy model charges the *intended* precision.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

# Bit widths from the paper.
ACT_BITS_HIGH = 12   # INT12 unsigned activations
ACT_BITS_LOW = 6     # INT6 unsigned activations (TIPS unimportant tokens)
WEIGHT_BITS = 8      # INT8 signed weights
SLICE_BITS = 7       # DBSC bit-slice PEs multiply int7 x int8

ACT_HIGH_MAX = (1 << ACT_BITS_HIGH) - 1   # 4095
ACT_LOW_MAX = (1 << ACT_BITS_LOW) - 1     # 63
WEIGHT_MAX = (1 << (WEIGHT_BITS - 1)) - 1  # 127
SLICE_MASK = (1 << 6) - 1                  # low 6 bits of a slice


class QTensor(NamedTuple):
    """Integer values plus the float scale used to (de)quantize."""
    values: jax.Array   # int32, exact integer payload
    scale: jax.Array    # float32 scalar or per-channel


def quantize_act(x: jax.Array, bits: int = ACT_BITS_HIGH,
                 axis=None) -> QTensor:
    """Symmetric-range unsigned activation quantization.

    Activations after the non-negative nonlinearity path (paper feeds
    unsigned INT12 into the PE).  Negative inputs are clipped at 0, matching
    an unsigned datapath — and for the same reason the scale comes from the
    POSITIVE range only (``max(x, 0)``): a large negative pre-activation
    can never be represented, so letting it inflate ``amax`` (as the seed's
    ``|x|`` reduction did) just wastes INT12/INT6 codes on headroom no
    value occupies and coarsens every representable positive.
    """
    qmax = (1 << bits) - 1
    pos = jnp.maximum(x, 0.0)
    if axis is None:
        amax = jnp.max(pos)
    else:
        amax = jnp.max(pos, axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), 0, qmax).astype(jnp.int32)
    return QTensor(q, scale.astype(jnp.float32))


def quantize_weight(w: jax.Array, bits: int = WEIGHT_BITS,
                    axis=None) -> QTensor:
    """Symmetric signed weight quantization (per-tensor or per-channel)."""
    qmax = (1 << (bits - 1)) - 1
    if axis is None:
        amax = jnp.max(jnp.abs(w))
    else:
        amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax).astype(jnp.int32)
    return QTensor(q, scale.astype(jnp.float32))


def dequantize(q: QTensor) -> jax.Array:
    return q.values.astype(jnp.float32) * q.scale


def fake_quant_act(x: jax.Array, bits: int = ACT_BITS_HIGH,
                   axis=None) -> jax.Array:
    """Round-trip quantization for quality experiments (straight-through)."""
    q = quantize_act(x, bits, axis)
    y = dequantize(q)
    return x + jax.lax.stop_gradient(y - x)


def fake_quant_weight(w: jax.Array, bits: int = WEIGHT_BITS,
                      axis=None) -> jax.Array:
    q = quantize_weight(w, bits, axis)
    y = dequantize(q)
    return w + jax.lax.stop_gradient(y - w)


def bitslice_split(x_int: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Split an unsigned INT12 payload into (hi, lo) 6-bit planes.

    Both planes fit the paper's signed 7-bit bit-slice PE operand range.
    ``x == hi * 64 + lo`` exactly.
    """
    lo = jnp.bitwise_and(x_int, SLICE_MASK)
    hi = jnp.right_shift(x_int, 6)
    return hi.astype(jnp.int32), lo.astype(jnp.int32)


def bitslice_merge(hi: jax.Array, lo: jax.Array) -> jax.Array:
    return (hi << 6) + lo


@functools.partial(jax.jit, static_argnames=("precision_bits",))
def quantized_matmul_reference(x: jax.Array, w: jax.Array,
                               precision_bits: int = ACT_BITS_HIGH):
    """INT-exact x @ w with per-tensor scales; oracle for the DBSC kernel."""
    qx = quantize_act(x, precision_bits)
    qw = quantize_weight(w)
    acc = jnp.matmul(qx.values, qw.values,
                     preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (qx.scale * qw.scale)


def mixed_precision_quantize(x: jax.Array, important: jax.Array,
                             scale: jax.Array | None = None) -> QTensor:
    """TIPS mixed-precision activation quantization.

    ``important`` is a boolean per-row (token) mask: True rows keep INT12,
    False rows are re-quantized to INT6 *on the same scale grid* (the paper's
    SIMD core quantizes both from the same cross-attention output; INT6 rows
    simply drop the 6 LSBs -> values live on a 64x coarser grid).
    """
    q = quantize_act(x, ACT_BITS_HIGH) if scale is None else QTensor(
        jnp.clip(jnp.round(x / scale), 0, ACT_HIGH_MAX).astype(jnp.int32),
        jnp.asarray(scale, jnp.float32))
    # INT6 on the same grid: keep the 6 MSBs (i.e. zero the low 6 bits).
    low = jnp.left_shift(jnp.right_shift(q.values, 6), 6)
    vals = jnp.where(important[..., None], q.values, low)
    return QTensor(vals, q.scale)
