"""Attention modules with the paper's features folded in (pure JAX).

``self_attention_pssa``  — pixel-wise self-attention whose post-softmax score
matrix is threshold-pruned (PSSA step 1) before the value matmul, and whose
compression statistics are returned for the EMA ledger.

``cross_attention_tips`` — cross-attention that additionally emits the CLS
attention score per query (CAS) for the IPSU (TIPS spotting).

``self_attention_pssa_fused`` — the same contract through the blocked
Pallas kernel (``repro.kernels.pssa_attention``): the score matrix never
exists in memory, and the PSSA byte accounting is assembled from integer
counters the kernel accumulates per query row.  Selection between the two
lives in ``repro.kernels.dispatch`` (``KernelPolicy``).

``cross_attention_tips_fused`` — cross-attention through the blocked
Pallas kernel (``repro.kernels.cross_attention_tips``): the (B, H, Tq, Tk)
probability tensor never exists in memory; the per-head CAS rides out of
the kernel and importance spotting happens on it downstream, shared with
the reference path (``core.precision.spot_cas``).

``self_attention_pssa`` is deliberately materializing — that is the paper's
*baseline* dataflow (SAS spills to DRAM) and the thing PSSA compresses; it
stays the stats oracle the fused path is tested against.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import pssa, precision as precision_mod, tips
from repro.kernels.cross_attention_tips.ops import cross_attention_cas
from repro.kernels.pssa_attention.ops import pssa_attention


class SelfAttnOut(NamedTuple):
    out: jax.Array
    stats: pssa.PSSAStats       # PSSARowCounters under ``row_stats``


def self_attention_pssa(q: jax.Array, k: jax.Array, v: jax.Array,
                        patch: int,
                        threshold: float = pssa.DEFAULT_THRESHOLD,
                        prune_scores: bool = True,
                        stats_rows: int | None = None,
                        reference_stats: bool = False,
                        row_stats: bool = False) -> SelfAttnOut:
    """(B, H, T, d) q/k/v -> (B, H, T, d); scores pruned at `threshold`.

    ``stats_rows`` limits the compression accounting to the first N batch
    rows (static).  The fused-CFG sampler sets it to the cond half: the
    energy ledger only ever consumes cond-prompt statistics, so skipping
    the uncond half keeps stats bit-identical to a cond-only call while
    halving the accounting cost per step.

    ``row_stats`` keeps the integer counters PER ROW instead of folding
    them: ``stats`` becomes a ``pssa.PSSARowCounters`` with (B,) leaves —
    the slot-serving runtime scatters them into per-iteration ledger
    buckets (rows sit at heterogeneous denoising steps).  Summing rows
    reproduces the folded counters bit-for-bit.
    """
    d = q.shape[-1]
    # per-row thresholds (phase-scheduled sampling): a (B,) array is
    # broadcast to (B, 1, 1, 1) — pruning and every counter stay the same
    # elementwise comparisons, and the stats slice carries its rows'
    # thresholds with it
    if getattr(threshold, "ndim", 0) == 1:
        threshold = threshold.reshape(threshold.shape[0], 1, 1, 1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(d))
    probs = jax.nn.softmax(scores, axis=-1)
    if prune_scores:
        probs_used = pssa.prune(probs, threshold)
    else:
        probs_used = probs
    probs_stat = probs if stats_rows is None else probs[:stats_rows]
    thr_stat = threshold
    if stats_rows is not None and getattr(threshold, "ndim", 0) == 4:
        thr_stat = threshold[:stats_rows]
    if row_stats:
        stats = pssa.row_counters(probs_stat, patch, thr_stat)
    else:
        compress = (pssa.compress_stats_reference if reference_stats
                    else pssa.compress_stats)
        stats = compress(probs_stat, patch, thr_stat)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs_used, v)
    return SelfAttnOut(out=out, stats=stats)


def self_attention_pssa_fused(q: jax.Array, k: jax.Array, v: jax.Array,
                              patch: int,
                              threshold: float = pssa.DEFAULT_THRESHOLD,
                              stats_rows: int | None = None,
                              interpret: bool | None = None,
                              bq: int = 128, bk: int = 128,
                              row_stats: bool = False) -> SelfAttnOut:
    """``self_attention_pssa`` through the blocked Pallas kernel.

    The (B, H, T, T) score matrix is never materialized: the kernel streams
    K blocks (two-pass online softmax), prunes at ``threshold`` before the
    value matmul, and accumulates the two PSSA counters — surviving-score
    count and patch-XOR bitmap popcount — per query row.  ``PSSAStats`` is
    assembled from those integer counters via ``pssa.stats_from_counters``,
    sharing the byte arithmetic with the materializing reference (equal
    counters => bit-identical stats).  ``stats_rows`` restricts accounting
    to the first N batch rows exactly as the reference does (row slices
    commute with the per-row counters).  Always prunes; callers wanting
    ``prune_scores=False`` or the seed stats oracle use the reference path
    (the dispatch layer downgrades those combinations).
    """
    b, h, t, d = q.shape
    out, nnz_rows, xor_rows = pssa_attention(
        q, k, v, threshold, patch=patch, interpret=interpret, bq=bq, bk=bk)
    rows = b if stats_rows is None else stats_rows
    x64 = bool(jax.config.read("jax_enable_x64"))
    int_dtype = jnp.int64 if x64 else jnp.int32
    if row_stats:
        # fold heads + query rows only: (B, H, T) -> (B,) per-row counters
        stats = pssa.PSSARowCounters(
            nnz=jnp.sum(nnz_rows[:rows], axis=(1, 2), dtype=int_dtype),
            ones_xor=jnp.sum(xor_rows[:rows], axis=(1, 2), dtype=int_dtype))
        return SelfAttnOut(out=out, stats=stats)
    nnz = jnp.sum(nnz_rows[:rows], dtype=int_dtype)
    ones_xor = jnp.sum(xor_rows[:rows], dtype=int_dtype)
    stats = pssa.stats_from_counters(nnz, ones_xor, lead=rows * h,
                                     tq=t, tk=t, patch=patch)
    return SelfAttnOut(out=out, stats=stats)


class CrossAttnOut(NamedTuple):
    out: jax.Array
    tips_result: tips.TIPSResult   # reported stats (cond rows under CFG);
    #                                TIPSRowCounters under ``row_stats``
    important_full: jax.Array      # full-batch mask for the FFN precision


def _spot_and_slice(cas: jax.Array, precision, stats_rows: int | None,
                    row_stats: bool = False, threshold_scale=None):
    """Shared spotting tail of both cross-attention implementations.

    ``cas`` is the head-averaged (B, Tq) CLS score; spotting (fixed or
    per-sample adaptive, per the ``PrecisionPolicy``) runs on it
    identically for the reference and fused paths, so routing parity
    reduces to CAS parity.  Returns (reported TIPSResult, full-batch
    importance mask) — with ``stats_rows`` the reported stats cover the
    first N rows only (the cond half under fused CFG), which commutes
    with spotting because both modes decide per sample.

    ``row_stats``: report a ``tips.TIPSRowCounters`` instead — the (B,)
    integer count of spotted-important tokens per row (slot-serving
    scatters these into per-iteration ledger buckets).

    ``threshold_scale`` (a (B,) float32 or None) is the phase-scheduled
    per-row scale on the spotting threshold (``precision.spot_cas``).
    """
    spotted = precision_mod.spot_cas(cas, precision,
                                     threshold_scale=threshold_scale)
    important_full = spotted.important
    if row_stats:
        imp = (spotted.important if stats_rows is None
               else spotted.important[:stats_rows])
        return tips.TIPSRowCounters(
            important=jnp.sum(imp, axis=-1, dtype=jnp.int32)), important_full
    if stats_rows is not None:
        imp = spotted.important[:stats_rows]
        spotted = tips.TIPSResult(
            important=imp, cas=spotted.cas[:stats_rows],
            low_precision_ratio=1.0 - jnp.mean(imp.astype(jnp.float32)))
    return spotted, important_full


def _as_precision_policy(precision, threshold, cls_index):
    """Legacy-call shim: a bare ``threshold`` means fixed spotting."""
    if precision is not None:
        if threshold is not None:
            raise ValueError(
                "pass either precision= or the legacy threshold=, not both "
                "(the policy carries the threshold)")
        return precision
    if threshold is None:
        raise ValueError("pass either precision= or threshold=")
    return precision_mod.PrecisionPolicy(threshold=threshold,
                                         cls_index=cls_index)


def cross_attention_tips(q: jax.Array, k_text: jax.Array, v_text: jax.Array,
                         threshold: float | None = None,
                         cls_index: int = 0,
                         stats_rows: int | None = None,
                         precision=None,
                         row_stats: bool = False,
                         threshold_scale=None) -> CrossAttnOut:
    """(B, H, Tq, d) pixel queries x (B, H, Tk, d) text keys, with TIPS.

    ``precision`` (a ``core.precision.PrecisionPolicy``) selects the
    spotting mode; passing only ``threshold`` keeps the legacy
    fixed-threshold behaviour.  The returned ``tips_result.important``
    always covers the FULL batch (the FFN precision mask needs every row);
    with ``stats_rows`` set, the *reported* CAS / low-precision ratio are
    restricted to the first N rows — the cond half under fused CFG —
    matching a cond-only call.
    """
    precision = _as_precision_policy(precision, threshold, cls_index)
    d = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k_text) / jnp.sqrt(float(d))
    probs = jax.nn.softmax(scores, axis=-1)
    cas = jnp.mean(probs[..., :, precision.cls_index], axis=-2)   # (B, Tq)
    spotted, important_full = _spot_and_slice(cas, precision, stats_rows,
                                              row_stats, threshold_scale)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v_text)
    return CrossAttnOut(out=out, tips_result=spotted,
                        important_full=important_full)


def cross_attention_tips_fused(q: jax.Array, k_text: jax.Array,
                               v_text: jax.Array,
                               threshold: float | None = None,
                               cls_index: int = 0,
                               stats_rows: int | None = None,
                               precision=None,
                               interpret: bool | None = None,
                               bq: int = 128,
                               row_stats: bool = False,
                               threshold_scale=None) -> CrossAttnOut:
    """``cross_attention_tips`` through the blocked Pallas kernel.

    The (B, H, Tq, Tk) probability tensor is never materialized: the
    kernel streams query blocks against the (small) text-key stripe and
    emits the per-head CAS directly (``repro.kernels.cross_attention_tips``).
    Spotting runs on the head-averaged CAS downstream, shared with the
    reference — the importance mask, low-precision ratio, and every ledger
    term derived from them are bit-identical to the reference path; the
    raw CAS is ulp-identical (the reference itself is not bitwise stable
    across jit contexts — DESIGN.md §7).
    """
    precision = _as_precision_policy(precision, threshold, cls_index)
    out, cas_bh = cross_attention_cas(q, k_text, v_text,
                                      cls_index=precision.cls_index,
                                      interpret=interpret, bq=bq)
    cas = jnp.mean(cas_bh, axis=-2)                               # (B, Tq)
    spotted, important_full = _spot_and_slice(cas, precision, stats_rows,
                                              row_stats, threshold_scale)
    return CrossAttnOut(out=out, tips_result=spotted,
                        important_full=important_full)
