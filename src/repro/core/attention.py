"""Attention modules with the paper's features folded in (pure JAX).

``self_attention_pssa``  — pixel-wise self-attention whose post-softmax score
matrix is threshold-pruned (PSSA step 1) before the value matmul, and whose
compression statistics are returned for the EMA ledger.

``cross_attention_tips`` — cross-attention that additionally emits the CLS
attention score per query (CAS) for the IPSU (TIPS spotting).

Both are deliberately materializing the score matrix — that is the paper's
dataflow (SAS spills to DRAM) and the thing PSSA compresses.  The Pallas
kernels in ``repro.kernels.pssa_attention`` implement the blocked/fused
TPU-native version used by the performance path.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import pssa, tips


class SelfAttnOut(NamedTuple):
    out: jax.Array
    stats: pssa.PSSAStats


def self_attention_pssa(q: jax.Array, k: jax.Array, v: jax.Array,
                        patch: int,
                        threshold: float = pssa.DEFAULT_THRESHOLD,
                        prune_scores: bool = True) -> SelfAttnOut:
    """(B, H, T, d) q/k/v -> (B, H, T, d); scores pruned at `threshold`."""
    d = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(d))
    probs = jax.nn.softmax(scores, axis=-1)
    if prune_scores:
        probs_used = pssa.prune(probs, threshold)
    else:
        probs_used = probs
    stats = pssa.compress_stats(probs, patch, threshold)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs_used, v)
    return SelfAttnOut(out=out, stats=stats)


class CrossAttnOut(NamedTuple):
    out: jax.Array
    tips_result: tips.TIPSResult


def cross_attention_tips(q: jax.Array, k_text: jax.Array, v_text: jax.Array,
                         threshold: float,
                         cls_index: int = 0) -> CrossAttnOut:
    """(B, H, Tq, d) pixel queries x (B, H, Tk, d) text keys, with TIPS."""
    d = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k_text) / jnp.sqrt(float(d))
    probs = jax.nn.softmax(scores, axis=-1)
    spotted = tips.spot(probs, threshold, cls_index)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v_text)
    return CrossAttnOut(out=out, tips_result=spotted)
