"""ServePolicies — the unified serving-policy bundle (DESIGN.md §13).

The engine/serving surface grew four parallel policy objects — kernel
routing (``kernels.dispatch.KernelPolicy``), TIPS/DBSC precision
(``core.precision.PrecisionPolicy``), temporal patch reuse
(``core.reuse.ReusePolicy``) and sampling (``diffusion.solvers
.SamplerPolicy`` / bank) — each threaded as its own kwarg through
``DiffusionEngine``, ``generate``, both CLIs and the schedulers, plus two
legacy fold-in knobs on ``UNetConfig``.  Every call site had to agree on
all four or silently fork an executable-cache entry.

``ServePolicies`` is the one frozen/hashable bundle they all consume:

* ``parse()`` builds it from the CLI flag specs (``--kernels``,
  ``--tips``, ``--reuse``, ``--solver``, ``--tiers``) — the shared
  wiring in ``repro.launch.cli`` feeds both CLIs and the cluster router
  through this single entry point;
* ``key()`` is the single policy component of the engine's executable
  cache keys — legacy spellings (per-policy kwargs, ``UNetConfig``
  fold-in knobs) normalize through the ``effective_*`` accessors into
  the SAME key, so old and new call sites share executables;
* ``describe()`` is the JSON view serving metrics and bench records
  embed, and it round-trips: ``parse(**specs_of(describe()))``
  reconstructs an equal bundle.

The legacy kwargs keep working as deprecated aliases (they emit
``DeprecationWarning`` with the ``repro legacy:`` message prefix — the
tier-1 suite runs with ``-W error::DeprecationWarning`` plus an
exclusion list for exactly this prefix, proving internal code paths are
warning-free while tests exercise the aliases deliberately).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.precision import PrecisionPolicy
from repro.core.reuse import ReusePolicy
from repro.kernels.dispatch import KernelPolicy

if False:  # typing only — see _solvers() for the runtime import
    from repro.diffusion.solvers import SamplerPolicy  # noqa: F401


def _solvers():
    # repro.diffusion.engine imports this module at its top level, and
    # the repro.diffusion package __init__ pulls engine in — importing
    # solvers lazily keeps ServePolicies importable from either side of
    # that cycle (the function runs only after this module is complete)
    from repro.diffusion import solvers

    return solvers

#: Message prefix of every legacy-alias DeprecationWarning in this repo.
#: pyproject.toml's filterwarnings exclusion list keys on it: the tier-1
#: suite errors on any OTHER DeprecationWarning, so internal code paths
#: are proven warning-free while the aliases stay usable (and tested).
LEGACY_WARNING_PREFIX = "repro legacy: "


@dataclasses.dataclass(frozen=True)
class ServePolicies:
    """Frozen bundle of every serving-policy axis.

    ``sampler`` / ``bank`` follow the engine's contract: ``sampler`` is
    the per-request solver/step-budget policy, ``bank`` the static tuple
    of DISTINCT policies a mixed-tier slot batch may carry (``sampler``
    must be an entry of ``bank`` when both are set; a bank without a
    sampler serves tiered traffic where each request picks its entry by
    ``policy_index``).  ``None`` on either keeps the config's DDIM
    schedule — byte-identical to the pre-bundle default path.
    """
    kernels: KernelPolicy = KernelPolicy()
    precision: PrecisionPolicy = PrecisionPolicy()
    reuse: ReusePolicy = ReusePolicy()
    sampler: Optional[SamplerPolicy] = None
    bank: Optional[Tuple[SamplerPolicy, ...]] = None

    def __post_init__(self):
        if self.bank is not None:
            object.__setattr__(self, "bank",
                               _solvers().as_bank(self.bank))
            if self.sampler is not None and self.sampler not in self.bank:
                raise ValueError(
                    f"ServePolicies.sampler {self.sampler.key()} is not an "
                    f"entry of the bank {[p.key() for p in self.bank]}")

    # -- construction ----------------------------------------------------
    @classmethod
    def parse(cls, kernels: str = "auto", tips: str = "fixed",
              reuse: str = "off", solver: str = "",
              tiers=None) -> "ServePolicies":
        """Build the bundle from the CLI flag specs.

        Mirrors the flags ``launch.cli.add_policy_args`` registers:
        ``kernels``/``tips``/``reuse`` are the per-axis policy specs,
        ``solver`` a single ``SamplerPolicy`` spec applied to every
        request, ``tiers`` a list of specs forming a mixed-tier bank.
        ``solver`` and ``tiers`` are exclusive (a bank already names
        every policy in flight — the same contract the CLIs enforce).
        """
        if solver and tiers:
            raise ValueError(
                "ServePolicies.parse: solver= and tiers= are exclusive "
                "(a bank already names every policy in flight)")
        bank = (_solvers().as_bank(tuple(_solvers().SamplerPolicy.parse(t)
                                          for t in tiers))
                if tiers else None)
        return cls(kernels=KernelPolicy.parse(kernels),
                   precision=PrecisionPolicy.parse(tips),
                   reuse=ReusePolicy.parse(reuse),
                   sampler=(_solvers().SamplerPolicy.parse(solver)
                        if solver else None),
                   bank=bank)

    @classmethod
    def from_config(cls, unet_cfg, sampler=None, bank=None
                    ) -> "ServePolicies":
        """Bundle the EFFECTIVE policies of a denoiser config.

        Reads through the ``effective_*`` accessors, so a config still
        carrying the legacy fold-in knobs (``use_dbsc_kernel``,
        ``tips_threshold``) lands on the same bundle — and therefore the
        same executable-cache key — as the modern spelling.
        """
        return cls(kernels=unet_cfg.effective_kernel_policy(),
                   precision=unet_cfg.effective_precision(),
                   reuse=unet_cfg.reuse_policy,
                   sampler=sampler,
                   bank=_solvers().as_bank(bank) if bank is not None
                   else None)

    # -- application -----------------------------------------------------
    def apply(self, cfg):
        """Pipeline config with this bundle's per-axis policies installed.

        Returns ``cfg`` (a ``pipeline.PipelineConfig``) with
        ``cfg.unet``'s ``kernel_policy`` / ``precision`` /
        ``reuse_policy`` replaced; the sampler axes are runtime
        arguments, not config fields, so they don't touch the config.
        """
        return dataclasses.replace(
            cfg, unet=dataclasses.replace(cfg.unet,
                                          kernel_policy=self.kernels,
                                          precision=self.precision,
                                          reuse_policy=self.reuse))

    def with_sampling(self, sampler=None, bank=None) -> "ServePolicies":
        """Copy with the sampling axes replaced (kernel/precision/reuse
        untouched) — how the engine folds per-call sampler arguments into
        the cache key."""
        return dataclasses.replace(
            self, sampler=sampler,
            bank=_solvers().as_bank(bank) if bank is not None else None)

    # -- views -----------------------------------------------------------
    def key(self) -> tuple:
        """The single policy component of an executable-cache key.

        A plain tuple of the five frozen/hashable axes.  Everything that
        can change traced computation is in here; nothing else is —
        equal bundles (however spelled: modern kwargs, legacy aliases,
        config fold-ins) share executables.
        """
        return (self.kernels, self.precision, self.reuse,
                self.sampler, self.bank)

    def describe(self) -> dict:
        """JSON-friendly view for serving metrics / bench records."""
        return {
            "kernels": self.kernels.describe(),
            "precision": self.precision.describe(),
            "reuse": self.reuse.describe(),
            "sampler": (None if self.sampler is None
                        else self.sampler.describe()),
            "bank": (None if self.bank is None
                     else [p.describe() for p in self.bank]),
        }


def legacy_warning(message: str) -> None:
    """Emit one repo-standard legacy-alias DeprecationWarning.

    All deprecation messages share ``LEGACY_WARNING_PREFIX`` so the
    tier-1 ``filterwarnings`` exclusion list can single them out while
    every other DeprecationWarning stays an error.
    """
    import warnings

    warnings.warn(LEGACY_WARNING_PREFIX + message, DeprecationWarning,
                  stacklevel=3)
