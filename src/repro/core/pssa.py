"""PSSA — Patch Similarity-based Sparsity Augmentation (paper §III).

Compresses the self-attention score (SAS) matrix before it is written to
external memory:

  1. *Prune*: zero all post-softmax scores below a fixed threshold.
  2. *Patch-XOR*: the SAS of a pixel-wise self-attention layer over an HxW
     feature map decomposes into (H*H) patches of shape (W, W) — query-row x
     key-row.  Adjacent patches along the key-row (horizontal) direction are
     similar, so XOR-ing adjacent *bitmap* patches yields a much sparser
     delta bitmap.  The first patch of each group is kept verbatim.
  3. *Local CSR*: each (possibly delta-) patch bitmap is CSR-encoded
     independently; small patches need small col indices (log2 W bits) and
     small row pointers, which beats one global CSR.

Everything here computes *exact* compressed byte counts so the energy model
is bytes-accurate.  The compression itself is lossless given the pruned SAS.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

# Fixed prune threshold on softmax scores (2^-13 — hardware-friendly).  The
# paper only says "predefined fixed threshold"; the value pins the operating
# point: a threshold t caps post-softmax density at 1/(t*T), and 2^-13 puts
# the T=4096 self-attention layers (the EMA-dominant ones) at the density
# where the paper's measured 61.2 % SAS EMA reduction is reachable.
DEFAULT_THRESHOLD = 1.0 / 8192.0


class PSSAStats(NamedTuple):
    """Byte-exact accounting of one SAS compression (all float scalars)."""
    nnz: jax.Array                # surviving scores after pruning
    total: jax.Array              # Tq * Tk elements
    bitmap_ones_raw: jax.Array    # ones in the pruned bitmap
    bitmap_ones_xor: jax.Array    # ones after patch-XOR (what CSR encodes)
    bytes_baseline: jax.Array     # dense SAS, no compression
    bytes_values: jax.Array       # payload of surviving values
    bytes_index_csr_global: jax.Array   # plain CSR over whole SAS (no XOR)
    bytes_index_rle: jax.Array          # run-length encoding of the bitmap
    bytes_index_pssa: jax.Array         # local per-patch CSR over XOR bitmap
    bytes_pssa_total: jax.Array         # values + PSSA index


def prune(sas: jax.Array, threshold: float = DEFAULT_THRESHOLD) -> jax.Array:
    """Unstructured threshold pruning of post-softmax scores."""
    return jnp.where(sas >= threshold, sas, 0.0)


def bitmap(sas_pruned: jax.Array) -> jax.Array:
    return (sas_pruned != 0.0)


def patch_xor(bm: jax.Array, patch: int) -> jax.Array:
    """XOR adjacent bitmap patches along the key (last) axis.

    ``bm``: (..., Tq, Tk) boolean.  Patches are (patch, patch) tiles; the
    XOR acts between horizontally-adjacent tiles, which for a bitmap reduces
    to a column-block delta: out[..., :, j] = bm[..., :, j] ^ bm[..., :, j-patch]
    for j >= patch within each row, with the first patch-column kept.
    """
    tk = bm.shape[-1]
    assert tk % patch == 0, (tk, patch)
    n = tk // patch
    r = bm.reshape(*bm.shape[:-1], n, patch)
    first = r[..., :1, :]
    delta = jnp.logical_xor(r[..., 1:, :], r[..., :-1, :])
    out = jnp.concatenate([first, delta], axis=-2)
    return out.reshape(bm.shape)


def patch_unxor(delta_bm: jax.Array, patch: int) -> jax.Array:
    """Inverse of :func:`patch_xor` (cumulative XOR over patch columns)."""
    tk = delta_bm.shape[-1]
    n = tk // patch
    r = delta_bm.reshape(*delta_bm.shape[:-1], n, patch)

    def step(carry, x):
        cur = jnp.logical_xor(carry, x)
        return cur, cur

    # scan over the patch-column axis
    r_t = jnp.moveaxis(r, -2, 0)
    _, out = jax.lax.scan(step, jnp.zeros_like(r_t[0]), r_t)
    out = jnp.moveaxis(out, 0, -2)
    return out.reshape(delta_bm.shape)


def compress_stats(sas: jax.Array, patch: int,
                   threshold: float = DEFAULT_THRESHOLD,
                   value_bits: int = 12) -> PSSAStats:
    """Exact compressed sizes (in bytes) for one SAS of shape (..., Tq, Tk).

    Leading axes (heads, batch) are folded into the totals.
    """
    pruned = prune(sas, threshold)
    bm = bitmap(pruned)
    xbm = patch_xor(bm, patch)

    tq, tk = sas.shape[-2], sas.shape[-1]
    lead = 1
    for s in sas.shape[:-2]:
        lead *= s

    total = jnp.asarray(lead * tq * tk, jnp.float64 if jax.config.read(
        "jax_enable_x64") else jnp.float32)
    nnz = jnp.sum(bm).astype(jnp.float32)
    ones_xor = jnp.sum(xbm).astype(jnp.float32)

    bytes_baseline = total * value_bits / 8.0
    bytes_values = nnz * value_bits / 8.0

    # --- plain global CSR over the pruned bitmap (per head-slice) ---
    col_bits_g = max(1, math.ceil(math.log2(tk)))
    ptr_bits_g = max(1, math.ceil(math.log2(tq * tk + 1)))
    bytes_csr = (nnz * col_bits_g + lead * (tq + 1) * ptr_bits_g) / 8.0

    # --- RLE: classic zero-run stream (the hardware format the paper
    # compares against): one run-length field per surviving value, wide
    # enough for the worst-case in-row zero run (log2 Tk bits). ---
    run_bits = max(1, math.ceil(math.log2(tk)))
    bytes_rle = nnz * run_bits / 8.0

    # --- PSSA: local CSR per (patch x patch) tile of the XOR bitmap ---
    col_bits_l = max(1, math.ceil(math.log2(patch)))
    ptr_bits_l = max(1, math.ceil(math.log2(patch * patch + 1)))
    n_tiles = lead * (tq // patch) * (tk // patch)
    bytes_pssa_idx = (ones_xor * col_bits_l
                      + n_tiles * (patch + 1) * ptr_bits_l) / 8.0

    return PSSAStats(
        nnz=nnz, total=total,
        bitmap_ones_raw=nnz, bitmap_ones_xor=ones_xor,
        bytes_baseline=bytes_baseline,
        bytes_values=bytes_values,
        bytes_index_csr_global=bytes_csr,
        bytes_index_rle=bytes_rle,
        bytes_index_pssa=bytes_pssa_idx,
        bytes_pssa_total=bytes_values + bytes_pssa_idx,
    )


def compress_decompress(sas: jax.Array, patch: int,
                        threshold: float = DEFAULT_THRESHOLD) -> jax.Array:
    """Losslessness check: prune -> bitmap -> XOR -> un-XOR -> re-mask.

    Returns the reconstructed pruned SAS; must equal ``prune(sas)`` exactly.
    """
    pruned = prune(sas, threshold)
    bm = bitmap(pruned)
    xbm = patch_xor(bm, patch)
    bm2 = patch_unxor(xbm, patch)
    return jnp.where(bm2, pruned, 0.0)


def ema_reduction(stats: PSSAStats) -> jax.Array:
    """Fractional EMA reduction of the SAS vs the uncompressed baseline."""
    return 1.0 - stats.bytes_pssa_total / stats.bytes_baseline
