"""PSSA — Patch Similarity-based Sparsity Augmentation (paper §III).

Compresses the self-attention score (SAS) matrix before it is written to
external memory:

  1. *Prune*: zero all post-softmax scores below a fixed threshold.
  2. *Patch-XOR*: the SAS of a pixel-wise self-attention layer over an HxW
     feature map decomposes into (H*H) patches of shape (W, W) — query-row x
     key-row.  Adjacent patches along the key-row (horizontal) direction are
     similar, so XOR-ing adjacent *bitmap* patches yields a much sparser
     delta bitmap.  The first patch of each group is kept verbatim.
  3. *Local CSR*: each (possibly delta-) patch bitmap is CSR-encoded
     independently; small patches need small col indices (log2 W bits) and
     small row pointers, which beats one global CSR.

Everything here computes *exact* compressed byte counts so the energy model
is bytes-accurate.  The compression itself is lossless given the pruned SAS.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

# Fixed prune threshold on softmax scores (2^-13 — hardware-friendly).  The
# paper only says "predefined fixed threshold"; the value pins the operating
# point: a threshold t caps post-softmax density at 1/(t*T), and 2^-13 puts
# the T=4096 self-attention layers (the EMA-dominant ones) at the density
# where the paper's measured 61.2 % SAS EMA reduction is reachable.
DEFAULT_THRESHOLD = 1.0 / 8192.0


class PSSAStats(NamedTuple):
    """Byte-exact accounting of one SAS compression (all float scalars)."""
    nnz: jax.Array                # surviving scores after pruning
    total: jax.Array              # Tq * Tk elements
    bitmap_ones_raw: jax.Array    # ones in the pruned bitmap
    bitmap_ones_xor: jax.Array    # ones after patch-XOR (what CSR encodes)
    bytes_baseline: jax.Array     # dense SAS, no compression
    bytes_values: jax.Array       # payload of surviving values
    bytes_index_csr_global: jax.Array   # plain CSR over whole SAS (no XOR)
    bytes_index_rle: jax.Array          # run-length encoding of the bitmap
    bytes_index_pssa: jax.Array         # local per-patch CSR over XOR bitmap
    bytes_pssa_total: jax.Array         # values + PSSA index


def prune(sas: jax.Array, threshold: float = DEFAULT_THRESHOLD) -> jax.Array:
    """Unstructured threshold pruning of post-softmax scores."""
    return jnp.where(sas >= threshold, sas, 0.0)


def bitmap(sas_pruned: jax.Array) -> jax.Array:
    return (sas_pruned != 0.0)


def patch_xor(bm: jax.Array, patch: int) -> jax.Array:
    """XOR adjacent bitmap patches along the key (last) axis.

    ``bm``: (..., Tq, Tk) boolean.  Patches are (patch, patch) tiles; the
    XOR acts between horizontally-adjacent tiles, which for a bitmap reduces
    to a column-block delta: out[..., :, j] = bm[..., :, j] ^ bm[..., :, j-patch]
    for j >= patch within each row, with the first patch-column kept.
    """
    tk = bm.shape[-1]
    assert tk % patch == 0, (tk, patch)
    n = tk // patch
    r = bm.reshape(*bm.shape[:-1], n, patch)
    first = r[..., :1, :]
    delta = jnp.logical_xor(r[..., 1:, :], r[..., :-1, :])
    out = jnp.concatenate([first, delta], axis=-2)
    return out.reshape(bm.shape)


def patch_unxor(delta_bm: jax.Array, patch: int) -> jax.Array:
    """Inverse of :func:`patch_xor` (cumulative XOR over patch columns)."""
    tk = delta_bm.shape[-1]
    n = tk // patch
    r = delta_bm.reshape(*delta_bm.shape[:-1], n, patch)

    def step(carry, x):
        cur = jnp.logical_xor(carry, x)
        return cur, cur

    # scan over the patch-column axis
    r_t = jnp.moveaxis(r, -2, 0)
    _, out = jax.lax.scan(step, jnp.zeros_like(r_t[0]), r_t)
    out = jnp.moveaxis(out, 0, -2)
    return out.reshape(delta_bm.shape)


def index_bit_widths(tq: int, tk: int, patch: int) -> dict:
    """Static field widths of the three index formats (exact Python ints)."""
    return {
        "col_bits_global": max(1, math.ceil(math.log2(tk))),
        "ptr_bits_global": max(1, math.ceil(math.log2(tq * tk + 1))),
        "run_bits": max(1, math.ceil(math.log2(tk))),
        "col_bits_local": max(1, math.ceil(math.log2(patch))),
        "ptr_bits_local": max(1, math.ceil(math.log2(patch * patch + 1))),
    }


def exact_byte_counts(nnz: int, ones_xor: int, lead: int, tq: int, tk: int,
                      patch: int, value_bits: int = 12) -> dict:
    """Byte accounting from integer counters in EXACT Python arithmetic.

    Python ints never round, so this is the ground truth for any SAS size —
    including the full-geometry 4096x4096 SAS with heads folded in, where
    counters exceed float32's 24-bit integer range (~16.7M) and the
    in-graph float math (see ``compress_stats``) starts rounding.  Use this
    for ledger-grade numbers; all divisions by 8 are exact in binary
    floating point.
    """
    w = index_bit_widths(tq, tk, patch)
    total = lead * tq * tk
    n_tiles = lead * (tq // patch) * (tk // patch)
    return {
        "total": total,
        "bytes_baseline": total * value_bits / 8.0,
        "bytes_values": nnz * value_bits / 8.0,
        "bytes_index_csr_global": (nnz * w["col_bits_global"]
                                   + lead * (tq + 1)
                                   * w["ptr_bits_global"]) / 8.0,
        "bytes_index_rle": nnz * w["run_bits"] / 8.0,
        "bytes_index_pssa": (ones_xor * w["col_bits_local"]
                             + n_tiles * (patch + 1)
                             * w["ptr_bits_local"]) / 8.0,
    }


def compress_stats(sas: jax.Array, patch: int,
                   threshold: float = DEFAULT_THRESHOLD,
                   value_bits: int = 12) -> PSSAStats:
    """Exact compressed sizes (in bytes) for one SAS of shape (..., Tq, Tk).

    Leading axes (heads, batch) are folded into the totals.

    Counter precision: the bitmap populations are accumulated in INTEGER
    dtype (int64 under x64, else int32 — exact up to 2^31, far beyond the
    134M-element full-geometry SAS) and only then converted to the widest
    available float for the byte arithmetic; every static quantity
    (element totals, pointer/field widths, tile counts) is computed with
    exact Python ints before conversion.  The seed implementation did all
    of this in float32, which silently rounds integers above ~16.7M — off
    by up to 8 elements per counter at full geometry.  Under
    ``jax_enable_x64`` every stored stat is float64 and therefore exact;
    without it the single final float32 rounding is at most 0.5 ulp
    (documented, and recoverable exactly via :func:`exact_byte_counts`).
    """
    bm = bitmap(prune(sas, threshold))
    tk = sas.shape[-1]
    assert tk % patch == 0, (tk, patch)

    x64 = bool(jax.config.read("jax_enable_x64"))
    int_dtype = jnp.int64 if x64 else jnp.int32

    # dynamic counters: integer accumulation, single conversion at the end.
    # The XOR-bitmap population is summed directly from the shifted slices
    # (first patch column verbatim + pairwise deltas) without materializing
    # the full delta bitmap that patch_xor would build — the counters are
    # identical (tests pin this against compress_stats_reference) and this
    # sits on the hot path of every attention layer.
    r = bm.reshape(*bm.shape[:-1], tk // patch, patch)
    nnz = jnp.sum(bm, dtype=int_dtype)
    ones_xor = (jnp.sum(r[..., 0, :], dtype=int_dtype)
                + jnp.sum(jnp.logical_xor(r[..., 1:, :], r[..., :-1, :]),
                          dtype=int_dtype))
    return _assemble_stats(nnz, ones_xor, sas.shape, patch, value_bits)


class PSSARowCounters(NamedTuple):
    """Per-batch-row integer PSSA counters (continuous-batching stats).

    ``nnz`` / ``ones_xor`` have shape (B,): each row's surviving-score
    count and patch-XOR bitmap population, heads and query rows folded.
    Summing any subset of rows reproduces ``compress_stats``' folded
    counters for that subset EXACTLY (integer addition is associative), so
    a slot-serving runtime can scatter rows into per-iteration buckets at
    heterogeneous denoising steps and still assemble byte stats that are
    bit-identical to a one-shot batch — see ``stats_from_counters``.
    """
    nnz: jax.Array
    ones_xor: jax.Array


def row_counters(sas: jax.Array, patch: int,
                 threshold: float = DEFAULT_THRESHOLD) -> PSSARowCounters:
    """Per-row integer counters for one SAS of shape (B, ..., Tq, Tk).

    The per-row partition of :func:`compress_stats`' fused counter math:
    identical pruning/bitmap/XOR arithmetic, reduced over every axis but
    the leading batch axis.
    """
    bm = bitmap(prune(sas, threshold))
    tk = sas.shape[-1]
    assert tk % patch == 0, (tk, patch)

    x64 = bool(jax.config.read("jax_enable_x64"))
    int_dtype = jnp.int64 if x64 else jnp.int32

    r = bm.reshape(*bm.shape[:-1], tk // patch, patch)
    nnz = jnp.sum(bm, axis=tuple(range(1, bm.ndim)), dtype=int_dtype)
    first = jnp.sum(r[..., 0, :], axis=tuple(range(1, bm.ndim)),
                    dtype=int_dtype)
    delta = jnp.sum(jnp.logical_xor(r[..., 1:, :], r[..., :-1, :]),
                    axis=tuple(range(1, r.ndim)), dtype=int_dtype)
    return PSSARowCounters(nnz=nnz, ones_xor=first + delta)


def compress_stats_reference(sas: jax.Array, patch: int,
                             threshold: float = DEFAULT_THRESHOLD,
                             value_bits: int = 12) -> PSSAStats:
    """Seed implementation of :func:`compress_stats`: materialize the full
    patch-XOR delta bitmap, then count.  Byte-identical results, ~an order
    of magnitude more memory traffic — kept as the oracle the fused counter
    path is tested against, and as the baseline ``benchmarks/bench_engine``
    charges when measuring this PR's loop-vs-engine trajectory.
    """
    bm = bitmap(prune(sas, threshold))
    xbm = patch_xor(bm, patch)
    x64 = bool(jax.config.read("jax_enable_x64"))
    int_dtype = jnp.int64 if x64 else jnp.int32
    nnz = jnp.sum(bm, dtype=int_dtype)
    ones_xor = jnp.sum(xbm, dtype=int_dtype)
    return _assemble_stats(nnz, ones_xor, sas.shape, patch, value_bits)


def _assemble_stats(nnz, ones_xor, shape, patch: int,
                    value_bits: int) -> PSSAStats:
    """Byte arithmetic from integer counters (shared by both impls)."""
    tq, tk = shape[-2], shape[-1]
    lead = 1
    for s in shape[:-2]:
        lead *= s

    x64 = bool(jax.config.read("jax_enable_x64"))
    count_dtype = jnp.float64 if x64 else jnp.float32
    nnz = nnz.astype(count_dtype)
    ones_xor = ones_xor.astype(count_dtype)

    # static quantities: exact Python-int arithmetic, converted once
    w = index_bit_widths(tq, tk, patch)
    total_i = lead * tq * tk
    n_tiles = lead * (tq // patch) * (tk // patch)
    total = jnp.asarray(float(total_i), count_dtype)
    bytes_baseline = jnp.asarray(total_i * value_bits / 8.0, count_dtype)
    ptr_global = jnp.asarray(
        lead * (tq + 1) * w["ptr_bits_global"] / 8.0, count_dtype)
    ptr_local = jnp.asarray(
        n_tiles * (patch + 1) * w["ptr_bits_local"] / 8.0, count_dtype)

    bytes_values = nnz * value_bits / 8.0
    bytes_csr = nnz * (w["col_bits_global"] / 8.0) + ptr_global
    bytes_rle = nnz * (w["run_bits"] / 8.0)
    bytes_pssa_idx = ones_xor * (w["col_bits_local"] / 8.0) + ptr_local

    return PSSAStats(
        nnz=nnz, total=total,
        bitmap_ones_raw=nnz, bitmap_ones_xor=ones_xor,
        bytes_baseline=bytes_baseline,
        bytes_values=bytes_values,
        bytes_index_csr_global=bytes_csr,
        bytes_index_rle=bytes_rle,
        bytes_index_pssa=bytes_pssa_idx,
        bytes_pssa_total=bytes_values + bytes_pssa_idx,
    )


def stats_from_counters(nnz: jax.Array, ones_xor: jax.Array,
                        lead: int, tq: int, tk: int, patch: int,
                        value_bits: int = 12) -> PSSAStats:
    """``PSSAStats`` from already-accumulated integer counters.

    The fused kernel path (``kernels.pssa_attention`` with ``patch`` set)
    counts surviving scores and XOR-bitmap ones *inside* the blocked
    attention kernel — the SAS never exists in memory — and hands the two
    scalars here.  Byte assembly is shared with :func:`compress_stats`, so
    equal counters give bit-identical stats.  ``lead`` folds every leading
    axis (batch rows x heads) exactly as ``compress_stats`` folds shape.
    """
    return _assemble_stats(nnz, ones_xor, (lead, tq, tk), patch, value_bits)


def compress_decompress(sas: jax.Array, patch: int,
                        threshold: float = DEFAULT_THRESHOLD) -> jax.Array:
    """Losslessness check: prune -> bitmap -> XOR -> un-XOR -> re-mask.

    Returns the reconstructed pruned SAS; must equal ``prune(sas)`` exactly.
    """
    pruned = prune(sas, threshold)
    bm = bitmap(pruned)
    xbm = patch_xor(bm, patch)
    bm2 = patch_unxor(xbm, patch)
    return jnp.where(bm2, pruned, 0.0)


def ema_reduction(stats: PSSAStats) -> jax.Array:
    """Fractional EMA reduction of the SAS vs the uncompressed baseline."""
    return 1.0 - stats.bytes_pssa_total / stats.bytes_baseline
