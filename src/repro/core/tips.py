"""TIPS — Text-based Important Pixel Spotting (paper §IV-A).

Cross-attention computes, for every pixel (token) query, a softmax over the
text keys.  The first text key is the CLS token, which captures the global
sentence context; because softmax normalizes each query row, a *small* CLS
attention score (CAS) implies *large* text attention scores (TAS) — i.e. the
pixel is strongly tied to the prompt.  Pixels with CAS below a threshold are
"important" and keep INT12 activations through the whole following FFN
stack; the rest drop to INT6.  This is sound because neither cross-attention
nor the FFN mixes information across pixel tokens.

Generalization used for decoder-only LMs (DESIGN.md §4): the attention-sink
(first) token plays the CLS role; we call the feature ``sink_mixed_precision``
— the math is identical because the CAS/TAS inverse relation is a property
of any softmax row, not of the CLS token per se.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Paper: TIPS active for the first 20 of 25 denoising iterations; the final
# 5 are quantization-vulnerable and run full INT12.
TIPS_ACTIVE_ITERS = 20
TOTAL_ITERS = 25


class TIPSResult(NamedTuple):
    important: jax.Array      # bool (..., Tq): True -> keep INT12
    cas: jax.Array            # (..., Tq) CLS attention score per query
    low_precision_ratio: jax.Array  # scalar in [0, 1]


class TIPSRowCounters(NamedTuple):
    """Per-batch-row integer TIPS accounting (continuous-batching stats).

    ``important`` has shape (B,): the count of spotted-important tokens in
    each row's CAS (before the tips-active OR — spotting always runs; the
    activity schedule is applied per iteration by the ledger).  Summing a
    subset of rows and dividing by ``rows * Tq`` reproduces the folded
    ``low_precision_ratio`` of that subset exactly whenever the division
    is exact (power-of-two ``rows * Tq`` — always true for the model's
    power-of-two resolutions and slot counts).
    """
    important: jax.Array


def spot(cross_attn_probs: jax.Array, threshold: float,
         cls_index: int = 0) -> TIPSResult:
    """Spot important pixels from post-softmax cross-attention scores.

    ``cross_attn_probs``: (..., heads, Tq, Tk_text) softmax rows.
    CAS is averaged over heads (the IPSU sees the aggregated score).
    Important  <=>  CAS < threshold  (small CAS -> pixel follows the text).
    """
    cas = cross_attn_probs[..., :, cls_index]        # (..., heads, Tq)
    cas = jnp.mean(cas, axis=-2)                      # (..., Tq)
    important = cas < threshold
    low_ratio = 1.0 - jnp.mean(important.astype(jnp.float32))
    return TIPSResult(important=important, cas=cas,
                      low_precision_ratio=low_ratio)


def adaptive_threshold(cas: jax.Array, target_low_ratio: float) -> jax.Array:
    """Threshold that marks ``1 - target_low_ratio`` of tokens important.

    The silicon uses a predefined threshold tuned offline; this helper does
    that offline tuning (quantile of the CAS distribution).
    """
    return jnp.quantile(cas, 1.0 - target_low_ratio)


def tips_schedule(iteration: jax.Array,
                  active_iters: int = TIPS_ACTIVE_ITERS) -> jax.Array:
    """True while TIPS may down-quantize (first 20/25 iterations)."""
    return iteration < active_iters


def apply_precision_mask(x: jax.Array, important: jax.Array,
                         active: jax.Array | bool = True) -> jax.Array:
    """Fake-quant an activation tensor per the TIPS mask.

    Rows marked important round-trip through INT12; others through INT6 on
    the same scale grid (see quant.mixed_precision_quantize).  When
    ``active`` is False every row stays INT12.

    The quantization scale is computed PER SAMPLE (reduced over every
    non-batch axis), not per tensor: each image's activation grid must not
    depend on what else shares the batch, so a fused cond+uncond CFG batch
    (sampler.cfg_batch) produces bitwise-identical results to two separate
    calls — the invariant tests/test_engine.py pins down.  Per-sample is
    also what the silicon does: the SIMD core rescales one image's
    activations at a time.
    """
    from repro.core import quant

    imp = jnp.logical_or(important, jnp.logical_not(active))
    axes = tuple(range(1, x.ndim))
    # unsigned grid: the scale spans the positive range only (negatives
    # clip to 0 in quant.mixed_precision_quantize — same rationale as
    # quant.quantize_act)
    amax = jnp.max(jnp.maximum(x, 0.0), axis=axes, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / quant.ACT_HIGH_MAX
    q = quant.mixed_precision_quantize(x, imp, scale=scale)
    y = (q.values.astype(jnp.float32) * q.scale).astype(x.dtype)
    return x + jax.lax.stop_gradient(y - x)


def workload_low_precision_fraction(ratios_per_iter: jax.Array,
                                    active_iters: int | None = None,
                                    total_iters: int | None = None,
                                    *, ddim=None) -> jax.Array:
    """Fraction of total FFN workload eligible for INT6 across the run.

    Paper Fig. 9(b): per-iteration low-precision ratio, zero for the last
    ``total - active`` iterations; overall claim is 44.8 %.

    The schedule is a property of the RUN, not of the paper: pass the
    run's ``DDIMConfig`` via ``ddim`` (any object with
    ``tips_active_iters`` / ``num_inference_steps``) — or the two counts
    explicitly — so e.g. a ``--steps 5`` serving run reports the fraction
    of ITS 5-iteration workload.  The paper's 20/25 operating point is
    only the fallback when neither is given.
    """
    if ddim is not None:
        if active_iters is None:
            active_iters = ddim.tips_active_iters
        if total_iters is None:
            total_iters = ddim.num_inference_steps
    if active_iters is None:
        active_iters = TIPS_ACTIVE_ITERS
    if total_iters is None:
        total_iters = TOTAL_ITERS
    r = ratios_per_iter[:active_iters]
    return jnp.sum(r) / total_iters
