"""PrecisionPolicy — the single source of TIPS/DBSC precision truth.

The paper's text-based mixed precision has three knobs that were scattered
across the codebase: the fixed CAS threshold (``UNetConfig.tips_threshold``),
the activity schedule (``tips_schedule`` / ``DDIMConfig.tips_active_iters``)
and an *unwired* target-ratio mode (``tips.adaptive_threshold`` — the offline
tuning helper the silicon's predefined threshold comes from).  This module
folds the spotting decision into one frozen, hashable policy object that
lives inside ``UNetConfig`` (next to ``KernelPolicy``), participates in the
``DiffusionEngine`` executable-cache key, and backs the ``--tips`` serving
flag.

Two spotting modes (``spotting``):

``fixed``     — the silicon's datapath: a predefined CAS threshold marks a
                pixel important (``cas < threshold``), tuned offline.
``adaptive``  — the offline tuning run *inside* the loop: each sample's CAS
                distribution is thresholded at the quantile that realizes
                ``target_low_ratio`` of its tokens at INT6.  The quantile is
                PER SAMPLE (reduced over the token axis only), for the same
                reason ``tips.apply_precision_mask`` scales per sample: one
                image's precision map must not depend on what else shares
                the batch, so a fused cond+uncond CFG batch spots exactly
                like two separate calls and ``stats_rows`` row slicing
                commutes with spotting.

``ffn_mid`` extends the TIPS mask to the SECOND FFN matmul (``ff_out``):
unimportant rows' mid activations (GEGLU output) are re-quantized to INT6
too — the paper's "INT12 through the whole following FFN stack" reading.
Off by default: the seed datapath only covered the first matmul, and the
energy ledger's MAC precision split follows this flag
(``diffusion.ledger.LedgerOptions.tips_mid``).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import tips

_SPOTTING = ("fixed", "adaptive")


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Which TIPS/DBSC precision decisions the runtime makes.

    Frozen + hashable so it can live inside ``UNetConfig``, flow through
    jit closures, and key the engine's executable cache (a policy change
    retraces instead of reusing a stale executable).
    """
    spotting: str = "fixed"
    threshold: float = 0.05          # fixed mode: important <=> CAS < this
    target_low_ratio: float = 0.448  # adaptive mode: INT6 fraction to realize
    ffn_mid: bool = False            # TIPS mask also covers ff_out (INT6 mid)
    cls_index: int = 0               # CLS position in the text keys

    def __post_init__(self):
        if self.spotting not in _SPOTTING:
            raise ValueError(
                f"PrecisionPolicy.spotting={self.spotting!r}: expected one "
                f"of {_SPOTTING}")
        if not 0.0 <= self.target_low_ratio <= 1.0:
            raise ValueError(
                f"PrecisionPolicy.target_low_ratio={self.target_low_ratio}: "
                f"expected a fraction in [0, 1]")
        if not 0.0 < self.threshold <= 1.0:
            raise ValueError(
                f"PrecisionPolicy.threshold={self.threshold}: CAS is a "
                f"softmax probability — expected a cut in (0, 1]")
        if self.cls_index < 0:
            raise ValueError(
                f"PrecisionPolicy.cls_index={self.cls_index}: must be >= 0")

    # -- presets ---------------------------------------------------------
    @classmethod
    def fixed(cls, threshold: float = 0.05) -> "PrecisionPolicy":
        """The silicon's predefined-threshold operating point."""
        return cls(spotting="fixed", threshold=threshold)

    @classmethod
    def adaptive(cls, target_low_ratio: float = 0.448) -> "PrecisionPolicy":
        """Per-sample quantile spotting that realizes a target INT6 ratio."""
        return cls(spotting="adaptive", target_low_ratio=target_low_ratio)

    @classmethod
    def parse(cls, spec: str) -> "PrecisionPolicy":
        """Build a policy from a CLI spec (the ``--tips`` flag).

        ``spec`` is a comma-separated list where a bare ``fixed`` /
        ``adaptive`` item selects the spotting mode and ``key=value`` items
        override fields, e.g. ``"adaptive,target=0.5,mid=true"`` or
        ``"threshold=0.02"``.  Keys: ``threshold``, ``target``
        (target_low_ratio), ``mid`` (ffn_mid), ``cls`` (cls_index).
        """
        fields = {}
        for item in filter(None, (s.strip() for s in spec.split(","))):
            if item in _SPOTTING:
                fields["spotting"] = item
                continue
            if "=" not in item:
                raise ValueError(
                    f"tips policy spec {item!r}: expected a spotting mode "
                    f"in {_SPOTTING} or key=value")
            key, val = (s.strip() for s in item.split("=", 1))
            if key == "threshold":
                fields["threshold"] = float(val)
            elif key == "target":
                fields["target_low_ratio"] = float(val)
            elif key == "mid":
                if val.lower() not in ("true", "false"):
                    raise ValueError(
                        f"tips policy spec: mid={val!r} (expected true or "
                        f"false)")
                fields["ffn_mid"] = val.lower() == "true"
            elif key == "cls":
                fields["cls_index"] = int(val)
            elif key == "spotting":
                fields["spotting"] = val
            else:
                raise ValueError(
                    f"tips policy spec: unknown key {key!r} (expected "
                    f"threshold, target, mid, cls or spotting)")
        return cls(**fields)

    # -- views -----------------------------------------------------------
    def describe(self) -> dict:
        """JSON-friendly view for serving metrics / benchmark records."""
        return {
            "spotting": self.spotting,
            "threshold": self.threshold,
            "target_low_ratio": self.target_low_ratio,
            "ffn_mid": self.ffn_mid,
            "cls_index": self.cls_index,
        }


def spot_cas(cas, policy: PrecisionPolicy,
             threshold_scale=None) -> tips.TIPSResult:
    """Importance spotting from head-averaged CAS per the policy.

    ``cas``: (..., Tq) CLS attention score per query (already averaged over
    heads — both attention implementations produce this identically, so
    spotting downstream of it is implementation-agnostic and reference-vs-
    fused parity reduces to CAS parity).

    ``fixed``: important <=> CAS < threshold.  ``adaptive``: important <=>
    CAS < the sample's ``1 - target_low_ratio`` CAS quantile — per sample
    (token-axis reduction only), so batch composition never changes a
    sample's precision map and row slicing (``stats_rows``) commutes.

    ``threshold_scale`` (a (B,) float32, phase-scheduled sampling) scales
    each row's effective threshold — the fixed threshold or the adaptive
    per-sample quantile — multiplicatively; ``None`` leaves both modes
    untouched, op for op.
    """
    if policy.spotting == "adaptive":
        thr = jnp.quantile(cas, 1.0 - policy.target_low_ratio,
                           axis=-1, keepdims=True)
    else:
        thr = policy.threshold
    if threshold_scale is not None:
        scale = threshold_scale.reshape(
            threshold_scale.shape + (1,) * (cas.ndim
                                            - threshold_scale.ndim))
        thr = thr * scale
    important = cas < thr
    low_ratio = 1.0 - jnp.mean(important.astype(jnp.float32))
    return tips.TIPSResult(important=important, cas=cas,
                           low_precision_ratio=low_ratio)
