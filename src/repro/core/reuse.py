"""ReusePolicy — temporal patch reuse across denoising steps (SIGE-style).

The paper's PSSA exploits *spatial* patch similarity inside one attention
score matrix; SIGE (SNIPPETS.md §3) applies the same patch-delta signal
*temporally*: between consecutive denoising iterations — and between an
edited request and its cached base — only a few percent of activation
patches actually change, so the transformer stages can gather the changed
patch rows, run attention/FFN on those alone, and scatter the results over
the previous step's cached activations.

This module holds the policy object and the cache pytree; the patch-delta
op lives in ``repro.kernels.patch_reuse`` (routed through
``kernels.dispatch`` like every other hot-path op) and the model-side
gather/compute/scatter in ``repro.diffusion.unet._transformer_block``.

Exactness contract (DESIGN.md §9): with ``threshold=0`` every patch is
active, the gather permutation is the identity (stable argsort of an
all-False key), and gather -> compute -> scatter is bit-identical to the
dense path — outputs AND integer reuse counters — across reference|fused
kernel routing, vmap/scan, fused-CFG, and slot-engine contexts.  The same
holds for a fully-changed input at any threshold: every patch trips the
delta, so cached values are provably never read.

Two operating modes:

``temporal``  — the cache is the *previous step's* activations, carried
                through the scan / slot state.  ``capacity`` must stay 1.0:
                the executable's gather width is static, and a fresh
                (invalid) cache marks every patch active on a row's first
                step.  Savings are modeled (EMA ledger + reuse counters);
                wall-clock shapes are unchanged.
``edit``      — the cache is a *base request's* recorded per-step
                activations (img2img / editing).  The caller seeds valid
                caches, so ``capacity < 1`` genuinely shrinks the gathered
                matmul shapes — the wall-clock lever the edit benchmark
                measures.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

_MODES = ("off", "temporal", "edit")


@dataclasses.dataclass(frozen=True)
class ReusePolicy:
    """Temporal patch-reuse decisions (frozen/hashable, like KernelPolicy).

    ``threshold``: a patch is active iff the max-abs delta of its tokens
    against the cached reference reaches it (0.0 -> every patch active ->
    dense bit-exactness).  ``capacity``: static fraction of patch slots the
    gather keeps per row — the executable-shape knob (1.0 -> all patches,
    identity permutation).  Invalid cache rows force all their patches
    active regardless of threshold.

    ``apriori_window``: a static ``(y0, x0, h, w)`` rectangle in LATENT
    pixel coordinates (the edit window ``make_edit_requests`` perturbs).
    When the changed region is known up front — inpainting masks, edit
    boxes — the patch activity is a compile-time constant: the UNet skips
    the patch-delta kernel entirely and activates exactly the patches
    whose tokens intersect the window at each block's resolution
    (``window_patch_mask``).  Hashable/static, so it joins the executable
    cache keys like every other policy field.
    """
    enabled: bool = False
    threshold: float = 0.0
    capacity: float = 1.0
    apriori_window: Tuple[int, int, int, int] | None = None

    def __post_init__(self):
        if self.threshold < 0.0:
            raise ValueError(
                f"ReusePolicy.threshold={self.threshold}: patch deltas are "
                f"max-abs values — expected >= 0")
        if not 0.0 < self.capacity <= 1.0:
            raise ValueError(
                f"ReusePolicy.capacity={self.capacity}: expected a patch "
                f"fraction in (0, 1]")
        if self.apriori_window is not None:
            win = tuple(int(v) for v in self.apriori_window)
            if len(win) != 4 or win[2] < 1 or win[3] < 1 or win[0] < 0 \
                    or win[1] < 0:
                raise ValueError(
                    f"ReusePolicy.apriori_window={self.apriori_window}: "
                    f"expected (y0, x0, h, w) with y0,x0 >= 0 and h,w >= 1")
            object.__setattr__(self, "apriori_window", win)

    # -- presets ---------------------------------------------------------
    @classmethod
    def off(cls) -> "ReusePolicy":
        """Dense path: no cache threaded, no reuse counters."""
        return cls()

    @classmethod
    def temporal(cls, threshold: float = 0.05) -> "ReusePolicy":
        """Previous-step reuse carried through the scan / slot state."""
        return cls(enabled=True, threshold=threshold, capacity=1.0)

    @classmethod
    def edit(cls, threshold: float = 0.05,
             capacity: float = 0.125) -> "ReusePolicy":
        """Base-request reuse with a shrunken static gather (img2img)."""
        return cls(enabled=True, threshold=threshold, capacity=capacity)

    @classmethod
    def parse(cls, spec: str) -> "ReusePolicy":
        """Build a policy from a CLI spec (the ``--reuse`` flag).

        ``spec`` is a mode name (``off`` | ``temporal`` | ``edit``) or a
        comma-separated list where a bare mode selects its preset and
        ``key=value`` items override fields, e.g. ``"temporal,threshold=0.02"``
        or ``"edit,threshold=0.1,capacity=0.25"``.
        """
        pol = None
        fields = {}
        for item in filter(None, (s.strip() for s in spec.split(","))):
            if item in _MODES:
                pol = cls.off() if item == "off" else getattr(cls, item)()
                continue
            if "=" not in item:
                raise ValueError(
                    f"reuse policy spec {item!r}: expected a mode in "
                    f"{_MODES} or key=value")
            key, val = (s.strip() for s in item.split("=", 1))
            if key == "threshold":
                fields["threshold"] = float(val)
            elif key == "capacity":
                fields["capacity"] = float(val)
            elif key == "enabled":
                if val.lower() not in ("true", "false"):
                    raise ValueError(
                        f"reuse policy spec: enabled={val!r} (expected true "
                        f"or false)")
                fields["enabled"] = val.lower() == "true"
            elif key == "window":
                parts = val.split(":")
                if len(parts) != 4:
                    raise ValueError(
                        f"reuse policy spec: window={val!r} (expected "
                        f"y0:x0:h:w in latent pixels)")
                fields["apriori_window"] = tuple(int(p) for p in parts)
            else:
                raise ValueError(
                    f"reuse policy spec: unknown key {key!r} (expected "
                    f"threshold, capacity, window or enabled)")
        base = pol if pol is not None else cls()
        return dataclasses.replace(base, **fields) if fields else base

    # -- views -----------------------------------------------------------
    def cap_patches(self, num_patches: int) -> int:
        """Static gather width: how many patch slots the plan keeps."""
        return min(num_patches,
                   max(1, int(math.ceil(self.capacity * num_patches))))

    def describe(self) -> dict:
        """JSON-friendly view for serving metrics / benchmark records."""
        return {"enabled": self.enabled, "threshold": self.threshold,
                "capacity": self.capacity,
                "apriori_window": (None if self.apriori_window is None
                                   else list(self.apriori_window))}


class ReuseRowCounters(NamedTuple):
    """Per-row integer reuse counters for ONE transformer block.

    ``computed``: patches actually gathered and recomputed this step;
    ``total``: patches in the block's token grid.  Realized reuse ratio =
    1 - computed/total.  Integer, so ledger accumulation across slots,
    steps, and dp shards is exact (the same contract as PSSARowCounters).
    """
    computed: jax.Array   # (rows,) int32
    total: jax.Array      # (rows,) int32


class LayerReuseCache(NamedTuple):
    """Cached activations of one transformer block (one denoising step).

    ``ref`` is the block's token-space INPUT (the delta reference); ``sa``
    / ``ca`` / ``ffn`` are the three pre-residual stage outputs the scatter
    falls back to for inactive patches.  Under fused-CFG prefix dedup the
    first block's ``ref``/``sa`` carry cond-half rows only (B) while
    ``ca``/``ffn`` carry [cond | uncond] (2B) — matching where the hidden
    state is tiled inside the block.
    """
    ref: jax.Array    # (rows_pre, T, C)
    sa: jax.Array     # (rows_pre, T, C)
    ca: jax.Array     # (rows_post, T, C)
    ffn: jax.Array    # (rows_post, T, C)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ReuseCache:
    """Per-request-row cached activations for every transformer block.

    ``valid`` is one bool per REQUEST row (the cond half under CFG): False
    forces every patch of that row active on the next step — the admit /
    fresh-state invalidation path.  ``layers`` follows
    ``stats.attn_layer_order``; each entry is a ``LayerReuseCache``.
    """
    valid: jax.Array                        # (B,) bool
    layers: Tuple[LayerReuseCache, ...]

    def tree_flatten(self):
        return (self.valid, self.layers), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        valid, layers = children
        return cls(valid=valid, layers=tuple(layers))

    def invalidate_row(self, row) -> "ReuseCache":
        """Mark one request row stale (slot admission)."""
        return dataclasses.replace(self,
                                   valid=self.valid.at[row].set(False))


def window_patch_mask(window, resolution: int, patch: int,
                      latent_size: int):
    """Static per-patch activity for an a-priori edit window.

    ``window`` is ``(y0, x0, h, w)`` in LATENT pixels; a patch of
    ``patch`` contiguous row-major tokens at ``resolution`` is active iff
    any of its tokens falls inside the window scaled to that resolution
    (outer bounds rounded outward, so boundary pixels are always covered
    — conservative, never misses a changed token).  Pure Python/ints at
    trace time: the result is a compile-time constant tuple of bools,
    which is what lets the UNet skip the patch-delta kernel entirely.
    """
    y0, x0, h, w = (int(v) for v in window)
    tokens = resolution * resolution
    npatch = max(1, tokens // patch)
    # scale the window bounds to this block's feature-map resolution
    y0r = (y0 * resolution) // latent_size
    x0r = (x0 * resolution) // latent_size
    y1r = -((-(y0 + h) * resolution) // latent_size)   # ceil division
    x1r = -((-(x0 + w) * resolution) // latent_size)
    y1r = min(resolution, max(y1r, y0r + 1))
    x1r = min(resolution, max(x1r, x0r + 1))
    mask = []
    for p in range(npatch):
        active = False
        for tok in range(p * patch, min((p + 1) * patch, tokens)):
            y, x = tok // resolution, tok % resolution
            if y0r <= y < y1r and x0r <= x < x1r:
                active = True
                break
        mask.append(active)
    return tuple(mask)


def layer_channels(cfg, resolution: int) -> int:
    """Channel width of the transformer block at ``resolution``.

    A config with a ``channels_at`` hook (every registered denoiser
    family) is the source of truth; the fallback is the UNet rule —
    ``unet_forward`` visits resolution ``latent_size >> i`` with
    ``block_channels[i]`` on the way down and revisits the same width on
    the way up, so the resolution determines the stage index.
    """
    ch_fn = getattr(cfg, "channels_at", None)
    if callable(ch_fn):
        return ch_fn(resolution)
    stage = (cfg.latent_size // resolution).bit_length() - 1
    return cfg.block_channels[stage]


def reuse_cache_zeros(cfg, batch: int, use_cfg: bool) -> "ReuseCache":
    """All-invalid cache matching ``unet_forward``'s block geometry.

    ``use_cfg`` mirrors the fused-CFG prefix dedup: the first attention
    block runs pre-dup (B rows) through its self-attention, later blocks
    (and the first block's cross-attn/FFN) see [cond | uncond] (2B rows).
    Invalid rows make the zero payloads unreachable: every patch of a
    fresh row is active, so nothing is ever read from them.
    """
    from repro.diffusion.stats import attn_layer_order

    dt = jnp.dtype(cfg.dtype)
    mult = 2 if use_cfg else 1
    layers = []
    for idx, lk in enumerate(attn_layer_order(cfg)):
        t = lk.resolution * lk.resolution
        c = layer_channels(cfg, lk.resolution)
        pre = batch if (use_cfg and idx == 0) else batch * mult
        post = batch * mult
        layers.append(LayerReuseCache(
            ref=jnp.zeros((pre, t, c), dt),
            sa=jnp.zeros((pre, t, c), dt),
            ca=jnp.zeros((post, t, c), dt),
            ffn=jnp.zeros((post, t, c), dt)))
    return ReuseCache(valid=jnp.zeros((batch,), bool),
                      layers=tuple(layers))
