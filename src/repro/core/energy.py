"""Analytic energy model (28 nm) for the SD-processor reproduction.

The paper evaluates *energy, throughput, and memory access* — not accuracy.
We therefore keep a bytes-accurate external-memory-access (EMA) ledger plus a
per-MAC energy table, calibrated so the **baseline** configuration lands on
the paper's published operating points:

  * 1.9 GB EMA per UNet iteration (INT12 act / INT8 weight, no compression)
  * 213.3 mJ/iter with EMA      (optimized datapath, compressed EMA)
  * 28.6 mJ/iter without EMA    (optimized datapath)
  * 225.6 mW average power, 3.84 TOPS peak, 250 MHz, 1 V

Derivation of the DRAM constant: the optimized run moves
1.9 GB x (1 - 0.378) = 1.18 GB and the EMA adder is 213.3 - 28.6 = 184.7 mJ,
giving 156 pJ/byte (= 19.6 pJ/bit — squarely in LPDDR4 territory).

MAC energies: the DBSC computes INT12xINT8 as two INT7xINT8 bit-slice
products.  The paper's +43.0 % FFN efficiency with 44.8 % of rows at INT6
pins the INT6:INT12 energy ratio at ~0.33 (0.552 + 0.448*c = 1/1.43).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

# ----------------------------------------------------------------------------
# Calibrated constants (28 nm, 1 V, 250 MHz)
# ----------------------------------------------------------------------------
DRAM_PJ_PER_BYTE = 156.0        # LPDDR-class external memory
SRAM_PJ_PER_BYTE = 1.25         # global buffer (192 KB) access
MAC_PJ = {
    "int12x8": 0.1143,          # full two-slice DBSC MAC (calibrated, see below)
    "int7x8": 0.0572,           # one bit-slice PE MAC
    "int6x8": 0.0377,           # low-precision path: one slice + narrow adders
    "int8x8": 0.0650,
    "bf16": 0.3800,             # reference only (not used by the ASIC path)
}
# Calibration note: with the BK-SDM-Tiny workload ledger
# (`repro.diffusion.ledger`) the INT12 MAC count is ~229 GMAC/iter; at
# 0.1143 pJ/MAC + SRAM traffic the compute-side energy lands on 28.6 mJ/iter
# after TIPS+DBSC, matching Table I.  See benchmarks/bench_energy_iter.py.

PEAK_TOPS = 3.84
AVG_POWER_MW = 225.6
FREQ_MHZ = 250.0


@dataclasses.dataclass(frozen=True)
class LayerTraffic:
    """EMA + compute footprint of one layer invocation."""
    name: str
    stage: str                  # 'cnn' | 'self_attn' | 'cross_attn' | 'ffn' | 'other'
    weight_bytes: float = 0.0
    act_in_bytes: float = 0.0
    act_out_bytes: float = 0.0
    sas_bytes: float = 0.0      # self-attention score write+read traffic
    macs_high: float = 0.0      # INT12-activation MACs
    macs_low: float = 0.0       # INT6-activation MACs (TIPS rows)

    @property
    def ema_bytes(self) -> float:
        return (self.weight_bytes + self.act_in_bytes
                + self.act_out_bytes + self.sas_bytes)


@dataclasses.dataclass
class EnergyReport:
    ema_bytes_total: float
    ema_bytes_by_stage: dict
    sas_bytes: float
    ema_energy_mj: float
    compute_energy_mj: float

    @property
    def total_mj(self) -> float:
        return self.ema_energy_mj + self.compute_energy_mj

    @property
    def sas_fraction(self) -> float:
        return self.sas_bytes / max(self.ema_bytes_total, 1e-12)

    def stage_fraction(self, *stages: str) -> float:
        tot = max(self.ema_bytes_total, 1e-12)
        return sum(self.ema_bytes_by_stage.get(s, 0.0) for s in stages) / tot


def report(layers: Iterable[LayerTraffic],
           dram_pj_per_byte: float = DRAM_PJ_PER_BYTE,
           mac_pj: dict = MAC_PJ) -> EnergyReport:
    by_stage: dict[str, float] = {}
    total = 0.0
    sas = 0.0
    macs_hi = 0.0
    macs_lo = 0.0
    for l in layers:
        by_stage[l.stage] = by_stage.get(l.stage, 0.0) + l.ema_bytes
        total += l.ema_bytes
        sas += l.sas_bytes
        macs_hi += l.macs_high
        macs_lo += l.macs_low
    ema_mj = total * dram_pj_per_byte * 1e-9
    compute_mj = (macs_hi * mac_pj["int12x8"]
                  + macs_lo * mac_pj["int6x8"]) * 1e-9
    return EnergyReport(
        ema_bytes_total=total,
        ema_bytes_by_stage=by_stage,
        sas_bytes=sas,
        ema_energy_mj=ema_mj,
        compute_energy_mj=compute_mj,
    )


def ffn_energy_gain(low_ratio: float, mac_pj: dict = MAC_PJ) -> float:
    """Paper Fig. 9(c): FFN energy-efficiency gain of DBSC mixed precision.

    Baseline: every row INT12.  DBSC: ``low_ratio`` of rows INT6.
    Returns the multiplicative efficiency gain (0.43 == +43 %).
    """
    base = mac_pj["int12x8"]
    mixed = (1.0 - low_ratio) * mac_pj["int12x8"] + low_ratio * mac_pj["int6x8"]
    return base / mixed - 1.0


def iter_time_s(total_macs: float, utilization: float = 0.5,
                peak_tops: float = PEAK_TOPS) -> float:
    """Wall time of one UNet iteration on the 3.84 TOPS array."""
    ops = 2.0 * total_macs
    return ops / (peak_tops * 1e12 * utilization)
