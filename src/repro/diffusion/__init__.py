from repro.diffusion.unet import UNetConfig, init_unet_params, unet_forward  # noqa: F401
from repro.diffusion.pipeline import StableDiffusionPipeline, PipelineConfig  # noqa: F401
from repro.diffusion.engine import DiffusionEngine, EngineOutput, SlotState  # noqa: F401
from repro.diffusion.stats import (LedgerAccum, SlotStats, UNetStats,  # noqa: F401
                                   attn_layer_order)
