from repro.diffusion.unet import UNetConfig, init_unet_params, unet_forward  # noqa: F401
from repro.diffusion.pipeline import StableDiffusionPipeline, PipelineConfig  # noqa: F401
