"""DiT denoiser: patchify -> N adaLN transformer blocks -> unpatchify.

Second model family behind the denoiser contract
(``repro.diffusion.denoiser``): a diffusion transformer in the DiT-S shape
(Peebles & Xie, 2023) with the cross-attention text conditioning the
serving stack assumes.  The paper's three features are properties of the
transformer blocks, not of the UNet that hosted them — so each DiT block
IS ``unet._transformer_block``, with adaLN timestep conditioning supplied
through its ``modulation`` hook:

  * self-attention routes through the PSSA fused kernel (score pruning +
    patch-XOR bitmap compression, integer counters bit-identical across
    ``reference|fused``);
  * cross-attention emits the CLS attention score TIPS thresholds;
  * the GEGLU FFN runs the DBSC mixed-precision path under the TIPS mask;

all via the UNCHANGED ``kernels.dispatch`` table, which is what makes the
banked ledger, quality tiers, temporal reuse, and continuous batching work
on DiT for free.

Geometry: latents (B, S, S, C) are patchified with stride ``patch`` into a
``(S/patch)``-sided token GRID — kept 2D, (B, g, g, D), because that is
exactly the feature-map shape ``_transformer_block`` and the patch-reuse
kernels operate on.  One token resolution for the whole network, so
``layer_order()`` is ``block{i}@g`` for i in range(depth).

adaLN: per block, ``silu(temb)`` maps through a per-block linear to 9
modulation vectors — (shift, scale, gate) per (self-attn, cross-attn, FFN)
stage.  Weights are randomly initialized like every other projection (this
is an inference-side reproduction; DiT's zero-init of the adaLN output is
a training-time device, and zero gates would switch the attention/FFN
stages out of the eps path entirely).  The final layer applies
(shift, scale) adaLN to the last norm, projects to patch pixels, and
unpatchifies.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.precision import PrecisionPolicy
from repro.core.reuse import ReuseCache, ReusePolicy
from repro.diffusion.stats import LayerKey, SlotStats, UNetStats, \
    attn_layer_order
from repro.diffusion.unet import (_lin_p, _norm_p, _transformer_block,
                                  _transformer_p, layer_norm,
                                  timestep_embedding)
from repro.kernels.dispatch import KernelPolicy


@dataclasses.dataclass(frozen=True)
class DiTConfig:
    """DiT-S/2-shaped text-conditioned diffusion transformer."""
    in_channels: int = 4
    out_channels: int = 4
    latent_size: int = 32              # 256x256 images -> 32x32x4 latents
    patch: int = 2                     # patchify stride (DiT-S/2)
    hidden_size: int = 384             # DiT-S width
    depth: int = 12                    # DiT-S depth
    num_heads: int = 6                 # DiT-S heads
    context_dim: int = 768             # CLIP ViT-L/14 text width
    text_len: int = 77
    time_dim: int = 384
    groups: int = 32                   # block entry GroupNorm (gcd'd)
    ffn_mult: int = 4                  # GEGLU hidden = 4 * hidden_size

    # --- paper features (same toggles/policies as UNetConfig) ---
    pssa: bool = True
    tips: bool = True
    dbsc: bool = True
    pssa_threshold: float = 1.0 / 8192.0
    pssa_stats_reference: bool = False
    kernel_policy: KernelPolicy = KernelPolicy()
    precision: PrecisionPolicy = PrecisionPolicy()
    reuse_policy: ReusePolicy = ReusePolicy()

    dtype: str = "float32"

    @property
    def token_res(self) -> int:
        """Side of the (square) token grid: latent_size / patch."""
        assert self.latent_size % self.patch == 0, \
            (self.latent_size, self.patch)
        return self.latent_size // self.patch

    def patch_size(self, resolution: int) -> int:
        """PSXU patch width at a feature-map resolution (same rule as the
        UNet — the PSSA bitmap geometry is a property of the kernel)."""
        return min(64, max(16, resolution))

    def effective_kernel_policy(self) -> KernelPolicy:
        return self.kernel_policy

    def effective_precision(self) -> PrecisionPolicy:
        return self.precision

    def smoke(self) -> "DiTConfig":
        """Reduced config that runs a full fwd pass on CPU in seconds."""
        return dataclasses.replace(
            self,
            latent_size=16,
            hidden_size=64,
            depth=4,
            num_heads=4,
            context_dim=32,
            text_len=8,
            time_dim=64,
            groups=8,
        )

    # --- denoiser-contract hooks (repro.diffusion.denoiser) ---
    def layer_order(self) -> tuple:
        """Canonical stats layer order: ``block{i}`` at the token res."""
        return tuple(LayerKey(f"block{i}", self.token_res)
                     for i in range(self.depth))

    def channels_at(self, resolution: int) -> int:
        """Token width at a feature-map resolution (single-res network)."""
        assert resolution == self.token_res, (resolution, self.token_res)
        return self.hidden_size

    def full_geometry(self) -> "DiTConfig":
        """Full DiT-S — the analytic-ledger extrapolation target."""
        return DiTConfig()

    def attn_resolutions(self) -> tuple:
        return (self.token_res,)


DIT_S2 = DiTConfig()


# ----------------------------------------------------------------------------
# Parameter init
# ----------------------------------------------------------------------------
def init_dit_params(key, cfg: DiTConfig):
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.hidden_size
    pe = cfg.patch * cfg.patch * cfg.in_channels
    po = cfg.patch * cfg.patch * cfg.out_channels
    keys = iter(jax.random.split(key, 8 + 2 * cfg.depth))
    p = {
        "patch_embed": _lin_p(next(keys), pe, d, dtype),
        "time_mlp1": _lin_p(next(keys), d, cfg.time_dim, dtype),
        "time_mlp2": _lin_p(next(keys), cfg.time_dim, cfg.time_dim, dtype),
        "blocks": [
            {"attn": _transformer_p(next(keys), d, cfg, dtype),
             # 9 modulation vectors: (shift, scale, gate) x (sa, ca, ffn)
             "ada": _lin_p(next(keys), cfg.time_dim, 9 * d, dtype)}
            for _ in range(cfg.depth)
        ],
        "final_norm": _norm_p(d, dtype),
        "final_ada": _lin_p(next(keys), cfg.time_dim, 2 * d, dtype),
        "final_out": _lin_p(next(keys), d, po, dtype),
    }
    return p


# ----------------------------------------------------------------------------
# Forward
# ----------------------------------------------------------------------------
def _patchify(latents, patch: int):
    """(B, S, S, C) -> (B, S/p, S/p, p*p*C) token grid."""
    b, s, _, c = latents.shape
    g = s // patch
    x = latents.reshape(b, g, patch, g, patch, c)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(b, g, g, patch * patch * c)


def _unpatchify(tokens, patch: int, out_channels: int):
    """(B, T, p*p*C) tokens (square T) -> (B, S, S, C)."""
    b, t, _ = tokens.shape
    g = int(round(t ** 0.5))
    x = tokens.reshape(b, g, g, patch, patch, out_channels)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(
        b, g * patch, g * patch, out_channels)


def dit_forward(params, latents, timesteps, context, cfg: DiTConfig,
                tips_active=True, stats_rows=None, cfg_dup: bool = False,
                row_stats: bool = False, reuse_cache=None, overrides=None):
    """latents (B, S, S, C), timesteps (B,), context (B|2B, Ttext, ctx).

    Same signature and keyword semantics as ``unet.unet_forward`` — the
    denoiser contract (see ``repro.diffusion.denoiser``).  Returns
    ``(eps, stats)`` (+ ``new_cache`` under temporal reuse) with one
    PSSA/TIPS entry per DiT block in ``cfg.layer_order()``.

    ``cfg_dup`` tiles the hidden state at block 0's cross-attention —
    block 0 is the first divergence point under fused CFG, exactly the
    UNet's first attention block (the reuse-cache pre-dup geometry
    matches for the same reason).
    """
    b = latents.shape[0]
    g = cfg.token_res
    tips_active = jnp.asarray(tips_active)
    policy = cfg.effective_kernel_policy()
    precision = cfg.effective_precision()
    reuse_pol = cfg.reuse_policy
    reuse_on = reuse_pol.enabled and reuse_cache is not None
    needs_dup = cfg_dup
    if cfg_dup:
        assert context.shape[0] == 2 * latents.shape[0], \
            (context.shape, latents.shape)

    temb = timestep_embedding(timesteps, cfg.hidden_size)
    temb = jnp.einsum("bd,dc->bc", temb, params["time_mlp1"]["w"]) \
        + params["time_mlp1"]["b"]
    temb = jnp.einsum("bd,dc->bc", jax.nn.silu(temb),
                      params["time_mlp2"]["w"]) + params["time_mlp2"]["b"]

    x = _patchify(latents, cfg.patch)
    h = jnp.einsum("bhwc,cd->bhwd", x, params["patch_embed"]["w"]) \
        + params["patch_embed"]["b"]

    pssa_stats: list = []
    tips_stats: list = []
    reuse_stats: list = []
    new_layer_caches: list = []
    for i, bp in enumerate(params["blocks"]):
        # per-block adaLN from the (possibly not-yet-tiled) time embedding;
        # (B, 1, D) vectors broadcast over tokens, and the block tiles
        # them to [cond | uncond] rows post-dup via its _per_rows rule
        ada = jnp.einsum("bd,dc->bc", jax.nn.silu(temb), bp["ada"]["w"]) \
            + bp["ada"]["b"]
        mod = tuple(m[:, None, :] for m in jnp.split(ada, 9, axis=-1))
        reuse_arg = None
        if reuse_on:
            reuse_arg = (reuse_pol, reuse_cache.layers[i], reuse_cache.valid)
        h, sa, ca, ru = _transformer_block(h, bp["attn"], context, cfg,
                                           tips_active, stats_rows,
                                           dup_after_self=needs_dup,
                                           policy=policy,
                                           precision=precision,
                                           row_stats=row_stats,
                                           reuse=reuse_arg,
                                           overrides=overrides,
                                           modulation=mod)
        if needs_dup:
            temb = jnp.concatenate([temb, temb], axis=0)
            needs_dup = False
        pssa_stats.append(sa)
        tips_stats.append(ca)
        if reuse_on:
            new_layer_caches.append(ru[0])
            reuse_stats.append(ru[1])

    if needs_dup:                      # depth == 0: tile eps like the UNet
        h = jnp.concatenate([h, h], axis=0)
        temb = jnp.concatenate([temb, temb], axis=0)

    bb = h.shape[0]                    # 2B under cfg_dup
    tokens = h.reshape(bb, g * g, cfg.hidden_size)
    ada = jnp.einsum("bd,dc->bc", jax.nn.silu(temb),
                     params["final_ada"]["w"]) + params["final_ada"]["b"]
    shift, scale = jnp.split(ada, 2, axis=-1)
    hn = layer_norm(tokens, params["final_norm"]["scale"],
                    params["final_norm"]["bias"])
    hn = hn * (1.0 + scale[:, None, :]) + shift[:, None, :]
    out = jnp.einsum("btd,dc->btc", hn, params["final_out"]["w"]) \
        + params["final_out"]["b"]
    eps = _unpatchify(out, cfg.patch, cfg.out_channels)

    stats_cls = SlotStats if row_stats else UNetStats
    stats = stats_cls.from_layer_list(attn_layer_order(cfg), pssa_stats,
                                      tips_stats,
                                      reuse=tuple(reuse_stats))
    if reuse_on:
        new_cache = ReuseCache(valid=jnp.ones_like(reuse_cache.valid),
                               layers=tuple(new_layer_caches))
        return eps, stats, new_cache
    return eps, stats


def abstract_dit_params(cfg: DiTConfig):
    return jax.eval_shape(lambda: init_dit_params(jax.random.PRNGKey(0),
                                                  cfg))


# --- denoiser-contract registration (repro.diffusion.denoiser) ---
from repro.diffusion import denoiser as _denoiser  # noqa: E402

_denoiser.register_family(_denoiser.FamilySpec(
    family="dit",
    config_cls=DiTConfig,
    init_params=init_dit_params,
    forward=dit_forward,
    abstract_params=abstract_dit_params,
))
