"""Stats pytree for the jittable diffusion engine.

The seed implementation threaded a string-keyed ``stats: dict`` through the
UNet forward and returned one dict per denoising iteration.  That shape is
hostile to whole-loop ``jax.lax.scan``/``jax.jit``: the dict is mutated in
place, its insertion order is an accident of control flow, and per-iteration
collection forces a Python-level sampler loop.

``UNetStats`` replaces it: a frozen dataclass registered as a pytree whose
*static* part (the layer order — ``(tag, resolution)`` pairs derived from
``UNetConfig``) lives in the treedef, and whose *dynamic* part (one
``PSSAStats`` + one ``TIPSResult`` per transformer block, in that fixed
order) are the leaves.  Because the treedef is identical at every denoising
step, a ``lax.scan`` over the sampler stacks every leaf along a leading
``num_steps`` axis — the whole 25-iteration stats trajectory comes back as
one pytree of ``(25, ...)`` arrays.

Parity path: ``step(i)`` / ``unstack()`` recover the per-iteration view and
``as_dict()`` reproduces the seed's ``{"pssa": {"down0.0@16": ...}, ...}``
dict exactly, so every downstream consumer (energy ledger, benchmarks) can
read either representation.  See DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.pssa import PSSAStats
from repro.core.tips import TIPSResult


@dataclasses.dataclass(frozen=True)
class LayerKey:
    """Static identity of one transformer block: tag + feature-map res."""
    tag: str
    resolution: int

    @property
    def name(self) -> str:
        return f"{self.tag}@{self.resolution}"


def attn_layer_order(cfg) -> Tuple[LayerKey, ...]:
    """Transformer blocks in forward-traversal order, derived from config.

    The canonical leaf order of every stats pytree, ``LedgerAccum``
    column order, and reuse-cache layer order — the denoiser contract's
    layer-order rule (DESIGN.md §11).  A config that defines its own
    ``layer_order()`` hook (every registered denoiser family does) is the
    source of truth; plain UNet-shaped configs fall back to the UNet
    traversal formula below.
    """
    order_fn = getattr(cfg, "layer_order", None)
    if callable(order_fn):
        return order_fn()
    return _unet_attn_layer_order(cfg)


def _unet_attn_layer_order(cfg) -> Tuple[LayerKey, ...]:
    """UNet traversal: down stages (attn at ``latent >> i``), optional mid
    block, then up stages (stage ``j`` revisits resolution
    ``latent >> rev[j]``) — mirrors ``unet_forward`` exactly.
    """
    order = []
    nstages = len(cfg.block_channels)
    for i, has_attn in enumerate(cfg.down_attn):
        if not has_attn:
            continue
        for r in range(cfg.resnets_per_down):
            order.append(LayerKey(f"down{i}.{r}", cfg.latent_size >> i))
    if cfg.has_mid_block:
        order.append(LayerKey("mid", cfg.latent_size >> (nstages - 1)))
    for j, i in enumerate(reversed(range(nstages))):
        if not cfg.down_attn[i]:
            continue
        for r in range(cfg.resnets_per_up):
            order.append(LayerKey(f"up{j}.{r}", cfg.latent_size >> i))
    return tuple(order)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class UNetStats:
    """Per-layer PSSA/TIPS stats in fixed, config-derived order.

    ``layers`` is static (treedef); ``pssa``/``tips`` are tuples of
    per-layer stat pytrees in the same order.  Leaves are scalars (or
    per-query arrays) for a single forward pass, and gain a leading
    ``num_steps`` axis after a scanned sampler run.

    ``reuse`` carries per-layer ``reuse.ReuseRowCounters`` (same order)
    when the forward ran with a temporal-reuse cache; it stays the empty
    tuple — contributing no leaves, so every existing treedef is
    unchanged — on the dense path.
    """
    layers: Tuple[LayerKey, ...]
    pssa: Tuple[PSSAStats, ...]
    tips: Tuple[TIPSResult, ...]
    reuse: Tuple = ()

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.pssa, self.tips, self.reuse), self.layers

    @classmethod
    def tree_unflatten(cls, layers, children):
        pssa, tips, reuse = children
        return cls(layers=layers, pssa=tuple(pssa), tips=tuple(tips),
                   reuse=tuple(reuse))

    # -- views -----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.layers)

    @property
    def num_steps(self) -> int:
        """Leading (scan) axis length; 0 for an unstacked single pass."""
        if not self.pssa:
            return 0
        lead = self.pssa[0].nnz
        return int(lead.shape[0]) if getattr(lead, "ndim", 0) >= 1 else 0

    def step(self, i: int) -> "UNetStats":
        """Per-iteration view of a stacked (scanned) stats pytree."""
        return jax.tree_util.tree_map(lambda x: x[i], self)

    def unstack(self) -> list:
        """Stacked stats -> list of per-step ``UNetStats`` (parity path)."""
        n = self.num_steps
        if n == 0:
            return [self]
        return [self.step(i) for i in range(n)]

    def as_dict(self) -> dict:
        """The seed's ``{"pssa": {...}, "tips": {...}}`` string-keyed view."""
        return {
            "pssa": {k.name: s for k, s in zip(self.layers, self.pssa)},
            "tips": {k.name: t for k, t in zip(self.layers, self.tips)},
        }

    # -- host transfer ---------------------------------------------------
    def ledger_fetch(self) -> "UNetStats":
        """Pull ONLY the scalar ledger leaves to host, in one transfer.

        A sharded engine keeps the stacked stats pytree on device — the
        per-row leaves (``TIPSResult.important`` / ``.cas``) batch-sharded
        across the mesh — until the energy ledger reads it.  The ledger
        consumes just the PSSA byte counters and the TIPS low-precision
        ratios, all scalars per (step, layer): this fetches exactly those
        in a single ``jax.device_get`` (instead of one device round-trip
        per ``float(...)`` in the ledger loops) and leaves the per-row
        leaves where they are.  Values are unchanged — host copies of the
        same arrays — so every report is bit-identical to an on-device
        read.
        """
        pssa_np, low_np, reuse_np = jax.device_get(
            (self.pssa, tuple(t.low_precision_ratio for t in self.tips),
             self.reuse))
        tips_np = tuple(
            t._replace(low_precision_ratio=low)
            for t, low in zip(self.tips, low_np))
        return UNetStats(layers=self.layers, pssa=tuple(pssa_np),
                         tips=tips_np, reuse=tuple(reuse_np))

    # -- construction ----------------------------------------------------
    @classmethod
    def from_layer_list(cls, layers, pssa, tips, reuse=()) -> "UNetStats":
        layers, pssa, tips = tuple(layers), tuple(pssa), tuple(tips)
        reuse = tuple(reuse)
        assert len(layers) == len(pssa) == len(tips), \
            (len(layers), len(pssa), len(tips))
        assert not reuse or len(reuse) == len(layers), \
            (len(reuse), len(layers))
        return cls(layers=layers, pssa=pssa, tips=tips, reuse=reuse)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SlotStats:
    """Per-layer PER-ROW integer counters (continuous-batching stats).

    The slot-serving counterpart of ``UNetStats``: same static layer order,
    but each layer carries ``pssa.PSSARowCounters`` / ``tips.TIPSRowCounters``
    whose leaves are (B,) integer vectors — one entry per batch row.  Rows
    sit at heterogeneous denoising steps under continuous batching, so the
    runtime scatters them into per-iteration ``LedgerAccum`` buckets
    instead of folding them at the source.  Integer addition is exact and
    associative, so any scatter order/occupancy reproduces the one-shot
    folded counters bit-for-bit (DESIGN.md §8).
    """
    layers: Tuple[LayerKey, ...]
    pssa: Tuple                     # per-layer PSSARowCounters
    tips: Tuple                     # per-layer TIPSRowCounters
    reuse: Tuple = ()               # per-layer ReuseRowCounters (or empty)

    def tree_flatten(self):
        return (self.pssa, self.tips, self.reuse), self.layers

    @classmethod
    def tree_unflatten(cls, layers, children):
        pssa, tips, reuse = children
        return cls(layers=layers, pssa=tuple(pssa), tips=tuple(tips),
                   reuse=tuple(reuse))

    def __len__(self) -> int:
        return len(self.layers)

    def counter_matrices(self):
        """Stack per-layer row counters: three (B, L) integer arrays.

        Columns follow ``layers`` order — the same order ``LedgerAccum``
        buckets use.  Returns (nnz, ones_xor, important).
        """
        nnz = jnp.stack([c.nnz for c in self.pssa], axis=1)
        ones_xor = jnp.stack([c.ones_xor for c in self.pssa], axis=1)
        imp = jnp.stack([t.important for t in self.tips], axis=1)
        return nnz, ones_xor, imp

    def reuse_counter_matrices(self):
        """Stack per-layer reuse counters: two (B, L) integer arrays.

        Returns (computed, total) in ``layers`` column order, or ``None``
        when the forward ran the dense path (no reuse counters).
        """
        if not self.reuse:
            return None
        computed = jnp.stack([r.computed for r in self.reuse], axis=1)
        total = jnp.stack([r.total for r in self.reuse], axis=1)
        return computed, total

    @classmethod
    def from_layer_list(cls, layers, pssa, tips, reuse=()) -> "SlotStats":
        layers, pssa, tips = tuple(layers), tuple(pssa), tuple(tips)
        reuse = tuple(reuse)
        assert len(layers) == len(pssa) == len(tips), \
            (len(layers), len(pssa), len(tips))
        assert not reuse or len(reuse) == len(layers), \
            (len(reuse), len(layers))
        return cls(layers=layers, pssa=pssa, tips=tips, reuse=reuse)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class LedgerAccum:
    """Per-DDIM-iteration integer ledger buckets for slot serving.

    One row per denoising iteration, one column per transformer block (in
    ``attn_layer_order``): ``nnz`` / ``ones_xor`` are the PSSA counters,
    ``imp`` the TIPS important-token counts, ``rows`` the number of
    accounted (active-slot) request rows that have executed the iteration.
    All integer — accumulation across steps, slots, and occupancy patterns
    is exact, so the energy report assembled from a drained accumulator is
    bit-identical to the same requests served one-shot
    (``pipeline.energy_report_from_accum``).  Counters are int32 without
    ``jax_enable_x64`` (exact to 2^31 — the same bound ``pssa.compress_stats``
    documents); a smoke-geometry serving run sits orders of magnitude below
    it.
    """
    nnz: jax.Array             # (num_steps, L) int
    ones_xor: jax.Array        # (num_steps, L) int
    imp: jax.Array             # (num_steps, L) int
    rows: jax.Array            # (num_steps,) int
    reuse_computed: jax.Array  # (num_steps, L) int — gathered patches
    reuse_total: jax.Array     # (num_steps, L) int — patch-grid size

    def tree_flatten(self):
        return (self.nnz, self.ones_xor, self.imp, self.rows,
                self.reuse_computed, self.reuse_total), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def zeros(cls, num_steps: int, num_layers: int) -> "LedgerAccum":
        x64 = bool(jax.config.read("jax_enable_x64"))
        dt = jnp.int64 if x64 else jnp.int32
        return cls(nnz=jnp.zeros((num_steps, num_layers), dt),
                   ones_xor=jnp.zeros((num_steps, num_layers), dt),
                   imp=jnp.zeros((num_steps, num_layers), dt),
                   rows=jnp.zeros((num_steps,), dt),
                   reuse_computed=jnp.zeros((num_steps, num_layers), dt),
                   reuse_total=jnp.zeros((num_steps, num_layers), dt))

    def scatter(self, step_idx: jax.Array, active: jax.Array,
                slot_stats: SlotStats) -> "LedgerAccum":
        """Add one slot step's per-row counters into their iteration buckets.

        ``step_idx`` (B,) is each slot's DDIM iteration for the step just
        executed; ``active`` (B,) masks unoccupied slots: their counters
        (UNet garbage) are zeroed BEFORE the scatter, so occupancy can
        never move a bucket.  Out-of-range indices (retired slots) are
        dropped, belt-and-braces on top of the mask.
        """
        nnz, ones_xor, imp = slot_stats.counter_matrices()
        gate = active.astype(self.nnz.dtype)[:, None]
        reuse = slot_stats.reuse_counter_matrices()
        if reuse is None:
            reuse_computed, reuse_total = self.reuse_computed, self.reuse_total
        else:
            computed, total = reuse
            reuse_computed = self.reuse_computed.at[step_idx].add(
                computed.astype(self.nnz.dtype) * gate, mode="drop")
            reuse_total = self.reuse_total.at[step_idx].add(
                total.astype(self.nnz.dtype) * gate, mode="drop")
        return LedgerAccum(
            nnz=self.nnz.at[step_idx].add(
                nnz.astype(self.nnz.dtype) * gate, mode="drop"),
            ones_xor=self.ones_xor.at[step_idx].add(
                ones_xor.astype(self.nnz.dtype) * gate, mode="drop"),
            imp=self.imp.at[step_idx].add(
                imp.astype(self.nnz.dtype) * gate, mode="drop"),
            rows=self.rows.at[step_idx].add(
                active.astype(self.rows.dtype), mode="drop"),
            reuse_computed=reuse_computed,
            reuse_total=reuse_total)


def coerce_per_step_stats(stats) -> list:
    """Normalize any supported stats shape to a per-iteration list.

    Accepts a stacked ``UNetStats`` (scan output), a single ``UNetStats``,
    a list of ``UNetStats``, or the legacy list-of-dicts — returns a list
    with one entry per denoising iteration.
    """
    if isinstance(stats, UNetStats):
        return stats.unstack()
    return list(stats)
