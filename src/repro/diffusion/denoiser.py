"""Denoiser contract: the model-agnostic interface the runtime serves.

The engine, sampler, stats pytrees, energy reports, and serving CLIs were
grown around one network — the BK-SDM-Tiny UNet.  None of the runtime
machinery actually *needs* a UNet: the paper's three features (PSSA on
self-attention, TIPS text-conditioned precision, the DBSC FFN datapath)
are properties of the transformer blocks, and everything downstream of the
forward pass consumes only

  * an eps prediction shaped like the latents,
  * a stats pytree whose STATIC layer order is derived from the config
    (``cfg.layer_order()``), and
  * an optional per-layer reuse cache in that same order.

``Denoiser`` freezes that interface.  It is a frozen/hashable dataclass —
``(family, cfg)`` — so it can sit inside jit-cache keys exactly like the
policy objects do, and the registry maps each frozen config class to its
family implementation.  ``repro.diffusion.unet`` (the original network)
and ``repro.diffusion.dit`` (patchify -> N adaLN-zero transformer blocks
-> unpatchify) each register themselves on import; ``make_denoiser(cfg)``
resolves lazily so this module stays import-cycle-free.

Contract (see DESIGN.md §11 for the full statement):

``init_params(key)``
    Fresh parameter pytree for ``cfg``.

``apply(params, latents, timesteps, context, **kw)``
    Pure forward.  ``latents`` (B, S, S, C), ``timesteps`` (B,),
    ``context`` (B or 2B, T_text, ctx_dim).  Keywords — all optional,
    all with UNet-identical semantics:

    - ``tips_active``: scalar or (B,) per-row TIPS activity;
    - ``stats_rows`` (static): restrict stats to the first N rows;
    - ``cfg_dup`` (static): shared-prefix CFG dedup — latents carry the
      cond half only, context carries [cond | uncond]; the hidden state
      is tiled to 2B rows at the first cross-attention and ``eps`` comes
      back with 2B rows (split by ``sampler.guided_eps``);
    - ``row_stats`` (static): per-row integer counters (``SlotStats``)
      instead of folded stats;
    - ``reuse_cache``: a ``core.reuse.ReuseCache`` with one
      ``LayerReuseCache`` per entry of ``layer_order()``; when given and
      ``cfg.reuse_policy.enabled``, the return gains a third element (the
      new cache);
    - ``overrides``: per-row phase threshold scales
      (``solvers.PhaseOverrides``) or None.

    Returns ``(eps, stats)`` or ``(eps, stats, new_cache)``.

``layer_order()``
    The static ``stats.LayerKey`` tuple — the canonical leaf order of
    every stats pytree, ``LedgerAccum`` column order, and reuse-cache
    layer order.  Must depend only on the (hashable) config.

Config hooks the runtime may call on ANY registered config (duck-typed,
with UNet-formula fallbacks for plain configs):

    ``cfg.layer_order()``       -> tuple[LayerKey, ...]
    ``cfg.channels_at(res)``    -> token width at a feature-map resolution
    ``cfg.full_geometry()``     -> the full-size config of the same family
                                   (analytic-ledger extrapolation target)
    ``cfg.attn_resolutions()``  -> distinct attention resolutions, sorted
                                   descending (measured-ratio remap keys)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple


class FamilySpec(NamedTuple):
    """One registered denoiser family (resolved by frozen config class)."""
    family: str
    config_cls: type
    init_params: Callable       # (key, cfg) -> params pytree
    forward: Callable           # (params, lat, t, ctx, cfg, **kw) -> tuple
    abstract_params: Callable   # (cfg) -> jax.eval_shape pytree


_REGISTRY: dict = {}            # family name -> FamilySpec
_BY_CONFIG: dict = {}           # config class -> FamilySpec

#: CLI vocabulary: ``--model`` flag values, in presentation order.
FAMILIES = ("unet", "dit")


def register_family(spec: FamilySpec) -> None:
    """Called at import time by each family module (unet.py, dit.py)."""
    _REGISTRY[spec.family] = spec
    _BY_CONFIG[spec.config_cls] = spec


def _ensure_registered() -> None:
    # Lazy: importing the family modules here (not at module top) keeps
    # denoiser.py importable from stats/engine/sampler without cycles.
    import repro.diffusion.unet    # noqa: F401  (registers "unet")
    import repro.diffusion.dit     # noqa: F401  (registers "dit")


def family_of(cfg) -> str:
    """The family name a (frozen) denoiser config belongs to."""
    _ensure_registered()
    spec = _BY_CONFIG.get(type(cfg))
    if spec is None:
        known = sorted(c.__name__ for c in _BY_CONFIG)
        raise TypeError(f"no denoiser family registered for "
                        f"{type(cfg).__name__}; known configs: {known}")
    return spec.family


@dataclasses.dataclass(frozen=True)
class Denoiser:
    """Frozen, hashable handle pairing a family with its config.

    Everything the runtime needs from a model flows through this object;
    ``engine.DiffusionEngine`` and ``pipeline.StableDiffusionPipeline``
    hold one instead of importing ``unet_forward`` directly.
    """
    family: str
    cfg: object                  # a frozen config dataclass (hashable)

    def _spec(self) -> FamilySpec:
        _ensure_registered()
        return _REGISTRY[self.family]

    def init_params(self, key):
        return self._spec().init_params(key, self.cfg)

    def apply(self, params, latents, timesteps, context, **kw):
        return self._spec().forward(params, latents, timesteps, context,
                                    self.cfg, **kw)

    def layer_order(self):
        from repro.diffusion.stats import attn_layer_order
        return attn_layer_order(self.cfg)

    def abstract_params(self):
        return self._spec().abstract_params(self.cfg)


def make_denoiser(cfg) -> Denoiser:
    """Resolve a config to its registered family's ``Denoiser``."""
    return Denoiser(family=family_of(cfg), cfg=cfg)
