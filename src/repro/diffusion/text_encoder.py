"""CLIP-style text encoder (stage 1 of the SD flow, Fig. 1(a)).

Bidirectional pre-LN transformer over the caption tokens with the CLS token
*first* — the position TIPS relies on (paper §IV-A cites BERT/Evo-ViT for the
CLS-first convention).  Full size mirrors CLIP ViT-L/14's text tower
(12L, d=768, 77 tokens); tests run the reduced config.

No pretrained weights offline — the encoder produces structurally-correct
context embeddings; the paper's evaluation (energy/EMA/throughput) does not
depend on caption semantics.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TextEncoderConfig:
    vocab_size: int = 49408
    max_len: int = 77
    d_model: int = 768
    num_layers: int = 12
    num_heads: int = 12
    d_ff: int = 3072
    dtype: str = "float32"

    def smoke(self) -> "TextEncoderConfig":
        return dataclasses.replace(self, vocab_size=256, max_len=8,
                                   d_model=32, num_layers=2, num_heads=4,
                                   d_ff=64)


CLIP_TEXT = TextEncoderConfig()


def init_text_encoder_params(key, cfg: TextEncoderConfig):
    dtype = jnp.dtype(cfg.dtype)
    d, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2 + cfg.num_layers)
    s = d ** -0.5

    def layer(k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        return {
            "ln1": jnp.ones((d,), dtype), "ln1_b": jnp.zeros((d,), dtype),
            "wqkv": (jax.random.normal(k1, (d, 3 * d)) * s).astype(dtype),
            "wo": (jax.random.normal(k2, (d, d)) * s).astype(dtype),
            "ln2": jnp.ones((d,), dtype), "ln2_b": jnp.zeros((d,), dtype),
            "w1": (jax.random.normal(k3, (d, dff)) * s).astype(dtype),
            "w2": (jax.random.normal(k4, (dff, d))
                   * dff ** -0.5).astype(dtype),
        }

    return {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_size, d))
                  * 0.02).astype(dtype),
        "pos": (jax.random.normal(ks[1], (cfg.max_len, d))
                * 0.01).astype(dtype),
        "layers": [layer(k) for k in ks[2:]],
        "ln_f": jnp.ones((d,), dtype),
        "ln_f_b": jnp.zeros((d,), dtype),
    }


def _ln(x, scale, bias, eps=1e-5):
    m = jnp.mean(x.astype(jnp.float32), -1, keepdims=True)
    v = jnp.var(x.astype(jnp.float32), -1, keepdims=True)
    return ((x.astype(jnp.float32) - m) * jax.lax.rsqrt(v + eps)
            * scale + bias).astype(x.dtype)


def encode_text(params, tokens, cfg: TextEncoderConfig):
    """tokens (B, T) int32, CLS at position 0 -> (B, T, d) context."""
    b, t = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0) + params["pos"][None, :t]
    nh, hd = cfg.num_heads, cfg.d_model // cfg.num_heads
    for lp in params["layers"]:
        x = _ln(h, lp["ln1"], lp["ln1_b"])
        qkv = jnp.einsum("btd,dk->btk", x, lp["wqkv"])
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(b, t, nh, hd)
        k = k.reshape(b, t, nh, hd)
        v = v.reshape(b, t, nh, hd)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
        probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(h.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, t, -1)
        h = h + jnp.einsum("btd,dk->btk", o, lp["wo"])
        x = _ln(h, lp["ln2"], lp["ln2_b"])
        h = h + jnp.einsum(
            "btf,fd->btd",
            jax.nn.gelu(jnp.einsum("btd,df->btf", x, lp["w1"])), lp["w2"])
    return _ln(h, params["ln_f"], params["ln_f_b"])
