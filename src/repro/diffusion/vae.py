"""VAE decoder (stage 3 of the SD flow, Fig. 1(a)): latents -> RGB image.

SD-v1 decoder geometry: 4-channel latents at S x S are decoded to a
(8S x 8S x 3) image through three nearest-neighbour x2 upsampling stages
with resnet blocks.  Reduced channel widths run on CPU; the full geometry is
only exercised through the analytic ledger (the decoder runs ONCE per image,
so it is a small EMA term next to 25 UNet iterations).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.diffusion.unet import _conv_p, _norm_p, conv2d, group_norm


@dataclasses.dataclass(frozen=True)
class VAEConfig:
    latent_channels: int = 4
    out_channels: int = 3
    channels: tuple = (512, 512, 256, 128)
    resnets_per_stage: int = 2
    groups: int = 32
    scale_factor: float = 0.18215       # SD-v1 latent scaling
    dtype: str = "float32"

    def smoke(self) -> "VAEConfig":
        return dataclasses.replace(self, channels=(32, 32, 16, 16), groups=8)


SD_VAE = VAEConfig()


def _resnet_p(key, cin, cout, dtype):
    ks = jax.random.split(key, 3)
    p = {"norm1": _norm_p(cin, dtype),
         "conv1": _conv_p(ks[0], 3, 3, cin, cout, dtype),
         "norm2": _norm_p(cout, dtype),
         "conv2": _conv_p(ks[1], 3, 3, cout, cout, dtype)}
    if cin != cout:
        p["skip"] = _conv_p(ks[2], 1, 1, cin, cout, dtype)
    return p


def init_vae_params(key, cfg: VAEConfig):
    dtype = jnp.dtype(cfg.dtype)
    keys = iter(jax.random.split(key, 64))
    chans = cfg.channels
    p = {"conv_in": _conv_p(next(keys), 3, 3, cfg.latent_channels, chans[0],
                            dtype)}
    stages = []
    cin = chans[0]
    for i, cout in enumerate(chans):
        st = {"resnets": []}
        for _ in range(cfg.resnets_per_stage):
            st["resnets"].append(_resnet_p(next(keys), cin, cout, dtype))
            cin = cout
        if i < len(chans) - 1:
            st["up"] = _conv_p(next(keys), 3, 3, cout, cout, dtype)
        stages.append(st)
    p["stages"] = stages
    p["norm_out"] = _norm_p(chans[-1], dtype)
    p["conv_out"] = _conv_p(next(keys), 3, 3, chans[-1], cfg.out_channels,
                            dtype)
    return p


def _resnet(x, p, groups):
    h = group_norm(x, p["norm1"]["scale"], p["norm1"]["bias"], groups)
    h = conv2d(jax.nn.silu(h), p["conv1"]["w"], p["conv1"]["b"])
    h = group_norm(h, p["norm2"]["scale"], p["norm2"]["bias"], groups)
    h = conv2d(jax.nn.silu(h), p["conv2"]["w"], p["conv2"]["b"])
    skip = x if "skip" not in p else conv2d(x, p["skip"]["w"],
                                            p["skip"]["b"], padding=0)
    return skip + h


def decode(params, latents, cfg: VAEConfig):
    """(B, S, S, 4) latents -> (B, 8S, 8S, 3) image in [-1, 1]."""
    h = conv2d(latents / cfg.scale_factor, params["conv_in"]["w"],
               params["conv_in"]["b"])
    for i, st in enumerate(params["stages"]):
        for rp in st["resnets"]:
            h = _resnet(h, rp, cfg.groups)
        if "up" in st:
            b, hh, ww, c = h.shape
            h = jax.image.resize(h, (b, 2 * hh, 2 * ww, c), "nearest")
            h = conv2d(h, st["up"]["w"], st["up"]["b"])
    h = group_norm(h, params["norm_out"]["scale"],
                   params["norm_out"]["bias"], cfg.groups)
    return jnp.tanh(conv2d(jax.nn.silu(h), params["conv_out"]["w"],
                           params["conv_out"]["b"]))
