"""End-to-end text-to-image pipeline (Fig. 1(a)) with the paper's features.

Stages: text encoding -> 25 iterative UNet denoising steps -> VAE decode.
The pipeline runs the reduced geometry on CPU and *measures* the quantities
the silicon measures — per-resolution PSSA compression ratios and
per-iteration TIPS low-precision ratios — then injects them into the
full-geometry analytic ledger to produce the paper's headline numbers
(EMA GB/iter, mJ/iter).  PSSA / TIPS / DBSC are feature toggles, so the
baseline-vs-optimized deltas of Figs. 5/9 fall out of the same code path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import energy, pssa
from repro.core.tips import TIPS_ACTIVE_ITERS
from repro.diffusion import ledger as L
from repro.diffusion import solvers as solvers_mod
from repro.diffusion.sampler import DDIMConfig, sample
from repro.diffusion.stats import (UNetStats, attn_layer_order,
                                   coerce_per_step_stats)
from repro.diffusion.denoiser import make_denoiser
from repro.diffusion.text_encoder import (TextEncoderConfig,
                                          encode_text,
                                          init_text_encoder_params)
from repro.diffusion.unet import UNetConfig
from repro.diffusion.vae import VAEConfig, decode, init_vae_params


def _iter_layer_stats(stats_one_iter, kind: str):
    """Yield (resolution, per-layer stats) from either stats representation.

    ``kind`` is "pssa" or "tips".  Supports the ``UNetStats`` pytree (layer
    resolutions are static metadata) and the legacy string-keyed dict view
    (resolution parsed from the "tag@res" key).
    """
    if isinstance(stats_one_iter, UNetStats):
        entries = getattr(stats_one_iter, kind)
        for lk, st in zip(stats_one_iter.layers, entries):
            yield lk.resolution, st
        return
    for key, st in stats_one_iter.get(kind, {}).items():
        yield int(key.rsplit("@", 1)[1]), st


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    # ``unet`` holds the DENOISER config — any registered family
    # (``UNetConfig`` or ``dit.DiTConfig``); the field keeps its
    # historical name because every consumer reads policies/geometry
    # through it and both families expose the same contract hooks.
    unet: UNetConfig = UNetConfig()
    text: TextEncoderConfig = TextEncoderConfig()
    vae: VAEConfig = VAEConfig()
    ddim: DDIMConfig = DDIMConfig()

    @staticmethod
    def smoke() -> "PipelineConfig":
        return PipelineConfig(
            unet=UNetConfig().smoke(),
            text=TextEncoderConfig().smoke(),
            vae=VAEConfig().smoke(),
            ddim=DDIMConfig(num_inference_steps=3, guidance_scale=1.0,
                            tips_active_iters=2),
        )


class StableDiffusionPipeline:
    """Holds params + jitted stage functions; reusable across prompts.

    This is the per-step reference path (25 Python dispatches, two UNet
    calls per step under CFG).  The production path is
    ``repro.diffusion.engine.DiffusionEngine`` — one jitted
    encode -> scanned-sampler -> decode computation with fused CFG; both
    feed the same ``energy_report`` (stats representations are
    interchangeable via ``repro.diffusion.stats``).
    """

    def __init__(self, cfg: PipelineConfig, key=None):
        self.cfg = cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        # context width must match: text d_model == unet context_dim
        assert cfg.text.d_model == cfg.unet.context_dim, \
            (cfg.text.d_model, cfg.unet.context_dim)
        self.denoiser = make_denoiser(cfg.unet)
        self.text_params = init_text_encoder_params(k1, cfg.text)
        self.unet_params = self.denoiser.init_params(k2)
        self.vae_params = init_vae_params(k3, cfg.vae)

        self._encode = jax.jit(
            lambda toks: encode_text(self.text_params, toks, cfg.text))
        self._unet = jax.jit(
            lambda lat, t, ctx, act: self.denoiser.apply(
                self.unet_params, lat, t, ctx, tips_active=act))
        self._decode = jax.jit(
            lambda lat: decode(self.vae_params, lat, cfg.vae))

    # ------------------------------------------------------------------
    def generate(self, prompt_tokens, key, uncond_tokens=None,
                 collect_stats: bool = True):
        """prompt_tokens (B, text_len) int32 -> (image, stats_per_iter)."""
        cfg = self.cfg
        context = self._encode(prompt_tokens)
        uncond = (self._encode(uncond_tokens)
                  if uncond_tokens is not None else None)
        b = prompt_tokens.shape[0]
        s = cfg.unet.latent_size
        latents = jax.random.normal(key, (b, s, s, cfg.unet.in_channels))
        latents, stats = sample(self._unet, latents, context, uncond,
                                cfg.ddim, collect_stats=collect_stats)
        image = self._decode(latents)
        return image, stats

    # ------------------------------------------------------------------
    # Measurement -> full-geometry ledger (delegates to module functions
    # so the engine/serving path can use them without a pipeline object)
    # ------------------------------------------------------------------
    def measured_sas_ratios(self, stats_one_iter) -> dict:
        return measured_sas_ratios(stats_one_iter)

    def measured_tips_ratio(self, stats_one_iter) -> float:
        return measured_tips_ratio(stats_one_iter)

    def energy_report(self, stats_per_iter, full_geometry: bool = True
                      ) -> "PipelineEnergyReport":
        return energy_report(self.cfg, stats_per_iter,
                             full_geometry=full_geometry)


def _sas_ratio_terms(stats_one_iter) -> dict:
    """Per-resolution (numerator, denominator) byte sums for the SAS ratio.

    Returned separately from the ratio so multi-batch serving can aggregate
    the terms across engine calls before dividing: the byte counters scale
    with the accounted row count (``stats_rows``), which makes the sums
    self-weighting by valid rows.
    """
    by_res: dict = {}
    for res, st in _iter_layer_stats(stats_one_iter, "pssa"):
        comp = float(st.bytes_pssa_total)
        base = float(st.bytes_baseline)
        num, den = by_res.get(res, (0.0, 0.0))
        by_res[res] = (num + comp, den + base)
    return by_res


def _tips_ratio_terms(stats_one_iter) -> tuple:
    """(numerator, denominator) of the workload-weighted INT6 fraction.

    The per-layer ``low_precision_ratio`` is a mean over the accounted
    batch rows, so the workload weight carries the row count (read from
    the ``important`` mask's static shape — no device transfer): batches
    with more valid rows count proportionally more when the terms are
    summed across engine calls.
    """
    num = den = 0.0
    for res, tr in _iter_layer_stats(stats_one_iter, "tips"):
        rows = float(tr.important.shape[0]) \
            if getattr(tr.important, "ndim", 0) >= 2 else 1.0
        work = float(res * res) * rows     # FFN MACs scale with token count
        num += float(tr.low_precision_ratio) * work
        den += work
    return num, den


def measured_sas_ratios(stats_one_iter) -> dict:
    """Per-resolution (compressed/dense) SAS ratio from PSSAStats.

    Accepts a single-step ``UNetStats`` pytree or the legacy
    ``{"pssa": {"tag@res": PSSAStats}}`` dict view.
    """
    return {res: num / max(den, 1e-12)
            for res, (num, den) in _sas_ratio_terms(stats_one_iter).items()}


def measured_tips_ratio(stats_one_iter) -> float:
    """Workload-weighted INT6 fraction across the iteration's FFNs."""
    num, den = _tips_ratio_terms(stats_one_iter)
    return num / max(den, 1e-12)


def energy_report(cfg: "PipelineConfig", stats_per_iter,
                  full_geometry: bool = True,
                  sampler_policy=None) -> "PipelineEnergyReport":
    """Headline numbers: EMA GB/iter + mJ/iter (Table I reproduction).

    ``stats_per_iter`` is either the stacked ``UNetStats`` a scanned
    engine run returns (leading axis = iterations) or the seed's list of
    per-iteration stats.  The reduced run's measured ratios drive the
    FULL BK-SDM-Tiny ledger (hardware adaptation note: patch locality is
    resolution-dependent, so per-resolution ratios transfer; DESIGN.md §2).
    A single-batch aggregation: delegates to :func:`energy_report_multi`.

    ``sampler_policy``: the ``solvers.SamplerPolicy`` the run used, when
    it is not the config's default schedule — the trajectory then carries
    ``policy.num_steps`` iterations and the TIPS-active window follows
    ``solvers.tips_active_schedule`` instead of ``tips_active_iters``.
    """
    return energy_report_multi(cfg, [stats_per_iter],
                               full_geometry=full_geometry,
                               sampler_policy=sampler_policy)


def energy_report_multi(cfg: "PipelineConfig", stats_per_batch,
                        full_geometry: bool = True,
                        sampler_policy=None) -> "PipelineEnergyReport":
    """Aggregate energy report across SEVERAL engine calls (serving).

    ``stats_per_batch``: one stats trajectory per engine call (stacked
    ``UNetStats`` or per-iteration list), each already restricted to its
    valid rows (``stats_rows`` masks padded tail rows out at the source).
    Per DDIM iteration, the SAS byte terms and the row-weighted TIPS terms
    are summed across batches BEFORE dividing, so every valid image row in
    the run — and no padded duplicate — contributes with equal weight.
    With a single entry this reduces exactly to :func:`energy_report`.

    With ``sampler_policy`` set, every trajectory must come from runs of
    that SAME policy (mixed-policy serving uses the banked accumulator
    path, :func:`energy_report_banked`).
    """
    fetched = []
    for s in stats_per_batch:
        if isinstance(s, UNetStats):
            s = s.ledger_fetch()        # one host transfer per engine call
        fetched.append(coerce_per_step_stats(s))
    if not fetched:
        raise ValueError("stats_per_batch is empty")
    n = (cfg.ddim.num_inference_steps if sampler_policy is None
         else sampler_policy.num_steps)
    tips_flags = (None if sampler_policy is None else
                  solvers_mod.tips_active_schedule(sampler_policy, cfg.ddim))
    for s in fetched:
        if len(s) != n:
            raise ValueError(
                f"stats trajectory has {len(s)} iterations, "
                f"{'policy' if sampler_policy else 'config'} says {n}")

    per_iter_terms = []
    for i in range(n):
        sas_terms: dict = {}
        tnum = tden = 0.0
        for s in fetched:
            for res, (num, den) in _sas_ratio_terms(s[i]).items():
                a, b = sas_terms.get(res, (0.0, 0.0))
                sas_terms[res] = (a + num, b + den)
            num, den = _tips_ratio_terms(s[i])
            tnum, tden = tnum + num, tden + den
        per_iter_terms.append((sas_terms, (tnum, tden)))
    return _report_from_terms(cfg, per_iter_terms,
                              full_geometry=full_geometry,
                              num_steps=n, tips_flags=tips_flags)


def _report_from_terms(cfg: "PipelineConfig", per_iter_terms,
                       full_geometry: bool = True,
                       num_steps: Optional[int] = None,
                       tips_flags=None) -> "PipelineEnergyReport":
    """Per-iteration aggregated terms -> the full-geometry ledger report.

    ``per_iter_terms``: one ``(sas_terms, (tips_num, tips_den))`` per DDIM
    iteration, where ``sas_terms`` maps resolution to summed
    (compressed, baseline) byte terms.  Shared tail of the batch-stats
    aggregation (:func:`energy_report_multi`) and the slot-serving
    accumulator path (:func:`energy_report_from_accum`) — both reduce to
    these terms, which is what makes the two serving modes' headlines
    comparable bit-for-bit.

    ``num_steps``: the trajectory length when a ``SamplerPolicy`` budget
    overrides the config's schedule (default: config steps).
    ``tips_flags``: per-iteration TIPS-active booleans for the same case
    (default: the config's ``i < tips_active_iters`` window); the
    ``cfg.unet.tips`` master toggle still gates both.
    """
    n = cfg.ddim.num_inference_steps if num_steps is None else num_steps
    if len(per_iter_terms) != n:
        raise ValueError(
            f"{len(per_iter_terms)} iteration terms, schedule says {n}")
    # contract hooks: full_geometry() is the family's analytic-ledger
    # extrapolation target, attn_resolutions() its measured-ratio remap
    # keys; the fallbacks reproduce the UNet formulas for plain configs
    if full_geometry:
        geom_fn = getattr(cfg.unet, "full_geometry", None)
        geom = geom_fn() if callable(geom_fn) else UNetConfig()
    else:
        geom = cfg.unet
    precision = cfg.unet.effective_precision()
    res_fn = getattr(geom, "attn_resolutions", None)
    geom_res = (list(res_fn()) if callable(res_fn) else
                sorted({geom.latent_size >> s
                        for s, a in enumerate(geom.down_attn) if a},
                       reverse=True))

    def remap(ratios: dict) -> dict:
        meas = sorted(ratios, reverse=True)
        return {g: ratios[m] for g, m in zip(geom_res, meas)}

    opts_per_iter = []
    for i, (sas_terms, (tnum, tden)) in enumerate(per_iter_terms):
        sas_ratio = {res: num / max(den, 1e-12)
                     for res, (num, den) in sas_terms.items()}
        tips_on = (i < cfg.ddim.tips_active_iters if tips_flags is None
                   else bool(tips_flags[i]))
        opts_per_iter.append(L.LedgerOptions(
            pssa=cfg.unet.pssa,
            tips=cfg.unet.tips and tips_on,
            sas_ratio=remap(sas_ratio),
            tips_low_ratio=tnum / max(tden, 1e-12),
            # MAC split mirrors the datapath's actual FFN mask coverage
            tips_mid=precision.ffn_mid,
        ))
    baseline_opts = [L.LedgerOptions()] * n
    return PipelineEnergyReport(
        optimized=L.generation_report(geom, opts_per_iter),
        baseline=L.generation_report(geom, baseline_opts),
        iterations=n,
    )


def ledger_terms_from_accum(cfg: "PipelineConfig", accum) -> list:
    """Per-iteration ledger terms from a slot-serving ``LedgerAccum``.

    The continuous-batching runtime accumulates INTEGER counters per DDIM
    iteration (``repro.diffusion.stats.LedgerAccum``); this assembles the
    same per-iteration (SAS byte, TIPS workload) terms that
    :func:`energy_report_multi` derives from per-call ``UNetStats`` —
    bit-identically, because both reduce the same integer counters through
    the same byte arithmetic (``pssa.stats_from_counters``) and the same
    float32 ratio step the device path uses.  Slot count, admission order,
    and occupancy cannot move a term: integer accumulation is exact.
    """
    nnz, ones_xor, imp, rows = _fetch_accum(accum)
    layers = attn_layer_order(cfg.unet)
    n = cfg.ddim.num_inference_steps
    if nnz.shape != (n, len(layers)):
        raise ValueError(f"accumulator shape {nnz.shape} does not match "
                         f"({n}, {len(layers)})")
    return _terms_from_counters(cfg, nnz, ones_xor, imp, rows, 0, n)


def _fetch_accum(accum):
    """One host transfer of the four SAS/TIPS counter planes."""
    import numpy as np

    return tuple(np.asarray(x) for x in jax.device_get(
        (accum.nnz, accum.ones_xor, accum.imp, accum.rows)))


def _terms_from_counters(cfg: "PipelineConfig", nnz, ones_xor, imp, rows,
                         start: int, n: int) -> list:
    """Bucket rows ``[start, start + n)`` -> per-iteration ledger terms.

    Shared by the legacy single-schedule accumulator (``start=0``) and the
    banked per-policy slices (``start = policy_index * bank_max_steps``):
    a policy's terms depend only on ITS buckets, so the same integers give
    the same floats no matter what else shared the slot batch.
    """
    layers = attn_layer_order(cfg.unet)
    heads = cfg.unet.num_heads
    per_iter_terms = []
    for i in range(start, start + n):
        sas_terms: dict = {}
        tnum = tden = 0.0
        r = int(rows[i])
        for li, lk in enumerate(layers):
            if r == 0:
                continue                  # nothing accounted yet
            res = lk.resolution
            tq = res * res
            st = pssa.stats_from_counters(
                jnp.asarray(int(nnz[i, li])), jnp.asarray(int(ones_xor[i, li])),
                lead=r * heads, tq=tq, tk=tq,
                patch=cfg.unet.patch_size(res))
            num, den = sas_terms.get(res, (0.0, 0.0))
            sas_terms[res] = (num + float(st.bytes_pssa_total),
                              den + float(st.bytes_baseline))
            # the one-shot path sums (1 - imp_c/(rows_c*Tq)) * Tq * rows_c
            # per call; with exact per-call folds (power-of-two
            # rows_c * Tq) that telescopes to the INTEGER
            # Tq*rows - imp_total, so the accumulator reproduces the
            # aggregated term without ever dividing
            tnum += float(tq * r - int(imp[i, li]))
            tden += float(tq * r)
        per_iter_terms.append((sas_terms, (tnum, tden)))
    return per_iter_terms


def banked_ledger_terms(cfg: "PipelineConfig", accum, bank) -> list:
    """Per-policy per-iteration ledger terms from a BANKED ``LedgerAccum``.

    A banked slot state (``init_slots(bank=...)``) scatters counters into
    bucket ``p * N + i`` (N = bank max budget), so policy ``p``'s
    trajectory is the contiguous row block ``[p*N, p*N + budget_p)``.
    Returns one per-iteration term list per bank entry, in bank order —
    each the exact analogue of what :func:`ledger_terms_from_accum`
    produces for a single-schedule run of only that policy's requests.
    """
    bank = solvers_mod.as_bank(bank)
    nnz, ones_xor, imp, rows = _fetch_accum(accum)
    layers = attn_layer_order(cfg.unet)
    n_max = solvers_mod.bank_max_steps(bank)
    want = (len(bank) * n_max, len(layers))
    if nnz.shape != want:
        raise ValueError(f"accumulator shape {nnz.shape} does not match "
                         f"banked layout {want}")
    return [_terms_from_counters(cfg, nnz, ones_xor, imp, rows,
                                 p * n_max, pol.num_steps)
            for p, pol in enumerate(bank)]


def energy_report_banked(cfg: "PipelineConfig", accum, bank,
                         full_geometry: bool = True
                         ) -> "BankedEnergyReport":
    """Per-policy + aggregate energy report for a banked serving run.

    Each policy's buckets flow through the SAME term assembly and ledger
    as a dedicated single-policy run, so every per-policy headline is
    bit-identical to serving that policy's requests alone — and invariant
    to slot count and admission order (integer accumulation).  Policies
    whose buckets saw no work (``rows[p*N] == 0``) are reported with
    ``images == 0`` and excluded from the aggregate.

    The per-image energy honestly charges each tier its OWN step budget:
    ``mj_per_image = mj_per_iter_with_ema * num_steps`` — the quantity the
    step-budget sweep compares across tiers.
    """
    terms = banked_ledger_terms(cfg, accum, bank)
    bank = solvers_mod.as_bank(bank)
    _, _, _, rows = _fetch_accum(accum)
    n_max = solvers_mod.bank_max_steps(bank)
    entries = []
    for p, (pol, t) in enumerate(zip(bank, terms)):
        # every admitted request visits its step-0 bucket exactly once
        images = int(rows[p * n_max])
        report = None
        if images > 0:
            report = _report_from_terms(
                cfg, t, full_geometry=full_geometry,
                num_steps=pol.num_steps,
                tips_flags=solvers_mod.tips_active_schedule(pol, cfg.ddim))
        entries.append(BankedPolicyReport(policy=pol, images=images,
                                          report=report))
    return BankedEnergyReport(entries=tuple(entries))


def energy_report_from_accum(cfg: "PipelineConfig", accum,
                             full_geometry: bool = True
                             ) -> "PipelineEnergyReport":
    """Energy report for a drained slot-serving run (DESIGN.md §8).

    Bit-identical to :func:`energy_report_multi` over the same requests
    served one-shot whenever the per-call float folds are exact —
    power-of-two accounted rows per call, always true for the test/bench
    configurations and trivially true for the single-call oracle.
    """
    return _report_from_terms(cfg, ledger_terms_from_accum(cfg, accum),
                              full_geometry=full_geometry)


def merge_ledger_accums(accums) -> "LedgerAccum":
    """Sum per-replica ``LedgerAccum``s into one cluster accumulator.

    The multi-replica ledger primitive (DESIGN.md §13): every replica's
    slot runtime scatters INTEGER counters into the same per-iteration
    (or per-(policy, step)) bucket layout, and integer addition is exact,
    associative and commutative — so the merged accumulator, and every
    report derived from it, is bit-identical at ANY replica count,
    routing decision, or admission order that serves the same requests.
    This is the cluster-scale analogue of ``energy_report_multi``'s
    sum-before-divide rule for micro-batch serving.
    """
    accums = list(accums)
    if not accums:
        raise ValueError("merge_ledger_accums: no accumulators")
    shapes = {tuple(a.nnz.shape) for a in accums}
    if len(shapes) > 1:
        raise ValueError(
            f"merge_ledger_accums: mismatched bucket layouts {shapes} — "
            f"replicas must share one bank/schedule")
    merged = accums[0]
    for a in accums[1:]:
        merged = jax.tree_util.tree_map(lambda x, y: x + y, merged, a)
    return merged


def energy_report_cluster(cfg: "PipelineConfig", accums, bank=None,
                          full_geometry: bool = True):
    """Energy report for a multi-replica (cluster-router) serving run.

    ``accums``: one drained ``LedgerAccum`` per replica.  Merged with
    :func:`merge_ledger_accums`, then reported through the same tail as
    single-replica slot serving — :func:`energy_report_banked` when the
    replicas served a sampler ``bank``, :func:`energy_report_from_accum`
    otherwise — so the cluster headline is bit-identical to one replica,
    and to the same requests served one-shot.
    """
    merged = merge_ledger_accums(accums)
    if bank is not None:
        return energy_report_banked(cfg, merged, bank,
                                    full_geometry=full_geometry)
    return energy_report_from_accum(cfg, merged,
                                    full_geometry=full_geometry)


def phase_breakdown_from_accum(cfg: "PipelineConfig", accum, bank) -> list:
    """Per-policy, per-phase realized ratios from a banked accumulator.

    Groups each policy's per-iteration terms by its phase schedule
    (``solvers.phase_index_schedule``) and reduces terms WITHIN each phase
    before dividing — the phase-resolved view of what the phase-scheduled
    thresholds actually did to SAS compression and the INT6 fraction.
    Returns, per bank entry, ``{"policy", "phases": [{"phase", "iters",
    "sas_ratio", "tips_low_ratio"}, ...]}``.
    """
    out = []
    bank = solvers_mod.as_bank(bank)
    for pol, terms in zip(bank, banked_ledger_terms(cfg, accum, bank)):
        phase_ids = solvers_mod.phase_index_schedule(pol)
        groups: dict = {}
        for i, (sas_terms, (tnum, tden)) in enumerate(terms):
            g = groups.setdefault(phase_ids[i], [0, {}, 0.0, 0.0])
            g[0] += 1
            for res, (num, den) in sas_terms.items():
                a, b = g[1].get(res, (0.0, 0.0))
                g[1][res] = (a + num, b + den)
            g[2] += tnum
            g[3] += tden
        phases = []
        for ph in sorted(groups):
            iters, sas, tnum, tden = groups[ph]
            snum = sum(n for n, _ in sas.values())
            sden = sum(d for _, d in sas.values())
            phases.append({
                "phase": ph, "iters": iters,
                "sas_ratio": snum / max(sden, 1e-12),
                "tips_low_ratio": tnum / max(tden, 1e-12)})
        out.append({"policy": pol.key(), "phases": phases})
    return out


def tips_ratios_from_accum(cfg: "PipelineConfig", accum) -> list:
    """Per-iteration realized INT6 row fraction from the accumulator."""
    return [num / max(den, 1e-12)
            for _, (num, den) in ledger_terms_from_accum(cfg, accum)]


def aggregated_tips_ratios_per_iter(cfg: "PipelineConfig",
                                    stats_per_batch) -> list:
    """Row-weighted per-iteration TIPS low-precision ratios across calls.

    Feeds ``tips.workload_low_precision_fraction(..., ddim=cfg.ddim)`` so
    a serving run reports the INT6 workload fraction of ITS schedule.
    """
    fetched = [coerce_per_step_stats(
        s.ledger_fetch() if isinstance(s, UNetStats) else s)
        for s in stats_per_batch]
    out = []
    for i in range(cfg.ddim.num_inference_steps):
        num = den = 0.0
        for s in fetched:
            a, b = _tips_ratio_terms(s[i])
            num, den = num + a, den + b
        out.append(num / max(den, 1e-12))
    return out


def reuse_ratios_from_accum(cfg: "PipelineConfig", accum) -> list:
    """Per-iteration REALIZED temporal-reuse ratio from a ``LedgerAccum``.

    Ratio ``i`` is ``1 - computed/total`` over the iteration's reuse row
    counters summed across layers and accounted rows — the fraction of
    patch rows served from the cache instead of recomputed.  Integer
    counters in, one float division out, so the value is bit-identical
    across slot counts, admission orders, and data-parallel layouts (the
    same invariance the SAS/TIPS buckets carry).  Iterations with no
    accounted reuse work (dense runs, not-yet-reached steps) report 0.0.
    """
    import numpy as np

    comp, tot = (np.asarray(x) for x in jax.device_get(
        (accum.reuse_computed, accum.reuse_total)))
    out = []
    for i in range(cfg.ddim.num_inference_steps):
        t = float(tot[i].sum())
        out.append(0.0 if t == 0.0 else 1.0 - float(comp[i].sum()) / t)
    return out


def aggregated_reuse_ratios_per_iter(cfg: "PipelineConfig",
                                     stats_per_batch) -> list:
    """Per-iteration realized reuse ratio across scanned engine calls.

    ``stats_per_batch``: stacked ``UNetStats`` trajectories whose
    ``reuse`` counters carry a leading iteration axis (what
    ``sample_scan_reuse`` returns).  Terms are summed across batches and
    layers before dividing — same reduction as
    :func:`reuse_ratios_from_accum`, so slot serving and one-shot serving
    report identical ratios for the same work.  Dense trajectories
    (empty ``reuse`` tuple) contribute nothing; all-dense input yields
    zeros.
    """
    import numpy as np

    out = []
    for i in range(cfg.ddim.num_inference_steps):
        num = den = 0.0
        for s in stats_per_batch:
            reuse = s.reuse if isinstance(s, UNetStats) else ()
            for c in reuse:
                comp, tot = (np.asarray(x) for x in
                             jax.device_get((c.computed, c.total)))
                num += float(comp[i].sum())
                den += float(tot[i].sum())
        out.append(0.0 if den == 0.0 else 1.0 - num / den)
    return out


@dataclasses.dataclass
class PipelineEnergyReport:
    optimized: energy.EnergyReport
    baseline: energy.EnergyReport
    iterations: int

    @property
    def ema_gb_per_iter_baseline(self) -> float:
        return self.baseline.ema_bytes_total / self.iterations / 1e9

    @property
    def ema_reduction(self) -> float:
        return 1.0 - (self.optimized.ema_bytes_total
                      / self.baseline.ema_bytes_total)

    @property
    def mj_per_iter_with_ema(self) -> float:
        return self.optimized.total_mj / self.iterations

    @property
    def mj_per_iter_compute(self) -> float:
        return self.optimized.compute_energy_mj / self.iterations

    def summary(self) -> dict:
        return {
            "ema_gb_per_iter_baseline": self.ema_gb_per_iter_baseline,
            "ema_gb_per_iter_optimized":
                self.optimized.ema_bytes_total / self.iterations / 1e9,
            "total_ema_reduction": self.ema_reduction,
            "sas_fraction_of_ema_baseline": self.baseline.sas_fraction,
            "transformer_ema_fraction_baseline":
                self.baseline.stage_fraction("self_attn", "cross_attn",
                                             "ffn"),
            "self_attn_fraction_of_transformer":
                (self.baseline.ema_bytes_by_stage.get("self_attn", 0.0)
                 / max(sum(self.baseline.ema_bytes_by_stage.get(s, 0.0)
                           for s in ("self_attn", "cross_attn", "ffn")),
                       1e-12)),
            "mj_per_iter_compute": self.mj_per_iter_compute,
            "mj_per_iter_with_ema": self.mj_per_iter_with_ema,
        }


@dataclasses.dataclass
class BankedPolicyReport:
    """One bank entry's share of a banked serving run.

    ``images`` is the request count that ran under this policy (read from
    its step-0 bucket's row counter — every admitted request visits it
    exactly once).  ``report`` is ``None`` when the policy served nothing.
    """
    policy: object                            # solvers.SamplerPolicy
    images: int
    report: Optional[PipelineEnergyReport]

    @property
    def mj_per_image(self) -> float:
        """Modeled energy per image at THIS policy's step budget."""
        if self.report is None:
            return 0.0
        return self.report.mj_per_iter_with_ema * self.policy.num_steps


@dataclasses.dataclass
class BankedEnergyReport:
    """Per-policy energy reports + the images-weighted aggregate."""
    entries: tuple                            # of BankedPolicyReport

    @property
    def images(self) -> int:
        return sum(e.images for e in self.entries)

    @property
    def mj_per_image(self) -> float:
        """Images-weighted mean energy per image across the bank."""
        total = self.images
        if total == 0:
            return 0.0
        return sum(e.mj_per_image * e.images for e in self.entries) / total

    def summary(self) -> dict:
        return {
            "images": self.images,
            "mj_per_image_weighted": self.mj_per_image,
            "per_policy": [
                {"policy": e.policy.key(),
                 "tier": e.policy.name or None,
                 "num_steps": e.policy.num_steps,
                 "images": e.images,
                 "mj_per_image": e.mj_per_image,
                 **({} if e.report is None else e.report.summary())}
                for e in self.entries],
        }
