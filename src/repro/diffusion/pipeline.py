"""End-to-end text-to-image pipeline (Fig. 1(a)) with the paper's features.

Stages: text encoding -> 25 iterative UNet denoising steps -> VAE decode.
The pipeline runs the reduced geometry on CPU and *measures* the quantities
the silicon measures — per-resolution PSSA compression ratios and
per-iteration TIPS low-precision ratios — then injects them into the
full-geometry analytic ledger to produce the paper's headline numbers
(EMA GB/iter, mJ/iter).  PSSA / TIPS / DBSC are feature toggles, so the
baseline-vs-optimized deltas of Figs. 5/9 fall out of the same code path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import energy, pssa
from repro.core.tips import TIPS_ACTIVE_ITERS
from repro.diffusion import ledger as L
from repro.diffusion.sampler import DDIMConfig, sample
from repro.diffusion.stats import (UNetStats, attn_layer_order,
                                   coerce_per_step_stats)
from repro.diffusion.text_encoder import (TextEncoderConfig,
                                          encode_text,
                                          init_text_encoder_params)
from repro.diffusion.unet import UNetConfig, init_unet_params, unet_forward
from repro.diffusion.vae import VAEConfig, decode, init_vae_params


def _iter_layer_stats(stats_one_iter, kind: str):
    """Yield (resolution, per-layer stats) from either stats representation.

    ``kind`` is "pssa" or "tips".  Supports the ``UNetStats`` pytree (layer
    resolutions are static metadata) and the legacy string-keyed dict view
    (resolution parsed from the "tag@res" key).
    """
    if isinstance(stats_one_iter, UNetStats):
        entries = getattr(stats_one_iter, kind)
        for lk, st in zip(stats_one_iter.layers, entries):
            yield lk.resolution, st
        return
    for key, st in stats_one_iter.get(kind, {}).items():
        yield int(key.rsplit("@", 1)[1]), st


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    unet: UNetConfig = UNetConfig()
    text: TextEncoderConfig = TextEncoderConfig()
    vae: VAEConfig = VAEConfig()
    ddim: DDIMConfig = DDIMConfig()

    @staticmethod
    def smoke() -> "PipelineConfig":
        return PipelineConfig(
            unet=UNetConfig().smoke(),
            text=TextEncoderConfig().smoke(),
            vae=VAEConfig().smoke(),
            ddim=DDIMConfig(num_inference_steps=3, guidance_scale=1.0,
                            tips_active_iters=2),
        )


class StableDiffusionPipeline:
    """Holds params + jitted stage functions; reusable across prompts.

    This is the per-step reference path (25 Python dispatches, two UNet
    calls per step under CFG).  The production path is
    ``repro.diffusion.engine.DiffusionEngine`` — one jitted
    encode -> scanned-sampler -> decode computation with fused CFG; both
    feed the same ``energy_report`` (stats representations are
    interchangeable via ``repro.diffusion.stats``).
    """

    def __init__(self, cfg: PipelineConfig, key=None):
        self.cfg = cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        # context width must match: text d_model == unet context_dim
        assert cfg.text.d_model == cfg.unet.context_dim, \
            (cfg.text.d_model, cfg.unet.context_dim)
        self.text_params = init_text_encoder_params(k1, cfg.text)
        self.unet_params = init_unet_params(k2, cfg.unet)
        self.vae_params = init_vae_params(k3, cfg.vae)

        self._encode = jax.jit(
            lambda toks: encode_text(self.text_params, toks, cfg.text))
        self._unet = jax.jit(
            lambda lat, t, ctx, act: unet_forward(
                self.unet_params, lat, t, ctx, cfg.unet, tips_active=act))
        self._decode = jax.jit(
            lambda lat: decode(self.vae_params, lat, cfg.vae))

    # ------------------------------------------------------------------
    def generate(self, prompt_tokens, key, uncond_tokens=None,
                 collect_stats: bool = True):
        """prompt_tokens (B, text_len) int32 -> (image, stats_per_iter)."""
        cfg = self.cfg
        context = self._encode(prompt_tokens)
        uncond = (self._encode(uncond_tokens)
                  if uncond_tokens is not None else None)
        b = prompt_tokens.shape[0]
        s = cfg.unet.latent_size
        latents = jax.random.normal(key, (b, s, s, cfg.unet.in_channels))
        latents, stats = sample(self._unet, latents, context, uncond,
                                cfg.ddim, collect_stats=collect_stats)
        image = self._decode(latents)
        return image, stats

    # ------------------------------------------------------------------
    # Measurement -> full-geometry ledger (delegates to module functions
    # so the engine/serving path can use them without a pipeline object)
    # ------------------------------------------------------------------
    def measured_sas_ratios(self, stats_one_iter) -> dict:
        return measured_sas_ratios(stats_one_iter)

    def measured_tips_ratio(self, stats_one_iter) -> float:
        return measured_tips_ratio(stats_one_iter)

    def energy_report(self, stats_per_iter, full_geometry: bool = True
                      ) -> "PipelineEnergyReport":
        return energy_report(self.cfg, stats_per_iter,
                             full_geometry=full_geometry)


def _sas_ratio_terms(stats_one_iter) -> dict:
    """Per-resolution (numerator, denominator) byte sums for the SAS ratio.

    Returned separately from the ratio so multi-batch serving can aggregate
    the terms across engine calls before dividing: the byte counters scale
    with the accounted row count (``stats_rows``), which makes the sums
    self-weighting by valid rows.
    """
    by_res: dict = {}
    for res, st in _iter_layer_stats(stats_one_iter, "pssa"):
        comp = float(st.bytes_pssa_total)
        base = float(st.bytes_baseline)
        num, den = by_res.get(res, (0.0, 0.0))
        by_res[res] = (num + comp, den + base)
    return by_res


def _tips_ratio_terms(stats_one_iter) -> tuple:
    """(numerator, denominator) of the workload-weighted INT6 fraction.

    The per-layer ``low_precision_ratio`` is a mean over the accounted
    batch rows, so the workload weight carries the row count (read from
    the ``important`` mask's static shape — no device transfer): batches
    with more valid rows count proportionally more when the terms are
    summed across engine calls.
    """
    num = den = 0.0
    for res, tr in _iter_layer_stats(stats_one_iter, "tips"):
        rows = float(tr.important.shape[0]) \
            if getattr(tr.important, "ndim", 0) >= 2 else 1.0
        work = float(res * res) * rows     # FFN MACs scale with token count
        num += float(tr.low_precision_ratio) * work
        den += work
    return num, den


def measured_sas_ratios(stats_one_iter) -> dict:
    """Per-resolution (compressed/dense) SAS ratio from PSSAStats.

    Accepts a single-step ``UNetStats`` pytree or the legacy
    ``{"pssa": {"tag@res": PSSAStats}}`` dict view.
    """
    return {res: num / max(den, 1e-12)
            for res, (num, den) in _sas_ratio_terms(stats_one_iter).items()}


def measured_tips_ratio(stats_one_iter) -> float:
    """Workload-weighted INT6 fraction across the iteration's FFNs."""
    num, den = _tips_ratio_terms(stats_one_iter)
    return num / max(den, 1e-12)


def energy_report(cfg: "PipelineConfig", stats_per_iter,
                  full_geometry: bool = True) -> "PipelineEnergyReport":
    """Headline numbers: EMA GB/iter + mJ/iter (Table I reproduction).

    ``stats_per_iter`` is either the stacked ``UNetStats`` a scanned
    engine run returns (leading axis = iterations) or the seed's list of
    per-iteration stats.  The reduced run's measured ratios drive the
    FULL BK-SDM-Tiny ledger (hardware adaptation note: patch locality is
    resolution-dependent, so per-resolution ratios transfer; DESIGN.md §2).
    A single-batch aggregation: delegates to :func:`energy_report_multi`.
    """
    return energy_report_multi(cfg, [stats_per_iter],
                               full_geometry=full_geometry)


def energy_report_multi(cfg: "PipelineConfig", stats_per_batch,
                        full_geometry: bool = True) -> "PipelineEnergyReport":
    """Aggregate energy report across SEVERAL engine calls (serving).

    ``stats_per_batch``: one stats trajectory per engine call (stacked
    ``UNetStats`` or per-iteration list), each already restricted to its
    valid rows (``stats_rows`` masks padded tail rows out at the source).
    Per DDIM iteration, the SAS byte terms and the row-weighted TIPS terms
    are summed across batches BEFORE dividing, so every valid image row in
    the run — and no padded duplicate — contributes with equal weight.
    With a single entry this reduces exactly to :func:`energy_report`.
    """
    fetched = []
    for s in stats_per_batch:
        if isinstance(s, UNetStats):
            s = s.ledger_fetch()        # one host transfer per engine call
        fetched.append(coerce_per_step_stats(s))
    if not fetched:
        raise ValueError("stats_per_batch is empty")
    n = cfg.ddim.num_inference_steps
    for s in fetched:
        if len(s) != n:
            raise ValueError(
                f"stats trajectory has {len(s)} iterations, config says {n}")

    per_iter_terms = []
    for i in range(n):
        sas_terms: dict = {}
        tnum = tden = 0.0
        for s in fetched:
            for res, (num, den) in _sas_ratio_terms(s[i]).items():
                a, b = sas_terms.get(res, (0.0, 0.0))
                sas_terms[res] = (a + num, b + den)
            num, den = _tips_ratio_terms(s[i])
            tnum, tden = tnum + num, tden + den
        per_iter_terms.append((sas_terms, (tnum, tden)))
    return _report_from_terms(cfg, per_iter_terms,
                              full_geometry=full_geometry)


def _report_from_terms(cfg: "PipelineConfig", per_iter_terms,
                       full_geometry: bool = True) -> "PipelineEnergyReport":
    """Per-iteration aggregated terms -> the full-geometry ledger report.

    ``per_iter_terms``: one ``(sas_terms, (tips_num, tips_den))`` per DDIM
    iteration, where ``sas_terms`` maps resolution to summed
    (compressed, baseline) byte terms.  Shared tail of the batch-stats
    aggregation (:func:`energy_report_multi`) and the slot-serving
    accumulator path (:func:`energy_report_from_accum`) — both reduce to
    these terms, which is what makes the two serving modes' headlines
    comparable bit-for-bit.
    """
    n = cfg.ddim.num_inference_steps
    if len(per_iter_terms) != n:
        raise ValueError(
            f"{len(per_iter_terms)} iteration terms, config says {n}")
    geom = UNetConfig() if full_geometry else cfg.unet
    precision = cfg.unet.effective_precision()
    geom_res = sorted({geom.latent_size >> s
                       for s, a in enumerate(geom.down_attn) if a},
                      reverse=True)

    def remap(ratios: dict) -> dict:
        meas = sorted(ratios, reverse=True)
        return {g: ratios[m] for g, m in zip(geom_res, meas)}

    opts_per_iter = []
    for i, (sas_terms, (tnum, tden)) in enumerate(per_iter_terms):
        sas_ratio = {res: num / max(den, 1e-12)
                     for res, (num, den) in sas_terms.items()}
        opts_per_iter.append(L.LedgerOptions(
            pssa=cfg.unet.pssa,
            tips=cfg.unet.tips and i < cfg.ddim.tips_active_iters,
            sas_ratio=remap(sas_ratio),
            tips_low_ratio=tnum / max(tden, 1e-12),
            # MAC split mirrors the datapath's actual FFN mask coverage
            tips_mid=precision.ffn_mid,
        ))
    baseline_opts = [L.LedgerOptions()] * n
    return PipelineEnergyReport(
        optimized=L.generation_report(geom, opts_per_iter),
        baseline=L.generation_report(geom, baseline_opts),
        iterations=n,
    )


def ledger_terms_from_accum(cfg: "PipelineConfig", accum) -> list:
    """Per-iteration ledger terms from a slot-serving ``LedgerAccum``.

    The continuous-batching runtime accumulates INTEGER counters per DDIM
    iteration (``repro.diffusion.stats.LedgerAccum``); this assembles the
    same per-iteration (SAS byte, TIPS workload) terms that
    :func:`energy_report_multi` derives from per-call ``UNetStats`` —
    bit-identically, because both reduce the same integer counters through
    the same byte arithmetic (``pssa.stats_from_counters``) and the same
    float32 ratio step the device path uses.  Slot count, admission order,
    and occupancy cannot move a term: integer accumulation is exact.
    """
    import numpy as np

    layers = attn_layer_order(cfg.unet)
    heads = cfg.unet.num_heads
    nnz, ones_xor, imp, rows = (np.asarray(x) for x in jax.device_get(
        (accum.nnz, accum.ones_xor, accum.imp, accum.rows)))
    n = cfg.ddim.num_inference_steps
    if nnz.shape != (n, len(layers)):
        raise ValueError(f"accumulator shape {nnz.shape} does not match "
                         f"({n}, {len(layers)})")
    per_iter_terms = []
    for i in range(n):
        sas_terms: dict = {}
        tnum = tden = 0.0
        r = int(rows[i])
        for li, lk in enumerate(layers):
            if r == 0:
                continue                  # nothing accounted yet
            res = lk.resolution
            tq = res * res
            st = pssa.stats_from_counters(
                jnp.asarray(int(nnz[i, li])), jnp.asarray(int(ones_xor[i, li])),
                lead=r * heads, tq=tq, tk=tq,
                patch=cfg.unet.patch_size(res))
            num, den = sas_terms.get(res, (0.0, 0.0))
            sas_terms[res] = (num + float(st.bytes_pssa_total),
                              den + float(st.bytes_baseline))
            # the one-shot path sums (1 - imp_c/(rows_c*Tq)) * Tq * rows_c
            # per call; with exact per-call folds (power-of-two
            # rows_c * Tq) that telescopes to the INTEGER
            # Tq*rows - imp_total, so the accumulator reproduces the
            # aggregated term without ever dividing
            tnum += float(tq * r - int(imp[i, li]))
            tden += float(tq * r)
        per_iter_terms.append((sas_terms, (tnum, tden)))
    return per_iter_terms


def energy_report_from_accum(cfg: "PipelineConfig", accum,
                             full_geometry: bool = True
                             ) -> "PipelineEnergyReport":
    """Energy report for a drained slot-serving run (DESIGN.md §8).

    Bit-identical to :func:`energy_report_multi` over the same requests
    served one-shot whenever the per-call float folds are exact —
    power-of-two accounted rows per call, always true for the test/bench
    configurations and trivially true for the single-call oracle.
    """
    return _report_from_terms(cfg, ledger_terms_from_accum(cfg, accum),
                              full_geometry=full_geometry)


def tips_ratios_from_accum(cfg: "PipelineConfig", accum) -> list:
    """Per-iteration realized INT6 row fraction from the accumulator."""
    return [num / max(den, 1e-12)
            for _, (num, den) in ledger_terms_from_accum(cfg, accum)]


def aggregated_tips_ratios_per_iter(cfg: "PipelineConfig",
                                    stats_per_batch) -> list:
    """Row-weighted per-iteration TIPS low-precision ratios across calls.

    Feeds ``tips.workload_low_precision_fraction(..., ddim=cfg.ddim)`` so
    a serving run reports the INT6 workload fraction of ITS schedule.
    """
    fetched = [coerce_per_step_stats(
        s.ledger_fetch() if isinstance(s, UNetStats) else s)
        for s in stats_per_batch]
    out = []
    for i in range(cfg.ddim.num_inference_steps):
        num = den = 0.0
        for s in fetched:
            a, b = _tips_ratio_terms(s[i])
            num, den = num + a, den + b
        out.append(num / max(den, 1e-12))
    return out


def reuse_ratios_from_accum(cfg: "PipelineConfig", accum) -> list:
    """Per-iteration REALIZED temporal-reuse ratio from a ``LedgerAccum``.

    Ratio ``i`` is ``1 - computed/total`` over the iteration's reuse row
    counters summed across layers and accounted rows — the fraction of
    patch rows served from the cache instead of recomputed.  Integer
    counters in, one float division out, so the value is bit-identical
    across slot counts, admission orders, and data-parallel layouts (the
    same invariance the SAS/TIPS buckets carry).  Iterations with no
    accounted reuse work (dense runs, not-yet-reached steps) report 0.0.
    """
    import numpy as np

    comp, tot = (np.asarray(x) for x in jax.device_get(
        (accum.reuse_computed, accum.reuse_total)))
    out = []
    for i in range(cfg.ddim.num_inference_steps):
        t = float(tot[i].sum())
        out.append(0.0 if t == 0.0 else 1.0 - float(comp[i].sum()) / t)
    return out


def aggregated_reuse_ratios_per_iter(cfg: "PipelineConfig",
                                     stats_per_batch) -> list:
    """Per-iteration realized reuse ratio across scanned engine calls.

    ``stats_per_batch``: stacked ``UNetStats`` trajectories whose
    ``reuse`` counters carry a leading iteration axis (what
    ``sample_scan_reuse`` returns).  Terms are summed across batches and
    layers before dividing — same reduction as
    :func:`reuse_ratios_from_accum`, so slot serving and one-shot serving
    report identical ratios for the same work.  Dense trajectories
    (empty ``reuse`` tuple) contribute nothing; all-dense input yields
    zeros.
    """
    import numpy as np

    out = []
    for i in range(cfg.ddim.num_inference_steps):
        num = den = 0.0
        for s in stats_per_batch:
            reuse = s.reuse if isinstance(s, UNetStats) else ()
            for c in reuse:
                comp, tot = (np.asarray(x) for x in
                             jax.device_get((c.computed, c.total)))
                num += float(comp[i].sum())
                den += float(tot[i].sum())
        out.append(0.0 if den == 0.0 else 1.0 - num / den)
    return out


@dataclasses.dataclass
class PipelineEnergyReport:
    optimized: energy.EnergyReport
    baseline: energy.EnergyReport
    iterations: int

    @property
    def ema_gb_per_iter_baseline(self) -> float:
        return self.baseline.ema_bytes_total / self.iterations / 1e9

    @property
    def ema_reduction(self) -> float:
        return 1.0 - (self.optimized.ema_bytes_total
                      / self.baseline.ema_bytes_total)

    @property
    def mj_per_iter_with_ema(self) -> float:
        return self.optimized.total_mj / self.iterations

    @property
    def mj_per_iter_compute(self) -> float:
        return self.optimized.compute_energy_mj / self.iterations

    def summary(self) -> dict:
        return {
            "ema_gb_per_iter_baseline": self.ema_gb_per_iter_baseline,
            "ema_gb_per_iter_optimized":
                self.optimized.ema_bytes_total / self.iterations / 1e9,
            "total_ema_reduction": self.ema_reduction,
            "sas_fraction_of_ema_baseline": self.baseline.sas_fraction,
            "transformer_ema_fraction_baseline":
                self.baseline.stage_fraction("self_attn", "cross_attn",
                                             "ffn"),
            "self_attn_fraction_of_transformer":
                (self.baseline.ema_bytes_by_stage.get("self_attn", 0.0)
                 / max(sum(self.baseline.ema_bytes_by_stage.get(s, 0.0)
                           for s in ("self_attn", "cross_attn", "ffn")),
                       1e-12)),
            "mj_per_iter_compute": self.mj_per_iter_compute,
            "mj_per_iter_with_ema": self.mj_per_iter_with_ema,
        }
