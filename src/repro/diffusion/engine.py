"""Fully-jitted batched diffusion engine (encode -> scan -> decode).

The seed pipeline dispatched 25 Python-level UNet steps (x2 under CFG).
``DiffusionEngine`` compiles the *whole* text-to-image path — text encoding,
the scanned DDIM loop with fused-CFG batched UNet calls, and the VAE decode
— into ONE ``jax.jit`` per (batch, geometry) signature:

  * one XLA computation per generation call: no per-step dispatch overhead,
    cross-step fusion, and the latent buffer is donated (updated in place);
  * classifier-free guidance costs one batched UNet call per step instead
    of two (cond + uncond concatenated along batch, split after);
  * the PSSA/TIPS statistics trajectory comes back as a stacked
    ``UNetStats`` pytree — ``(num_steps, ...)`` leaves — feeding the
    full-geometry energy ledger without leaving the device until read.

Compiled executables are cached per input signature, so a serving front-end
(``repro.launch.serve_diffusion``) pays compilation once per micro-batch
shape and then streams generations through it.

Data-parallel mesh mode (DESIGN.md §6): pass ``mesh`` (a
``jax.sharding.Mesh`` with a ``data`` axis, e.g. from
``repro.launch.mesh.make_elastic_mesh`` / ``make_smoke_mesh`` /
``make_data_mesh``) and the engine replicates the UNet/text/VAE parameters
across the mesh while sharding prompt tokens and latents along the data
axes.  The executable cache is keyed on the mesh signature, so an elastic
relaunch onto a different mesh (``place_on_mesh``) retraces instead of
reusing stale executables.  The stacked stats pytree comes back with its
per-row leaves still batch-sharded; only the scalar ledger counters are
pulled to host, once, when the energy report reads them.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.diffusion.sampler import sample_scan
from repro.diffusion.text_encoder import encode_text, init_text_encoder_params
from repro.diffusion.unet import init_unet_params, unet_forward
from repro.diffusion.vae import decode, init_vae_params
from repro.launch.mesh import dp_axes_of, dp_size_of, mesh_signature


@dataclasses.dataclass
class EngineOutput:
    """One engine call: images plus the stacked stats trajectory."""
    images: jax.Array            # (B, 8S, 8S, 3) in [-1, 1]
    latents: jax.Array           # (B, S, S, 4) final denoised latents
    stats: object                # UNetStats, leaves (num_steps, ...)


def _check_cfg_inputs(guidance_scale: float, uncond_tokens) -> bool:
    """CFG contract: ``uncond_tokens`` iff ``guidance_scale != 1.0``.

    The seed engine silently disabled CFG when ``guidance_scale != 1.0``
    but no unconditional prompt was supplied — a guidance-7.5 run would
    quietly produce unguided images.  Both mismatch directions now raise.
    """
    wants_cfg = guidance_scale != 1.0
    has_uncond = uncond_tokens is not None
    if wants_cfg and not has_uncond:
        raise ValueError(
            f"guidance_scale={guidance_scale} requires classifier-free "
            "guidance but uncond_tokens is None — pass the unconditional "
            "prompt tokens (or set ddim.guidance_scale=1.0)")
    if has_uncond and not wants_cfg:
        raise ValueError(
            "uncond_tokens were passed but ddim.guidance_scale == 1.0 "
            "disables classifier-free guidance — drop uncond_tokens or "
            "set a guidance_scale != 1.0")
    return wants_cfg


class DiffusionEngine:
    """Holds params; jits the whole generate path once per signature.

    ``cfg`` is a ``repro.diffusion.pipeline.PipelineConfig``.  Use
    ``generate(prompt_tokens, key, uncond_tokens=...)``; pass
    ``uncond_tokens`` iff ``cfg.ddim.guidance_scale != 1.0`` (a mismatch
    raises ``ValueError``).
    ``kernel_policy`` (a ``repro.kernels.dispatch.KernelPolicy``) overrides
    the UNet's per-op kernel routing — e.g. ``KernelPolicy.fused()`` runs
    self-attention through the blocked Pallas kernel so the score matrix
    never materializes; stats stay bit-identical to the reference policy.
    ``precision_policy`` (a ``repro.core.precision.PrecisionPolicy``)
    overrides the UNet's TIPS/DBSC precision runtime; both policies are
    part of the executable-cache key, so changing either on a live engine
    (``set_precision``) retraces instead of reusing a stale executable.
    ``mesh`` switches on data-parallel sharded execution (see module
    docstring); ``None`` keeps the seed single-device behaviour untouched.
    """

    def __init__(self, cfg, key=None, kernel_policy=None, mesh=None,
                 precision_policy=None):
        if kernel_policy is not None:
            # route the UNet hot path per the policy (kernels.dispatch)
            cfg = dataclasses.replace(
                cfg, unet=dataclasses.replace(cfg.unet,
                                              kernel_policy=kernel_policy))
        if precision_policy is not None:
            cfg = dataclasses.replace(
                cfg, unet=dataclasses.replace(cfg.unet,
                                              precision=precision_policy))
        self.cfg = cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        assert cfg.text.d_model == cfg.unet.context_dim, \
            (cfg.text.d_model, cfg.unet.context_dim)
        self.text_params = init_text_encoder_params(k1, cfg.text)
        self.unet_params = init_unet_params(k2, cfg.unet)
        self.vae_params = init_vae_params(k3, cfg.vae)
        # jitted executables keyed by (batch, use_cfg, stats_rows, mesh
        # signature); geometry is fixed per engine so the signature is the
        # leading dims plus the placement.
        self._compiled: dict = {}
        self.last_wall_s: Optional[float] = None
        self.mesh = None
        self.dp_size = 1
        self._data_sharding = None
        if mesh is not None:
            self.place_on_mesh(mesh)

    # ------------------------------------------------------------------
    # Mesh placement
    # ------------------------------------------------------------------
    def place_on_mesh(self, mesh) -> "DiffusionEngine":
        """Place params on ``mesh``: replicated weights, data-sharded batch.

        Callable again after an elastic resize — executables compiled for
        the previous mesh stay cached under the old signature and new
        signatures retrace against the new placement.
        """
        replicated = NamedSharding(mesh, P())
        self.mesh = mesh
        self.dp_size = dp_size_of(mesh)
        self._data_sharding = NamedSharding(mesh, P(dp_axes_of(mesh)))
        self.text_params = jax.device_put(self.text_params, replicated)
        self.unet_params = jax.device_put(self.unet_params, replicated)
        self.vae_params = jax.device_put(self.vae_params, replicated)
        return self

    def _shard_batch(self, x):
        """Commit a batch-leading array to the data axes (no-op unsharded)."""
        if x is None or self._data_sharding is None:
            return x
        return jax.device_put(x, self._data_sharding)

    # ------------------------------------------------------------------
    def _run(self, prompt_tokens, uncond_tokens, latents, stats_rows=None):
        """Traced end-to-end path; ``uncond_tokens`` may be None (static)."""
        cfg = self.cfg
        context = encode_text(self.text_params, prompt_tokens, cfg.text)
        uncond = (encode_text(self.text_params, uncond_tokens, cfg.text)
                  if uncond_tokens is not None else None)

        def unet_apply(lat, tvec, ctx, active, stats_rows=None,
                       cfg_dup=False):
            return unet_forward(self.unet_params, lat, tvec, ctx, cfg.unet,
                                tips_active=active, stats_rows=stats_rows,
                                cfg_dup=cfg_dup)

        latents, stats = sample_scan(unet_apply, latents, context, uncond,
                                     cfg.ddim, stats_rows=stats_rows)
        images = decode(self.vae_params, latents, cfg.vae)
        return images, latents, stats

    def set_precision(self, policy) -> "DiffusionEngine":
        """Switch the TIPS/DBSC precision runtime on a live engine.

        The policy participates in the executable-cache key, so the next
        ``generate`` retraces against the new policy; executables compiled
        for the previous policy stay cached under their own key.
        """
        self.cfg = dataclasses.replace(
            self.cfg, unet=dataclasses.replace(self.cfg.unet,
                                               precision=policy))
        return self

    def _get_compiled(self, batch: int, use_cfg: bool,
                      stats_rows: Optional[int] = None):
        # positions 0-3 are load-bearing (tests introspect them); the two
        # policy objects are appended so a policy change retraces
        key = (batch, use_cfg, stats_rows, mesh_signature(self.mesh),
               self.cfg.unet.effective_kernel_policy(),
               self.cfg.unet.effective_precision())
        fn = self._compiled.get(key)
        if fn is None:
            if use_cfg:
                fn = jax.jit(lambda p, u, l: self._run(p, u, l, stats_rows),
                             donate_argnums=(2,))
            else:
                fn = jax.jit(lambda p, l: self._run(p, None, l, stats_rows),
                             donate_argnums=(1,))
            self._compiled[key] = fn
        return fn

    # ------------------------------------------------------------------
    def init_latents(self, batch: int, key) -> jax.Array:
        s = self.cfg.unet.latent_size
        return jax.random.normal(key, (batch, s, s,
                                       self.cfg.unet.in_channels))

    def generate(self, prompt_tokens, key, uncond_tokens=None,
                 latents=None, stats_rows=None) -> EngineOutput:
        """(B, text_len) int32 tokens -> EngineOutput.

        The initial ``latents`` buffer (drawn from ``key`` unless given) is
        donated to the compiled call.  Wall time of the call (device sync
        included) lands in ``self.last_wall_s``.

        ``stats_rows`` (static) restricts the PSSA/TIPS accounting to the
        first N rows — serving sets it to the valid row count of a padded
        tail micro-batch.  Under a mesh, ``batch`` must be a multiple of
        the data-parallel degree (the serving front-end pads to it).
        """
        cfg = self.cfg
        use_cfg = _check_cfg_inputs(cfg.ddim.guidance_scale, uncond_tokens)
        batch = prompt_tokens.shape[0]
        if self.mesh is not None and batch % self.dp_size:
            raise ValueError(
                f"batch {batch} must be a multiple of the data-parallel "
                f"degree {self.dp_size} under mesh "
                f"{dict(self.mesh.shape)} — pad the micro-batch")
        if latents is None:
            latents = self.init_latents(batch, key)
        prompt_tokens = self._shard_batch(prompt_tokens)
        uncond_tokens = self._shard_batch(uncond_tokens)
        latents = self._shard_batch(latents)
        fn = self._get_compiled(batch, use_cfg, stats_rows)
        t0 = time.perf_counter()
        if use_cfg:
            images, latents, stats = fn(prompt_tokens, uncond_tokens,
                                        latents)
        else:
            images, latents, stats = fn(prompt_tokens, latents)
        jax.block_until_ready(images)
        self.last_wall_s = time.perf_counter() - t0
        return EngineOutput(images=images, latents=latents, stats=stats)

    # ------------------------------------------------------------------
    def warmup(self, batch: int, use_cfg: Optional[bool] = None,
               stats_rows: Optional[int] = None) -> float:
        """Compile (and discard) one call for the given signature.

        ``use_cfg`` defaults to what the config demands
        (``guidance_scale != 1.0``); forcing it AGAINST the config raises
        the same ``ValueError`` as ``generate`` — a warmed-up signature
        the engine would refuse to serve is a bug, not a cache entry.
        Returns the wall seconds the warmup call took (compile + run).
        """
        cfg = self.cfg
        if use_cfg is None:
            use_cfg = cfg.ddim.guidance_scale != 1.0
        toks = jnp.zeros((batch, cfg.text.max_len), jnp.int32)
        un = jnp.zeros((batch, cfg.text.max_len), jnp.int32) if use_cfg \
            else None
        t0 = time.perf_counter()
        self.generate(toks, jax.random.PRNGKey(0), uncond_tokens=un,
                      stats_rows=stats_rows)
        return time.perf_counter() - t0
