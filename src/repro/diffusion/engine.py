"""Fully-jitted batched diffusion engine (encode -> scan -> decode).

The seed pipeline dispatched 25 Python-level UNet steps (x2 under CFG).
``DiffusionEngine`` compiles the *whole* text-to-image path — text encoding,
the scanned DDIM loop with fused-CFG batched UNet calls, and the VAE decode
— into ONE ``jax.jit`` per (batch, geometry) signature:

  * one XLA computation per generation call: no per-step dispatch overhead,
    cross-step fusion, and the latent buffer is donated (updated in place);
  * classifier-free guidance costs one batched UNet call per step instead
    of two (cond + uncond concatenated along batch, split after);
  * the PSSA/TIPS statistics trajectory comes back as a stacked
    ``UNetStats`` pytree — ``(num_steps, ...)`` leaves — feeding the
    full-geometry energy ledger without leaving the device until read.

Compiled executables are cached per input signature, so a serving front-end
(``repro.launch.serve_diffusion``) pays compilation once per micro-batch
shape and then streams generations through it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.diffusion.sampler import sample_scan
from repro.diffusion.text_encoder import encode_text, init_text_encoder_params
from repro.diffusion.unet import init_unet_params, unet_forward
from repro.diffusion.vae import decode, init_vae_params


@dataclasses.dataclass
class EngineOutput:
    """One engine call: images plus the stacked stats trajectory."""
    images: jax.Array            # (B, 8S, 8S, 3) in [-1, 1]
    latents: jax.Array           # (B, S, S, 4) final denoised latents
    stats: object                # UNetStats, leaves (num_steps, ...)


class DiffusionEngine:
    """Holds params; jits the whole generate path once per signature.

    ``cfg`` is a ``repro.diffusion.pipeline.PipelineConfig``.  Use
    ``generate(prompt_tokens, key, uncond_tokens=...)``; pass
    ``uncond_tokens`` iff ``cfg.ddim.guidance_scale != 1.0``.
    ``kernel_policy`` (a ``repro.kernels.dispatch.KernelPolicy``) overrides
    the UNet's per-op kernel routing — e.g. ``KernelPolicy.fused()`` runs
    self-attention through the blocked Pallas kernel so the score matrix
    never materializes; stats stay bit-identical to the reference policy.
    """

    def __init__(self, cfg, key=None, kernel_policy=None):
        if kernel_policy is not None:
            # route the UNet hot path per the policy (kernels.dispatch)
            cfg = dataclasses.replace(
                cfg, unet=dataclasses.replace(cfg.unet,
                                              kernel_policy=kernel_policy))
        self.cfg = cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        assert cfg.text.d_model == cfg.unet.context_dim, \
            (cfg.text.d_model, cfg.unet.context_dim)
        self.text_params = init_text_encoder_params(k1, cfg.text)
        self.unet_params = init_unet_params(k2, cfg.unet)
        self.vae_params = init_vae_params(k3, cfg.vae)
        # jitted executables keyed by (batch, use_cfg); geometry is fixed
        # per engine so the signature is just the leading dims.
        self._compiled: dict = {}
        self.last_wall_s: Optional[float] = None

    # ------------------------------------------------------------------
    def _run(self, prompt_tokens, uncond_tokens, latents):
        """Traced end-to-end path; ``uncond_tokens`` may be None (static)."""
        cfg = self.cfg
        context = encode_text(self.text_params, prompt_tokens, cfg.text)
        uncond = (encode_text(self.text_params, uncond_tokens, cfg.text)
                  if uncond_tokens is not None else None)

        def unet_apply(lat, tvec, ctx, active, stats_rows=None,
                       cfg_dup=False):
            return unet_forward(self.unet_params, lat, tvec, ctx, cfg.unet,
                                tips_active=active, stats_rows=stats_rows,
                                cfg_dup=cfg_dup)

        latents, stats = sample_scan(unet_apply, latents, context, uncond,
                                     cfg.ddim)
        images = decode(self.vae_params, latents, cfg.vae)
        return images, latents, stats

    def _get_compiled(self, batch: int, use_cfg: bool):
        key = (batch, use_cfg)
        fn = self._compiled.get(key)
        if fn is None:
            if use_cfg:
                fn = jax.jit(lambda p, u, l: self._run(p, u, l),
                             donate_argnums=(2,))
            else:
                fn = jax.jit(lambda p, l: self._run(p, None, l),
                             donate_argnums=(1,))
            self._compiled[key] = fn
        return fn

    # ------------------------------------------------------------------
    def init_latents(self, batch: int, key) -> jax.Array:
        s = self.cfg.unet.latent_size
        return jax.random.normal(key, (batch, s, s,
                                       self.cfg.unet.in_channels))

    def generate(self, prompt_tokens, key, uncond_tokens=None,
                 latents=None) -> EngineOutput:
        """(B, text_len) int32 tokens -> EngineOutput.

        The initial ``latents`` buffer (drawn from ``key`` unless given) is
        donated to the compiled call.  Wall time of the call (device sync
        included) lands in ``self.last_wall_s``.
        """
        cfg = self.cfg
        use_cfg = (cfg.ddim.guidance_scale != 1.0
                   and uncond_tokens is not None)
        batch = prompt_tokens.shape[0]
        if latents is None:
            latents = self.init_latents(batch, key)
        fn = self._get_compiled(batch, use_cfg)
        t0 = time.perf_counter()
        if use_cfg:
            images, latents, stats = fn(prompt_tokens, uncond_tokens,
                                        latents)
        else:
            images, latents, stats = fn(prompt_tokens, latents)
        jax.block_until_ready(images)
        self.last_wall_s = time.perf_counter() - t0
        return EngineOutput(images=images, latents=latents, stats=stats)

    # ------------------------------------------------------------------
    def warmup(self, batch: int, use_cfg: Optional[bool] = None) -> float:
        """Compile (and discard) one call for the given signature.

        Returns the wall seconds the warmup call took (compile + run).
        """
        cfg = self.cfg
        if use_cfg is None:
            use_cfg = cfg.ddim.guidance_scale != 1.0
        toks = jnp.zeros((batch, cfg.text.max_len), jnp.int32)
        un = jnp.zeros((batch, cfg.text.max_len), jnp.int32) if use_cfg \
            else None
        t0 = time.perf_counter()
        self.generate(toks, jax.random.PRNGKey(0), uncond_tokens=un)
        return time.perf_counter() - t0
