"""Fully-jitted batched diffusion engine (encode -> scan -> decode).

The seed pipeline dispatched 25 Python-level UNet steps (x2 under CFG).
``DiffusionEngine`` compiles the *whole* text-to-image path — text encoding,
the scanned DDIM loop with fused-CFG batched UNet calls, and the VAE decode
— into ONE ``jax.jit`` per (batch, geometry) signature:

  * one XLA computation per generation call: no per-step dispatch overhead,
    cross-step fusion, and the latent buffer is donated (updated in place);
  * classifier-free guidance costs one batched UNet call per step instead
    of two (cond + uncond concatenated along batch, split after);
  * the PSSA/TIPS statistics trajectory comes back as a stacked
    ``UNetStats`` pytree — ``(num_steps, ...)`` leaves — feeding the
    full-geometry energy ledger without leaving the device until read.

Compiled executables are cached per input signature, so a serving front-end
(``repro.launch.serve_diffusion``) pays compilation once per micro-batch
shape and then streams generations through it.

Data-parallel mesh mode (DESIGN.md §6): pass ``mesh`` (a
``jax.sharding.Mesh`` with a ``data`` axis, e.g. from
``repro.launch.mesh.make_elastic_mesh`` / ``make_smoke_mesh`` /
``make_data_mesh``) and the engine replicates the UNet/text/VAE parameters
across the mesh while sharding prompt tokens and latents along the data
axes.  The executable cache is keyed on the mesh signature, so an elastic
relaunch onto a different mesh (``place_on_mesh``) retraces instead of
reusing stale executables.  The stacked stats pytree comes back with its
per-row leaves still batch-sharded; only the scalar ledger counters are
pulled to host, once, when the energy report reads them.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.policies import ServePolicies, legacy_warning
from repro.core.reuse import ReuseCache, reuse_cache_zeros
from repro.diffusion import solvers as solvers_mod
from repro.diffusion.denoiser import make_denoiser
from repro.diffusion.sampler import (denoise_step, sample_scan,
                                     sample_scan_reuse)
from repro.diffusion.stats import LedgerAccum, attn_layer_order
from repro.diffusion.text_encoder import encode_text, init_text_encoder_params
from repro.diffusion.vae import decode, init_vae_params
from repro.launch.mesh import dp_axes_of, dp_size_of, mesh_signature


@dataclasses.dataclass
class EngineOutput:
    """One engine call: images plus the stacked stats trajectory."""
    images: jax.Array            # (B, 8S, 8S, 3) in [-1, 1]
    latents: jax.Array           # (B, S, S, 4) final denoised latents
    stats: object                # UNetStats, leaves (num_steps, ...)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SlotState:
    """Persistent in-flight batch for continuous serving (DESIGN.md §8).

    One row per slot.  ``step_idx`` is the next DDIM iteration each slot
    will execute; ``active`` marks occupied slots (inactive rows still run
    through the fixed-shape UNet step, their results discarded and their
    stats masked).  ``accum`` holds the per-iteration integer ledger
    buckets each executed step scatters into.  ``uncond_context`` is
    ``None`` (static, via the treedef) when the engine's config disables
    CFG.  The whole state is donated to the jitted ``slot_step``
    executable, so a serving loop updates it in place.
    """
    latents: jax.Array                     # (S, s, s, C)
    context: jax.Array                     # (S, Tk, d) encoded cond text
    uncond_context: Optional[jax.Array]    # (S, Tk, d) or None
    step_idx: jax.Array                    # (S,) int32
    active: jax.Array                      # (S,) bool
    accum: LedgerAccum
    # per-slot previous-step activations for temporal patch reuse; None
    # (static, via the treedef) when cfg.unet.reuse_policy is disabled
    reuse_cache: Optional[ReuseCache] = None
    # sampler bank (static tuple of SamplerPolicy, in the treedef): when
    # set, ``policy_id`` selects each row's (solver, steps) pair and
    # ``solver_hist`` (S, H, s, s, C) carries multistep solver history;
    # the ledger buckets become per-(policy, step) — see init_slots.
    # ``bank=None`` keeps the legacy single-schedule state byte-identical.
    policy_id: Optional[jax.Array] = None  # (S,) int32 or None
    solver_hist: Optional[jax.Array] = None
    bank: Optional[tuple] = None

    def tree_flatten(self):
        return ((self.latents, self.context, self.uncond_context,
                 self.step_idx, self.active, self.accum,
                 self.reuse_cache, self.policy_id, self.solver_hist),
                self.bank)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, bank=aux)

    @property
    def num_slots(self) -> int:
        return int(self.step_idx.shape[0])


def _check_cfg_inputs(guidance_scale: float, uncond_tokens) -> bool:
    """CFG contract: ``uncond_tokens`` iff ``guidance_scale != 1.0``.

    The seed engine silently disabled CFG when ``guidance_scale != 1.0``
    but no unconditional prompt was supplied — a guidance-7.5 run would
    quietly produce unguided images.  Both mismatch directions now raise.
    """
    wants_cfg = guidance_scale != 1.0
    has_uncond = uncond_tokens is not None
    if wants_cfg and not has_uncond:
        raise ValueError(
            f"guidance_scale={guidance_scale} requires classifier-free "
            "guidance but uncond_tokens is None — pass the unconditional "
            "prompt tokens (or set ddim.guidance_scale=1.0)")
    if has_uncond and not wants_cfg:
        raise ValueError(
            "uncond_tokens were passed but ddim.guidance_scale == 1.0 "
            "disables classifier-free guidance — drop uncond_tokens or "
            "set a guidance_scale != 1.0")
    return wants_cfg


class DiffusionEngine:
    """Holds params; jits the whole generate path once per signature.

    ``cfg`` is a ``repro.diffusion.pipeline.PipelineConfig``.  Use
    ``generate(prompt_tokens, key, uncond_tokens=...)``; pass
    ``uncond_tokens`` iff ``cfg.ddim.guidance_scale != 1.0`` (a mismatch
    raises ``ValueError``).

    ``policies`` (a ``repro.core.policies.ServePolicies``) is THE policy
    surface (DESIGN.md §13): one frozen bundle of kernel routing,
    TIPS/DBSC precision, temporal patch reuse, and the sampling defaults
    (``sampler`` for ``generate``, ``bank`` for ``init_slots``).  The
    bundle — re-derived through the config's ``effective_*`` accessors —
    is the single policy component of every executable-cache key, so any
    spelling (``policies=``, the deprecated per-policy kwargs below, or
    the legacy ``UNetConfig`` fold-in knobs) that resolves to the same
    effective policies shares executables.

    ``kernel_policy`` / ``precision_policy`` / ``reuse_policy`` are
    deprecated aliases that fold into the bundle (DeprecationWarning);
    ``mesh`` switches on data-parallel sharded execution (see module
    docstring); ``None`` keeps the seed single-device behaviour untouched.
    """

    def __init__(self, cfg, key=None, kernel_policy=None, mesh=None,
                 precision_policy=None, reuse_policy=None, policies=None):
        if (kernel_policy is not None or precision_policy is not None
                or reuse_policy is not None):
            if policies is not None:
                raise ValueError(
                    "pass either policies=ServePolicies(...) or the "
                    "legacy per-policy kwargs, not both")
            legacy_warning(
                "DiffusionEngine(kernel_policy=/precision_policy=/"
                "reuse_policy=) are deprecated aliases — pass "
                "policies=ServePolicies(kernels=..., precision=..., "
                "reuse=...); cache keys and ledgers are identical")
            policies = ServePolicies.from_config(cfg.unet)
            if kernel_policy is not None:
                policies = dataclasses.replace(policies,
                                               kernels=kernel_policy)
            if precision_policy is not None:
                policies = dataclasses.replace(policies,
                                               precision=precision_policy)
            if reuse_policy is not None:
                policies = dataclasses.replace(policies,
                                               reuse=reuse_policy)
        self._default_sampler = policies.sampler if policies else None
        self._default_bank = policies.bank if policies else None
        if policies is not None:
            cfg = policies.apply(cfg)
        if cfg.unet.reuse_policy.enabled and cfg.unet.reuse_policy.capacity < 1.0:
            # a fresh engine run starts from an INVALID cache: every patch
            # of every row is active on step 0, so a sub-1.0 static gather
            # capacity would silently reuse zeros.  capacity < 1 belongs to
            # the edit path (sampler.sample_scan_reuse with recorded
            # base_caches), where the reference is valid from step 0.
            raise ValueError(
                f"reuse_policy.capacity={cfg.unet.reuse_policy.capacity} < "
                f"1.0 on the engine's temporal path — the cache starts "
                f"invalid, so capacity must be 1.0 (use the edit-mode "
                f"sampler with recorded base caches for shrunken gathers)")
        self.cfg = cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        assert cfg.text.d_model == cfg.unet.context_dim, \
            (cfg.text.d_model, cfg.unet.context_dim)
        # the denoiser contract resolves cfg.unet (ANY registered family
        # config — UNet or DiT) to its forward/init; everything below this
        # line is model-agnostic.  The attribute keeps its historical name:
        # it is the denoiser's parameter pytree, whichever family owns it.
        self.denoiser = make_denoiser(cfg.unet)
        self.text_params = init_text_encoder_params(k1, cfg.text)
        self.unet_params = self.denoiser.init_params(k2)
        self.vae_params = init_vae_params(k3, cfg.vae)
        # jitted executables keyed by (batch, use_cfg, stats_rows, mesh
        # signature); geometry is fixed per engine so the signature is the
        # leading dims plus the placement.
        self._compiled: dict = {}
        # slot-mode executables: step per (slots, use_cfg, policies), plus
        # the encode/decode stages cached separately (admission and
        # retirement run them outside the per-step computation)
        self._slot_compiled: dict = {}
        self._encode_fn = None
        self._decode_fn = None
        self._admit_fn = None
        self.last_wall_s: Optional[float] = None
        self.mesh = None
        self.dp_size = 1
        self._data_sharding = None
        if mesh is not None:
            self.place_on_mesh(mesh)

    # ------------------------------------------------------------------
    # Mesh placement
    # ------------------------------------------------------------------
    def place_on_mesh(self, mesh) -> "DiffusionEngine":
        """Place params on ``mesh``: replicated weights, data-sharded batch.

        Callable again after an elastic resize — executables compiled for
        the previous mesh stay cached under the old signature and new
        signatures retrace against the new placement.
        """
        replicated = NamedSharding(mesh, P())
        self.mesh = mesh
        self.dp_size = dp_size_of(mesh)
        self._data_sharding = NamedSharding(mesh, P(dp_axes_of(mesh)))
        self.text_params = jax.device_put(self.text_params, replicated)
        self.unet_params = jax.device_put(self.unet_params, replicated)
        self.vae_params = jax.device_put(self.vae_params, replicated)
        return self

    def _shard_batch(self, x):
        """Commit a batch-leading array to the data axes (no-op unsharded)."""
        if x is None or self._data_sharding is None:
            return x
        return jax.device_put(x, self._data_sharding)

    # ------------------------------------------------------------------
    def _run(self, prompt_tokens, uncond_tokens, latents, stats_rows=None,
             sampler_policy=None, sampler_bank=None, policy_id=None):
        """Traced end-to-end path; ``uncond_tokens`` may be None (static)."""
        cfg = self.cfg
        context = encode_text(self.text_params, prompt_tokens, cfg.text)
        uncond = (encode_text(self.text_params, uncond_tokens, cfg.text)
                  if uncond_tokens is not None else None)

        def unet_apply(lat, tvec, ctx, active, **kw):
            return self.denoiser.apply(self.unet_params, lat, tvec, ctx,
                                       tips_active=active, **kw)

        if cfg.unet.reuse_policy.enabled:
            cache = reuse_cache_zeros(cfg.unet, latents.shape[0],
                                      use_cfg=uncond_tokens is not None)
            latents, stats = sample_scan_reuse(
                unet_apply, latents, context, uncond, cfg.ddim,
                reuse_cache=cache, stats_rows=stats_rows,
                sampler_policy=sampler_policy,
                sampler_bank=sampler_bank, policy_id=policy_id)
        else:
            latents, stats = sample_scan(unet_apply, latents, context,
                                         uncond, cfg.ddim,
                                         stats_rows=stats_rows,
                                         sampler_policy=sampler_policy,
                                         sampler_bank=sampler_bank,
                                         policy_id=policy_id)
        images = decode(self.vae_params, latents, cfg.vae)
        return images, latents, stats

    def set_precision(self, policy) -> "DiffusionEngine":
        """Switch the TIPS/DBSC precision runtime on a live engine.

        The policy participates in the executable-cache key, so the next
        ``generate`` retraces against the new policy; executables compiled
        for the previous policy stay cached under their own key.
        """
        self.cfg = dataclasses.replace(
            self.cfg, unet=dataclasses.replace(self.cfg.unet,
                                               precision=policy))
        # the frozen handle closes over its config — rebuild it so the
        # retrace actually traces the new policy (params are unaffected:
        # precision never changes parameter shapes)
        self.denoiser = make_denoiser(self.cfg.unet)
        return self

    @property
    def policies(self) -> ServePolicies:
        """The engine's effective ``ServePolicies`` bundle.

        Re-derived from the live config through the ``effective_*``
        accessors (so legacy fold-in knobs and ``set_precision`` swaps
        are reflected), with the engine-level sampling defaults riding
        along.  This is what routers/schedulers read instead of the four
        per-axis kwargs.
        """
        return ServePolicies.from_config(self.cfg.unet,
                                         sampler=self._default_sampler,
                                         bank=self._default_bank)

    def _policy_key(self, sampler_policy=None,
                    sampler_bank=None) -> ServePolicies:
        """The single policy component of an executable-cache key.

        One frozen ``ServePolicies`` value per distinct effective policy
        set — legacy spellings normalize through ``effective_*`` to the
        same bundle, so they share executables with the modern API.
        """
        return ServePolicies.from_config(self.cfg.unet,
                                         sampler=sampler_policy,
                                         bank=sampler_bank)

    def _cache_key(self, batch: int, use_cfg: bool,
                   stats_rows: Optional[int] = None,
                   sampler_policy=None, sampler_bank=None) -> tuple:
        # positions 0-3 are load-bearing (tests introspect them); the
        # ServePolicies bundle is THE policy tail — a change on any
        # policy axis retraces
        return (batch, use_cfg, stats_rows, mesh_signature(self.mesh),
                self._policy_key(sampler_policy, sampler_bank))

    def _get_compiled(self, batch: int, use_cfg: bool,
                      stats_rows: Optional[int] = None,
                      sampler_policy=None, sampler_bank=None):
        key = self._cache_key(batch, use_cfg, stats_rows, sampler_policy,
                              sampler_bank)
        fn = self._compiled.get(key)
        if fn is None:
            # under a bank the policy index is a RUNTIME operand (a (B,)
            # int32 array) so the one-shot program keeps the same dynamic
            # coefficient gathers the slot executable has — a trace-time
            # constant would let XLA fold the gathers and shift FMA
            # contraction, breaking the bit-exact oracle contract
            if use_cfg and sampler_bank is not None:
                fn = jax.jit(
                    lambda p, u, l, pid: self._run(p, u, l, stats_rows,
                                                   sampler_policy,
                                                   sampler_bank, pid),
                    donate_argnums=(2,))
            elif use_cfg:
                fn = jax.jit(
                    lambda p, u, l: self._run(p, u, l, stats_rows,
                                              sampler_policy),
                    donate_argnums=(2,))
            elif sampler_bank is not None:
                fn = jax.jit(
                    lambda p, l, pid: self._run(p, None, l, stats_rows,
                                                sampler_policy,
                                                sampler_bank, pid),
                    donate_argnums=(1,))
            else:
                fn = jax.jit(
                    lambda p, l: self._run(p, None, l, stats_rows,
                                           sampler_policy),
                    donate_argnums=(1,))
            self._compiled[key] = fn
        return fn

    # ------------------------------------------------------------------
    def init_latents(self, batch: int, key) -> jax.Array:
        s = self.cfg.unet.latent_size
        return jax.random.normal(key, (batch, s, s,
                                       self.cfg.unet.in_channels))

    def generate(self, prompt_tokens, key, uncond_tokens=None,
                 latents=None, stats_rows=None,
                 sampler_policy=None, sampler_bank=None) -> EngineOutput:
        """(B, text_len) int32 tokens -> EngineOutput.

        The initial ``latents`` buffer (drawn from ``key`` unless given) is
        donated to the compiled call.  Wall time of the call (device sync
        included) lands in ``self.last_wall_s``.

        ``stats_rows`` (static) restricts the PSSA/TIPS accounting to the
        first N rows — serving sets it to the valid row count of a padded
        tail micro-batch.  Under a mesh, ``batch`` must be a multiple of
        the data-parallel degree (the serving front-end pads to it).

        ``sampler_policy`` (a ``solvers.SamplerPolicy``) swaps the solver
        and per-request step budget for this call; it joins the
        executable-cache key, so each distinct policy compiles once.  The
        stats trajectory then carries ``policy.num_steps`` leading steps.

        ``sampler_bank`` (static tuple of policies containing
        ``sampler_policy``) traces this call under the full bank's
        structure with every row pinned to the policy's index — the
        bit-exact one-shot oracle for mixed-tier slot serving
        (DESIGN.md §10).  It joins the cache key too.
        """
        cfg = self.cfg
        if (sampler_policy is None and sampler_bank is None
                and self._default_sampler is not None):
            # engine-level sampling defaults from the ServePolicies
            # bundle (a bank without a sampler only feeds init_slots —
            # one-shot generate needs a concrete policy)
            sampler_policy = self._default_sampler
            sampler_bank = self._default_bank
        if sampler_bank is not None:
            sampler_bank = solvers_mod.as_bank(sampler_bank)
            if sampler_policy not in sampler_bank:
                raise ValueError(
                    f"sampler_policy {sampler_policy and sampler_policy.key()}"
                    f" is not an entry of sampler_bank "
                    f"{[p.key() for p in sampler_bank]}")
        use_cfg = _check_cfg_inputs(cfg.ddim.guidance_scale, uncond_tokens)
        batch = prompt_tokens.shape[0]
        if self.mesh is not None and batch % self.dp_size:
            raise ValueError(
                f"batch {batch} must be a multiple of the data-parallel "
                f"degree {self.dp_size} under mesh "
                f"{dict(self.mesh.shape)} — pad the micro-batch")
        if latents is None:
            latents = self.init_latents(batch, key)
        prompt_tokens = self._shard_batch(prompt_tokens)
        uncond_tokens = self._shard_batch(uncond_tokens)
        latents = self._shard_batch(latents)
        fn = self._get_compiled(batch, use_cfg, stats_rows, sampler_policy,
                                sampler_bank)
        if sampler_bank is not None:
            pid = jnp.full((batch,), sampler_bank.index(sampler_policy),
                           jnp.int32)
        t0 = time.perf_counter()
        if use_cfg and sampler_bank is not None:
            images, latents, stats = fn(prompt_tokens, uncond_tokens,
                                        latents, pid)
        elif use_cfg:
            images, latents, stats = fn(prompt_tokens, uncond_tokens,
                                        latents)
        elif sampler_bank is not None:
            images, latents, stats = fn(prompt_tokens, latents, pid)
        else:
            images, latents, stats = fn(prompt_tokens, latents)
        jax.block_until_ready(images)
        self.last_wall_s = time.perf_counter() - t0
        return EngineOutput(images=images, latents=latents, stats=stats)

    # ------------------------------------------------------------------
    def warmup(self, batch: int, use_cfg: Optional[bool] = None,
               stats_rows: Optional[int] = None,
               sampler_policy=None, sampler_bank=None) -> float:
        """Compile (and discard) one call for the given signature.

        ``use_cfg`` defaults to what the config demands
        (``guidance_scale != 1.0``); forcing it AGAINST the config raises
        the same ``ValueError`` as ``generate`` — a warmed-up signature
        the engine would refuse to serve is a bug, not a cache entry.
        Returns the wall seconds the warmup call took (compile + run).
        """
        cfg = self.cfg
        if use_cfg is None:
            use_cfg = cfg.ddim.guidance_scale != 1.0
        toks = jnp.zeros((batch, cfg.text.max_len), jnp.int32)
        un = jnp.zeros((batch, cfg.text.max_len), jnp.int32) if use_cfg \
            else None
        t0 = time.perf_counter()
        self.generate(toks, jax.random.PRNGKey(0), uncond_tokens=un,
                      stats_rows=stats_rows, sampler_policy=sampler_policy,
                      sampler_bank=sampler_bank)
        return time.perf_counter() - t0

    # ------------------------------------------------------------------
    # Slot-state mode: continuous batching (DESIGN.md §8)
    # ------------------------------------------------------------------
    def init_slots(self, num_slots: int, bank=None) -> SlotState:
        """Fresh all-inactive slot state for ``num_slots`` in-flight rows.

        The slot count is the step executable's batch signature — pick it
        once per serving run (every ``slot_step`` reuses the same compiled
        program regardless of occupancy).  Single-device only: slot
        admission rewrites individual batch rows between steps, which
        would thrash a data-sharded placement.

        ``bank`` (tuple of ``solvers.SamplerPolicy``) turns on the
        phase-aware sampling runtime: requests admitted with different
        ``policy_index`` values coexist in the SAME jitted ``slot_step``
        (per-row coefficient gathers), with multistep solver history in
        ``solver_hist`` and the ledger widened to per-(policy, step)
        buckets — bucket ``p * N + i`` (N = bank max budget) holds policy
        ``p``'s step-``i`` counters, so per-policy energy normalization
        stays exact (``pipeline.energy_report_banked``).  ``bank=None``
        is the legacy single-schedule state, untouched.

        Replica safety (DESIGN.md §13): the slot API is functional —
        state in, state out, with donation consuming only the PASSED
        state's buffers — so one engine may drive N independent
        ``SlotState``s ("replicas") through the SAME cached executables.
        Each replica's ``accum`` is its own integer ledger; summing them
        (``pipeline.merge_ledger_accums``) reproduces the one-shot
        headline bit-for-bit at any replica count or admission order.
        The cluster router (``repro.launch.router``) is built on exactly
        this: call ``init_slots`` once per replica.
        """
        if self.mesh is not None:
            raise ValueError(
                "slot-state mode is single-device: per-slot admission "
                "rewrites batch rows between steps (use micro-batch "
                "serving for mesh execution)")
        if num_slots < 1:
            raise ValueError(f"num_slots={num_slots} must be >= 1")
        if bank is None:
            bank = self._default_bank
        cfg = self.cfg
        s, c = cfg.unet.latent_size, cfg.unet.in_channels
        ctx_shape = (num_slots, cfg.text.max_len, cfg.text.d_model)
        use_cfg = cfg.ddim.guidance_scale != 1.0
        if bank is not None:
            bank = solvers_mod.as_bank(bank)
        num_buckets = (cfg.ddim.num_inference_steps if bank is None
                       else len(bank) * solvers_mod.bank_max_steps(bank))
        return SlotState(
            latents=jnp.zeros((num_slots, s, s, c)),
            # cond and uncond context must be DISTINCT buffers: the state
            # is donated to the admit/step executables, and XLA rejects
            # donating one buffer twice
            context=jnp.zeros(ctx_shape),
            uncond_context=jnp.zeros(ctx_shape) if use_cfg else None,
            step_idx=jnp.zeros((num_slots,), jnp.int32),
            active=jnp.zeros((num_slots,), bool),
            accum=LedgerAccum.zeros(num_buckets,
                                    len(attn_layer_order(cfg.unet))),
            # all-invalid: a slot's first step after admission computes
            # every patch dense (nothing is ever read from the zeros)
            reuse_cache=(reuse_cache_zeros(cfg.unet, num_slots, use_cfg)
                         if cfg.unet.reuse_policy.enabled else None),
            policy_id=(jnp.zeros((num_slots,), jnp.int32)
                       if bank is not None else None),
            solver_hist=(solvers_mod.init_history(bank, num_slots,
                                                  (s, s, c))
                         if bank is not None else None),
            bank=bank)

    def _encode_compiled(self):
        if self._encode_fn is None:
            self._encode_fn = jax.jit(
                lambda toks: encode_text(self.text_params, toks,
                                         self.cfg.text))
        return self._encode_fn

    def admit(self, state: SlotState, slot: int, prompt_tokens, key,
              uncond_tokens=None, latents=None,
              policy_index: int = 0) -> SlotState:
        """Occupy one slot with a new request (between steps).

        ``prompt_tokens`` is (1, text_len); the initial latent row is
        drawn from ``key`` (or passed explicitly — the oracle tests hand
        the same per-request draw to the one-shot engine).  Text encoding
        runs through its own cached executable; the step executable never
        retraces on admission.  The same CFG contract as ``generate``
        applies, plus the slot state itself must have been built for the
        same CFG mode.

        ``policy_index`` selects the request's ``SamplerPolicy`` from the
        state's bank (banked states only); admission zeroes the row's
        solver history, so a multistep solver restarts its warmup exactly
        as a fresh one-shot run would.
        """
        use_cfg = _check_cfg_inputs(self.cfg.ddim.guidance_scale,
                                    uncond_tokens)
        if use_cfg != (state.uncond_context is not None):
            raise ValueError(
                "slot state CFG mode does not match the admit call — "
                "rebuild the state with init_slots() for this config")
        if state.bank is None:
            if policy_index != 0:
                raise ValueError(
                    f"policy_index={policy_index} on a bank-less slot "
                    f"state — build the state with init_slots(bank=...)")
        elif not 0 <= policy_index < len(state.bank):
            raise ValueError(
                f"policy_index={policy_index} outside the state's bank "
                f"of {len(state.bank)} policies")
        enc = self._encode_compiled()
        ctx = enc(prompt_tokens)
        if latents is None:
            latents = self.init_latents(1, key)
        if self._admit_fn is None:
            # one fused dispatch per admission (slot index and policy
            # traced, so any slot/policy reuses the same executable);
            # state donated
            def _adm(state, slot, ctx_row, lat_row, un_row, pid):
                new = dataclasses.replace(
                    state,
                    latents=state.latents.at[slot].set(lat_row),
                    context=state.context.at[slot].set(ctx_row),
                    step_idx=state.step_idx.at[slot].set(0),
                    active=state.active.at[slot].set(True))
                if un_row is not None:
                    new = dataclasses.replace(
                        new, uncond_context=state.uncond_context
                        .at[slot].set(un_row))
                if state.reuse_cache is not None:
                    # cache invalidation on admit: the row's first step
                    # must not reuse the previous occupant's activations
                    new = dataclasses.replace(
                        new,
                        reuse_cache=new.reuse_cache.invalidate_row(slot))
                if state.policy_id is not None:
                    # zeroed history: multistep warmup weights multiply
                    # exact zeros, never the previous occupant's outputs
                    new = dataclasses.replace(
                        new,
                        policy_id=state.policy_id.at[slot].set(pid),
                        solver_hist=state.solver_hist.at[slot].set(0.0))
                return new
            self._admit_fn = jax.jit(_adm, donate_argnums=(0,))
        un_row = enc(uncond_tokens)[0] if use_cfg else None
        return self._admit_fn(state, jnp.int32(slot), ctx[0], latents[0],
                              un_row, jnp.int32(policy_index))

    def _slot_step_traced(self, state: SlotState) -> SlotState:
        cfg = self.cfg

        def unet_apply(lat, tvec, ctx, act, **kw):
            return self.denoiser.apply(self.unet_params, lat, tvec, ctx,
                                       tips_active=act, **kw)

        if state.bank is not None:
            lat, stats, new_cache, new_hist = denoise_step(
                unet_apply, state.latents, state.context,
                state.uncond_context, state.step_idx, cfg.ddim,
                active=state.active, row_stats=True,
                reuse_cache=state.reuse_cache, bank=state.bank,
                policy_id=state.policy_id, solver_hist=state.solver_hist)
            # per-(policy, step) bucket p*N + i; rows whose counter sits
            # at/past their budget (possible only if a finished slot was
            # not retired before the next step) map out of range and the
            # scatter's mode="drop" discards them — a short-budget row
            # can never bleed into the next policy's step-0 bucket
            n_max = solvers_mod.bank_max_steps(state.bank)
            budgets = jnp.asarray([p.num_steps for p in state.bank],
                                  jnp.int32)[state.policy_id]
            bucket = jnp.where(state.step_idx < budgets,
                               state.policy_id * n_max + state.step_idx,
                               len(state.bank) * n_max)
            accum = state.accum.scatter(bucket, state.active, stats)
            return dataclasses.replace(
                state, latents=lat, accum=accum, reuse_cache=new_cache,
                solver_hist=new_hist,
                step_idx=state.step_idx + state.active.astype(jnp.int32))

        out = denoise_step(unet_apply, state.latents, state.context,
                           state.uncond_context, state.step_idx,
                           cfg.ddim, active=state.active,
                           row_stats=True, reuse_cache=state.reuse_cache)
        if state.reuse_cache is not None:
            lat, stats, new_cache = out
        else:
            (lat, stats), new_cache = out, None
        # stats masking invariant: inactive rows are zeroed BEFORE the
        # scatter, and each active row lands in ITS iteration's bucket —
        # integer adds, so any occupancy pattern reproduces the one-shot
        # folded counters exactly (reuse counters included)
        accum = state.accum.scatter(state.step_idx, state.active, stats)
        return dataclasses.replace(
            state, latents=lat, accum=accum, reuse_cache=new_cache,
            step_idx=state.step_idx + state.active.astype(jnp.int32))

    def slot_step(self, state: SlotState) -> SlotState:
        """Advance every active slot by ONE denoising iteration (jitted).

        One executable per (slot count, CFG mode, policies, sampler bank)
        — compiled on first use, donated state, reused for the whole
        serving run.  Wall seconds land in ``self.last_wall_s``.
        """
        key = (state.num_slots, state.uncond_context is not None,
               self._policy_key(None, state.bank))
        fn = self._slot_compiled.get(key)
        if fn is None:
            fn = jax.jit(self._slot_step_traced, donate_argnums=(0,))
            self._slot_compiled[key] = fn
        t0 = time.perf_counter()
        state = fn(state)
        jax.block_until_ready(state.latents)
        self.last_wall_s = time.perf_counter() - t0
        return state

    def finished_slots(self, state: SlotState) -> list:
        """Active slots whose step counter has run off THEIR schedule.

        Banked states compare each row against its own policy's step
        budget — short-budget (draft-tier) rows retire early while
        quality-tier neighbours keep stepping.
        """
        if state.bank is not None:
            idx, act, pid = jax.device_get(
                (state.step_idx, state.active, state.policy_id))
            budgets = [p.num_steps for p in state.bank]
            return [i for i in range(len(idx))
                    if act[i] and idx[i] >= budgets[pid[i]]]
        n = self.cfg.ddim.num_inference_steps
        idx, act = jax.device_get((state.step_idx, state.active))
        return [i for i in range(len(idx)) if act[i] and idx[i] >= n]

    def decode_slots(self, state: SlotState, slots=None) -> jax.Array:
        """VAE-decode slot latents through a cached executable.

        ``slots=None`` decodes the whole buffer in one batch-S call;
        passing the finished slot list decodes ONLY those rows, one
        batch-1 call each — a retirement event typically frees one or two
        slots, so this is the serving path (decoding the full buffer
        would spend a multiple of the per-step wall on unfinished rows).
        Both shapes hit one cached executable each, and a decoded row is
        bit-identical whichever path produced it (and bit-identical to
        the decode fused inside ``generate`` — tests pin this), so the
        choice is pure wall time.
        """
        if self._decode_fn is None:
            self._decode_fn = jax.jit(
                lambda lat: decode(self.vae_params, lat, self.cfg.vae))
        if slots is None:
            return self._decode_fn(state.latents)
        # power-of-two chunking bounds the executable count to log2(S)+1
        # while keeping retirement decodes near the per-row optimum; a
        # scheduler warms those sizes off the clock (see
        # ContinuousScheduler.warmup)
        slots = list(slots)
        if not slots:
            raise ValueError(
                "decode_slots: empty slot list — guard on "
                "finished_slots() (or pass slots=None for the whole "
                "buffer)")
        out, i = [], 0
        while i < len(slots):
            c = 1 << ((len(slots) - i).bit_length() - 1)
            sel = jnp.asarray(slots[i:i + c], jnp.int32)
            out.append(self._decode_fn(state.latents[sel]))
            i += c
        return out[0] if len(out) == 1 else jnp.concatenate(out, axis=0)

    def decode_preview(self, state: SlotState, slots) -> jax.Array:
        """Progressive preview decode of IN-FLIGHT slot latents.

        Decodes the named rows at whatever denoising iteration each has
        reached — the time-to-first-pixel path: a router calls this every
        K steps so a client sees the image sharpen while its slot is
        still denoising.  Runs through the SAME cached power-of-two
        chunked decode executables as retirement decode (``decode_slots``
        — a preview of a row that just finished is bit-identical to its
        final image), and the call is dispatched asynchronously like any
        jax computation: the router materializes the pixels off the hot
        ``slot_step`` loop.
        """
        return self.decode_slots(state, list(slots))

    def retire(self, state: SlotState, slots) -> SlotState:
        """Free finished slots (after decoding); rows become admissible."""
        idx = jnp.asarray(list(slots), jnp.int32)
        return dataclasses.replace(state,
                                   active=state.active.at[idx].set(False))
