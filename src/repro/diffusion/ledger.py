"""Bytes-accurate EMA + MAC ledger for the full BK-SDM-Tiny geometry.

The paper's evaluation is energy / throughput / external-memory-access, so
the reproduction target is this ledger: it walks the exact UNet architecture
(`diffusion.unet.UNetConfig`, full size — no tensors allocated) and emits one
``core.energy.LayerTraffic`` entry per layer per iteration:

  * activations INT12 (1.5 B/elem), weights INT8 (1 B/elem) — the paper's
    operating precision;
  * the self-attention score (SAS) is written to DRAM after softmax and read
    back for the PV matmul (the attention core's dataflow) — 2x traffic,
    which is what PSSA compresses;
  * FFN MACs split INT12/INT6 by the TIPS low-precision ratio;
  * the 192 KB global memory cannot hold a 64x64 feature map, so every
    layer's activations round-trip DRAM (the paper's 1.9 GB/iter premise).

Measured quantities (PSSA compression ratio per resolution, TIPS ratio per
iteration) come from the JAX implementation and are injected through
``LedgerOptions`` — the ledger itself stays exact arithmetic.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

from repro.core.energy import (DRAM_PJ_PER_BYTE, EnergyReport, LayerTraffic,
                               report)
from repro.diffusion.unet import UNetConfig

ACT_BYTES = 1.5        # INT12
WEIGHT_BYTES = 1.0     # INT8
SAS_BYTES = 1.5        # scores stored INT12


@dataclasses.dataclass(frozen=True)
class LedgerOptions:
    """What the datapath does this iteration."""
    pssa: bool = False
    tips: bool = False
    # measured (compressed bytes / dense bytes) for the SAS, per feature-map
    # resolution; 1.0 = no compression.  Keys are resolutions (64/32/16).
    sas_ratio: Optional[dict] = None
    # measured fraction of tokens at INT6 in the FFN this iteration
    tips_low_ratio: float = 0.0
    # whether the TIPS mask covers the second FFN matmul too (the paper's
    # "INT12 through the whole FFN stack" reading, and this ledger's
    # historical accounting).  The functional datapath exposes the same
    # switch as PrecisionPolicy.ffn_mid; energy_report passes it through so
    # the MAC precision split matches what the datapath actually does.
    tips_mid: bool = True
    batch: int = 1

    def sas_factor(self, res: int) -> float:
        if not self.pssa:
            return 1.0
        if self.sas_ratio and res in self.sas_ratio:
            return float(self.sas_ratio[res])
        # paper Fig. 5(a): PSSA cuts SAS EMA by 61.2 % vs no compression
        return 1.0 - 0.612


def _resnet_traffic(tag, res, cin, cout, tdim, batch) -> LayerTraffic:
    t = res * res * batch
    macs = t * 9 * cin * cout + t * 9 * cout * cout + batch * tdim * cout
    w = 9 * cin * cout + 9 * cout * cout + tdim * cout
    if cin != cout:
        w += cin * cout
        macs += t * cin * cout
    return LayerTraffic(
        name=tag, stage="cnn",
        weight_bytes=w * WEIGHT_BYTES,
        act_in_bytes=t * cin * ACT_BYTES,
        act_out_bytes=t * cout * ACT_BYTES,
        macs_high=macs,
    )


def _transformer_traffic(tag, res, c, cfg: UNetConfig,
                         opts: LedgerOptions) -> list:
    """One transformer block -> [self_attn, cross_attn, ffn] entries."""
    b = opts.batch
    t = res * res * b
    heads = cfg.num_heads
    tt = cfg.text_len * b
    dff = cfg.ffn_mult * c
    out = []

    # --- self-attention ---
    sas_dense = heads * (res * res) ** 2 * b * SAS_BYTES * 2.0   # write+read
    sas = sas_dense * opts.sas_factor(res)
    qkvo_w = 4 * c * c
    sa_macs = t * 4 * c * c + 2.0 * heads * (res * res) ** 2 * b * (c // heads)
    out.append(LayerTraffic(
        name=tag + ".self_attn", stage="self_attn",
        weight_bytes=qkvo_w * WEIGHT_BYTES,
        act_in_bytes=t * c * ACT_BYTES,
        act_out_bytes=t * 4 * c * ACT_BYTES,   # q,k,v spill + attn out
        sas_bytes=sas,
        macs_high=sa_macs,
    ))

    # --- cross-attention (scores are T x 77 — small; still DRAM traffic) ---
    cas = heads * (res * res) * cfg.text_len * b * SAS_BYTES * 2.0
    ca_macs = (t * 2 * c * c + tt * 2 * cfg.context_dim * c
               + 2.0 * heads * (res * res) * cfg.text_len * b * (c // heads))
    out.append(LayerTraffic(
        name=tag + ".cross_attn", stage="cross_attn",
        weight_bytes=(2 * c * c + 2 * cfg.context_dim * c) * WEIGHT_BYTES,
        act_in_bytes=(t * c + tt * cfg.context_dim) * ACT_BYTES,
        act_out_bytes=(t * 2 * c + tt * 2 * c) * ACT_BYTES,
        sas_bytes=cas,
        macs_high=ca_macs,
    ))

    # --- FFN (GEGLU) with TIPS mixed precision ---
    # The GEGLU runs as one fused layer (mid activations stay on-chip, so
    # there is no mid byte term); the MAC precision split is per matmul:
    # the up projection always follows the TIPS row mask, the down
    # projection (ff_out) only when the datapath's mask coverage extends
    # to it (``tips_mid`` <-> PrecisionPolicy.ffn_mid).
    macs_up = t * 2 * dff * c                     # geglu up (2f)
    macs_down = t * dff * c                       # down (ff_out)
    low = opts.tips_low_ratio if opts.tips else 0.0
    low_down = low if opts.tips_mid else 0.0
    ffn_w = 2 * dff * c + dff * c
    # TIPS also halves the *activation* bytes of INT6 rows (12 -> 6 bits)
    act_in = t * c * (1.0 - 0.5 * low) * ACT_BYTES
    out.append(LayerTraffic(
        name=tag + ".ffn", stage="ffn",
        weight_bytes=ffn_w * WEIGHT_BYTES,
        act_in_bytes=act_in,
        act_out_bytes=t * c * ACT_BYTES,
        macs_high=macs_up * (1.0 - low) + macs_down * (1.0 - low_down),
        macs_low=macs_up * low + macs_down * low_down,
    ))
    return out


def unet_ledger(cfg: UNetConfig,
                opts: LedgerOptions = LedgerOptions()) -> list:
    """All LayerTraffic entries of ONE UNet iteration (full geometry)."""
    entries = []
    chans = cfg.block_channels
    res = cfg.latent_size
    b = opts.batch

    entries.append(LayerTraffic(
        name="conv_in", stage="cnn",
        weight_bytes=9 * cfg.in_channels * chans[0] * WEIGHT_BYTES,
        act_in_bytes=res * res * cfg.in_channels * b * ACT_BYTES,
        act_out_bytes=res * res * chans[0] * b * ACT_BYTES,
        macs_high=res * res * b * 9 * cfg.in_channels * chans[0]))

    # --- down path ---
    skip_channels = [chans[0]]
    cin = chans[0]
    for i, cout in enumerate(chans):
        for r in range(cfg.resnets_per_down):
            entries.append(_resnet_traffic(f"down{i}.res{r}", res, cin, cout,
                                           cfg.time_dim, b))
            if cfg.down_attn[i]:
                entries.extend(_transformer_traffic(
                    f"down{i}.attn{r}", res, cout, cfg, opts))
            cin = cout
            skip_channels.append(cout)
        if i < len(chans) - 1:
            entries.append(LayerTraffic(
                name=f"down{i}.downsample", stage="cnn",
                weight_bytes=9 * cout * cout * WEIGHT_BYTES,
                act_in_bytes=res * res * cout * b * ACT_BYTES,
                act_out_bytes=(res // 2) ** 2 * cout * b * ACT_BYTES,
                macs_high=(res // 2) ** 2 * b * 9 * cout * cout))
            skip_channels.append(cout)
            res //= 2

    # --- up path ---
    rev = list(reversed(range(len(chans))))
    cin = chans[-1]
    for j, i in enumerate(rev):
        cout = chans[i]
        for r in range(cfg.resnets_per_up):
            skip_c = skip_channels.pop()
            entries.append(_resnet_traffic(f"up{j}.res{r}", res,
                                           cin + skip_c, cout,
                                           cfg.time_dim, b))
            if cfg.down_attn[i]:
                entries.extend(_transformer_traffic(
                    f"up{j}.attn{r}", res, cout, cfg, opts))
            cin = cout
        if j < len(chans) - 1:
            entries.append(LayerTraffic(
                name=f"up{j}.upsample", stage="cnn",
                weight_bytes=9 * cout * cout * WEIGHT_BYTES,
                act_in_bytes=res * res * cout * b * ACT_BYTES,
                act_out_bytes=(res * 2) ** 2 * cout * b * ACT_BYTES,
                macs_high=(res * 2) ** 2 * b * 9 * cout * cout))
            res *= 2

    entries.append(LayerTraffic(
        name="conv_out", stage="cnn",
        weight_bytes=9 * chans[0] * cfg.out_channels * WEIGHT_BYTES,
        act_in_bytes=res * res * chans[0] * b * ACT_BYTES,
        act_out_bytes=res * res * cfg.out_channels * b * ACT_BYTES,
        macs_high=res * res * b * 9 * chans[0] * cfg.out_channels))
    return entries


def dit_ledger(cfg, opts: LedgerOptions = LedgerOptions()) -> list:
    """All LayerTraffic entries of ONE DiT iteration (full geometry).

    ``cfg`` is a ``repro.diffusion.dit.DiTConfig``.  Patch embedding and
    the final projection are the only non-transformer stages; every block
    reuses ``_transformer_traffic`` at the (single) token resolution, so
    the SAS/CAS/FFN accounting — and the measured-ratio injection points —
    are IDENTICAL to the UNet's transformer stages.
    """
    b = opts.batch
    g = cfg.latent_size // cfg.patch
    d = cfg.hidden_size
    t = g * g * b
    pe = cfg.patch * cfg.patch * cfg.in_channels
    po = cfg.patch * cfg.patch * cfg.out_channels
    entries = [LayerTraffic(
        name="patch_embed", stage="cnn",
        weight_bytes=pe * d * WEIGHT_BYTES,
        act_in_bytes=cfg.latent_size ** 2 * cfg.in_channels * b * ACT_BYTES,
        act_out_bytes=t * d * ACT_BYTES,
        macs_high=t * pe * d)]
    for i in range(cfg.depth):
        entries.extend(_transformer_traffic(f"block{i}", g, d, cfg, opts))
    entries.append(LayerTraffic(
        name="final_layer", stage="cnn",
        weight_bytes=d * po * WEIGHT_BYTES,
        act_in_bytes=t * d * ACT_BYTES,
        act_out_bytes=cfg.latent_size ** 2 * cfg.out_channels * b * ACT_BYTES,
        macs_high=t * d * po))
    return entries


def denoiser_ledger(cfg, opts: LedgerOptions = LedgerOptions()) -> list:
    """Dispatch to the family's per-iteration ledger (denoiser contract)."""
    if isinstance(cfg, UNetConfig):
        return unet_ledger(cfg, opts)
    from repro.diffusion.dit import DiTConfig
    if isinstance(cfg, DiTConfig):
        return dit_ledger(cfg, opts)
    raise TypeError(f"no ledger for config type {type(cfg).__name__}")


def iteration_report(cfg,
                     opts: LedgerOptions = LedgerOptions()) -> EnergyReport:
    return report(denoiser_ledger(cfg, opts))


def generation_report(cfg, per_iter_opts: Iterable[LedgerOptions]
                      ) -> EnergyReport:
    """Whole text-to-image run: one denoiser ledger per iteration."""
    entries = []
    for opts in per_iter_opts:
        entries.extend(denoiser_ledger(cfg, opts))
    return report(entries)
