"""BK-SDM-style UNet (paper's workload) with PSSA / TIPS / DBSC folded in.

Architecturally compressed Stable-Diffusion UNet following BK-SDM-Tiny
(Kim et al., 2023 — the paper's evaluation network): SD-v1 block layout with
one resnet + one transformer block per down stage, two per up stage, and the
mid-block removed.  Each block is

    CNN stage          — two 3x3 convs (resnet, GroupNorm + SiLU, time-embed
                         FiLM add), input-stationary on the DBSC;
    transformer stage  — self-attention (pixel-wise; PSSA prunes + compresses
                         the score matrix on its way to DRAM), cross-attention
                         over the text keys (emits the CLS attention score
                         that TIPS thresholds), and a GEGLU FFN whose rows run
                         INT12/INT6 mixed-precision per the TIPS mask.

The module is pure JAX and runs at reduced size on CPU (tests/examples); the
full BK-SDM-Tiny geometry is exercised analytically by ``diffusion.ledger``
(bytes/MACs) and by shape-level ``jax.eval_shape`` checks — matching how the
paper itself evaluates (energy / EMA / throughput, not accuracy).

Forward returns ``(eps, stats)`` where ``stats`` is a ``UNetStats`` pytree
(fixed config-derived layer order — see ``repro.diffusion.stats``) carrying
per-layer PSSA compression statistics and per-cross-attn TIPS ratios for the
energy ledger.  Being a registered pytree with static layer keys, it flows
through ``jax.lax.scan``/``jax.jit`` unchanged — the property the jitted
``DiffusionEngine`` builds on.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.precision import PrecisionPolicy
from repro.core.reuse import (LayerReuseCache, ReuseCache, ReusePolicy,
                              ReuseRowCounters, window_patch_mask)
from repro.diffusion.stats import (SlotStats, UNetStats, attn_layer_order,
                                   _unet_attn_layer_order)
from repro.kernels import dispatch
from repro.kernels.dispatch import KernelPolicy
from repro.kernels.patch_reuse import ops as reuse_ops


@dataclasses.dataclass(frozen=True)
class UNetConfig:
    in_channels: int = 4
    out_channels: int = 4
    block_channels: tuple = (320, 640, 1280, 1280)
    down_attn: tuple = (True, True, True, False)
    resnets_per_down: int = 1          # BK-SDM-Tiny: 1 (base SD: 2)
    resnets_per_up: int = 2            # BK-SDM-Tiny: 2 (base SD: 3)
    has_mid_block: bool = False        # removed in BK-SDM-Small/Tiny
    transformer_depth: int = 1
    num_heads: int = 8
    context_dim: int = 768             # CLIP ViT-L/14 text width
    text_len: int = 77
    time_dim: int = 1280
    latent_size: int = 64              # 512x512 images -> 64x64x4 latents
    groups: int = 32
    ffn_mult: int = 4                  # GEGLU hidden = 4 * channels

    # --- paper features ---
    pssa: bool = True
    tips: bool = True
    dbsc: bool = True
    # legacy FFN-only kernel toggle; folded into kernel_policy (ffn="dbsc")
    # by effective_kernel_policy() — prefer setting kernel_policy directly
    use_dbsc_kernel: bool = False
    pssa_threshold: float = 1.0 / 8192.0
    # legacy fixed CAS threshold; folded into `precision` by
    # effective_precision() — prefer setting the policy directly
    tips_threshold: float = 0.05
    # route PSSA accounting through the seed's materializing reference
    # implementation (benchmark baseline / oracle; see core.pssa)
    pssa_stats_reference: bool = False
    # per-op kernel routing (repro.kernels.dispatch): which implementation
    # self-attention / cross-attention / FFN / bitmap use, interpret
    # auto-selection, blocks
    kernel_policy: KernelPolicy = KernelPolicy()
    # TIPS/DBSC precision runtime (repro.core.precision): spotting mode,
    # thresholds, second-matmul coverage — the single source of precision
    # truth the engine keys its executable cache on
    precision: PrecisionPolicy = PrecisionPolicy()
    # temporal patch reuse (repro.core.reuse): SIGE-style gather/scatter of
    # changed patches over cached previous-step activations; takes effect
    # when a ReuseCache is threaded into unet_forward
    reuse_policy: ReusePolicy = ReusePolicy()

    dtype: str = "float32"

    def __post_init__(self):
        # the legacy fold-in knobs are deprecated aliases of the policy
        # objects (DESIGN.md §13): warn at the spelling site — the
        # construction that sets a non-default value — not in the
        # effective_* reads, which internal code calls on every trace.
        # Function-local import: core.policies imports diffusion.solvers,
        # and this module loads first in the package __init__.
        legacy_default = next(f.default for f in dataclasses.fields(self)
                              if f.name == "tips_threshold")
        if self.use_dbsc_kernel:
            from repro.core.policies import legacy_warning
            legacy_warning(
                "UNetConfig.use_dbsc_kernel is a deprecated alias — set "
                "kernel_policy=KernelPolicy(ffn='dbsc') (or "
                "ServePolicies(kernels=...)); the cache key and ledger "
                "are identical either way")
        if self.tips_threshold != legacy_default:
            from repro.core.policies import legacy_warning
            legacy_warning(
                "UNetConfig.tips_threshold is a deprecated alias — set "
                "precision=PrecisionPolicy(threshold=...) (or "
                "ServePolicies(precision=...)); the cache key and ledger "
                "are identical either way")

    def patch_size(self, resolution: int) -> int:
        """PSXU patch width at a given feature-map resolution (16/32/64)."""
        return min(64, max(16, resolution))

    def effective_kernel_policy(self) -> KernelPolicy:
        """``kernel_policy`` with the legacy ``use_dbsc_kernel`` folded in."""
        pol = self.kernel_policy
        if self.use_dbsc_kernel and pol.ffn == "reference":
            pol = dataclasses.replace(pol, ffn="dbsc")
        return pol

    def effective_precision(self) -> PrecisionPolicy:
        """``precision`` with the legacy ``tips_threshold`` folded in.

        A non-default ``tips_threshold`` on an otherwise-default
        fixed-spotting policy overrides the policy threshold (mirrors the
        ``use_dbsc_kernel`` fold); an explicitly-configured policy wins.
        """
        pol = self.precision
        legacy_default = next(f.default for f in dataclasses.fields(self)
                              if f.name == "tips_threshold")
        if (self.tips_threshold != legacy_default
                and pol.spotting == "fixed"
                and pol.threshold == PrecisionPolicy().threshold):
            pol = dataclasses.replace(pol, threshold=self.tips_threshold)
        return pol

    def smoke(self) -> "UNetConfig":
        """Reduced config that runs a full fwd pass on CPU in seconds."""
        return dataclasses.replace(
            self,
            block_channels=(32, 64, 64, 64),
            num_heads=4,
            context_dim=32,
            text_len=8,
            time_dim=64,
            latent_size=16,
            groups=8,
        )

    # --- denoiser-contract hooks (repro.diffusion.denoiser) ---
    def layer_order(self) -> tuple:
        """Canonical stats layer order for this config (contract hook)."""
        return _unet_attn_layer_order(self)

    def channels_at(self, resolution: int) -> int:
        """Token width at a feature-map resolution (contract hook)."""
        stage = (self.latent_size // resolution).bit_length() - 1
        return self.block_channels[stage]

    def full_geometry(self) -> "UNetConfig":
        """Full-size config of this family — the analytic-ledger
        extrapolation target (contract hook)."""
        return UNetConfig()

    def attn_resolutions(self) -> tuple:
        """Distinct attention resolutions, sorted descending (contract
        hook; measured-ratio remap keys for the energy ledger)."""
        return tuple(sorted({self.latent_size >> s
                             for s, a in enumerate(self.down_attn) if a},
                            reverse=True))

    @property
    def num_down_attn_layers(self) -> int:
        return sum(self.resnets_per_down * self.transformer_depth
                   for a in self.down_attn if a)


BK_SDM_TINY = UNetConfig()


# ----------------------------------------------------------------------------
# Primitive layers
# ----------------------------------------------------------------------------
def conv2d(x, w, b=None, stride: int = 1, padding: int = 1):
    """NHWC conv with HWIO weights."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if b is not None:
        y = y + b
    return y


def group_norm(x, scale, bias, groups: int, eps: float = 1e-5):
    n, h, w, c = x.shape
    g = math.gcd(groups, c)
    xg = x.reshape(n, h, w, g, c // g).astype(jnp.float32)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(n, h, w, c) * scale + bias).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    mean = jnp.mean(x.astype(jnp.float32), axis=-1, keepdims=True)
    var = jnp.var(x.astype(jnp.float32), axis=-1, keepdims=True)
    y = (x.astype(jnp.float32) - mean) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def timestep_embedding(t, dim: int, max_period: float = 10000.0):
    """Sinusoidal (B,) int timesteps -> (B, dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period)
                    * jnp.arange(half, dtype=jnp.float32) / half)
    ang = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


# ----------------------------------------------------------------------------
# Parameter init
# ----------------------------------------------------------------------------
def _conv_p(key, kh, kw, cin, cout, dtype):
    s = 1.0 / math.sqrt(kh * kw * cin)
    k1, k2 = jax.random.split(key)
    return {"w": (jax.random.uniform(k1, (kh, kw, cin, cout), jnp.float32,
                                     -s, s)).astype(dtype),
            "b": jnp.zeros((cout,), dtype)}


def _lin_p(key, cin, cout, dtype, bias=True):
    s = 1.0 / math.sqrt(cin)
    p = {"w": (jax.random.uniform(key, (cin, cout), jnp.float32,
                                  -s, s)).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((cout,), dtype)
    return p


def _norm_p(c, dtype):
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def _resnet_p(key, cin, cout, tdim, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "norm1": _norm_p(cin, dtype),
        "conv1": _conv_p(ks[0], 3, 3, cin, cout, dtype),
        "time": _lin_p(ks[1], tdim, cout, dtype),
        "norm2": _norm_p(cout, dtype),
        "conv2": _conv_p(ks[2], 3, 3, cout, cout, dtype),
    }
    if cin != cout:
        p["skip"] = _conv_p(ks[3], 1, 1, cin, cout, dtype)
    return p


def _transformer_p(key, c, cfg: UNetConfig, dtype):
    ks = jax.random.split(key, 12)
    dff = cfg.ffn_mult * c
    return {
        "norm_in": _norm_p(c, dtype),
        "proj_in": _lin_p(ks[0], c, c, dtype),
        "ln1": _norm_p(c, dtype),
        "sa_q": _lin_p(ks[1], c, c, dtype, bias=False),
        "sa_k": _lin_p(ks[2], c, c, dtype, bias=False),
        "sa_v": _lin_p(ks[3], c, c, dtype, bias=False),
        "sa_o": _lin_p(ks[4], c, c, dtype),
        "ln2": _norm_p(c, dtype),
        "ca_q": _lin_p(ks[5], c, c, dtype, bias=False),
        "ca_k": _lin_p(ks[6], cfg.context_dim, c, dtype, bias=False),
        "ca_v": _lin_p(ks[7], cfg.context_dim, c, dtype, bias=False),
        "ca_o": _lin_p(ks[8], c, c, dtype),
        "ln3": _norm_p(c, dtype),
        "ff_geglu": _lin_p(ks[9], c, 2 * dff, dtype),
        "ff_out": _lin_p(ks[10], dff, c, dtype),
        "proj_out": _lin_p(ks[11], c, c, dtype),
    }


def init_unet_params(key, cfg: UNetConfig):
    dtype = jnp.dtype(cfg.dtype)
    chans = cfg.block_channels
    keys = iter(jax.random.split(key, 256))
    p = {
        "time_mlp1": _lin_p(next(keys), chans[0], cfg.time_dim, dtype),
        "time_mlp2": _lin_p(next(keys), cfg.time_dim, cfg.time_dim, dtype),
        "conv_in": _conv_p(next(keys), 3, 3, cfg.in_channels, chans[0], dtype),
    }
    # --- down path (track the skip-channel stack exactly as forward pushes) ---
    down = []
    skip_channels = [chans[0]]          # conv_in output
    cin = chans[0]
    for i, cout in enumerate(chans):
        stage = {"resnets": [], "attns": []}
        for _ in range(cfg.resnets_per_down):
            stage["resnets"].append(
                _resnet_p(next(keys), cin, cout, cfg.time_dim, dtype))
            if cfg.down_attn[i]:
                stage["attns"].append(
                    _transformer_p(next(keys), cout, cfg, dtype))
            cin = cout
            skip_channels.append(cout)
        if i < len(chans) - 1:
            stage["down"] = _conv_p(next(keys), 3, 3, cout, cout, dtype)
            skip_channels.append(cout)
        down.append(stage)
    p["down"] = down

    if cfg.has_mid_block:
        c = chans[-1]
        p["mid"] = {
            "res1": _resnet_p(next(keys), c, c, cfg.time_dim, dtype),
            "attn": _transformer_p(next(keys), c, cfg, dtype),
            "res2": _resnet_p(next(keys), c, c, cfg.time_dim, dtype),
        }

    # --- up path (pops the skip stack in reverse; widths vary across
    #     stage boundaries, so cin comes from the tracked stack) ---
    up = []
    rev = list(reversed(range(len(chans))))
    cin = chans[-1]
    for j, i in enumerate(rev):
        cout = chans[i]
        stage = {"resnets": [], "attns": []}
        for r in range(cfg.resnets_per_up):
            skip_c = skip_channels.pop()
            stage["resnets"].append(_resnet_p(
                next(keys), cin + skip_c, cout, cfg.time_dim, dtype))
            if cfg.down_attn[i]:
                stage["attns"].append(
                    _transformer_p(next(keys), cout, cfg, dtype))
            cin = cout
        if j < len(chans) - 1:
            stage["up"] = _conv_p(next(keys), 3, 3, cout, cout, dtype)
        up.append(stage)
    assert not skip_channels, f"unbalanced skips: {skip_channels}"
    p["up"] = up

    p["norm_out"] = _norm_p(chans[0], dtype)
    p["conv_out"] = _conv_p(next(keys), 3, 3, chans[0], cfg.out_channels,
                            dtype)
    return p


# ----------------------------------------------------------------------------
# Blocks
# ----------------------------------------------------------------------------
def _resnet(x, p, temb, groups):
    h = group_norm(x, p["norm1"]["scale"], p["norm1"]["bias"], groups)
    h = conv2d(jax.nn.silu(h), p["conv1"]["w"], p["conv1"]["b"])
    t = jnp.einsum("bd,dc->bc", jax.nn.silu(temb), p["time"]["w"]) \
        + p["time"]["b"]
    h = h + t[:, None, None, :]
    h = group_norm(h, p["norm2"]["scale"], p["norm2"]["bias"], groups)
    h = conv2d(jax.nn.silu(h), p["conv2"]["w"], p["conv2"]["b"])
    skip = x if "skip" not in p else conv2d(x, p["skip"]["w"], p["skip"]["b"],
                                            padding=0)
    return skip + h


def _attn_heads(x, w, heads):
    b, t, _ = x.shape
    y = jnp.einsum("btc,cd->btd", x, w)
    return y.reshape(b, t, heads, -1).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, t, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * d)


def _transformer_block(x2d, p, context, cfg: UNetConfig, tips_active,
                       stats_rows=None, dup_after_self: bool = False,
                       policy: KernelPolicy | None = None,
                       precision: PrecisionPolicy | None = None,
                       row_stats: bool = False, reuse=None,
                       overrides=None, modulation=None):
    """x2d: (B, H, W, C) -> (out, PSSAStats, TIPSResult, reuse_out).

    ``tips_active`` is a scalar flag (whole-batch schedule) or a (B,) row
    vector — continuous batching runs slots at heterogeneous denoising
    iterations, so each row carries its own activity bit.  ``row_stats``
    reports per-row integer counters instead of folded stats (the slot
    runtime scatters them into per-iteration ledger buckets).

    ``policy`` selects the per-op kernel implementation (reference vs
    Pallas) via ``repro.kernels.dispatch``; ``precision`` the TIPS
    spotting mode / FFN coverage; None falls back to the config's
    effective policies.

    ``stats_rows`` (static) restricts the returned stats to the first N
    batch rows — the cond half under a fused-CFG batch.

    ``dup_after_self``: CFG prefix deduplication.  Under fused CFG, the
    cond and uncond halves are IDENTICAL until the first cross-attention
    (only the text context differs), so the fused path runs everything up
    to and including this block's self-attention on the cond half alone
    and tiles the hidden state to both halves here — exact, and it halves
    the most expensive self-attention in the network (the first block sits
    at the highest resolution).  ``x2d`` then has half as many rows as
    ``context``.

    ``reuse``: ``None`` (dense path, ``reuse_out`` is None) or a
    ``(ReusePolicy, LayerReuseCache, valid)`` triple.  With it, the block
    thresholds the per-patch delta of its token input against the cached
    reference, gathers only active patch rows into the self-attention
    queries / cross-attention queries / FFN rows (K/V, norms, and
    projections stay dense), and scatters the stage outputs over the
    cached activations; ``reuse_out`` is then
    ``(new LayerReuseCache, ReuseRowCounters)``.  At threshold 0 (or an
    invalid cache row) every patch is active, the plan is the identity,
    and the block is bit-identical to the dense path (DESIGN.md §9).

    ``overrides`` (a ``solvers.PhaseOverrides`` or None) carries per-row
    phase-scheduled threshold SCALES ((B,) request-row arrays, tiled to
    [cond | uncond] where the hidden state was); each lane is None when
    the sampler bank never schedules it, which keeps the unscheduled
    trace — and its kernel routing — exactly the legacy one.

    ``modulation``: adaLN-zero timestep conditioning (the DiT family).
    ``None`` — what every UNet call passes — leaves the trace exactly as
    before.  Otherwise a 9-tuple of (B, 1, C)-broadcastable arrays,
    ``(shift, scale, gate)`` per stage in (self-attn, cross-attn, FFN)
    order: after each stage's ``layer_norm`` the hidden state becomes
    ``hn * (1 + scale) + shift``, and the stage's projection is
    multiplied by ``gate`` before the residual add (and before any reuse
    scatter, so the cache holds gated activations like it holds projected
    ones).  Arrays carry request rows and are tiled to [cond | uncond]
    by the same ``_per_rows`` rule as the override lanes.
    """
    b, hgt, wid, c = x2d.shape
    res = hgt  # feature-map resolution
    heads = cfg.num_heads
    if policy is None:
        policy = cfg.effective_kernel_policy()
    if precision is None:
        precision = cfg.effective_precision()

    def _per_rows(vec, nrows):
        # override lanes are per REQUEST row; tile to [cond | uncond]
        # rows where the hidden state was tiled (same precedent as
        # tips_active / valid below)
        if vec is not None and vec.shape[0] != nrows:
            vec = jnp.concatenate([vec, vec], axis=0)
        return vec

    rows = gate_rows = cache = None
    if reuse is not None:
        rp, cache, valid = reuse
        tokens_in = x2d.reshape(b, hgt * wid, c)
        patch_r = cfg.patch_size(res)
        if rp.apriori_window is not None:
            # the edit region is known up front: patch activity is a
            # compile-time constant — the patch-delta kernel is skipped
            # entirely (the win of an a-priori reuse plan)
            mask = window_patch_mask(rp.apriori_window, res, patch_r,
                                     cfg.latent_size)
            changed = jnp.broadcast_to(jnp.asarray(mask, bool)[None, :],
                                       (b, len(mask)))
        else:
            reuse_scale = (None if overrides is None
                           else _per_rows(overrides.reuse_scale, b))
            if reuse_scale is not None:
                # per-row thresholds: compute the raw per-patch delta
                # (threshold 0 — same values regardless) and compare at
                # the call site
                delta, _ = dispatch.patch_delta(policy, tokens_in,
                                                cache.ref, patch=patch_r,
                                                threshold=0.0)
                changed = delta >= (rp.threshold
                                    * reuse_scale)[:, None]
            else:
                _, changed = dispatch.patch_delta(policy, tokens_in,
                                                  cache.ref,
                                                  patch=patch_r,
                                                  threshold=rp.threshold)
        vrow = valid
        if vrow.shape[0] != b:
            # post-dup layers carry [cond | uncond] rows; validity is per
            # request row, so tile it like the hidden state was
            vrow = jnp.concatenate([vrow, vrow], axis=0)
        act = jnp.logical_or(changed, jnp.logical_not(vrow)[:, None])
        npatch = tokens_in.shape[1] // patch_r
        order, gate = reuse_ops.reuse_plan(act, rp.cap_patches(npatch))
        rows = reuse_ops.plan_token_rows(order, patch_r)
        gate_rows = jnp.repeat(gate, patch_r, axis=1)
        sr = b if stats_rows is None else stats_rows
        counters = ReuseRowCounters(
            computed=jnp.sum(gate.astype(jnp.int32), axis=1)[:sr],
            total=jnp.full((b,), npatch, jnp.int32)[:sr])

    h = group_norm(x2d, p["norm_in"]["scale"], p["norm_in"]["bias"],
                   cfg.groups)
    h = h.reshape(b, hgt * wid, c)
    h = jnp.einsum("btc,cd->btd", h, p["proj_in"]["w"]) + p["proj_in"]["b"]
    resid = h

    # --- self-attention (PSSA) ---
    hn = layer_norm(h, p["ln1"]["scale"], p["ln1"]["bias"])
    if modulation is not None:
        hn = hn * (1.0 + _per_rows(modulation[1], hn.shape[0])) \
            + _per_rows(modulation[0], hn.shape[0])
    # reuse: queries gathered to the active patch rows, K/V stay dense —
    # every gathered query still attends over the full token set
    hn_q = hn if reuse is None else reuse_ops.gather_rows(hn, rows)
    q = _attn_heads(hn_q, p["sa_q"]["w"], heads)
    k = _attn_heads(hn, p["sa_k"]["w"], heads)
    v = _attn_heads(hn, p["sa_v"]["w"], heads)
    patch = cfg.patch_size(res)
    sa_threshold = cfg.pssa_threshold
    if overrides is not None and overrides.pssa_scale is not None:
        # self-attention runs on the cond half pre-dup (b == request
        # rows) and on [cond | uncond] in post-dup blocks — tile to match
        sa_threshold = cfg.pssa_threshold * _per_rows(
            overrides.pssa_scale, q.shape[0])
    sa = dispatch.self_attention(policy, q, k, v, patch=patch,
                                 threshold=sa_threshold,
                                 prune_scores=cfg.pssa,
                                 stats_rows=None if dup_after_self
                                 else stats_rows,
                                 reference_stats=cfg.pssa_stats_reference,
                                 row_stats=row_stats)
    sa_proj = jnp.einsum("btd,dc->btc", _merge_heads(sa.out),
                         p["sa_o"]["w"]) + p["sa_o"]["b"]
    if modulation is not None:
        sa_proj = sa_proj * _per_rows(modulation[2], sa_proj.shape[0])
    if reuse is not None:
        sa_proj = reuse_ops.scatter_rows(cache.sa, rows, sa_proj, gate_rows)
    sa_full = sa_proj
    h = resid + sa_proj

    if dup_after_self:
        # tile [cond] -> [cond | uncond]; divergence starts at cross-attn
        h = jnp.concatenate([h, h], axis=0)
        x2d = jnp.concatenate([x2d, x2d], axis=0)
        b = x2d.shape[0]
        if reuse is not None:
            # the plan was computed on the cond half; both halves share it
            rows = jnp.concatenate([rows, rows], axis=0)
            gate_rows = jnp.concatenate([gate_rows, gate_rows], axis=0)

    # --- cross-attention (TIPS CAS source) ---
    resid = h
    hn = layer_norm(h, p["ln2"]["scale"], p["ln2"]["bias"])
    if modulation is not None:
        hn = hn * (1.0 + _per_rows(modulation[4], hn.shape[0])) \
            + _per_rows(modulation[3], hn.shape[0])
    hn_q = hn if reuse is None else reuse_ops.gather_rows(hn, rows)
    q = _attn_heads(hn_q, p["ca_q"]["w"], heads)
    kt = _attn_heads(context, p["ca_k"]["w"], heads)
    vt = _attn_heads(context, p["ca_v"]["w"], heads)
    tips_scale = (None if overrides is None
                  else _per_rows(overrides.tips_scale, h.shape[0]))
    ca = dispatch.cross_attention(policy, q, kt, vt, precision=precision,
                                  stats_rows=stats_rows,
                                  row_stats=row_stats,
                                  threshold_scale=tips_scale)
    ca_proj = jnp.einsum("btd,dc->btc", _merge_heads(ca.out),
                         p["ca_o"]["w"]) + p["ca_o"]["b"]
    if modulation is not None:
        ca_proj = ca_proj * _per_rows(modulation[5], ca_proj.shape[0])
    if reuse is not None:
        ca_proj = reuse_ops.scatter_rows(cache.ca, rows, ca_proj, gate_rows)
    ca_full = ca_proj
    h = resid + ca_proj

    # --- FFN (GEGLU) with TIPS mixed precision ---
    resid = h
    hn = layer_norm(h, p["ln3"]["scale"], p["ln3"]["bias"])
    if modulation is not None:
        hn = hn * (1.0 + _per_rows(modulation[7], hn.shape[0])) \
            + _per_rows(modulation[6], hn.shape[0])
    hn_f = hn if reuse is None else reuse_ops.gather_rows(hn, rows)
    if cfg.tips:
        active = tips_active
        if getattr(active, "ndim", 0) == 1:
            # per-row activity (continuous batching): broadcast over tokens;
            # under cfg_dup the rows doubled at the cross-attn, tile to match
            if active.shape[0] != h.shape[0]:
                active = jnp.concatenate([active, active], axis=0)
            active = active[:, None]
        # ca.important_full already lives on the gathered rows (the
        # cross-attention queries were gathered with the same plan)
        important = jnp.logical_or(ca.important_full,
                                   jnp.logical_not(active))
    else:
        important = None
    ffn = dispatch.ffn_geglu(policy, hn_f, p, important,
                             precision=precision)
    if modulation is not None:
        ffn = ffn * _per_rows(modulation[8], ffn.shape[0])
    if reuse is not None:
        ffn = reuse_ops.scatter_rows(cache.ffn, rows, ffn, gate_rows)
    ffn_full = ffn
    h = resid + ffn

    h = jnp.einsum("btc,cd->btd", h, p["proj_out"]["w"]) + p["proj_out"]["b"]
    out = x2d + h.reshape(b, hgt, wid, c)
    if reuse is None:
        return out, sa.stats, ca.tips_result, None
    new_cache = LayerReuseCache(ref=tokens_in, sa=sa_full, ca=ca_full,
                                ffn=ffn_full)
    return out, sa.stats, ca.tips_result, (new_cache, counters)


def _downsample(x, p):
    return conv2d(x, p["w"], p["b"], stride=2)


def _upsample(x, p):
    b, h, w, c = x.shape
    x = jax.image.resize(x, (b, 2 * h, 2 * w, c), "nearest")
    return conv2d(x, p["w"], p["b"])


# ----------------------------------------------------------------------------
# Forward
# ----------------------------------------------------------------------------
def unet_forward(params, latents, timesteps, context, cfg: UNetConfig,
                 tips_active: bool | jax.Array = True,
                 stats_rows: Optional[int] = None,
                 cfg_dup: bool = False,
                 row_stats: bool = False,
                 reuse_cache: Optional[ReuseCache] = None,
                 overrides=None):
    """latents (B, S, S, 4), timesteps (B,), context (B, Ttext, ctx_dim).

    Returns (eps-prediction (B, S, S, 4), ``UNetStats`` pytree) with one
    PSSA/TIPS entry per transformer block in ``attn_layer_order(cfg)``.
    ``stats_rows`` (static) restricts stats to the first N batch rows; the
    fused-CFG path sets it to the cond half so accounting matches a
    cond-only call at half the cost.

    ``tips_active`` accepts a scalar (whole batch on one schedule) or a
    (B,) per-row vector — continuous batching runs each slot at its own
    denoising iteration.  ``row_stats`` (static) switches the stats
    container to a ``SlotStats`` of per-row integer counters (same layer
    order) for scatter into per-iteration ledger buckets.

    ``cfg_dup``: fused-CFG prefix deduplication.  ``latents``/``timesteps``
    carry ONLY the cond half (B rows) while ``context`` carries
    ``[cond | uncond]`` (2B rows); everything up to the first
    cross-attention — identical for both halves — runs once on B rows and
    the hidden state is tiled to 2B there.  ``eps`` comes back with 2B
    rows, split by ``sampler.guided_eps``.

    ``reuse_cache`` (a ``core.reuse.ReuseCache`` built for this batch/CFG
    geometry) switches on temporal patch reuse when
    ``cfg.reuse_policy.enabled``: each transformer block gathers only the
    patches whose input delta against the cache reaches the policy
    threshold and scatters over the cached activations.  The return then
    gains a third element — the NEW cache (this step's activations, all
    rows valid) — and ``stats`` carries per-layer ``ReuseRowCounters``.

    ``overrides`` (a ``solvers.PhaseOverrides``) threads phase-scheduled
    per-row threshold scales to every transformer block; None — the
    default, and what every unscheduled sampler bank produces — leaves
    each block's trace exactly as before.
    """
    pssa_stats: list = []
    tips_stats: list = []
    reuse_stats: list = []
    new_layer_caches: list = []
    tips_active = jnp.asarray(tips_active)
    policy = cfg.effective_kernel_policy()
    precision = cfg.effective_precision()
    reuse_pol = cfg.reuse_policy
    reuse_on = reuse_pol.enabled and reuse_cache is not None
    needs_dup = cfg_dup
    if cfg_dup:
        assert context.shape[0] == 2 * latents.shape[0], \
            (context.shape, latents.shape)

    temb = timestep_embedding(timesteps, cfg.block_channels[0])
    temb = jnp.einsum("bd,dc->bc", temb, params["time_mlp1"]["w"]) \
        + params["time_mlp1"]["b"]
    temb = jnp.einsum("bd,dc->bc", jax.nn.silu(temb),
                      params["time_mlp2"]["w"]) + params["time_mlp2"]["b"]

    def attn_block(h, bp):
        nonlocal temb, needs_dup
        reuse_arg = None
        if reuse_on:
            reuse_arg = (reuse_pol, reuse_cache.layers[len(pssa_stats)],
                         reuse_cache.valid)
        h, sa, ca, ru = _transformer_block(h, bp, context, cfg, tips_active,
                                           stats_rows,
                                           dup_after_self=needs_dup,
                                           policy=policy,
                                           precision=precision,
                                           row_stats=row_stats,
                                           reuse=reuse_arg,
                                           overrides=overrides)
        if needs_dup:
            # downstream resnets now see [cond | uncond] rows
            temb = jnp.concatenate([temb, temb], axis=0)
            needs_dup = False
        pssa_stats.append(sa)
        tips_stats.append(ca)
        if reuse_on:
            new_layer_caches.append(ru[0])
            reuse_stats.append(ru[1])
        return h

    def pop_skip(h):
        skip = skips.pop()
        if skip.shape[0] != h.shape[0]:   # recorded before duplication
            skip = jnp.concatenate([skip, skip], axis=0)
        return skip

    h = conv2d(latents, params["conv_in"]["w"], params["conv_in"]["b"])
    skips = [h]

    for i, stage in enumerate(params["down"]):
        for r, rp in enumerate(stage["resnets"]):
            h = _resnet(h, rp, temb, cfg.groups)
            if stage["attns"]:
                h = attn_block(h, stage["attns"][r])
            skips.append(h)
        if "down" in stage:
            h = _downsample(h, stage["down"])
            skips.append(h)

    if cfg.has_mid_block:
        mp = params["mid"]
        h = _resnet(h, mp["res1"], temb, cfg.groups)
        h = attn_block(h, mp["attn"])
        h = _resnet(h, mp["res2"], temb, cfg.groups)

    for j, stage in enumerate(params["up"]):
        for r, rp in enumerate(stage["resnets"]):
            skip = pop_skip(h)
            h = _resnet(jnp.concatenate([h, skip], axis=-1), rp, temb,
                        cfg.groups)
            if stage["attns"]:
                h = attn_block(h, stage["attns"][r])
        if "up" in stage:
            h = _upsample(h, stage["up"])

    if needs_dup:                     # no cross-attention anywhere: tile eps
        h = jnp.concatenate([h, h], axis=0)

    h = group_norm(h, params["norm_out"]["scale"], params["norm_out"]["bias"],
                   cfg.groups)
    eps = conv2d(jax.nn.silu(h), params["conv_out"]["w"],
                 params["conv_out"]["b"])
    stats_cls = SlotStats if row_stats else UNetStats
    stats = stats_cls.from_layer_list(attn_layer_order(cfg), pssa_stats,
                                      tips_stats,
                                      reuse=tuple(reuse_stats))
    if reuse_on:
        new_cache = ReuseCache(valid=jnp.ones_like(reuse_cache.valid),
                               layers=tuple(new_layer_caches))
        return eps, stats, new_cache
    return eps, stats


def abstract_unet_params(cfg: UNetConfig):
    return jax.eval_shape(lambda: init_unet_params(jax.random.PRNGKey(0),
                                                   cfg))


# --- denoiser-contract registration (repro.diffusion.denoiser) ---
from repro.diffusion import denoiser as _denoiser  # noqa: E402

_denoiser.register_family(_denoiser.FamilySpec(
    family="unet",
    config_cls=UNetConfig,
    init_params=init_unet_params,
    forward=unet_forward,
    abstract_params=abstract_unet_params,
))
