"""SamplerPolicy — pluggable few-step solvers + phase-scheduled policies.

The paper's 28.6 mJ/iter headline is per *iteration*; the other axis of
end-to-end energy is how many iterations an image needs.  SD-Acc
(arXiv:2507.01309) shows denoising is phase-heterogeneous (structure ->
content -> detail) and that solver/step scheduling is the biggest
end-to-end lever.  This module is that lever's policy layer:

``SamplerPolicy``   — frozen/hashable: which solver (``ddim`` |
                      ``dpm2m`` | ``plms``), how many steps, and an
                      optional ``PhaseSchedule``.  Policies join the
                      engine's executable-cache keys, so the set of
                      DISTINCT policies in flight (the *bank*) keeps
                      cache keys finite while per-row integer
                      ``policy_id`` s select coefficients at trace time.
``PhaseSchedule``   — per-phase overrides (TIPS activity, PSSA / TIPS /
                      reuse threshold scales) resolved PER ROW PER STEP
                      inside the scan body from precomputed tables —
                      never from Python control flow, so one executable
                      serves every phase mix.
``solver_tables``   — the (P, N) per-(policy, step) coefficient tables
                      the generalized ``sampler.denoise_step`` gathers
                      per row: timesteps, DDIM alphas, DPM-Solver++(2M)
                      exponential-integrator coefficients, TIPS
                      activity, and phase threshold scales.

Exactness contracts (DESIGN.md §10):

* DDIM rows reproduce ``sampler.ddim_step`` op-for-op: the tables hold
  the SAME float32 ``alphas_cumprod`` gathers the legacy path computes,
  and the transfer arithmetic is the shared ``ddim_transfer`` helper —
  a single-policy ``(ddim, 25)`` bank is bit-identical to the
  policy-free engine.
* A request's trajectory depends only on its OWN (solver, steps) pair:
  per-row gathers + elementwise candidate selection mean a mixed-tier
  slot batch produces images bit-identical to one-shot runs of the same
  policy (tests/test_solvers.py pins this).

Solver math:

* ``ddim``  — deterministic eta=0 transfer (the seed's operating point).
* ``plms``  — PNDM's linear-multistep mode: Adams–Bashforth combination
  of the last <=4 eps predictions (warmup orders 1/2/3/4), then the same
  DDIM transfer.  History = 3 previous eps.
* ``dpm2m`` — DPM-Solver++(2M), data-prediction space: with
  ``lambda = log(alpha/sigma)``, ``h_i = lambda_{i+1} - lambda_i``,
  ``r = h_{i-1}/h_i`` and ``m2 = h_i / (2 h_{i-1})``,

      x_{i+1} = (sigma_{i+1}/sigma_i) x_i
                - alpha_{i+1} (e^{-h_i} - 1) [(1+m2) x0_i - m2 x0_{i-1}]

  with ``m2 = 0`` on the first step (no history) and the final step
  (lower-order-final: the final sigma is 0, h = inf, and
  ``expm1(-inf) = -1`` makes the transfer land exactly on the data
  prediction).  History = 1 previous x0.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

SOLVERS = ("ddim", "plms", "dpm2m")
# timestep spacing over the training trajectory (SamplerPolicy.schedule)
SCHEDULES = ("uniform", "karras")
KARRAS_RHO = 7.0
# per-row solver family ids inside the coefficient tables
SOLVER_ID = {name: i for i, name in enumerate(SOLVERS)}
# previous-step model outputs each family reads (eps for plms, x0 for dpm2m)
SOLVER_HISTORY = {"ddim": 0, "plms": 3, "dpm2m": 1}

# Adams–Bashforth eps-combination weights by available history length
# (PNDM's warmup orders); row h weighs [eps_t, eps_{t-1}, eps_{t-2}, eps_{t-3}]
PLMS_WEIGHTS = (
    (1.0, 0.0, 0.0, 0.0),
    (3.0 / 2.0, -1.0 / 2.0, 0.0, 0.0),
    (23.0 / 12.0, -16.0 / 12.0, 5.0 / 12.0, 0.0),
    (55.0 / 24.0, -59.0 / 24.0, 37.0 / 24.0, -9.0 / 24.0),
)


def _triple(val, kind=float) -> tuple:
    t = tuple(val)
    if len(t) != 3:
        raise ValueError(f"phase schedules have 3 phases, got {val!r}")
    return tuple(kind(v) for v in t)


@dataclasses.dataclass(frozen=True)
class PhaseSchedule:
    """Per-phase policy overrides over the denoising trajectory.

    Phases follow SD-Acc's structure -> content -> detail split:
    ``boundaries`` are budget fractions — step ``i`` of an ``n``-step
    trajectory is in phase 0 while ``i < ceil(b0*n)``, phase 1 while
    ``i < ceil(b1*n)``, else phase 2.

    ``tips_on`` replaces the default ``tips_active_iters`` schedule with
    per-phase TIPS activity (paper Fig. 9(b): the detail phase is
    quantization-vulnerable, hence the ``(True, True, False)`` default).
    The ``*_scale`` triples MULTIPLY the static policy thresholds
    (``UNetConfig.pssa_threshold``, ``PrecisionPolicy.threshold``,
    ``ReusePolicy.threshold``) per phase; scales are resolved per row
    per step from the solver tables, so they never enter an executable
    cache key (DESIGN.md §10 cache-key rules).  ``tips_scale`` applies
    to fixed spotting only (adaptive spotting targets a ratio, not a
    threshold).
    """
    boundaries: Tuple[float, float] = (0.4, 0.8)
    tips_on: Tuple[bool, bool, bool] = (True, True, False)
    pssa_scale: Tuple[float, float, float] = (1.0, 1.0, 1.0)
    tips_scale: Tuple[float, float, float] = (1.0, 1.0, 1.0)
    reuse_scale: Tuple[float, float, float] = (1.0, 1.0, 1.0)

    def __post_init__(self):
        b0, b1 = self.boundaries
        if not 0.0 <= b0 <= b1 <= 1.0:
            raise ValueError(
                f"PhaseSchedule.boundaries={self.boundaries}: expected "
                f"0 <= b0 <= b1 <= 1")
        for fname in ("pssa_scale", "tips_scale", "reuse_scale"):
            if any(s <= 0.0 for s in getattr(self, fname)):
                raise ValueError(
                    f"PhaseSchedule.{fname}={getattr(self, fname)}: "
                    f"threshold scales must be > 0")

    # -- presets ---------------------------------------------------------
    @classmethod
    def detail_guard(cls) -> "PhaseSchedule":
        """Mirror the paper's late-iteration guard, generalized per phase:
        TIPS off in the detail phase (quantization-vulnerable), PSSA
        pruned harder while features are coarse, reuse threshold relaxed
        mid-trajectory (content phase changes slowly between steps)."""
        return cls(tips_on=(True, True, False),
                   pssa_scale=(2.0, 2.0, 1.0),
                   reuse_scale=(1.0, 2.0, 1.0))

    @classmethod
    def parse(cls, spec: str) -> "PhaseSchedule":
        """``"detail_guard"`` or ``key=v0:v1[:v2]`` items, e.g.
        ``"boundaries=0.3:0.8,pssa=2:2:1,tips=on:on:off"``."""
        spec = spec.strip()
        if spec in ("detail_guard", "default"):
            return (cls.detail_guard() if spec == "detail_guard" else cls())
        fields = {}
        for item in filter(None, (s.strip() for s in spec.split(","))):
            if "=" not in item:
                raise ValueError(
                    f"phase spec {item!r}: expected key=v0:v1[:v2] or "
                    f"'detail_guard'")
            key, val = (s.strip() for s in item.split("=", 1))
            parts = val.split(":")
            if key == "boundaries":
                if len(parts) != 2:
                    raise ValueError(
                        f"phase spec: boundaries={val!r} (expected b0:b1)")
                fields["boundaries"] = (float(parts[0]), float(parts[1]))
            elif key == "tips":
                fields["tips_on"] = _triple(
                    (p.lower() in ("on", "true", "1") for p in parts), bool)
            elif key in ("pssa", "tips_scale", "reuse"):
                name = {"pssa": "pssa_scale", "tips_scale": "tips_scale",
                        "reuse": "reuse_scale"}[key]
                fields[name] = _triple((float(p) for p in parts))
            else:
                raise ValueError(
                    f"phase spec: unknown key {key!r} (expected boundaries, "
                    f"tips, pssa, tips_scale or reuse)")
        return cls(**fields)

    # -- views -----------------------------------------------------------
    def phase_of(self, i: int, num_steps: int) -> int:
        """Which phase step ``i`` of an ``num_steps`` trajectory is in."""
        b0, b1 = self.boundaries
        if i < math.ceil(b0 * num_steps):
            return 0
        if i < math.ceil(b1 * num_steps):
            return 1
        return 2

    @property
    def schedules_pssa(self) -> bool:
        return self.pssa_scale != (1.0, 1.0, 1.0)

    @property
    def schedules_tips_threshold(self) -> bool:
        return self.tips_scale != (1.0, 1.0, 1.0)

    @property
    def schedules_reuse(self) -> bool:
        return self.reuse_scale != (1.0, 1.0, 1.0)

    def describe(self) -> dict:
        return {"boundaries": list(self.boundaries),
                "tips_on": list(self.tips_on),
                "pssa_scale": list(self.pssa_scale),
                "tips_scale": list(self.tips_scale),
                "reuse_scale": list(self.reuse_scale)}


@dataclasses.dataclass(frozen=True)
class SamplerPolicy:
    """Frozen/hashable per-request sampling decision.

    ``name`` is a display label (tier name in traces); it is excluded
    from equality/hash so renaming a tier can never fork an executable
    cache entry.

    ``schedule`` picks how the budget's timesteps are spaced over the
    training trajectory: ``"uniform"`` (the legacy equispaced grid —
    byte-identical tables to the pre-schedule code) or ``"karras"``
    (the rho=7 sigma ramp of Karras et al. 2022, snapped to the nearest
    discrete training timesteps so ``alphas_cumprod`` gathers stay
    exact).  The schedule only changes WHICH (t, t_prev) boundaries the
    tables hold; every solver family consumes them unchanged.
    """
    solver: str = "ddim"
    num_steps: int = 25
    phases: Optional[PhaseSchedule] = None
    schedule: str = "uniform"
    name: str = dataclasses.field(default="", compare=False)

    def __post_init__(self):
        if self.solver not in SOLVERS:
            raise ValueError(
                f"SamplerPolicy.solver={self.solver!r}: expected one of "
                f"{SOLVERS}")
        if self.num_steps < 1:
            raise ValueError(
                f"SamplerPolicy.num_steps={self.num_steps}: expected >= 1")
        if self.schedule not in SCHEDULES:
            raise ValueError(
                f"SamplerPolicy.schedule={self.schedule!r}: expected one "
                f"of {SCHEDULES}")

    # -- presets / tiers -------------------------------------------------
    @classmethod
    def ddim(cls, num_steps: int = 25, **kw) -> "SamplerPolicy":
        return cls(solver="ddim", num_steps=num_steps, **kw)

    @classmethod
    def dpm2m(cls, num_steps: int = 12, **kw) -> "SamplerPolicy":
        return cls(solver="dpm2m", num_steps=num_steps, **kw)

    @classmethod
    def plms(cls, num_steps: int = 12, **kw) -> "SamplerPolicy":
        return cls(solver="plms", num_steps=num_steps, **kw)

    @classmethod
    def tier(cls, name: str) -> "SamplerPolicy":
        """Quality-tier presets for serving admission."""
        try:
            return TIERS[name]
        except KeyError:
            raise ValueError(
                f"unknown quality tier {name!r}: expected one of "
                f"{tuple(TIERS)}") from None

    @classmethod
    def parse(cls, spec: str) -> "SamplerPolicy":
        """CLI spec: a tier name (``draft`` | ``balanced`` | ``quality``),
        a solver name, or a comma list with ``steps=N`` /
        ``schedule=uniform|karras`` /
        ``phases=<PhaseSchedule spec with ; separators>`` overrides,
        e.g. ``"dpm2m,steps=10,schedule=karras,phases=detail_guard"``."""
        spec = spec.strip()
        if spec in TIERS:
            return TIERS[spec]
        solver = None
        fields: dict = {}
        for item in filter(None, (s.strip() for s in spec.split(","))):
            if item in SOLVERS:
                solver = item
                continue
            if "=" not in item:
                raise ValueError(
                    f"sampler spec {item!r}: expected a tier in "
                    f"{tuple(TIERS)}, a solver in {SOLVERS} or key=value")
            key, val = (s.strip() for s in item.split("=", 1))
            if key == "steps":
                fields["num_steps"] = int(val)
            elif key == "solver":
                solver = val
            elif key == "schedule":
                fields["schedule"] = val
            elif key == "phases":
                fields["phases"] = PhaseSchedule.parse(val.replace(";", ","))
            elif key == "name":
                fields["name"] = val
            else:
                raise ValueError(
                    f"sampler spec: unknown key {key!r} (expected steps, "
                    f"solver, schedule, phases or name)")
        base = cls() if solver is None else cls(solver=solver)
        return dataclasses.replace(base, **fields) if fields else base

    # -- views -----------------------------------------------------------
    @property
    def solver_id(self) -> int:
        return SOLVER_ID[self.solver]

    @property
    def history(self) -> int:
        """Previous model outputs this solver reads (the hist depth)."""
        return SOLVER_HISTORY[self.solver]

    def key(self) -> str:
        """Stable short label (bank dict keys, bench records)."""
        base = f"{self.solver}-{self.num_steps}"
        return base if self.schedule == "uniform" else \
            f"{base}-{self.schedule}"

    def label(self) -> str:
        return self.name or self.key()

    def describe(self) -> dict:
        return {"solver": self.solver, "num_steps": self.num_steps,
                "schedule": self.schedule, "name": self.label(),
                "phases": (None if self.phases is None
                           else self.phases.describe())}


TIERS = {
    "draft": SamplerPolicy(solver="dpm2m", num_steps=8, name="draft"),
    "balanced": SamplerPolicy(solver="dpm2m", num_steps=12, name="balanced"),
    "quality": SamplerPolicy(solver="ddim", num_steps=25, name="quality"),
}


# ----------------------------------------------------------------------------
# Bank views (a bank = static tuple of distinct SamplerPolicies)
# ----------------------------------------------------------------------------
def as_bank(policies) -> tuple:
    """Normalize to a hashable bank tuple; validates emptiness."""
    bank = (policies,) if isinstance(policies, SamplerPolicy) \
        else tuple(policies)
    if not bank:
        raise ValueError("sampler bank is empty")
    for p in bank:
        if not isinstance(p, SamplerPolicy):
            raise TypeError(f"bank entries must be SamplerPolicy, got "
                            f"{type(p).__name__}")
    return bank


def bank_max_steps(bank) -> int:
    return max(p.num_steps for p in bank)


def bank_history(bank) -> int:
    """Static hist depth of the slot buffer: the bank's worst case."""
    return max(p.history for p in bank)


def bank_schedules(bank) -> tuple:
    """(pssa, tips_threshold, reuse) — which override lanes are live.

    Static booleans derived from the bank, so an unscheduled bank traces
    the exact legacy UNet call (no override operands, no kernel-routing
    downgrades) and its executables stay bit-compatible.
    """
    ph = [p.phases for p in bank if p.phases is not None]
    return (any(s.schedules_pssa for s in ph),
            any(s.schedules_tips_threshold for s in ph),
            any(s.schedules_reuse for s in ph))


def tips_active_schedule(policy: SamplerPolicy, ddim_cfg) -> tuple:
    """Host-side per-step TIPS activity for one policy.

    Without phases this scales the config's ``tips_active_iters``
    operating point to the policy's budget (exactly ``i <
    tips_active_iters`` when the budget matches the config — the legacy
    schedule, bit-for-bit); with phases it is the per-phase activity.
    """
    n = policy.num_steps
    if policy.phases is not None:
        return tuple(bool(policy.phases.tips_on[policy.phases.phase_of(i, n)])
                     for i in range(n))
    if n == ddim_cfg.num_inference_steps:
        active = ddim_cfg.tips_active_iters
    else:
        active = max(1, n * ddim_cfg.tips_active_iters
                     // ddim_cfg.num_inference_steps)
    return tuple(i < active for i in range(n))


def phase_index_schedule(policy: SamplerPolicy) -> tuple:
    """Host-side per-step phase index (0/1/2) for one policy.

    Policies without a schedule still have well-defined phases (the
    default boundaries) — the ledger's per-(phase, layer) breakdown
    groups buckets by this.
    """
    ph = policy.phases if policy.phases is not None else PhaseSchedule()
    return tuple(ph.phase_of(i, policy.num_steps)
                 for i in range(policy.num_steps))


def _scale_schedule(policy: SamplerPolicy, field: str) -> tuple:
    ph = policy.phases
    if ph is None:
        return (1.0,) * policy.num_steps
    scales = getattr(ph, field)
    return tuple(float(scales[ph.phase_of(i, policy.num_steps)])
                 for i in range(policy.num_steps))


# ----------------------------------------------------------------------------
# Per-(policy, step) coefficient tables
# ----------------------------------------------------------------------------
class SolverTables(NamedTuple):
    """(P, N) gather tables (N = bank max budget; padded rows repeat the
    final step — per-row step clipping means padding is never read)."""
    t: jax.Array            # (P, N) int32 UNet timesteps
    a_t: jax.Array          # (P, N) f32 alphas_cumprod[t]
    a_prev: jax.Array       # (P, N) f32 alphas_cumprod at the next boundary
    c_lat: jax.Array        # (P, N) f32 dpm2m latent carry (sigma ratio)
    c_d: jax.Array          # (P, N) f32 dpm2m data-prediction coefficient
    m2: jax.Array           # (P, N) f32 dpm2m second-order weight
    tips: jax.Array         # (P, N) bool per-step TIPS activity
    pssa_scale: jax.Array   # (P, N) f32 phase threshold scales
    tips_scale: jax.Array   # (P, N) f32
    reuse_scale: jax.Array  # (P, N) f32
    solver: jax.Array       # (P,) int32 family id
    budget: jax.Array       # (P,) int32 per-policy step budget


def _pad_last(vals: list, n: int) -> list:
    return list(vals) + [vals[-1]] * (n - len(vals))


def solver_tables(bank, ddim_cfg) -> SolverTables:
    """Build the bank's coefficient tables (trace-time jnp constants).

    The DDIM columns are computed with the SAME jnp float32 chain the
    legacy path uses (``alphas_cumprod`` gathers, the same ``where`` for
    the final boundary), so a per-row gather from these tables feeds
    ``ddim_transfer`` values bit-identical to ``sampler.ddim_step``.
    """
    from repro.diffusion.sampler import alphas_cumprod  # lazy: no cycle

    bank = as_bank(bank)
    n_max = bank_max_steps(bank)
    acp = alphas_cumprod(ddim_cfg)
    rows: dict = {f: [] for f in SolverTables._fields if f not in
                  ("solver", "budget")}
    for p in bank:
        n = p.num_steps
        if p.schedule == "karras":
            # Karras et al. 2022 rho-ramp over sigma = sqrt((1-a)/a),
            # snapped to the nearest DISCRETE training timestep so the
            # a_t/a_prev gathers below stay exact alphas_cumprod values
            # (no interpolated alphas — the bit-identity contracts rely
            # on gathered table entries).  t_prev chains the selected
            # timesteps; -1 marks the final boundary (a_prev = 1).
            all_sigmas = jnp.sqrt((1.0 - acp) / acp)
            inv_rho = 1.0 / KARRAS_RHO
            s_max, s_min = all_sigmas[-1], all_sigmas[0]
            ramp = jnp.linspace(0.0, 1.0, n)
            sigmas = (s_max ** inv_rho
                      + ramp * (s_min ** inv_rho - s_max ** inv_rho)
                      ) ** KARRAS_RHO
            ts = jnp.argmin(
                jnp.abs(all_sigmas[None, :] - sigmas[:, None]),
                axis=1).astype(jnp.int32)
            t_prev = jnp.concatenate(
                [ts[1:], jnp.asarray([-1], jnp.int32)])
        else:
            step = ddim_cfg.num_train_steps // n
            ts = jnp.arange(n - 1, -1, -1) * step
            t_prev = ts - step
        a_t = acp[ts]
        a_prev = jnp.where(t_prev >= 0, acp[jnp.maximum(t_prev, 0)], 1.0)
        # DPM-Solver++(2M) exponential-integrator coefficients
        alpha_c, sigma_c = jnp.sqrt(a_t), jnp.sqrt(1.0 - a_t)
        alpha_n, sigma_n = jnp.sqrt(a_prev), jnp.sqrt(1.0 - a_prev)
        lam_c = jnp.log(alpha_c / sigma_c)
        lam_n = jnp.log(alpha_n / sigma_n)       # +inf at the final boundary
        h = lam_n - lam_c
        c_lat = sigma_n / sigma_c                # 0 at the final boundary
        c_d = -alpha_n * jnp.expm1(-h)           # alpha_n at the final step
        first_or_last = (jnp.arange(n) == 0) | (jnp.arange(n) == n - 1)
        m2 = jnp.where(first_or_last, 0.0,
                       h / (2.0 * jnp.concatenate([h[:1], h[:-1]])))
        rows["t"].append(_pad_last(list(jnp.asarray(ts, jnp.int32)), n_max))
        for name, arr in (("a_t", a_t), ("a_prev", a_prev),
                          ("c_lat", c_lat), ("c_d", c_d), ("m2", m2)):
            rows[name].append(_pad_last(list(arr), n_max))
        rows["tips"].append(_pad_last(
            list(tips_active_schedule(p, ddim_cfg)), n_max))
        for name, field in (("pssa_scale", "pssa_scale"),
                            ("tips_scale", "tips_scale"),
                            ("reuse_scale", "reuse_scale")):
            rows[name].append(_pad_last(
                list(_scale_schedule(p, field)), n_max))
    stack = {name: jnp.stack([jnp.asarray(
        r, jnp.int32 if name == "t" else
        bool if name == "tips" else jnp.float32) for r in vals])
        for name, vals in rows.items()}
    return SolverTables(
        solver=jnp.asarray([p.solver_id for p in bank], jnp.int32),
        budget=jnp.asarray([p.num_steps for p in bank], jnp.int32),
        **stack)


class PhaseOverrides(NamedTuple):
    """Per-row threshold scales resolved from the tables for one step.

    Each lane is ``None`` (bank never schedules it — the UNet call is
    the exact legacy trace) or a (B,) float32 of multiplicative scales
    on the static policy thresholds.  The UNet threads these down to
    the dispatch layer (``repro.kernels.dispatch``).
    """
    pssa_scale: Optional[jax.Array] = None
    tips_scale: Optional[jax.Array] = None
    reuse_scale: Optional[jax.Array] = None


def gather_overrides(tables: SolverTables, bank, policy_id, idx
                     ) -> Optional[PhaseOverrides]:
    """Per-row override scales for the rows' current steps (or None)."""
    sched_pssa, sched_tips, sched_reuse = bank_schedules(bank)
    if not (sched_pssa or sched_tips or sched_reuse):
        return None
    return PhaseOverrides(
        pssa_scale=(tables.pssa_scale[policy_id, idx] if sched_pssa
                    else None),
        tips_scale=(tables.tips_scale[policy_id, idx] if sched_tips
                    else None),
        reuse_scale=(tables.reuse_scale[policy_id, idx] if sched_reuse
                     else None))


# ----------------------------------------------------------------------------
# The generalized per-row solver update
# ----------------------------------------------------------------------------
def ddim_transfer(latents, eps, a_t, a_prev):
    """The deterministic DDIM (eta=0) transfer, coefficients pre-gathered.

    Shared by the legacy ``sampler.ddim_step`` and every banked solver
    candidate (PLMS applies it to the multistep eps combination), so the
    arithmetic literally cannot drift between paths.
    """
    x0 = (latents - jnp.sqrt(1.0 - a_t) * eps) / jnp.sqrt(a_t)
    return jnp.sqrt(a_prev) * x0 + jnp.sqrt(1.0 - a_prev) * eps


def init_history(bank, batch: int, latent_shape) -> jax.Array:
    """(B, H, *latent) zeroed solver history (H may be 0: ddim-only)."""
    h = bank_history(as_bank(bank))
    return jnp.zeros((batch, h) + tuple(latent_shape), jnp.float32)


def solver_update(latents, eps, hist, tables: SolverTables, bank,
                  policy_id, idx):
    """One per-row solver step: (new_latents, new_hist).

    ``idx`` is the (B,) CLIPPED step index, ``hist`` the (B, H, ...)
    newest-first model-output history (eps for plms rows, x0 for dpm2m
    rows — selected per row on write, so a row's buffer always holds
    what ITS solver reads).  Candidate updates are computed elementwise
    for every family present in the bank (static set) and selected per
    row — each row's arithmetic is identical to a single-policy run of
    its own (solver, steps) pair, which is the mixed-tier bit-identity
    contract.
    """
    bank = as_bank(bank)
    fams = {p.solver for p in bank}
    b = latents.shape[0]
    shape = (b,) + (1,) * (latents.ndim - 1)
    a_t = tables.a_t[policy_id, idx].reshape(shape)
    a_prev = tables.a_prev[policy_id, idx].reshape(shape)
    hmax = bank_history(bank)

    cands: dict = {}
    store: dict = {}
    if "ddim" in fams:
        cands["ddim"] = ddim_transfer(latents, eps, a_t, a_prev)
        store["ddim"] = eps                   # never read (history 0)
    if "plms" in fams:
        w = jnp.asarray(PLMS_WEIGHTS, jnp.float32)[jnp.minimum(idx, 3)]
        eps_lin = w[:, 0].reshape(shape) * eps
        for j in range(min(3, hmax)):
            eps_lin = eps_lin + w[:, j + 1].reshape(shape) * hist[:, j]
        cands["plms"] = ddim_transfer(latents, eps_lin, a_t, a_prev)
        store["plms"] = eps
    if "dpm2m" in fams:
        alpha_c, sigma_c = jnp.sqrt(a_t), jnp.sqrt(1.0 - a_t)
        x0 = (latents - sigma_c * eps) / alpha_c
        m2 = tables.m2[policy_id, idx].reshape(shape)
        x0_prev = hist[:, 0] if hmax >= 1 else jnp.zeros_like(x0)
        d = (1.0 + m2) * x0 - m2 * x0_prev
        cands["dpm2m"] = (tables.c_lat[policy_id, idx].reshape(shape)
                          * latents
                          + tables.c_d[policy_id, idx].reshape(shape) * d)
        store["dpm2m"] = x0

    if len(fams) == 1:
        fam = next(iter(fams))
        new_lat, stored = cands[fam], store[fam]
    else:
        solver = tables.solver[policy_id].reshape(shape)
        names = [f for f in SOLVERS if f in fams]
        new_lat, stored = cands[names[0]], store[names[0]]
        for fam in names[1:]:
            sel = solver == SOLVER_ID[fam]
            new_lat = jnp.where(sel, cands[fam], new_lat)
            stored = jnp.where(sel, store[fam], stored)

    if hmax > 0:
        new_hist = jnp.concatenate(
            [stored[:, None], hist[:, :hmax - 1]], axis=1)
    else:
        new_hist = hist
    return new_lat, new_hist
