"""DDIM sampler — 25 denoising iterations (the paper's operating point).

Deterministic DDIM (eta = 0) over a linear-beta DDPM schedule, with optional
classifier-free guidance.  TIPS is active for the first 20 of the 25
iterations (paper Fig. 9(b)): the last 5 are quantization-vulnerable and run
full INT12 — the sampler passes ``tips_active`` per step.

Two interchangeable loop implementations:

``sample``       — the seed's Python loop (25 dispatches, two UNet calls per
                   step under CFG).  Kept as the parity/reference path: its
                   per-iteration stats list is the ground truth the scanned
                   path is tested against.
``sample_scan``  — all 25 steps inside one ``jax.lax.scan`` with
                   ``tips_active`` as a per-step traced array, and cond +
                   uncond CFG fused into ONE batched UNet call (concatenate
                   along batch, split after).  Halves dispatch count, makes
                   the whole loop jittable (the ``DiffusionEngine`` wraps
                   encode -> scan -> decode in a single ``jax.jit``), and
                   returns the stats trajectory as a stacked ``UNetStats``
                   pytree (leading axis = iterations).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.tips import TIPS_ACTIVE_ITERS


@dataclasses.dataclass(frozen=True)
class DDIMConfig:
    num_train_steps: int = 1000
    num_inference_steps: int = 25        # paper: 25 UNet iterations
    beta_start: float = 0.00085
    beta_end: float = 0.012
    guidance_scale: float = 7.5
    tips_active_iters: int = TIPS_ACTIVE_ITERS


def alphas_cumprod(cfg: DDIMConfig):
    betas = jnp.linspace(cfg.beta_start ** 0.5, cfg.beta_end ** 0.5,
                         cfg.num_train_steps) ** 2
    return jnp.cumprod(1.0 - betas)


def timestep_schedule(cfg: DDIMConfig):
    """Descending DDIM timesteps, e.g. [960, 920, ..., 0] for 25 steps."""
    step = cfg.num_train_steps // cfg.num_inference_steps
    return jnp.arange(cfg.num_inference_steps - 1, -1, -1) * step


def ddim_step(latents, eps, t, t_prev, acp):
    """One deterministic DDIM update (eta = 0)."""
    a_t = acp[t]
    a_prev = jnp.where(t_prev >= 0, acp[jnp.maximum(t_prev, 0)], 1.0)
    x0 = (latents - jnp.sqrt(1.0 - a_t) * eps) / jnp.sqrt(a_t)
    return jnp.sqrt(a_prev) * x0 + jnp.sqrt(1.0 - a_prev) * eps


def cfg_batch(latents, context, uncond_context):
    """Fuse cond + uncond into one batch: (B,...) -> (2B,...).

    Row layout is [cond | uncond] along the leading axis; undo with
    ``jnp.split(eps, 2)``.  Each half attends to its own context, so the
    fused call is arithmetically identical to two separate calls.
    """
    lat2 = jnp.concatenate([latents, latents], axis=0)
    ctx2 = jnp.concatenate([context, uncond_context], axis=0)
    return lat2, ctx2


def guided_eps(eps_fused, guidance_scale):
    """Split a fused [cond | uncond] eps and apply CFG."""
    eps_c, eps_u = jnp.split(eps_fused, 2, axis=0)
    return eps_u + guidance_scale * (eps_c - eps_u)


def sample(unet_apply, latents, context, uncond_context, cfg: DDIMConfig,
           collect_stats: bool = False):
    """Run the denoising loop as 25 Python-level dispatches (seed path).

    ``unet_apply(latents, timesteps, context, tips_active)`` -> (eps, stats).
    Kept for per-step inspectability and as the reference the scanned
    implementation is verified against (tests/test_engine.py).
    """
    acp = alphas_cumprod(cfg)
    ts = timestep_schedule(cfg)
    step = cfg.num_train_steps // cfg.num_inference_steps
    all_stats = []
    for i in range(cfg.num_inference_steps):
        t = ts[i]
        tips_active = i < cfg.tips_active_iters
        b = latents.shape[0]
        tvec = jnp.full((b,), t, jnp.int32)
        eps_c, stats = unet_apply(latents, tvec, context, tips_active)
        if cfg.guidance_scale != 1.0 and uncond_context is not None:
            eps_u, _ = unet_apply(latents, tvec, uncond_context, tips_active)
            eps = eps_u + cfg.guidance_scale * (eps_c - eps_u)
        else:
            eps = eps_c
        latents = ddim_step(latents, eps, t, t - step, acp)
        if collect_stats:
            all_stats.append(stats)
    return latents, all_stats


def sample_scan(unet_apply, latents, context, uncond_context,
                cfg: DDIMConfig, stats_rows=None):
    """Run all denoising steps inside one ``jax.lax.scan``.

    Per-step traced inputs (xs): the DDIM timestep and the TIPS activity
    flag.  Under CFG the cond and uncond UNet evaluations are fused into a
    single batched call per step with the shared prefix deduplicated, and
    ``unet_apply`` must accept static ``stats_rows`` and ``cfg_dup``
    keywords (``repro.diffusion.unet.unet_forward`` does) — stats
    restricted to the cond rows, latents carrying only the cond half.
    ``stats_rows`` (static) further restricts the PSSA/TIPS accounting to
    the first N batch rows — the serving front-end sets it to the valid
    (non-padded) row count of a tail micro-batch so padded duplicate rows
    never leak into the energy ledger.
    Returns ``(latents,
    stacked_stats)`` where ``stacked_stats`` is a ``UNetStats`` whose
    leaves carry a leading ``num_inference_steps`` axis; reconstruct the
    per-step view with ``stacked_stats.step(i)`` / ``.unstack()``.
    """
    acp = alphas_cumprod(cfg)
    ts = timestep_schedule(cfg)
    step = cfg.num_train_steps // cfg.num_inference_steps
    n = cfg.num_inference_steps
    tips_flags = jnp.arange(n) < cfg.tips_active_iters

    use_cfg = cfg.guidance_scale != 1.0 and uncond_context is not None
    if use_cfg:
        ctx_fused = jnp.concatenate([context, uncond_context], axis=0)
    b = latents.shape[0]
    if stats_rows is not None and not (0 < stats_rows <= b):
        raise ValueError(f"stats_rows={stats_rows} outside [1, {b}]")

    def body(lat, xs):
        t, active = xs
        if use_cfg:
            tvec = jnp.full((b,), t, jnp.int32)
            # cfg_dup: latents stay at b rows — the UNet tiles the hidden
            # state to [cond | uncond] at the first cross-attention (the
            # halves are identical before it).  stats_rows defaults to b:
            # PSSA/TIPS accounted on the cond half only — the ledger never
            # consumes uncond stats (the two-call reference path computes
            # and discards them; the fused path skips them).
            rows = b if stats_rows is None else stats_rows
            eps_fused, stats = unet_apply(lat, tvec, ctx_fused, active,
                                          stats_rows=rows, cfg_dup=True)
            eps = guided_eps(eps_fused, cfg.guidance_scale)
        else:
            tvec = jnp.full((b,), t, jnp.int32)
            eps, stats = unet_apply(lat, tvec, context, active,
                                    stats_rows=stats_rows)
        lat = ddim_step(lat, eps, t, t - step, acp)
        return lat, stats

    latents, stacked = jax.lax.scan(body, latents, (ts, tips_flags))
    return latents, stacked
