"""DDIM sampler — 25 denoising iterations (the paper's operating point).

Deterministic DDIM (eta = 0) over a linear-beta DDPM schedule, with optional
classifier-free guidance.  TIPS is active for the first 20 of the 25
iterations (paper Fig. 9(b)): the last 5 are quantization-vulnerable and run
full INT12 — the sampler passes ``tips_active`` per step.

Two interchangeable loop implementations:

``sample``       — the seed's Python loop (25 dispatches, two UNet calls per
                   step under CFG).  Kept as the parity/reference path: its
                   per-iteration stats list is the ground truth the scanned
                   path is tested against.
``sample_scan``  — all 25 steps inside one ``jax.lax.scan`` with
                   ``tips_active`` as a per-step traced array, and cond +
                   uncond CFG fused into ONE batched UNet call (concatenate
                   along batch, split after).  Halves dispatch count, makes
                   the whole loop jittable (the ``DiffusionEngine`` wraps
                   encode -> scan -> decode in a single ``jax.jit``), and
                   returns the stats trajectory as a stacked ``UNetStats``
                   pytree (leading axis = iterations).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.tips import TIPS_ACTIVE_ITERS


@dataclasses.dataclass(frozen=True)
class DDIMConfig:
    num_train_steps: int = 1000
    num_inference_steps: int = 25        # paper: 25 UNet iterations
    beta_start: float = 0.00085
    beta_end: float = 0.012
    guidance_scale: float = 7.5
    tips_active_iters: int = TIPS_ACTIVE_ITERS


def alphas_cumprod(cfg: DDIMConfig):
    betas = jnp.linspace(cfg.beta_start ** 0.5, cfg.beta_end ** 0.5,
                         cfg.num_train_steps) ** 2
    return jnp.cumprod(1.0 - betas)


def timestep_schedule(cfg: DDIMConfig):
    """Descending DDIM timesteps, e.g. [960, 920, ..., 0] for 25 steps."""
    step = cfg.num_train_steps // cfg.num_inference_steps
    return jnp.arange(cfg.num_inference_steps - 1, -1, -1) * step


def ddim_step(latents, eps, t, t_prev, acp):
    """One deterministic DDIM update (eta = 0).

    ``t`` / ``t_prev`` are a scalar timestep (whole batch on one schedule)
    or (B,) per-row timesteps — continuous batching runs each slot at its
    own denoising iteration, so the alphas are gathered per row and
    broadcast over the spatial axes.  Per-row values equal to the scalar
    produce bit-identical updates (same elementwise arithmetic).
    """
    a_t = acp[t]
    a_prev = jnp.where(t_prev >= 0, acp[jnp.maximum(t_prev, 0)], 1.0)
    if jnp.ndim(a_t) == 1:
        shape = (latents.shape[0],) + (1,) * (latents.ndim - 1)
        a_t, a_prev = a_t.reshape(shape), a_prev.reshape(shape)
    x0 = (latents - jnp.sqrt(1.0 - a_t) * eps) / jnp.sqrt(a_t)
    return jnp.sqrt(a_prev) * x0 + jnp.sqrt(1.0 - a_prev) * eps


def cfg_batch(latents, context, uncond_context):
    """Fuse cond + uncond into one batch: (B,...) -> (2B,...).

    Row layout is [cond | uncond] along the leading axis; undo with
    ``jnp.split(eps, 2)``.  Each half attends to its own context, so the
    fused call is arithmetically identical to two separate calls.
    """
    lat2 = jnp.concatenate([latents, latents], axis=0)
    ctx2 = jnp.concatenate([context, uncond_context], axis=0)
    return lat2, ctx2


def guided_eps(eps_fused, guidance_scale):
    """Split a fused [cond | uncond] eps and apply CFG."""
    eps_c, eps_u = jnp.split(eps_fused, 2, axis=0)
    return eps_u + guidance_scale * (eps_c - eps_u)


def sample(unet_apply, latents, context, uncond_context, cfg: DDIMConfig,
           collect_stats: bool = False):
    """Run the denoising loop as 25 Python-level dispatches (seed path).

    ``unet_apply(latents, timesteps, context, tips_active)`` -> (eps, stats).
    Kept for per-step inspectability and as the reference the scanned
    implementation is verified against (tests/test_engine.py).
    """
    acp = alphas_cumprod(cfg)
    ts = timestep_schedule(cfg)
    step = cfg.num_train_steps // cfg.num_inference_steps
    all_stats = []
    for i in range(cfg.num_inference_steps):
        t = ts[i]
        tips_active = i < cfg.tips_active_iters
        b = latents.shape[0]
        tvec = jnp.full((b,), t, jnp.int32)
        eps_c, stats = unet_apply(latents, tvec, context, tips_active)
        if cfg.guidance_scale != 1.0 and uncond_context is not None:
            eps_u, _ = unet_apply(latents, tvec, uncond_context, tips_active)
            eps = eps_u + cfg.guidance_scale * (eps_c - eps_u)
        else:
            eps = eps_c
        latents = ddim_step(latents, eps, t, t - step, acp)
        if collect_stats:
            all_stats.append(stats)
    return latents, all_stats


def denoise_step(unet_apply, latents, context, uncond_context, step_idx,
                cfg: DDIMConfig, stats_rows=None, active=None,
                row_stats: bool = False, reuse_cache=None):
    """ONE denoising update at PER-SLOT step indices (the scan body).

    ``step_idx`` is (B,) int32 — each batch row's DDIM iteration in
    ``[0, num_inference_steps)`` (a scalar is broadcast).  Rows may sit at
    *different* iterations: the DDIM alphas and the per-row TIPS activity
    flag are gathered per row, which is what lets a continuous-batching
    server interleave requests at heterogeneous steps in one batched UNet
    call.  With every row at the same index the arithmetic is elementwise
    identical to the homogeneous path, so ``sample_scan`` (whose scan body
    this is) produces bit-identical latents to the seed loop.

    Under CFG the cond and uncond UNet evaluations are fused into a single
    batched call with the shared prefix deduplicated; ``unet_apply`` must
    accept static ``stats_rows`` and ``cfg_dup`` keywords
    (``repro.diffusion.unet.unet_forward`` does) — stats restricted to the
    cond rows, latents carrying only the cond half.  ``stats_rows``
    (static) restricts the PSSA/TIPS accounting to the first N batch rows.

    ``active`` (B,) bool gates slot serving: inactive rows keep their
    latents unchanged (their UNet work is computed and discarded — the
    fixed-shape price of slot serving) and their step index is clipped
    into range; the CALLER must mask their stats out (``LedgerAccum``
    multiplies counters by the mask before the scatter).  ``row_stats``
    requests per-row integer counters (``SlotStats``) instead of folded
    stats; it is forwarded to ``unet_apply`` only when set, so legacy
    closures without the keyword keep working.

    ``reuse_cache`` (a ``core.reuse.ReuseCache``) threads the temporal
    patch-reuse reference through the UNet; ``unet_apply`` then returns a
    third element — the new cache — and so does this function:
    ``(latents, stats, new_cache)``.  Without it the two-tuple contract
    is unchanged.
    """
    acp = alphas_cumprod(cfg)
    ts = timestep_schedule(cfg)
    step = cfg.num_train_steps // cfg.num_inference_steps
    b = latents.shape[0]
    step_idx = jnp.asarray(step_idx, jnp.int32)
    if step_idx.ndim == 0:
        step_idx = jnp.full((b,), step_idx, jnp.int32)
    idx = jnp.clip(step_idx, 0, cfg.num_inference_steps - 1)
    t = ts[idx]                                   # (B,) per-row timesteps
    tips_vec = idx < cfg.tips_active_iters        # (B,) per-row TIPS flag
    kw = {"row_stats": True} if row_stats else {}
    if reuse_cache is not None:
        kw["reuse_cache"] = reuse_cache

    use_cfg = cfg.guidance_scale != 1.0 and uncond_context is not None
    if use_cfg:
        # cfg_dup: latents stay at b rows — the UNet tiles the hidden
        # state to [cond | uncond] at the first cross-attention (the
        # halves are identical before it).  stats_rows defaults to b:
        # PSSA/TIPS accounted on the cond half only — the ledger never
        # consumes uncond stats (the two-call reference path computes
        # and discards them; the fused path skips them).
        ctx_fused = jnp.concatenate([context, uncond_context], axis=0)
        rows = b if stats_rows is None else stats_rows
        out = unet_apply(latents, t, ctx_fused, tips_vec,
                         stats_rows=rows, cfg_dup=True, **kw)
    else:
        out = unet_apply(latents, t, context, tips_vec,
                         stats_rows=stats_rows, **kw)
    if reuse_cache is not None:
        eps, stats, new_cache = out
    else:
        eps, stats = out
        new_cache = None
    if use_cfg:
        eps = guided_eps(eps, cfg.guidance_scale)
    new_lat = ddim_step(latents, eps, t, t - step, acp)
    if active is not None:
        keep = active.reshape((b,) + (1,) * (latents.ndim - 1))
        new_lat = jnp.where(keep, new_lat, latents)
    if reuse_cache is not None:
        return new_lat, stats, new_cache
    return new_lat, stats


def sample_scan(unet_apply, latents, context, uncond_context,
                cfg: DDIMConfig, stats_rows=None):
    """Run all denoising steps inside one ``jax.lax.scan``.

    The scan body is :func:`denoise_step` with every row at the same step
    index — the same executable building block the continuous-batching
    engine (``DiffusionEngine.slot_step``) runs standalone with
    heterogeneous per-slot indices, so the two paths cannot drift.
    Under CFG the cond and uncond UNet evaluations are fused into a
    single batched call per step with the shared prefix deduplicated.
    ``stats_rows`` (static) restricts the PSSA/TIPS accounting to the
    first N batch rows — the serving front-end sets it to the valid
    (non-padded) row count of a tail micro-batch so padded duplicate rows
    never leak into the energy ledger.
    Returns ``(latents,
    stacked_stats)`` where ``stacked_stats`` is a ``UNetStats`` whose
    leaves carry a leading ``num_inference_steps`` axis; reconstruct the
    per-step view with ``stacked_stats.step(i)`` / ``.unstack()``.
    """
    n = cfg.num_inference_steps
    b = latents.shape[0]
    if stats_rows is not None and not (0 < stats_rows <= b):
        raise ValueError(f"stats_rows={stats_rows} outside [1, {b}]")

    def body(lat, i):
        return denoise_step(unet_apply, lat, context, uncond_context,
                            jnp.full((b,), i, jnp.int32), cfg,
                            stats_rows=stats_rows)

    latents, stacked = jax.lax.scan(body, latents, jnp.arange(n))
    return latents, stacked


def sample_scan_reuse(unet_apply, latents, context, uncond_context,
                      cfg: DDIMConfig, reuse_cache=None, stats_rows=None,
                      base_caches=None, record_caches: bool = False):
    """Scanned denoising loop with the temporal-reuse cache threaded.

    Two cache sources, mirroring the two ``ReusePolicy`` modes:

    * **temporal** — ``reuse_cache`` (typically all-invalid zeros from
      ``core.reuse.reuse_cache_zeros``) rides the scan carry: each step
      reuses the PREVIOUS step's activations.  ``record_caches=True``
      additionally stacks every step's emitted cache along a leading axis
      (the base-trace recorder for edit serving) and returns
      ``(latents, stats, caches)``.
    * **edit** — ``base_caches`` is such a recorded stack from a BASE
      request; step ``i`` reuses the base's step-``i`` activations
      (indexed from the stack, nothing carried), which is what makes
      ``capacity < 1`` safe: the reference is valid from step 0.

    Returns ``(latents, stacked_stats)`` (plus the recorded caches when
    asked); ``stacked_stats`` carries per-layer reuse counters.
    """
    n = cfg.num_inference_steps
    b = latents.shape[0]
    if stats_rows is not None and not (0 < stats_rows <= b):
        raise ValueError(f"stats_rows={stats_rows} outside [1, {b}]")
    if (reuse_cache is None) == (base_caches is None):
        raise ValueError(
            "pass exactly one of reuse_cache (temporal mode) or "
            "base_caches (edit mode)")

    if base_caches is not None:
        def body(lat, i):
            cache_i = jax.tree_util.tree_map(lambda x: x[i], base_caches)
            lat, stats, _ = denoise_step(
                unet_apply, lat, context, uncond_context,
                jnp.full((b,), i, jnp.int32), cfg, stats_rows=stats_rows,
                reuse_cache=cache_i)
            return lat, stats

        latents, stacked = jax.lax.scan(body, latents, jnp.arange(n))
        return latents, stacked

    def body(carry, i):
        lat, cache = carry
        lat, stats, cache = denoise_step(
            unet_apply, lat, context, uncond_context,
            jnp.full((b,), i, jnp.int32), cfg, stats_rows=stats_rows,
            reuse_cache=cache)
        ys = (stats, cache) if record_caches else stats
        return (lat, cache), ys

    (latents, _), ys = jax.lax.scan(body, (latents, reuse_cache),
                                    jnp.arange(n))
    if record_caches:
        stacked, caches = ys
        return latents, stacked, caches
    return latents, ys
