"""DDIM sampler — 25 denoising iterations (the paper's operating point).

Deterministic DDIM (eta = 0) over a linear-beta DDPM schedule, with optional
classifier-free guidance.  TIPS is active for the first 20 of the 25
iterations (paper Fig. 9(b)): the last 5 are quantization-vulnerable and run
full INT12 — the sampler passes ``tips_active`` per step.

Two interchangeable loop implementations:

``sample``       — the seed's Python loop (25 dispatches, two UNet calls per
                   step under CFG).  Kept as the parity/reference path: its
                   per-iteration stats list is the ground truth the scanned
                   path is tested against.
``sample_scan``  — all 25 steps inside one ``jax.lax.scan`` with
                   ``tips_active`` as a per-step traced array, and cond +
                   uncond CFG fused into ONE batched UNet call (concatenate
                   along batch, split after).  Halves dispatch count, makes
                   the whole loop jittable (the ``DiffusionEngine`` wraps
                   encode -> scan -> decode in a single ``jax.jit``), and
                   returns the stats trajectory as a stacked ``UNetStats``
                   pytree (leading axis = iterations).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.tips import TIPS_ACTIVE_ITERS
from repro.diffusion import solvers as solvers_mod


@dataclasses.dataclass(frozen=True)
class DDIMConfig:
    num_train_steps: int = 1000
    num_inference_steps: int = 25        # paper: 25 UNet iterations
    beta_start: float = 0.00085
    beta_end: float = 0.012
    guidance_scale: float = 7.5
    tips_active_iters: int = TIPS_ACTIVE_ITERS


def alphas_cumprod(cfg: DDIMConfig):
    betas = jnp.linspace(cfg.beta_start ** 0.5, cfg.beta_end ** 0.5,
                         cfg.num_train_steps) ** 2
    return jnp.cumprod(1.0 - betas)


def timestep_schedule(cfg: DDIMConfig):
    """Descending DDIM timesteps, e.g. [960, 920, ..., 0] for 25 steps."""
    step = cfg.num_train_steps // cfg.num_inference_steps
    return jnp.arange(cfg.num_inference_steps - 1, -1, -1) * step


def ddim_step(latents, eps, t, t_prev, acp):
    """One deterministic DDIM update (eta = 0).

    ``t`` / ``t_prev`` are a scalar timestep (whole batch on one schedule)
    or (B,) per-row timesteps — continuous batching runs each slot at its
    own denoising iteration, so the alphas are gathered per row and
    broadcast over the spatial axes.  Per-row values equal to the scalar
    produce bit-identical updates (same elementwise arithmetic).
    """
    a_t = acp[t]
    a_prev = jnp.where(t_prev >= 0, acp[jnp.maximum(t_prev, 0)], 1.0)
    if jnp.ndim(a_t) == 1:
        shape = (latents.shape[0],) + (1,) * (latents.ndim - 1)
        a_t, a_prev = a_t.reshape(shape), a_prev.reshape(shape)
    return solvers_mod.ddim_transfer(latents, eps, a_t, a_prev)


def cfg_batch(latents, context, uncond_context):
    """Fuse cond + uncond into one batch: (B,...) -> (2B,...).

    Row layout is [cond | uncond] along the leading axis; undo with
    ``jnp.split(eps, 2)``.  Each half attends to its own context, so the
    fused call is arithmetically identical to two separate calls.
    """
    lat2 = jnp.concatenate([latents, latents], axis=0)
    ctx2 = jnp.concatenate([context, uncond_context], axis=0)
    return lat2, ctx2


def guided_eps(eps_fused, guidance_scale):
    """Split a fused [cond | uncond] eps and apply CFG."""
    eps_c, eps_u = jnp.split(eps_fused, 2, axis=0)
    return eps_u + guidance_scale * (eps_c - eps_u)


def sample(unet_apply, latents, context, uncond_context, cfg: DDIMConfig,
           collect_stats: bool = False):
    """Run the denoising loop as 25 Python-level dispatches (seed path).

    ``unet_apply(latents, timesteps, context, tips_active)`` -> (eps, stats).
    Kept for per-step inspectability and as the reference the scanned
    implementation is verified against (tests/test_engine.py).
    """
    acp = alphas_cumprod(cfg)
    ts = timestep_schedule(cfg)
    step = cfg.num_train_steps // cfg.num_inference_steps
    all_stats = []
    for i in range(cfg.num_inference_steps):
        t = ts[i]
        tips_active = i < cfg.tips_active_iters
        b = latents.shape[0]
        tvec = jnp.full((b,), t, jnp.int32)
        eps_c, stats = unet_apply(latents, tvec, context, tips_active)
        if cfg.guidance_scale != 1.0 and uncond_context is not None:
            eps_u, _ = unet_apply(latents, tvec, uncond_context, tips_active)
            eps = eps_u + cfg.guidance_scale * (eps_c - eps_u)
        else:
            eps = eps_c
        latents = ddim_step(latents, eps, t, t - step, acp)
        if collect_stats:
            all_stats.append(stats)
    return latents, all_stats


def denoise_step(unet_apply, latents, context, uncond_context, step_idx,
                cfg: DDIMConfig, stats_rows=None, active=None,
                row_stats: bool = False, reuse_cache=None,
                bank=None, policy_id=None, solver_hist=None):
    """ONE denoising update at PER-SLOT step indices (the scan body).

    ``step_idx`` is (B,) int32 — each batch row's DDIM iteration in
    ``[0, num_inference_steps)`` (a scalar is broadcast).  Rows may sit at
    *different* iterations: the DDIM alphas and the per-row TIPS activity
    flag are gathered per row, which is what lets a continuous-batching
    server interleave requests at heterogeneous steps in one batched UNet
    call.  With every row at the same index the arithmetic is elementwise
    identical to the homogeneous path, so ``sample_scan`` (whose scan body
    this is) produces bit-identical latents to the seed loop.

    Under CFG the cond and uncond UNet evaluations are fused into a single
    batched call with the shared prefix deduplicated; ``unet_apply`` must
    accept static ``stats_rows`` and ``cfg_dup`` keywords
    (``repro.diffusion.unet.unet_forward`` does) — stats restricted to the
    cond rows, latents carrying only the cond half.  ``stats_rows``
    (static) restricts the PSSA/TIPS accounting to the first N batch rows.

    ``active`` (B,) bool gates slot serving: inactive rows keep their
    latents unchanged (their UNet work is computed and discarded — the
    fixed-shape price of slot serving) and their step index is clipped
    into range; the CALLER must mask their stats out (``LedgerAccum``
    multiplies counters by the mask before the scatter).  ``row_stats``
    requests per-row integer counters (``SlotStats``) instead of folded
    stats; it is forwarded to ``unet_apply`` only when set, so legacy
    closures without the keyword keep working.

    ``reuse_cache`` (a ``core.reuse.ReuseCache``) threads the temporal
    patch-reuse reference through the UNet; ``unet_apply`` then returns a
    third element — the new cache — and so does this function:
    ``(latents, stats, new_cache)``.  Without it the two-tuple contract
    is unchanged.

    ``bank`` (static tuple of ``solvers.SamplerPolicy``) switches the
    update to the generalized per-row solver path: ``policy_id`` (B,)
    int32 selects each row's policy, step indices clip to PER-ROW budgets,
    timesteps / TIPS activity / solver coefficients are gathered from the
    bank's ``SolverTables``, phase-schedule threshold scales (when any
    bank policy schedules them) are resolved per row and passed to
    ``unet_apply`` as ``overrides``, and multistep solver history rides
    ``solver_hist`` (B, H, ...).  The banked return contract is always a
    4-tuple ``(latents, stats, new_cache_or_None, new_hist)``.  With
    ``bank=None`` every legacy contract above is unchanged, op for op.
    """
    acp = alphas_cumprod(cfg)
    ts = timestep_schedule(cfg)
    step = cfg.num_train_steps // cfg.num_inference_steps
    b = latents.shape[0]
    step_idx = jnp.asarray(step_idx, jnp.int32)
    if step_idx.ndim == 0:
        step_idx = jnp.full((b,), step_idx, jnp.int32)
    if bank is not None:
        bank = solvers_mod.as_bank(bank)
        tables = solvers_mod.solver_tables(bank, cfg)
        if policy_id is None:
            policy_id = jnp.zeros((b,), jnp.int32)
        policy_id = jnp.asarray(policy_id, jnp.int32)
        if solver_hist is None:
            solver_hist = solvers_mod.init_history(bank, b, latents.shape[1:])
        idx = jnp.clip(step_idx, 0, tables.budget[policy_id] - 1)
        t = tables.t[policy_id, idx]              # (B,) per-row timesteps
        tips_vec = tables.tips[policy_id, idx]    # (B,) per-row TIPS flag
    else:
        idx = jnp.clip(step_idx, 0, cfg.num_inference_steps - 1)
        t = ts[idx]                               # (B,) per-row timesteps
        tips_vec = idx < cfg.tips_active_iters    # (B,) per-row TIPS flag
    kw = {"row_stats": True} if row_stats else {}
    if reuse_cache is not None:
        kw["reuse_cache"] = reuse_cache
    if bank is not None:
        overrides = solvers_mod.gather_overrides(tables, bank, policy_id,
                                                 idx)
        if overrides is not None:
            kw["overrides"] = overrides

    use_cfg = cfg.guidance_scale != 1.0 and uncond_context is not None
    if use_cfg:
        # cfg_dup: latents stay at b rows — the UNet tiles the hidden
        # state to [cond | uncond] at the first cross-attention (the
        # halves are identical before it).  stats_rows defaults to b:
        # PSSA/TIPS accounted on the cond half only — the ledger never
        # consumes uncond stats (the two-call reference path computes
        # and discards them; the fused path skips them).
        ctx_fused = jnp.concatenate([context, uncond_context], axis=0)
        rows = b if stats_rows is None else stats_rows
        out = unet_apply(latents, t, ctx_fused, tips_vec,
                         stats_rows=rows, cfg_dup=True, **kw)
    else:
        out = unet_apply(latents, t, context, tips_vec,
                         stats_rows=stats_rows, **kw)
    if reuse_cache is not None:
        eps, stats, new_cache = out
    else:
        eps, stats = out
        new_cache = None
    if use_cfg:
        eps = guided_eps(eps, cfg.guidance_scale)
    if bank is not None:
        new_lat, new_hist = solvers_mod.solver_update(
            latents, eps, solver_hist, tables, bank, policy_id, idx)
    else:
        new_lat = ddim_step(latents, eps, t, t - step, acp)
        new_hist = None
    if active is not None:
        keep = active.reshape((b,) + (1,) * (latents.ndim - 1))
        new_lat = jnp.where(keep, new_lat, latents)
        if new_hist is not None and new_hist.shape[1] > 0:
            new_hist = jnp.where(keep[:, None], new_hist, solver_hist)
    if bank is not None:
        return new_lat, stats, new_cache, new_hist
    if reuse_cache is not None:
        return new_lat, stats, new_cache
    return new_lat, stats


def _resolve_bank(sampler_policy, sampler_bank):
    """(bank, num_scan_steps, policy_index) for the banked scan paths.

    Without ``sampler_bank`` the policy becomes its own single-entry
    bank.  With it, the scan runs under the full bank's structure but
    only for ``sampler_policy``'s own step budget, rows pinned to its
    index — mirroring what a slot row of that policy executes before
    retiring.
    """
    if sampler_bank is None:
        bank = solvers_mod.as_bank(sampler_policy)
        return bank, solvers_mod.bank_max_steps(bank), 0
    bank = solvers_mod.as_bank(sampler_bank)
    if sampler_policy not in bank:
        raise ValueError(
            f"sampler_policy {sampler_policy.key()} is not an entry of "
            f"sampler_bank {[p.key() for p in bank]}")
    return bank, sampler_policy.num_steps, bank.index(sampler_policy)


def sample_scan(unet_apply, latents, context, uncond_context,
                cfg: DDIMConfig, stats_rows=None, sampler_policy=None,
                sampler_bank=None, policy_id=None):
    """Run all denoising steps inside one ``jax.lax.scan``.

    The scan body is :func:`denoise_step` with every row at the same step
    index — the same executable building block the continuous-batching
    engine (``DiffusionEngine.slot_step``) runs standalone with
    heterogeneous per-slot indices, so the two paths cannot drift.
    Under CFG the cond and uncond UNet evaluations are fused into a
    single batched call per step with the shared prefix deduplicated.
    ``stats_rows`` (static) restricts the PSSA/TIPS accounting to the
    first N batch rows — the serving front-end sets it to the valid
    (non-padded) row count of a tail micro-batch so padded duplicate rows
    never leak into the energy ledger.
    Returns ``(latents,
    stacked_stats)`` where ``stacked_stats`` is a ``UNetStats`` whose
    leaves carry a leading ``num_inference_steps`` axis; reconstruct the
    per-step view with ``stacked_stats.step(i)`` / ``.unstack()``.

    ``sampler_policy`` (a ``solvers.SamplerPolicy``) swaps the solver and
    the step budget: the scan runs ``policy.num_steps`` iterations of the
    banked :func:`denoise_step` with a single-policy bank, multistep
    history in the carry.  A ``(ddim, num_inference_steps)`` policy is
    bit-identical to the default path (same gathered coefficients, same
    shared transfer arithmetic — tests/test_solvers.py pins it).

    ``sampler_bank`` (static tuple of policies containing
    ``sampler_policy``) traces the scan body under the FULL bank — full
    coefficient tables, full multistep-history depth, the complete
    per-row select structure — with every row pinned to
    ``sampler_policy``'s index.  XLA specializes fusion clusters (and
    hence FMA contraction) to the traced graph, so a collapsed
    single-policy program can drift ~1e-6 from the mixed-bank slot
    executable even for logically identical rows; sharing the bank
    structure is what makes the one-shot path a bit-exact oracle for
    mixed-tier slot serving (DESIGN.md §10).  ``policy_id`` (a (B,)
    int32 ARRAY of the policy's bank index) must then arrive as a traced
    runtime operand, not a trace-time constant — a constant lets XLA
    fold the per-row coefficient gathers into the UNet's fusion clusters
    and shift FMA contraction relative to the slot executable (whose
    ``policy_id`` lives in donated state).  The engine passes it through
    the jit boundary (``DiffusionEngine._get_compiled``).
    """
    b = latents.shape[0]
    if stats_rows is not None and not (0 < stats_rows <= b):
        raise ValueError(f"stats_rows={stats_rows} outside [1, {b}]")
    if sampler_bank is not None and sampler_policy is None:
        raise ValueError("sampler_bank requires sampler_policy (the "
                         "bank entry to run every row under)")
    if sampler_policy is not None:
        bank, n, pid0 = _resolve_bank(sampler_policy, sampler_bank)
        if policy_id is None:
            policy_id = jnp.full((b,), pid0, jnp.int32)

        def body(carry, i):
            lat, hist = carry
            lat, stats, _, hist = denoise_step(
                unet_apply, lat, context, uncond_context,
                jnp.full((b,), i, jnp.int32), cfg, stats_rows=stats_rows,
                bank=bank, policy_id=policy_id, solver_hist=hist)
            return (lat, hist), stats

        hist0 = solvers_mod.init_history(bank, b, latents.shape[1:])
        (latents, _), stacked = jax.lax.scan(body, (latents, hist0),
                                             jnp.arange(n))
        return latents, stacked

    n = cfg.num_inference_steps

    def body(lat, i):
        return denoise_step(unet_apply, lat, context, uncond_context,
                            jnp.full((b,), i, jnp.int32), cfg,
                            stats_rows=stats_rows)

    latents, stacked = jax.lax.scan(body, latents, jnp.arange(n))
    return latents, stacked


def sample_scan_reuse(unet_apply, latents, context, uncond_context,
                      cfg: DDIMConfig, reuse_cache=None, stats_rows=None,
                      base_caches=None, record_caches: bool = False,
                      sampler_policy=None, sampler_bank=None,
                      policy_id=None):
    """Scanned denoising loop with the temporal-reuse cache threaded.

    Two cache sources, mirroring the two ``ReusePolicy`` modes:

    * **temporal** — ``reuse_cache`` (typically all-invalid zeros from
      ``core.reuse.reuse_cache_zeros``) rides the scan carry: each step
      reuses the PREVIOUS step's activations.  ``record_caches=True``
      additionally stacks every step's emitted cache along a leading axis
      (the base-trace recorder for edit serving) and returns
      ``(latents, stats, caches)``.
    * **edit** — ``base_caches`` is such a recorded stack from a BASE
      request; step ``i`` reuses the base's step-``i`` activations
      (indexed from the stack, nothing carried), which is what makes
      ``capacity < 1`` safe: the reference is valid from step 0.

    Returns ``(latents, stacked_stats)`` (plus the recorded caches when
    asked); ``stacked_stats`` carries per-layer reuse counters.

    ``sampler_policy`` composes with both modes exactly as in
    :func:`sample_scan`: the banked :func:`denoise_step` with a
    single-policy bank, solver history alongside the cache in the carry.
    (Edit-mode ``base_caches`` must have been recorded with the same
    policy — the per-step references are indexed by step.)
    ``sampler_bank`` likewise mirrors :func:`sample_scan`: trace under
    the full bank with rows pinned to ``sampler_policy``'s index.
    """
    b = latents.shape[0]
    if stats_rows is not None and not (0 < stats_rows <= b):
        raise ValueError(f"stats_rows={stats_rows} outside [1, {b}]")
    if (reuse_cache is None) == (base_caches is None):
        raise ValueError(
            "pass exactly one of reuse_cache (temporal mode) or "
            "base_caches (edit mode)")
    if sampler_bank is not None and sampler_policy is None:
        raise ValueError("sampler_bank requires sampler_policy (the "
                         "bank entry to run every row under)")
    bank = None
    if sampler_policy is not None:
        bank, n, pid0 = _resolve_bank(sampler_policy, sampler_bank)
        if policy_id is None:
            policy_id = jnp.full((b,), pid0, jnp.int32)
        hist0 = solvers_mod.init_history(bank, b, latents.shape[1:])
    else:
        n = cfg.num_inference_steps

    if base_caches is not None:
        def body(carry, i):
            lat, hist = carry
            cache_i = jax.tree_util.tree_map(lambda x: x[i], base_caches)
            if bank is not None:
                lat, stats, _, hist = denoise_step(
                    unet_apply, lat, context, uncond_context,
                    jnp.full((b,), i, jnp.int32), cfg,
                    stats_rows=stats_rows, reuse_cache=cache_i,
                    bank=bank, policy_id=policy_id, solver_hist=hist)
            else:
                lat, stats, _ = denoise_step(
                    unet_apply, lat, context, uncond_context,
                    jnp.full((b,), i, jnp.int32), cfg,
                    stats_rows=stats_rows, reuse_cache=cache_i)
            return (lat, hist), stats

        hist_init = hist0 if bank is not None else jnp.zeros((b, 0))
        (latents, _), stacked = jax.lax.scan(body, (latents, hist_init),
                                             jnp.arange(n))
        return latents, stacked

    def body(carry, i):
        lat, cache, hist = carry
        if bank is not None:
            lat, stats, cache, hist = denoise_step(
                unet_apply, lat, context, uncond_context,
                jnp.full((b,), i, jnp.int32), cfg, stats_rows=stats_rows,
                reuse_cache=cache, bank=bank, policy_id=policy_id,
                solver_hist=hist)
        else:
            lat, stats, cache = denoise_step(
                unet_apply, lat, context, uncond_context,
                jnp.full((b,), i, jnp.int32), cfg, stats_rows=stats_rows,
                reuse_cache=cache)
        ys = (stats, cache) if record_caches else stats
        return (lat, cache, hist), ys

    hist_init = hist0 if bank is not None else jnp.zeros((b, 0))
    (latents, _, _), ys = jax.lax.scan(
        body, (latents, reuse_cache, hist_init), jnp.arange(n))
    if record_caches:
        stacked, caches = ys
        return latents, stacked, caches
    return latents, ys
