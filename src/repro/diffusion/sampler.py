"""DDIM sampler — 25 denoising iterations (the paper's operating point).

Deterministic DDIM (eta = 0) over a linear-beta DDPM schedule, with optional
classifier-free guidance.  TIPS is active for the first 20 of the 25
iterations (paper Fig. 9(b)): the last 5 are quantization-vulnerable and run
full INT12 — the sampler passes ``tips_active`` per step.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.tips import TIPS_ACTIVE_ITERS


@dataclasses.dataclass(frozen=True)
class DDIMConfig:
    num_train_steps: int = 1000
    num_inference_steps: int = 25        # paper: 25 UNet iterations
    beta_start: float = 0.00085
    beta_end: float = 0.012
    guidance_scale: float = 7.5
    tips_active_iters: int = TIPS_ACTIVE_ITERS


def alphas_cumprod(cfg: DDIMConfig):
    betas = jnp.linspace(cfg.beta_start ** 0.5, cfg.beta_end ** 0.5,
                         cfg.num_train_steps) ** 2
    return jnp.cumprod(1.0 - betas)


def timestep_schedule(cfg: DDIMConfig):
    """Descending DDIM timesteps, e.g. [960, 920, ..., 0] for 25 steps."""
    step = cfg.num_train_steps // cfg.num_inference_steps
    return jnp.arange(cfg.num_inference_steps - 1, -1, -1) * step


def ddim_step(latents, eps, t, t_prev, acp):
    """One deterministic DDIM update (eta = 0)."""
    a_t = acp[t]
    a_prev = jnp.where(t_prev >= 0, acp[jnp.maximum(t_prev, 0)], 1.0)
    x0 = (latents - jnp.sqrt(1.0 - a_t) * eps) / jnp.sqrt(a_t)
    return jnp.sqrt(a_prev) * x0 + jnp.sqrt(1.0 - a_prev) * eps


def sample(unet_apply, latents, context, uncond_context, cfg: DDIMConfig,
           collect_stats: bool = False):
    """Run the full 25-iteration denoising loop.

    ``unet_apply(latents, timesteps, context, tips_active)`` -> (eps, stats).
    Python loop (25 iterations, each jit-compiled once) so per-iteration
    stats stay inspectable — matching how the paper instruments per-iteration
    low-precision ratios (Fig. 9(b)).
    """
    acp = alphas_cumprod(cfg)
    ts = timestep_schedule(cfg)
    step = cfg.num_train_steps // cfg.num_inference_steps
    all_stats = []
    for i in range(cfg.num_inference_steps):
        t = ts[i]
        tips_active = i < cfg.tips_active_iters
        b = latents.shape[0]
        tvec = jnp.full((b,), t, jnp.int32)
        eps_c, stats = unet_apply(latents, tvec, context, tips_active)
        if cfg.guidance_scale != 1.0 and uncond_context is not None:
            eps_u, _ = unet_apply(latents, tvec, uncond_context, tips_active)
            eps = eps_u + cfg.guidance_scale * (eps_c - eps_u)
        else:
            eps = eps_c
        latents = ddim_step(latents, eps, t, t - step, acp)
        if collect_stats:
            all_stats.append(stats)
    return latents, all_stats
