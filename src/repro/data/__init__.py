from repro.data.pipeline import (  # noqa: F401
    DataState, SyntheticLMDataset, make_batch_specs, shard_assignment)
