"""Deterministic, resumable, shardable data pipeline.

Production posture (DESIGN.md §5):
  * every batch is a pure function of (seed, step) — no hidden iterator
    state, so checkpoint/restore needs only the step counter, and ANY host
    can regenerate ANY shard (straggler takeover / elastic re-balance);
  * ``shard_assignment`` maps host -> contiguous batch rows, recomputed from
    the live host count, so a relaunch at fewer hosts rebalances cleanly;
  * synthetic token streams here (no external corpora offline); the
    interface (``batch_at``) is what a real tokenized-corpus loader would
    implement.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataState:
    """Everything needed to resume the pipeline exactly."""
    seed: int
    step: int

    def advance(self, n: int = 1) -> "DataState":
        return DataState(self.seed, self.step + n)


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    embedding_input: bool = False
    d_model: int = 0

    def batch_at(self, step: int, host: int = 0, num_hosts: int = 1):
        """Generate (the host's rows of) batch #step.

        Pure in (seed, step, GLOBAL row index): every row has its own
        counter-based stream, so any host regenerates any other host's rows
        bitwise (the straggler-takeover / elastic-rebalance contract)."""
        lo, hi = shard_assignment(self.global_batch, host, num_hosts)
        rows = hi - lo
        toks = np.empty((rows, self.seq_len), np.int64)
        embs = (np.empty((rows, self.seq_len, self.d_model), np.float32)
                if self.embedding_input else None)
        for r in range(rows):
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, lo + r]))
            # Markov-ish stream: token_{t+1} depends on token_t so the model
            # has signal to fit (loss decreases in the examples).
            base = rng.integers(0, self.vocab_size)
            steps = rng.integers(0, 17, size=(self.seq_len - 1,)).cumsum()
            toks[r, 0] = base
            toks[r, 1:] = base + steps
            if embs is not None:
                embs[r] = rng.standard_normal(
                    (self.seq_len, self.d_model)).astype(np.float32)
        tokens = (toks % self.vocab_size).astype(np.int32)
        batch = {"labels": jnp.asarray(np.roll(tokens, -1, axis=1))}
        if embs is not None:
            batch["embeds"] = jnp.asarray(embs, jnp.bfloat16)
        else:
            batch["tokens"] = jnp.asarray(tokens)
        return batch


def shard_assignment(global_batch: int, host: int, num_hosts: int):
    """Contiguous row range [lo, hi) owned by ``host`` (balanced +-1)."""
    q, r = divmod(global_batch, num_hosts)
    lo = host * q + min(host, r)
    hi = lo + q + (1 if host < r else 0)
    return lo, hi


def make_batch_specs(cfg, shape, dp_axes):
    """ShapeDtypeStructs + PartitionSpecs for one global batch."""
    from jax.sharding import PartitionSpec as P
    b, t = shape.global_batch, shape.seq_len
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    specs = {"labels": (jax.ShapeDtypeStruct((b, t), jnp.int32),
                        P(dp, None))}
    if cfg.embedding_input:
        specs["embeds"] = (jax.ShapeDtypeStruct((b, t, cfg.d_model),
                                                jnp.bfloat16),
                           P(dp, None, None))
    else:
        specs["tokens"] = (jax.ShapeDtypeStruct((b, t), jnp.int32),
                           P(dp, None))
    return specs
