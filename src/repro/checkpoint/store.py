"""Numpy-backed pytree checkpointing (atomic, resumable, integrity-checked).

Layout:  <dir>/step_<N>/
             manifest.json    — tree structure, shapes/dtypes, config hash
             leaf_<i>.npy     — one file per leaf (mmap-able on restore)
         <dir>/step_<N>.tmp-… during write, atomically renamed when complete.

Fault tolerance: a crash mid-write leaves only a .tmp dir which is ignored
(and garbage-collected on the next save); ``latest_step`` only ever sees
complete checkpoints.  In a multi-host deployment each host writes its own
param shard under the same step directory (shard_<host>); here (single
process) there is one shard.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import numpy as np


def _tree_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save_checkpoint(directory: str, step: int, tree, meta: dict | None = None,
                    keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp-{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat, treedef = _tree_paths(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(flat),
        "meta": meta or {},
        "leaves": [],
    }
    for i, leaf in enumerate(flat):
        arr = np.asarray(leaf)
        logical_dtype = str(arr.dtype)
        # ml_dtypes (bfloat16 etc.) don't survive np.save -> store a
        # bit-compatible integer view and the logical dtype in the manifest
        if arr.dtype.kind == "V" or logical_dtype == "bfloat16":
            arr = arr.view(np.uint16)
            logical_dtype = "bfloat16"
        path = os.path.join(tmp, f"leaf_{i:05d}.npy")
        np.save(path, arr)
        manifest["leaves"].append({
            "shape": list(arr.shape),
            "dtype": logical_dtype,
            "sha256_16": hashlib.sha256(arr.tobytes()).hexdigest()[:16],
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)               # atomic publish

    # GC old checkpoints + stale tmp dirs
    steps = sorted(_complete_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
    for name in os.listdir(directory):
        if ".tmp-" in name and not name.endswith(f"-{os.getpid()}"):
            shutil.rmtree(os.path.join(directory, name), ignore_errors=True)
    return final


def _complete_steps(directory: str):
    out = []
    if not os.path.isdir(directory):
        return out
    for name in os.listdir(directory):
        if name.startswith("step_") and ".tmp" not in name:
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
    return out


def latest_step(directory: str):
    steps = _complete_steps(directory)
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, like_tree):
    """Restore into the structure of ``like_tree`` (shape/dtype verified)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = _tree_paths(like_tree)
    assert manifest["num_leaves"] == len(flat), "tree structure changed"
    out = []
    for i, (leaf, spec) in enumerate(zip(flat, manifest["leaves"])):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        if spec["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        want = tuple(getattr(leaf, "shape", np.shape(leaf)))
        assert tuple(arr.shape) == want, (i, arr.shape, want)
        out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out), manifest["meta"]
