"""Config registry: 10 assigned architectures + the paper's own BK-SDM."""
from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, shape_applicable  # noqa: F401


def get_arch(name: str) -> ArchConfig:
    import importlib
    mod = importlib.import_module(
        "repro.configs." + name.replace("-", "_").replace(".", "_"))
    return mod.CONFIG


ARCH_NAMES = [
    "mamba2-130m",
    "qwen2-moe-a2.7b",
    "llama4-scout-17b-a16e",
    "yi-34b",
    "chatglm3-6b",
    "llama3-8b",
    "yi-9b",
    "internvl2-26b",
    "musicgen-large",
    "hymba-1.5b",
]
