"""hymba-1.5b [hybrid] — parallel attn+mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Sliding-window attention (1024) everywhere except 3 global layers
(first/middle/last, per the Hymba paper) -> sub-quadratic, runs long_500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    sliding_window=1024,
    global_attn_every=16,    # layers 0, 16, 31 stay global (see models.hybrid)
    subquadratic=True,
)
