"""The paper's own workload: BK-SDM-Tiny text-to-image pipeline.

Not one of the 10 assigned LM architectures — this is the diffusion config
the processor was evaluated on (MS-COCO, 25 DDIM iterations).  Exposed here
so ``--arch bk-sdm`` selects the paper-faithful pipeline in examples and
benchmarks.  See ``repro.diffusion`` for the model itself.
"""
import dataclasses

from repro.diffusion.pipeline import PipelineConfig
from repro.diffusion.sampler import DDIMConfig
from repro.diffusion.text_encoder import TextEncoderConfig
from repro.diffusion.unet import UNetConfig
from repro.diffusion.vae import VAEConfig
from repro.kernels.dispatch import KernelPolicy

CONFIG = PipelineConfig(
    unet=UNetConfig(),            # BK-SDM-Tiny geometry (full)
    text=TextEncoderConfig(),     # CLIP ViT-L/14 text tower geometry
    vae=VAEConfig(),
    ddim=DDIMConfig(num_inference_steps=25),
)

SMOKE = PipelineConfig.smoke()


def with_kernel_policy(cfg: PipelineConfig,
                       policy: KernelPolicy) -> PipelineConfig:
    """Pipeline config with the UNet hot path routed per ``policy``."""
    return dataclasses.replace(
        cfg, unet=dataclasses.replace(cfg.unet, kernel_policy=policy))


# Serving path: blocked Pallas attention + PSXU kernel — the SAS never
# materializes (interpret auto-selected per backend; see kernels.dispatch).
FUSED = with_kernel_policy(CONFIG, KernelPolicy.fused())
SMOKE_FUSED = with_kernel_policy(SMOKE, KernelPolicy.fused())
