"""The paper's own workload: BK-SDM-Tiny text-to-image pipeline.

Not one of the 10 assigned LM architectures — this is the diffusion config
the processor was evaluated on (MS-COCO, 25 DDIM iterations).  Exposed here
so ``--arch bk-sdm`` selects the paper-faithful pipeline in examples and
benchmarks.  See ``repro.diffusion`` for the model itself.
"""
from repro.diffusion.pipeline import PipelineConfig
from repro.diffusion.sampler import DDIMConfig
from repro.diffusion.text_encoder import TextEncoderConfig
from repro.diffusion.unet import UNetConfig
from repro.diffusion.vae import VAEConfig

CONFIG = PipelineConfig(
    unet=UNetConfig(),            # BK-SDM-Tiny geometry (full)
    text=TextEncoderConfig(),     # CLIP ViT-L/14 text tower geometry
    vae=VAEConfig(),
    ddim=DDIMConfig(num_inference_steps=25),
)

SMOKE = PipelineConfig.smoke()
