"""The paper's own workload: BK-SDM-Tiny text-to-image pipeline.

Not one of the 10 assigned LM architectures — this is the diffusion config
the processor was evaluated on (MS-COCO, 25 DDIM iterations).  Exposed here
so ``--arch bk-sdm`` selects the paper-faithful pipeline in examples and
benchmarks.  See ``repro.diffusion`` for the model itself.
"""
import dataclasses

from repro.core.precision import PrecisionPolicy
from repro.diffusion.pipeline import PipelineConfig
from repro.diffusion.sampler import DDIMConfig
from repro.diffusion.text_encoder import TextEncoderConfig
from repro.diffusion.unet import UNetConfig
from repro.diffusion.vae import VAEConfig
from repro.kernels.dispatch import KernelPolicy

CONFIG = PipelineConfig(
    unet=UNetConfig(),            # BK-SDM-Tiny geometry (full)
    text=TextEncoderConfig(),     # CLIP ViT-L/14 text tower geometry
    vae=VAEConfig(),
    ddim=DDIMConfig(num_inference_steps=25),
)

SMOKE = PipelineConfig.smoke()


def with_kernel_policy(cfg: PipelineConfig,
                       policy: KernelPolicy) -> PipelineConfig:
    """Pipeline config with the UNet hot path routed per ``policy``."""
    return dataclasses.replace(
        cfg, unet=dataclasses.replace(cfg.unet, kernel_policy=policy))


def with_precision(cfg: PipelineConfig,
                   policy: PrecisionPolicy) -> PipelineConfig:
    """Pipeline config with the TIPS/DBSC precision runtime set."""
    return dataclasses.replace(
        cfg, unet=dataclasses.replace(cfg.unet, precision=policy))


# Serving path: blocked Pallas attention (self + cross) + PSXU kernel —
# neither the SAS nor the cross-attention probability tensor materializes
# (interpret auto-selected per backend; see kernels.dispatch).
FUSED = with_kernel_policy(CONFIG, KernelPolicy.fused())
SMOKE_FUSED = with_kernel_policy(SMOKE, KernelPolicy.fused())

# Paper operating point for the precision runtime: whole-FFN TIPS coverage
# ("INT12 through the whole following FFN stack", §IV-A) at the measured
# 44.8 % workload target via per-sample adaptive spotting.
ADAPTIVE = with_precision(CONFIG, PrecisionPolicy.adaptive())
PAPER_PRECISION = with_precision(
    CONFIG, PrecisionPolicy(spotting="fixed", ffn_mid=True))
