"""chatglm3-6b [dense] — RoPE 2d (partial rotary), GQA kv=2 [arXiv:2406.12793].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rotary_pct=0.5,          # 2-D RoPE: rotate half of each head dim
)
