"""musicgen-large [audio] — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L d_model=2048 32H (kv=32, i.e. MHA) d_ff=8192 vocab=2048.
EnCodec frontend is a STUB — input_specs() provides precomputed frame
embeddings.  MusicGen has true text cross-attention, so TIPS applies in its
original (CLS-token) form here (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="dense",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    embedding_input=True,
    ffn_activation="gelu",
)
