"""internvl2-26b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
Backbone only; the InternViT frontend is a STUB — input_specs() provides
precomputed patch embeddings (DESIGN.md §6).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    embedding_input=True,
)
