"""llama4-scout-17b-a16e [moe] — MoE, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, 16e top-1 (+1 shared).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    num_shared_experts=1,
    top_k=1,
    moe_d_ff=8192,
    rope_theta=500000.0,
)
