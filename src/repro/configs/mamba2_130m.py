"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

24L d_model=768, attention-free, d_ff=0, vocab=50280, ssm_state=128.
PSSA/TIPS inapplicable (no attention scores) — see DESIGN.md §6.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=1,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    subquadratic=True,
    pssa=False,
    tips=False,
)
