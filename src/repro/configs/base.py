"""Architecture config schema + input-shape sets for the assigned archs."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # 'dense' | 'moe' | 'ssm' | 'hybrid'
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # per-expert hidden dim (d_ff if 0)
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba-2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4

    # --- attention details ---
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0         # chatglm3 2-D RoPE == 0.5
    sliding_window: int = 0         # 0 -> full attention
    global_attn_every: int = 0      # hybrid: which layers stay global

    # --- modality ---
    embedding_input: bool = False   # vlm/audio stub frontend (precomputed embeds)

    # --- capability flags ---
    subquadratic: bool = False      # may run long_500k

    # --- paper features (first-class, per DESIGN.md §4) ---
    pssa: bool = True               # self-attn score pruning + compression
    tips: bool = True               # sink-token mixed-precision FFN
    dbsc: bool = True               # bit-slice quantized FFN execution (serving)
    pssa_threshold: float = 1.0 / 8192.0
    tips_threshold: float = 0.05

    # --- training ---
    ffn_activation: str = "swiglu"
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # --- performance knobs (§Perf hillclimb) ---
    tp_size: int = 16               # TP degree on the 256-chip pod
    remat_save_collectives: bool = False  # save post-psum acts (no AR replay)
    kv_cache_dtype: str = "bfloat16"      # 'int8' halves decode KV traffic
    use_ssd_kernel: bool = False    # fused Pallas SSD (serving/prefill path)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family == "moe" and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    @property
    def d_inner(self) -> int:       # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def scaled(self, **overrides) -> "ArchConfig":
        return dataclasses.replace(self, **overrides)

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            num_layers=2,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            d_ff=128,
            vocab_size=512,
            head_dim=16,
        )
        if self.family == "moe":
            # generous capacity: smoke batches are tiny, so the binomial
            # tail of per-expert load is fat — capacity-drop semantics are
            # tested separately, equivalence tests should not hit drops
            kw.update(num_experts=4, top_k=min(self.top_k, 2), moe_d_ff=64,
                      num_shared_experts=min(self.num_shared_experts, 1),
                      moe_capacity_factor=8.0)
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=8, ssm_head_dim=16)
        if self.sliding_window:
            kw.update(sliding_window=16)
        return self.scaled(name=self.name + "-smoke", **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # 'train' | 'prefill' | 'decode'


# The four LM shape sets from the assignment.
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic attention (DESIGN.md §6)."""
    if shape.name == "long_500k":
        return cfg.subquadratic
    return True
