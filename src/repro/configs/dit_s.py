"""DiT-S/2 text-conditioned diffusion-transformer pipeline presets.

The second denoiser family behind the denoiser contract (DESIGN.md §11):
patchify -> 12 transformer blocks with adaLN timestep conditioning ->
unpatchify.  The transformer blocks are the SAME ``_transformer_block``
the UNet uses, so PSSA sparsity augmentation, TIPS text-based mixed
precision, DBSC and temporal patch reuse apply unchanged — these presets
mirror ``configs.bk_sdm`` with the UNet geometry swapped for
``repro.diffusion.dit.DiTConfig``.
"""
import dataclasses

from repro.configs.bk_sdm import with_kernel_policy, with_precision
from repro.core.precision import PrecisionPolicy
from repro.diffusion.dit import DiTConfig
from repro.diffusion.pipeline import PipelineConfig
from repro.diffusion.sampler import DDIMConfig
from repro.diffusion.text_encoder import TextEncoderConfig
from repro.diffusion.vae import VAEConfig
from repro.kernels.dispatch import KernelPolicy

CONFIG = PipelineConfig(
    unet=DiTConfig(),             # DiT-S/2 geometry (full): 12 x d=384
    text=TextEncoderConfig(),     # CLIP ViT-L/14 text tower geometry
    vae=VAEConfig(),
    ddim=DDIMConfig(num_inference_steps=25),
)

# reduced geometry that runs a full fwd pass on CPU in seconds
SMOKE = dataclasses.replace(PipelineConfig.smoke(),
                            unet=DiTConfig().smoke())

# Serving path: blocked Pallas attention (self + cross) + PSXU kernel —
# identical kernel routing semantics to the UNet presets.
FUSED = with_kernel_policy(CONFIG, KernelPolicy.fused())
SMOKE_FUSED = with_kernel_policy(SMOKE, KernelPolicy.fused())

# Paper operating point for the precision runtime (see configs.bk_sdm).
ADAPTIVE = with_precision(CONFIG, PrecisionPolicy.adaptive())
PAPER_PRECISION = with_precision(
    CONFIG, PrecisionPolicy(spotting="fixed", ffn_mid=True))
