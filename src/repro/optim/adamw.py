"""AdamW in pure JAX (pytree-native, shard-friendly).

Optimizer state mirrors the param tree (m, v per leaf), so the same
PartitionSpecs shard it — no extra sharding logic needed at the launcher.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamWState, params):
        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
        step = state.step + 1
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m2 = self.b1 * m + (1 - self.b1) * g
            v2 = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mh = m2 / b1c
            vh = v2 / b2c
            delta = mh / (jnp.sqrt(vh) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * delta
            return newp.astype(p.dtype), m2, v2

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        newp = treedef.unflatten([o[0] for o in out])
        newm = treedef.unflatten([o[1] for o in out])
        newv = treedef.unflatten([o[2] for o in out])
        return newp, AdamWState(step=step, m=newm, v=newv), gnorm


def adamw(**kw) -> AdamW:
    return AdamW(**kw)
