"""Gradient compression for the DP all-reduce path (1000+ node posture).

INT8 quantization with error feedback: each step quantizes (grad + residual)
to int8 per-leaf scales, all-reduces the int8 payload (8x less DP traffic),
and carries the quantization error into the next step.  Convergence-tested
on the smoke model in tests/test_optim.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _q(x):
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_gradients(grads, residual=None):
    """-> (quantized grads pytree of (int8, scale), new residual pytree)."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                grads)

    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, scale = _q(x)
        deq = q.astype(jnp.float32) * scale
        return (q, scale), x - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    qtree = treedef.unflatten([p[0] for p in pairs])
    new_res = treedef.unflatten([p[1] for p in pairs])
    return qtree, new_res


def decompress_gradients(qtree):
    return jax.tree.map(
        lambda q: q[0].astype(jnp.float32) * q[1], qtree,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)


def error_feedback_update(grads, residual):
    """Round-trip compress/decompress (what the wire would carry) + residual."""
    qtree, new_res = compress_gradients(grads, residual)
    deq = decompress_gradients(qtree)
    return deq, new_res
