from repro.optim.adamw import AdamW, adamw  # noqa: F401
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine  # noqa: F401
from repro.optim.compression import compress_gradients, error_feedback_update  # noqa: F401
