"""Text-to-image serving front-end over the jitted DiffusionEngine.

  PYTHONPATH=src python -m repro.launch.serve_diffusion --smoke \
      --requests 8 --micro-batch 4 --steps 5 [--guidance 7.5] \
      [--model unet|dit] [--kernels fused] [--tips adaptive] [--mesh 4] \
      [--ledger] [--continuous --slots 4 --arrival-rate 2.0 --burst 2] \
      [--solver dpm2m,steps=12] [--tiers draft balanced quality] \
      [--replicas 2 --slo-steps 12 --preview-every 2]

The policy flags (``--kernels``/``--tips``/--reuse/``--solver``/
``--tiers``) are the shared ``launch.cli`` wiring: they parse into ONE
frozen ``core.policies.ServePolicies`` bundle consumed by this CLI,
``examples/generate_image.py`` and the cluster router alike.

Cluster mode (``--replicas N``, DESIGN.md §13): N slot-state replicas
behind occupancy-routed FIFO admission with decode off the hot step
loop (``launch.router.ClusterRouter``).  ``--slo-steps D`` sets a
round-denominated deadline — under overload requests degrade to a lower
``--tiers`` bank entry instead of queueing (``--no-degrade`` for the
queueing baseline); ``--preview-every K`` decodes in-flight latents
every K rounds for streaming previews.  The merged ledger keeps the
``--ledger`` headline bit-identical across replica counts.

``--model`` selects the denoiser family behind the contract (DESIGN.md
§11): the BK-SDM UNet (default) or the DiT-S/2 transformer.  Every
serving mode, kernel policy, quality tier and the banked energy ledger
work unchanged for both families; reports carry the active family under
``denoiser_family``.

Phase-aware sampling (DESIGN.md §10): ``--solver`` swaps the solver /
step budget for every request (``SamplerPolicy`` spec: tier name, solver
name, or ``dpm2m,steps=10,phases=detail_guard``); ``--tiers`` serves a
MIXED quality-tier trace through the continuous scheduler — each request
round-robins a bank entry, every tier coexists in the one jitted
``slot_step`` via per-row coefficient gathers, and the ``--ledger``
report becomes the per-policy banked breakdown with each tier normalized
by its own step budget.

Micro-batching: incoming prompts are queued and packed into fixed-size
micro-batches (padding the tail with repeats), each served by ONE compiled
engine call — the whole encode -> scanned-denoise -> decode path is a single
XLA computation, with cond+uncond CFG fused into one batched UNet call per
step.  The engine caches one executable per micro-batch signature, so after
the first call every shape is compile-free.

Continuous batching (``--continuous``, DESIGN.md §8): instead of draining
fixed micro-batches, a persistent ``--slots``-row batch stays in flight and
every denoising step advances all occupied slots — each at its OWN
iteration index.  Finished rows are decoded and swapped for queued prompts
between steps, so a request arriving mid-generation starts one UNet
iteration later instead of one full generation later.  ``--arrival-rate``
(requests/s, with ``--burst`` arrivals at a time; 0 = all at once) drives a
deterministic bursty trace, and the report adds enqueue->image latency
percentiles (p50/p95), queueing delay, occupancy and goodput.  The
``--ledger`` headline comes from the integer per-iteration accumulator and
is bit-identical to the same requests served one-shot, at any slot count
or occupancy (tests/test_continuous.py pins this).

Mesh mode (``--mesh N``): data-parallel sharded execution over N devices
(DESIGN.md §6).  On a CPU host the N devices are simulated with the
dry-run's ``XLA_FLAGS`` trick (set before jax initializes); on TPU the
first N real devices are used.  The scheduler rounds the micro-batch up to
a multiple of the dp degree, shards prompt tokens and latents along the
``data`` axis (params replicated), and masks padded tail rows out of every
reported metric: ``stats_rows`` restricts the PSSA/TIPS accounting to the
valid rows at the source, so the energy ledger never sees a padded
duplicate.

Reports aggregate imgs/s (valid images only), per-iteration wall time, and
(with ``--ledger``) the full-geometry energy headline driven by the stats
of EVERY micro-batch — the per-iteration SAS/TIPS terms are summed across
engine calls before dividing (``pipeline.energy_report_multi``), with the
stats pytrees staying on device (batch-sharded under a mesh) until that
single host read.

``--kernels`` selects the per-op kernel routing (``KernelPolicy``):
``reference`` (materializing pure-JAX), ``fused`` (blocked Pallas
attention, self AND cross — neither the SAS nor the cross-attention
probability tensor materializes; stats bit-identical), ``autotuned``
(``fused`` with block sizes from the committed autotune table —
``kernels.autotune``), or per-op overrides like
``self_attention=fused,ffn=dbsc,ffn_quant=int8``.  Interpret mode is
auto-selected per backend, so the same flag works on CPU and TPU.

``--tips`` selects the precision runtime (``PrecisionPolicy``): ``fixed``
(the silicon's predefined CAS threshold), ``adaptive`` (per-sample
quantile spotting realizing a target INT6 ratio), or field overrides like
``adaptive,target=0.5,mid=true``.  The ``--ledger`` report names the
active policy and its per-iteration realized low-precision ratios.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time


def make_config(args):
    """Config for a CLI namespace — delegates to the shared wiring.

    Kept as the module's historical entry point (benches build bare
    namespaces for it); the flag semantics now live once in
    ``repro.launch.cli`` so this CLI, the example, and the cluster
    router cannot drift.
    """
    from repro.launch.cli import config_from_args

    return config_from_args(args)


def synthetic_requests(cfg, n: int, seed: int = 7):
    """n prompt token rows (no tokenizer offline; semantics don't matter)."""
    import jax
    return jax.random.randint(jax.random.PRNGKey(seed),
                              (n, cfg.text.max_len), 0, cfg.text.vocab_size)


def micro_batches(requests, batch: int):
    """Pack request rows into fixed-size batches, padding the tail.

    Returns (batched_tokens, valid_count) pairs; padded rows repeat the
    first request so every call hits the same compiled signature.  Padded
    rows are masked out downstream: ``valid`` drives both the imgs/s
    accounting and the ``stats_rows`` ledger restriction.
    """
    import jax.numpy as jnp
    n = requests.shape[0]
    out = []
    for i in range(0, n, batch):
        chunk = requests[i:i + batch]
        valid = chunk.shape[0]
        if valid < batch:
            pad = jnp.broadcast_to(chunk[:1],
                                   (batch - valid,) + chunk.shape[1:])
            chunk = jnp.concatenate([chunk, pad], axis=0)
        out.append((chunk, valid))
    return out


def serve(cfg, requests, micro_batch: int, key=None, ledger: bool = False,
          mesh=None, sampler_policy=None) -> dict:
    """Drain the request queue through the engine; return serving metrics.

    ``mesh``: optional ``jax.sharding.Mesh`` for data-parallel execution;
    the effective micro-batch is rounded up to a multiple of its dp size.

    ``sampler_policy``: a ``solvers.SamplerPolicy`` applied to EVERY
    request (micro-batches share one scan executable, so one policy per
    run; mixed tiers need ``serve_continuous`` with a bank).  The energy
    ledger then normalizes by the policy's own step budget.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import tips
    from repro.diffusion.engine import DiffusionEngine
    from repro.diffusion.pipeline import (aggregated_reuse_ratios_per_iter,
                                          aggregated_tips_ratios_per_iter,
                                          energy_report_multi)
    from repro.launch.mesh import dp_size_of

    key = key if key is not None else jax.random.PRNGKey(0)
    eng = DiffusionEngine(cfg, key=key, mesh=mesh)
    dp = dp_size_of(mesh) if mesh is not None else 1
    # micro-batches must tile evenly over the data axis
    micro_batch = -(-micro_batch // dp) * dp
    use_cfg = cfg.ddim.guidance_scale != 1.0
    uncond = (jnp.zeros((micro_batch, cfg.text.max_len), jnp.int32)
              if use_cfg else None)

    # warm exactly the signatures the loop will run: the full-batch one
    # (skipped when every request fits in one padded tail) and the tail's
    # stats_rows one — compiles land in compile_s, not the serving wall
    n_requests = int(requests.shape[0])
    tail = n_requests % micro_batch
    compile_s = 0.0
    if n_requests >= micro_batch:
        compile_s += eng.warmup(micro_batch, use_cfg,
                                sampler_policy=sampler_policy)
    if tail:
        compile_s += eng.warmup(micro_batch, use_cfg, stats_rows=tail,
                                sampler_policy=sampler_policy)
    batches = micro_batches(requests, micro_batch)

    images = 0
    padded = 0
    wall = 0.0
    stats_per_batch = []        # (stacked UNetStats, valid rows) per call
    for i, (toks, valid) in enumerate(batches):
        # a padded tail batch compiles its own stats_rows signature once
        rows = valid if valid < micro_batch else None
        out = eng.generate(toks, jax.random.fold_in(key, i),
                           uncond_tokens=uncond, stats_rows=rows,
                           sampler_policy=sampler_policy)
        wall += eng.last_wall_s
        images += valid
        padded += micro_batch - valid
        stats_per_batch.append(out.stats)

    steps = (cfg.ddim.num_inference_steps if sampler_policy is None
             else sampler_policy.num_steps)
    metrics = {
        "requests": int(requests.shape[0]),
        "denoiser_family": eng.denoiser.family,
        "kernel_policy": cfg.unet.effective_kernel_policy().describe(),
        "precision_policy": cfg.unet.effective_precision().describe(),
        "micro_batch": micro_batch,
        "mesh": None if mesh is None else {
            "dp": dp,
            "shape": {k: int(v) for k, v in mesh.shape.items()},
            "devices": int(mesh.devices.size),
        },
        "engine_calls": len(batches),
        "padded_rows": padded,
        "steps_per_image": steps,
        "guidance_fused_cfg": use_cfg,
        "compile_s": compile_s,
        "serve_wall_s": wall,
        "imgs_per_s": images / max(wall, 1e-9),
        "iter_wall_ms": 1e3 * wall / max(len(batches) * steps, 1),
    }
    if sampler_policy is not None:
        metrics["sampler_policy"] = sampler_policy.describe()
    if ledger and stats_per_batch:
        # ONE host read per call of the scalar ledger leaves; per-row
        # leaves never leave the mesh (stats stay batch-sharded)
        fetched = [s.ledger_fetch() for s in stats_per_batch]
        rep = energy_report_multi(cfg, fetched,
                                  sampler_policy=sampler_policy)
        metrics["energy"] = {k: float(v) for k, v in rep.summary().items()}
        if steps == cfg.ddim.num_inference_steps:
            # the per-iteration ratio extras index the CONFIG schedule;
            # a policy with its own budget reports through the energy
            # summary above (its TIPS window already step-scaled there)
            ratios = aggregated_tips_ratios_per_iter(cfg, fetched)
            # realized (not target) INT6 row fraction, per DDIM iteration
            # — the number the active PrecisionPolicy actually delivered
            metrics["tips_low_ratio_per_iter"] = [float(r) for r in ratios]
            metrics["tips_workload_low_fraction"] = float(
                tips.workload_low_precision_fraction(jnp.asarray(ratios),
                                                     ddim=cfg.ddim))
            # realized per-iteration temporal-reuse ratio (zeros when off)
            metrics["reuse_ratio_per_iter"] = [
                float(r) for r in
                aggregated_reuse_ratios_per_iter(cfg, stats_per_batch)]
    return metrics


def serve_continuous(cfg, num_requests: int, num_slots: int,
                     arrival_rate: float = 0.0, burst: int = 1,
                     key=None, ledger: bool = False, seed: int = 7,
                     edit: bool = False, bank=None) -> dict:
    """Serve a synthetic request trace through the continuous scheduler.

    ``arrival_rate`` is requests/second, arriving ``burst`` at a time
    (0 = the whole queue is available at t=0).  Compilation happens off
    the clock (``warmup``), so the latency percentiles measure serving,
    not tracing.  ``edit`` switches the trace to the img2img/editing
    request class (``scheduler.make_edit_requests``): every request is
    the same base latent with a localized edit window — the workload
    ``--reuse temporal`` serves with most patch rows cached.

    ``bank`` (tuple of ``solvers.SamplerPolicy``): mixed quality-tier
    serving — requests cycle through the bank's tiers round-robin, all
    inside one step executable, and the ``--ledger`` report becomes the
    per-policy banked breakdown (``pipeline.energy_report_banked``).
    """
    import jax

    from repro.diffusion.engine import DiffusionEngine
    from repro.launch.scheduler import (ContinuousScheduler, apply_trace,
                                        bursty_trace, make_edit_requests,
                                        make_requests)

    key = key if key is not None else jax.random.PRNGKey(0)
    eng = DiffusionEngine(cfg, key=key)
    if edit:
        requests = make_edit_requests(cfg, num_requests, seed=seed)
    else:
        requests = make_requests(cfg, num_requests, seed=seed, bank=bank)
    if arrival_rate > 0:
        gap = burst / arrival_rate
        apply_trace(requests, bursty_trace(num_requests, burst, gap))
    sched = ContinuousScheduler(eng, num_slots, bank=bank)
    compile_s = sched.warmup()
    metrics = sched.run(requests, ledger=ledger)
    metrics.pop("state")
    metrics.update(
        compile_s=compile_s,
        kernel_policy=cfg.unet.effective_kernel_policy().describe(),
        precision_policy=cfg.unet.effective_precision().describe(),
        reuse_policy=cfg.unet.reuse_policy.describe(),
        steps_per_image=(cfg.ddim.num_inference_steps if bank is None
                         else [p.num_steps for p in bank]),
        workload="edit" if edit else "t2i",
        arrival={"rate_per_s": arrival_rate, "burst": burst},
    )
    return metrics


def serve_cluster(cfg, num_requests: int, replicas: int, num_slots: int,
                  arrival_rate: float = 0.0, burst: int = 1, key=None,
                  ledger: bool = False, seed: int = 7, bank=None,
                  slo_steps: int = 0, degrade: bool = True,
                  preview_every: int = 0) -> dict:
    """Serve a synthetic trace through the multi-replica cluster router.

    ``replicas`` independent slot states share one engine's executables
    (``launch.router.ClusterRouter``); ``slo_steps`` (>0) turns on
    round-denominated SLO admission — under overload a request degrades
    to a lower bank tier instead of queueing (``degrade=False`` is the
    queueing baseline).  ``preview_every`` streams progressive preview
    decodes of in-flight rows.  The ``--ledger`` headline merges every
    replica's integer accumulator (``pipeline.energy_report_cluster``)
    and is bit-identical at any replica count.
    """
    import jax

    from repro.diffusion.engine import DiffusionEngine
    from repro.launch.router import ClusterRouter, RouterSLO
    from repro.launch.scheduler import (apply_trace, bursty_trace,
                                        make_requests)

    key = key if key is not None else jax.random.PRNGKey(0)
    eng = DiffusionEngine(cfg, key=key)
    router = ClusterRouter(eng, replicas, num_slots, bank=bank,
                           slo=RouterSLO(deadline_steps=slo_steps or None,
                                         degrade=degrade),
                           preview_every=preview_every)
    requests = make_requests(cfg, num_requests, seed=seed,
                             bank=router.bank)
    if arrival_rate > 0:
        gap = burst / arrival_rate
        apply_trace(requests, bursty_trace(num_requests, burst, gap))
    compile_s = router.warmup()
    metrics = router.run(requests, ledger=ledger)
    metrics.pop("states")
    metrics.update(
        compile_s=compile_s,
        kernel_policy=cfg.unet.effective_kernel_policy().describe(),
        precision_policy=cfg.unet.effective_precision().describe(),
        reuse_policy=cfg.unet.reuse_policy.describe(),
        steps_per_image=(cfg.ddim.num_inference_steps
                         if router.bank is None
                         else [p.num_steps for p in router.bank]),
        workload="t2i",
        arrival={"rate_per_s": arrival_rate, "burst": burst},
    )
    return metrics


def main():
    from repro.launch.cli import add_policy_args

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced geometry (CPU-friendly)")
    add_policy_args(ap)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--micro-batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=5,
                    help="DDIM iterations (paper: 25)")
    ap.add_argument("--guidance", type=float, default=1.0)
    ap.add_argument("--ledger", action="store_true",
                    help="print the full-geometry energy headline")
    ap.add_argument("--mesh", type=int, default=0,
                    help="data-parallel degree: shard micro-batches over N "
                         "devices (simulated host devices on CPU, real on "
                         "TPU); 0 = single-device")
    ap.add_argument("--edit", action="store_true",
                    help="serve the img2img/editing request class (shared "
                         "base latent + localized per-request edits) — "
                         "pair with --continuous and --reuse temporal")
    ap.add_argument("--continuous", action="store_true",
                    help="slot-based continuous batching instead of fixed "
                         "micro-batches (DESIGN.md §8)")
    ap.add_argument("--slots", type=int, default=4,
                    help="in-flight slot count for --continuous")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="request arrivals per second for --continuous "
                         "(0 = whole queue available at t=0)")
    ap.add_argument("--burst", type=int, default=1,
                    help="arrivals per burst for --arrival-rate")
    ap.add_argument("--replicas", type=int, default=0,
                    help="cluster-router mode (DESIGN.md §13): run N "
                         "slot-engine replicas behind occupancy routing "
                         "(0 = single scheduler); uses --slots per replica")
    ap.add_argument("--slo-steps", type=int, default=0,
                    help="router SLO: enqueue->image deadline in router "
                         "rounds; under overload requests degrade to a "
                         "lower --tiers entry instead of queueing "
                         "(0 = no SLO)")
    ap.add_argument("--no-degrade", action="store_true",
                    help="queue instead of degrading when the SLO cannot "
                         "be met (the positive-control baseline)")
    ap.add_argument("--preview-every", type=int, default=0,
                    help="router streaming: decode progressive previews "
                         "of in-flight rows every K rounds (0 = off)")
    args = ap.parse_args()
    if args.steps < 1:
        ap.error("--steps must be >= 1")
    if args.micro_batch < 1:
        ap.error("--micro-batch must be >= 1")
    if args.requests < 1:
        ap.error("--requests must be >= 1")
    if args.mesh < 0:
        ap.error("--mesh must be >= 0")
    if args.slots < 1:
        ap.error("--slots must be >= 1")
    if args.burst < 1:
        ap.error("--burst must be >= 1")
    if args.arrival_rate < 0:
        ap.error("--arrival-rate must be >= 0")
    if args.continuous and args.mesh > 1:
        ap.error("--continuous is single-device (see DESIGN.md §8); "
                 "drop --mesh")
    if args.edit and not args.continuous:
        ap.error("--edit rides the slot scheduler's admit(latents=) path; "
                 "add --continuous")
    if args.tiers and not (args.continuous or args.replicas):
        ap.error("--tiers is mixed-tier serving over the slot engine; "
                 "add --continuous or --replicas (micro-batches share one "
                 "scan executable — use --solver for a single policy)")
    if args.replicas < 0:
        ap.error("--replicas must be >= 0")
    if args.replicas:
        if args.mesh > 1:
            ap.error("--replicas runs the single-device slot runtime per "
                     "replica (DESIGN.md §13); drop --mesh")
        if args.edit:
            ap.error("--replicas serves t2i traces; --edit rides the "
                     "single-replica --continuous path")
        if args.continuous:
            ap.error("--replicas IS continuous batching across N slot "
                     "states; drop --continuous")
    if args.slo_steps and not args.replicas:
        ap.error("--slo-steps is cluster-router admission; add --replicas")
    if args.slo_steps and not args.no_degrade and not args.tiers:
        ap.error("SLO degradation picks lower tiers from a bank; add "
                 "--tiers (or --no-degrade for the queueing baseline)")
    if args.preview_every and not args.replicas:
        ap.error("--preview-every is cluster-router streaming; add "
                 "--replicas")
    if args.tiers and args.solver:
        ap.error("--tiers and --solver are exclusive: a bank already "
                 "names every policy in flight")
    if args.tiers and args.edit:
        ap.error("--edit traces share one base latent workload; tiered "
                 "admission is t2i-only for now")

    if args.mesh > 1:
        # must run before the first jax backend init; only meaningful for
        # host (CPU) platforms — TPU/GPU expose their real devices
        plat = (os.environ.get("JAX_PLATFORMS")
                or os.environ.get("JAX_PLATFORM_NAME") or "cpu")
        if "tpu" not in plat and "gpu" not in plat and "cuda" not in plat:
            from repro.launch.mesh import simulate_host_devices
            simulate_host_devices(args.mesh)

    from repro.launch.cli import config_from_args, policies_from_args
    from repro.launch.mesh import make_data_mesh

    mesh = make_data_mesh(args.mesh) if args.mesh > 1 else None
    # ONE parse of the policy surface feeds the config, the engine's
    # bundle, and the scheduler/router bank — the CLIs cannot drift from
    # each other or from the programmatic ServePolicies API
    policies = policies_from_args(args)
    cfg = config_from_args(args, policies=policies)
    sampler_policy = policies.sampler
    bank = policies.bank
    sampling = ("tiers " + "+".join(p.label() for p in bank) if bank
                else sampler_policy.key() if sampler_policy
                else f"ddim@{args.steps}")
    batching = (f"router replicas={args.replicas} slots={args.slots}"
                if args.replicas
                else f"continuous slots={args.slots}" if args.continuous
                else f"micro-batch {args.micro_batch}")
    print(f"engine: model {args.model}, latent {cfg.unet.latent_size}^2, "
          f"sampling {sampling}, "
          f"guidance {args.guidance} "
          f"({'fused-CFG' if args.guidance != 1.0 else 'no CFG'}), "
          f"{batching}, kernels {args.kernels}, "
          f"tips {args.tips}, reuse {args.reuse}, "
          f"workload {'edit' if args.edit else 't2i'}, "
          f"mesh {'dp=' + str(args.mesh) if mesh is not None else 'none'}")
    if args.replicas:
        if bank is None and sampler_policy is not None:
            bank = (sampler_policy,)      # single-tier bank
        metrics = serve_cluster(cfg, args.requests, args.replicas,
                                args.slots,
                                arrival_rate=args.arrival_rate,
                                burst=args.burst, ledger=args.ledger,
                                bank=bank, slo_steps=args.slo_steps,
                                degrade=not args.no_degrade,
                                preview_every=args.preview_every)
    elif args.continuous:
        if bank is None and sampler_policy is not None:
            bank = (sampler_policy,)      # single-tier bank
        metrics = serve_continuous(cfg, args.requests, args.slots,
                                   arrival_rate=args.arrival_rate,
                                   burst=args.burst, ledger=args.ledger,
                                   edit=args.edit, bank=bank)
    else:
        reqs = synthetic_requests(cfg, args.requests)
        metrics = serve(cfg, reqs, args.micro_batch, ledger=args.ledger,
                        mesh=mesh, sampler_policy=sampler_policy)
    print(json.dumps(metrics, indent=2))


if __name__ == "__main__":
    main()
