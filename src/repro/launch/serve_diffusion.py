"""Text-to-image serving front-end over the jitted DiffusionEngine.

  PYTHONPATH=src python -m repro.launch.serve_diffusion --smoke \
      --requests 8 --micro-batch 4 --steps 5 [--guidance 7.5] \
      [--kernels fused]

Micro-batching: incoming prompts are queued and packed into fixed-size
micro-batches (padding the tail with repeats), each served by ONE compiled
engine call — the whole encode -> scanned-denoise -> decode path is a single
XLA computation, with cond+uncond CFG fused into one batched UNet call per
step.  The engine caches one executable per micro-batch signature, so after
the first call every shape is compile-free.

Reports imgs/s, per-iteration wall time, and (with ``--ledger``) the
full-geometry energy headline driven by the measured stats trajectory.

``--kernels`` selects the per-op kernel routing (``KernelPolicy``):
``reference`` (materializing pure-JAX), ``fused`` (blocked Pallas
attention — the SAS never materializes; stats bit-identical), or per-op
overrides like ``self_attention=fused,ffn=dbsc``.  Interpret mode is
auto-selected per backend, so the same flag works on CPU and TPU.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.diffusion.engine import DiffusionEngine
from repro.diffusion.pipeline import PipelineConfig, energy_report
from repro.diffusion.sampler import DDIMConfig
from repro.kernels.dispatch import KernelPolicy


def make_config(args) -> PipelineConfig:
    cfg = PipelineConfig.smoke() if args.smoke else PipelineConfig()
    policy = KernelPolicy.parse(args.kernels)
    return dataclasses.replace(
        cfg,
        unet=dataclasses.replace(cfg.unet, kernel_policy=policy),
        ddim=DDIMConfig(
            num_inference_steps=args.steps,
            guidance_scale=args.guidance,
            tips_active_iters=max(1, args.steps * 20 // 25)))


def synthetic_requests(cfg: PipelineConfig, n: int, seed: int = 7):
    """n prompt token rows (no tokenizer offline; semantics don't matter)."""
    return jax.random.randint(jax.random.PRNGKey(seed),
                              (n, cfg.text.max_len), 0, cfg.text.vocab_size)


def micro_batches(requests, batch: int):
    """Pack request rows into fixed-size batches, padding the tail.

    Returns (batched_tokens, valid_count) pairs; padded rows repeat the
    first request so every call hits the same compiled signature.
    """
    n = requests.shape[0]
    out = []
    for i in range(0, n, batch):
        chunk = requests[i:i + batch]
        valid = chunk.shape[0]
        if valid < batch:
            pad = jnp.broadcast_to(chunk[:1],
                                   (batch - valid,) + chunk.shape[1:])
            chunk = jnp.concatenate([chunk, pad], axis=0)
        out.append((chunk, valid))
    return out


def serve(cfg: PipelineConfig, requests, micro_batch: int,
          key=None, ledger: bool = False) -> dict:
    """Drain the request queue through the engine; return serving metrics."""
    key = key if key is not None else jax.random.PRNGKey(0)
    eng = DiffusionEngine(cfg, key=key)
    use_cfg = cfg.ddim.guidance_scale != 1.0
    uncond = (jnp.zeros((micro_batch, cfg.text.max_len), jnp.int32)
              if use_cfg else None)

    compile_s = eng.warmup(micro_batch, use_cfg)
    batches = micro_batches(requests, micro_batch)

    images = 0
    wall = 0.0
    last_stats = None
    for i, (toks, valid) in enumerate(batches):
        out = eng.generate(toks, jax.random.fold_in(key, i),
                           uncond_tokens=uncond)
        wall += eng.last_wall_s
        images += valid
        last_stats = out.stats

    steps = cfg.ddim.num_inference_steps
    metrics = {
        "requests": int(requests.shape[0]),
        "kernel_policy": cfg.unet.effective_kernel_policy().describe(),
        "micro_batch": micro_batch,
        "engine_calls": len(batches),
        "steps_per_image": steps,
        "guidance_fused_cfg": use_cfg,
        "compile_s": compile_s,
        "serve_wall_s": wall,
        "imgs_per_s": images / max(wall, 1e-9),
        "iter_wall_ms": 1e3 * wall / max(len(batches) * steps, 1),
    }
    if ledger and last_stats is not None:
        rep = energy_report(cfg, last_stats)
        metrics["energy"] = {k: float(v) for k, v in rep.summary().items()}
    return metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced geometry (CPU-friendly)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--micro-batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=5,
                    help="DDIM iterations (paper: 25)")
    ap.add_argument("--guidance", type=float, default=1.0)
    ap.add_argument("--ledger", action="store_true",
                    help="print the full-geometry energy headline")
    ap.add_argument("--kernels", default="reference",
                    help="kernel policy: 'reference', 'fused', or per-op "
                         "overrides like 'self_attention=fused,ffn=dbsc' "
                         "(see repro.kernels.dispatch.KernelPolicy)")
    args = ap.parse_args()
    if args.steps < 1:
        ap.error("--steps must be >= 1")
    if args.micro_batch < 1:
        ap.error("--micro-batch must be >= 1")
    if args.requests < 1:
        ap.error("--requests must be >= 1")

    cfg = make_config(args)
    print(f"engine: latent {cfg.unet.latent_size}^2, {args.steps} steps, "
          f"guidance {args.guidance} "
          f"({'fused-CFG' if args.guidance != 1.0 else 'no CFG'}), "
          f"micro-batch {args.micro_batch}, kernels {args.kernels}")
    reqs = synthetic_requests(cfg, args.requests)
    metrics = serve(cfg, reqs, args.micro_batch, ledger=args.ledger)
    print(json.dumps(metrics, indent=2))


if __name__ == "__main__":
    main()
