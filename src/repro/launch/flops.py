"""Exact, loop-aware FLOP counting by walking the jaxpr.

XLA's ``compiled.cost_analysis()`` on the CPU backend counts a while-loop
(layer scan / microbatch scan) body ONCE, which silently undercounts a
60-layer model by 60x.  This walker recurses through scan/while/pjit/remat/
shard_map sub-jaxprs and multiplies by trip counts, so the count is exact
for the real schedule (including remat recompute and gradient accumulation).

Convention: matmul/conv FLOPs only (2*MACs) — the standard MFU accounting;
elementwise ops are excluded (they are counted in the *memory* roofline
term instead).
"""
from __future__ import annotations

import math

import jax
from jax import core


def _prod(xs):
    out = 1
    for x in xs:
        out *= int(x)
    return out


def _dot_flops(eqn) -> float:
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    lhs, rhs = eqn.invars[0].aval.shape, eqn.invars[1].aval.shape
    batch = _prod(lhs[i] for i in lb)
    contract = _prod(lhs[i] for i in lc)
    lfree = _prod(d for i, d in enumerate(lhs) if i not in lc and i not in lb)
    rfree = _prod(d for i, d in enumerate(rhs) if i not in rc and i not in rb)
    return 2.0 * batch * contract * lfree * rfree


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval.shape
    rhs = eqn.invars[1].aval.shape
    dn = eqn.params["dimension_numbers"]
    rhs_spec = dn.rhs_spec                  # (O, I_per_group, spatial...)
    kernel_in = rhs[rhs_spec[1]]            # already per-group channels
    window = _prod(rhs[i] for i in rhs_spec[2:])
    return 2.0 * _prod(out) * kernel_in * window


_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr",
                    "body_jaxpr")


def count_jaxpr(jaxpr, shard_multiplier: float = 1.0) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            total += _dot_flops(eqn) * shard_multiplier
        elif name == "conv_general_dilated":
            total += _conv_flops(eqn) * shard_multiplier
        elif name == "scan":
            body = eqn.params["jaxpr"]
            n = eqn.params["length"]
            total += n * count_jaxpr(body.jaxpr, shard_multiplier)
        elif name == "while":
            # bounded fori_loop: trip count not in params; treat cond/body
            # once (not used on hot paths of this codebase)
            for key in ("cond_jaxpr", "body_jaxpr"):
                total += count_jaxpr(eqn.params[key].jaxpr, shard_multiplier)
        elif name == "shard_map":
            body = eqn.params["jaxpr"]
            mesh = eqn.params["mesh"]
            mult = shard_multiplier * _prod(mesh.shape.values())
            total += count_jaxpr(body, mult)
        elif name == "cond":
            branches = eqn.params["branches"]
            # count the largest branch (they are alternatives)
            total += max(count_jaxpr(b.jaxpr, shard_multiplier)
                         for b in branches)
        else:
            for key in _SUBJAXPR_PARAMS:
                if key in eqn.params:
                    sub = eqn.params[key]
                    sub = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                    total += count_jaxpr(sub, shard_multiplier)
    return total


def flops_of_callable(fn, *abstract_args) -> float:
    """Global (whole-cluster) matmul FLOPs of one call of ``fn``."""
    jaxpr = jax.make_jaxpr(fn)(*abstract_args)
    return count_jaxpr(jaxpr.jaxpr)
