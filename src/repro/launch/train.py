"""Production training launcher.

Single entry point for every assigned architecture:

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
      --steps 1000 --batch 32 --seq 512 [--smoke] [--grad-compression]

On this CPU container ``--smoke`` (reduced geometry) is the practical mode;
the full configs are exercised through ``repro.launch.dryrun``.  The mesh is
built from the LIVE device count (``make_elastic_mesh``) so a relaunch after
losing hosts rebalances automatically; checkpoints make the relaunch resume
exactly where it stopped.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_NAMES, get_arch
from repro.data import SyntheticLMDataset
from repro.launch.mesh import make_elastic_mesh
from repro.launch.model_flops import param_count
from repro.models.layers import ShardCtx
from repro.optim import AdamW, linear_warmup_cosine
from repro.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family geometry (CPU-trainable)")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--sharded", action="store_true",
                    help="shard over the live devices (elastic mesh)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    print(f"{cfg.name}: {param_count(cfg) / 1e6:.1f} M params, "
          f"{len(jax.devices())} device(s)")

    ctx = None
    if args.sharded:
        mesh = make_elastic_mesh()
        ctx = ShardCtx(mesh=mesh, dp_axes=("data",))
        print(f"elastic mesh: {dict(mesh.shape)}")

    ds = SyntheticLMDataset(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=0,
        embedding_input=cfg.embedding_input, d_model=cfg.d_model)
    opt = AdamW(lr=linear_warmup_cosine(
        args.lr, warmup=min(20, args.steps // 10 + 1),
        total_steps=args.steps))
    tc = TrainConfig(
        steps=args.steps, checkpoint_every=max(10, args.steps // 5),
        log_every=max(1, args.steps // 20),
        checkpoint_dir=args.ckpt_dir or f"/tmp/repro_{cfg.name}",
        grad_compression=args.grad_compression)
    trainer = Trainer(cfg, ds, opt, tc, ctx=ctx)
    _, history = trainer.run(key=jax.random.PRNGKey(0))
    if history:
        print(f"loss {history[0][1]:.4f} -> {history[-1][1]:.4f}")


if __name__ == "__main__":
    main()
