"""Shared CLI wiring for the serving-policy surface (DESIGN.md §13).

``serve_diffusion``, ``examples/generate_image.py`` and the cluster
router (``repro.launch.router``) all expose the same policy flags —
``--model --kernels --tips --reuse --solver --tiers``.  Before
``ServePolicies`` each CLI registered and parsed them independently and
they drifted (the example lacked ``--reuse``; help strings disagreed).
This module is the single registration + parsing point:

* :func:`add_policy_args` registers the flags on an ``ArgumentParser``;
* :func:`policies_from_args` turns the parsed namespace into one
  ``core.policies.ServePolicies`` bundle (with the serving
  reuse-capacity clamp);
* :func:`config_from_args` builds the ``PipelineConfig`` (geometry,
  denoiser family, schedule) with the bundle's per-axis policies
  installed.

A CLI that consumes these three cannot drift from the others — new
policy axes land here once.
"""
from __future__ import annotations

import dataclasses


def add_policy_args(ap, tiers: bool = True):
    """Register the shared policy flags on ``ap``.

    ``tiers=False`` omits ``--tiers`` for single-request CLIs (a bank is
    meaningless when exactly one request is in flight).  Returns ``ap``.
    """
    ap.add_argument("--model", choices=("unet", "dit"), default="unet",
                    help="denoiser family (DESIGN.md §11): the BK-SDM "
                         "UNet (default) or the DiT-S/2 transformer; both "
                         "serve through the same engine/scheduler spine "
                         "and kernel dispatch table")
    ap.add_argument("--kernels", default="auto",
                    help="kernel policy: 'auto' (fused on compiled "
                         "backends, reference on interpret backends), "
                         "'reference', 'fused', 'autotuned' (fused with "
                         "the committed block-size table), or per-op "
                         "overrides like 'self_attention=fused,ffn=dbsc,"
                         "ffn_quant=int8' "
                         "(see repro.kernels.dispatch.KernelPolicy)")
    ap.add_argument("--tips", default="fixed",
                    help="precision policy: 'fixed', 'adaptive', or field "
                         "overrides like 'adaptive,target=0.5,mid=true' "
                         "(see repro.core.precision.PrecisionPolicy)")
    ap.add_argument("--reuse", default="off",
                    help="temporal patch-reuse policy: 'off', 'temporal', "
                         "or overrides like 'temporal,threshold=0.1' "
                         "(see repro.core.reuse.ReusePolicy)")
    ap.add_argument("--solver", default="",
                    help="sampler policy for EVERY request: a tier name "
                         "('draft'|'balanced'|'quality'), a solver "
                         "('ddim'|'plms'|'dpm2m'), or overrides like "
                         "'dpm2m,steps=10,phases=detail_guard' "
                         "(see repro.diffusion.solvers.SamplerPolicy); "
                         "empty = the config's DDIM schedule")
    if tiers:
        ap.add_argument("--tiers", nargs="+", default=None,
                        help="mixed quality-tier serving bank: one "
                             "SamplerPolicy spec per tier (e.g. --tiers "
                             "draft balanced quality); requests cycle "
                             "through the tiers round-robin inside one "
                             "step executable")
    return ap


def policies_from_args(args, clamp_reuse_capacity: bool = True):
    """Parsed namespace -> one frozen ``ServePolicies`` bundle.

    ``clamp_reuse_capacity`` (default): serving engines run the TEMPORAL
    reuse path (cache starts invalid), where a sub-1.0 static gather
    capacity is illegal — clamp to 1.0 so ``--reuse edit,threshold=...``
    selects the edit threshold defaults while serving stays exact.
    """
    from repro.core.policies import ServePolicies

    pol = ServePolicies.parse(kernels=getattr(args, "kernels", "auto"),
                              tips=getattr(args, "tips", "fixed"),
                              reuse=getattr(args, "reuse", "off"),
                              solver=getattr(args, "solver", ""),
                              tiers=getattr(args, "tiers", None))
    if (clamp_reuse_capacity and pol.reuse.enabled
            and pol.reuse.capacity < 1.0):
        pol = dataclasses.replace(
            pol, reuse=dataclasses.replace(pol.reuse, capacity=1.0))
    return pol


def config_from_args(args, policies=None, steps=None, guidance=None):
    """Build the ``PipelineConfig`` a CLI run serves.

    Geometry from ``--smoke`` (absent = smoke, the CLI-demo default),
    denoiser family from ``--model``, schedule from ``--steps`` /
    ``--guidance`` (overridable via the keyword args), and the policy
    bundle's kernel/precision/reuse axes installed via
    ``ServePolicies.apply``.  ``policies=None`` parses the bundle from
    ``args`` (:func:`policies_from_args`).
    """
    from repro.diffusion.pipeline import PipelineConfig
    from repro.diffusion.sampler import DDIMConfig

    smoke = getattr(args, "smoke", True)
    cfg = PipelineConfig.smoke() if smoke else PipelineConfig()
    if getattr(args, "model", "unet") == "dit":
        # swap the denoiser family; the engine/sampler/serving spine is
        # family-agnostic through the denoiser contract (DESIGN.md §11)
        from repro.diffusion.dit import DiTConfig
        dit = DiTConfig()
        cfg = dataclasses.replace(cfg, unet=dit.smoke() if smoke else dit)
    steps = steps if steps is not None else getattr(args, "steps", 5)
    guidance = (guidance if guidance is not None
                else getattr(args, "guidance", 1.0))
    cfg = dataclasses.replace(
        cfg,
        ddim=DDIMConfig(num_inference_steps=steps,
                        guidance_scale=guidance,
                        tips_active_iters=max(1, steps * 20 // 25)))
    if policies is None:
        policies = policies_from_args(args)
    return policies.apply(cfg)
