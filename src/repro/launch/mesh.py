"""Mesh construction (production + elastic variants) and version compat.

All constructors are FUNCTIONS so importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import math
import os

import jax


def use_mesh(mesh):
    """Version-portable "active mesh" context manager.

    jax >= 0.6 exposes ``jax.set_mesh`` (usable as a context manager);
    earlier versions (the container floor is 0.4.37) activate a mesh by
    entering the ``Mesh`` object itself.  Everything in this repo annotates
    shardings explicitly with ``NamedSharding``, which works under either —
    the context only matters for code that resolves bare axis names.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def simulate_host_devices(count: int) -> None:
    """Expose ``count`` fake host devices (CPU) to this process.

    Same ``XLA_FLAGS`` trick as the dry-run: must be called BEFORE the
    first jax backend init, so callers (``serve_diffusion --mesh N``,
    ``benchmarks/bench_sharded_engine``) invoke it from their entrypoint
    prior to any jax device use.  A pre-existing flag with a DIFFERENT
    count (e.g. exported by an earlier recipe) is replaced, not silently
    kept — the caller asked for ``count`` devices.
    """
    import re
    flag = f"--xla_force_host_platform_device_count={count}"
    cur = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" in cur:
        cur = re.sub(r"--xla_force_host_platform_device_count=\d+", flag,
                     cur)
        os.environ["XLA_FLAGS"] = cur
    else:
        os.environ["XLA_FLAGS"] = (cur + " " + flag).strip()


def mesh_signature(mesh) -> tuple | None:
    """Hashable identity of a mesh: axis names, sizes, and device ids.

    Used to key compiled-executable caches (``DiffusionEngine``): two
    meshes with the same signature shard a program identically, and an
    elastic relaunch onto different devices (or a reshaped mesh) must not
    reuse executables compiled for the old placement.
    """
    if mesh is None:
        return None
    return (tuple(mesh.axis_names),
            tuple(int(mesh.shape[a]) for a in mesh.axis_names),
            tuple(int(d.id) for d in mesh.devices.flat))


def make_production_mesh(*, multi_pod: bool = False, tp_size: int = 16):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    ``tp_size`` re-slices the same chips into (256/tp, tp) — the §Perf
    hillclimb uses tp=8 for archs whose head count does not divide 16
    (yi-34b: 56 heads -> GSPMD pads to 64 at tp=16; 56 % 8 == 0)."""
    per_pod = 256
    assert per_pod % tp_size == 0, tp_size
    dp = per_pod // tp_size
    shape = (2, dp, tp_size) if multi_pod else (dp, tp_size)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size_of(mesh) -> int:
    """Total data-parallel degree (product of the pod/data axis sizes)."""
    return math.prod(int(mesh.shape[a]) for a in dp_axes_of(mesh))


def make_data_mesh(dp: int):
    """(dp, 1) pure data-parallel mesh over the first ``dp`` live devices.

    Unlike ``make_elastic_mesh`` this does not insist on using every
    device — serving picks its dp degree (``serve_diffusion --mesh N``)
    and leaves the rest to other replicas.
    """
    import numpy as np
    devs = jax.devices()
    if len(devs) < dp:
        raise ValueError(f"--mesh {dp} needs {dp} devices, "
                         f"have {len(devs)}")
    return jax.sharding.Mesh(np.asarray(devs[:dp]).reshape(dp, 1),
                             ("data", "model"))


def make_elastic_mesh(tp_size: int = 16):
    """Build the largest (data, model) mesh from the LIVE device count.

    Elastic scaling: after losing hosts, relaunch calls this and gets a
    smaller-but-valid mesh (model axis preserved so param shards stay
    compatible; the data axis absorbs the loss).
    """
    n = len(jax.devices())
    tp = min(tp_size, n)
    while n % tp:
        tp -= 1
    return jax.make_mesh((n // tp, tp), ("data", "model"))


def make_smoke_mesh():
    """1x1 mesh on the single CPU device (tests exercise the sharded code
    paths without fake devices)."""
    return jax.make_mesh((1, 1), ("data", "model"))
