"""Mesh construction (production + elastic variants).

All constructors are FUNCTIONS so importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, tp_size: int = 16):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    ``tp_size`` re-slices the same chips into (256/tp, tp) — the §Perf
    hillclimb uses tp=8 for archs whose head count does not divide 16
    (yi-34b: 56 heads -> GSPMD pads to 64 at tp=16; 56 % 8 == 0)."""
    per_pod = 256
    assert per_pod % tp_size == 0, tp_size
    dp = per_pod // tp_size
    shape = (2, dp, tp_size) if multi_pod else (dp, tp_size)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_elastic_mesh(tp_size: int = 16):
    """Build the largest (data, model) mesh from the LIVE device count.

    Elastic scaling: after losing hosts, relaunch calls this and gets a
    smaller-but-valid mesh (model axis preserved so param shards stay
    compatible; the data axis absorbs the loss).
    """
    n = len(jax.devices())
    tp = min(tp_size, n)
    while n % tp:
        tp -= 1
    return jax.make_mesh((n // tp, tp), ("data", "model"))


def make_smoke_mesh():
    """1x1 mesh on the single CPU device (tests exercise the sharded code
    paths without fake devices)."""
    return jax.make_mesh((1, 1), ("data", "model"))
