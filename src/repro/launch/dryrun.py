import os


def force_fake_devices(count: int = 512) -> None:
    """Give this process ``count`` fake host devices for AOT compilation.

    Must run before the first jax backend init — called from the
    ``__main__`` entrypoint below, NOT at import time: pure helpers in this
    module (``collective_bytes_from_hlo``, ``pick_microbatches``,
    ``choose_tp_fold``) are imported by the test suite, and an import-time
    env mutation would silently put the ENTIRE suite (collected before any
    test runs) on a 512-device platform — exactly what tests/conftest.py
    promises never happens to smoke tests and benches.
    """
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --xla_force_host_platform_device_count"
                                 f"={count}")


"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this AOT-compiles the real step function (train_step /
prefill / decode_step) against ShapeDtypeStruct inputs on the production
mesh — no device allocation — and records:

  * memory_analysis()  (per-device bytes — proves the config fits)
  * cost_analysis()    (HLO FLOPs / bytes for the roofline)
  * collective-op operand bytes parsed from the optimized HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) for the collective roofline term.

Results land in benchmarks/results/dryrun_<mesh>_<arch>_<shape>.json and
EXPERIMENTS.md §Dry-run / §Roofline are generated from them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, SHAPES, get_arch, shape_applicable
from repro.configs.base import ArchConfig, ShapeConfig
from repro.data import make_batch_specs
from repro.launch.mesh import dp_axes_of, make_production_mesh, use_mesh
from repro.models import transformer as T
from repro.models.layers import ShardCtx
from repro.optim import AdamW
from repro.train import make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-device bytes moved by every collective in the optimized HLO.

    The SPMD-partitioned module carries *per-device* shapes; we take the
    RESULT type(s) on the LHS of each collective (for an all-reduce the
    result equals the operand; for an all-gather the result is the full
    gathered block a device materializes — i.e. the bytes it receives).
    A ring all-reduce moves ~2x its payload per link, accounted via the
    ``weighted`` field.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    line_re = re.compile(
        r"=\s+(\(?[\w\[\]{},*/ ]*?\)?)\s+(all-gather|all-reduce|"
        r"reduce-scatter|all-to-all|collective-permute)(-start)?\((.*)")

    def _bytes(types: str) -> float:
        total = 0.0
        for dt, dims in shape_re.findall(types):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        return total

    for line in hlo_text.splitlines():
        m = line_re.search(line)
        if not m:
            continue
        result_types, kind, operands = m.group(1), m.group(2), m.group(4)
        # per-device link traffic ~= the FULL (unsharded) payload a device
        # touches: result side for all-gather/all-reduce (gathered block),
        # OPERAND side for reduce-scatter (the result is 1/n of the payload
        # but each device still streams the whole input around the ring).
        if kind == "reduce-scatter":
            total = _bytes(operands)
        else:
            total = _bytes(result_types)
        out[kind] += total
        count[kind] += 1
    # effective per-link traffic: ring AR sends ~2x payload
    out["weighted"] = (2.0 * out["all-reduce"] + out["all-gather"]
                       + out["reduce-scatter"] + out["all-to-all"]
                       + out["collective-permute"])
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count
    return out


def choose_tp_fold(cfg: ArchConfig, shape: ShapeConfig,
                   devices: int = 256) -> bool:
    """TP-fold policy (§Perf iteration 1): a model whose parameters fit a
    single chip many times over pays per-layer TP collectives for nothing —
    fold the 'model' axis into data parallelism for small non-MoE models in
    training.  (MoE keeps TP/EP; decode keeps TP for KV sharding.)

    Guard: folding turns every chip into a DP rank, so the global batch
    must still divide the device count (multi-pod 512 > batch 256 -> keep
    TP)."""
    if shape.kind != "train" or cfg.family == "moe":
        return False
    if shape.global_batch % devices:
        return False
    from repro.launch.model_flops import param_count
    return param_count(cfg) * 2 < 1e9        # < 1 GB of bf16 params


def _strip_model(tree):
    """Replace the 'model' axis with None in every PartitionSpec leaf."""
    def fix(s):
        return P(*(None if a == "model" else a for a in s))
    return jax.tree.map(fix, tree, is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; weak-type-correct, no allocation)
# ----------------------------------------------------------------------------
def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh,
                tp_fold: bool | None = None, fsdp: bool = False):
    """-> (abstract args tuple, in_shardings tuple) for the step function.

    ``fsdp``: ZeRO-3 — PARAMETERS (not just optimizer state) are sharded
    over the data axes on a leading divisible dim; XLA all-gathers each
    layer's weights on use and the gradient all-reduce becomes a
    reduce-scatter.  Required for yi-34b-class models to fit 16 GB HBM."""
    if tp_fold is None:
        tp_fold = choose_tp_fold(cfg, shape, int(mesh.devices.size))
    dp = dp_axes_of(mesh) + (("model",) if tp_fold else ())
    dps = dp if len(dp) > 1 else dp[0]
    tp = 1 if tp_fold else mesh.shape["model"]
    ns = lambda spec: NamedSharding(mesh, spec)

    pspecs = T.param_specs(cfg, tp)
    if tp_fold:
        pspecs = _strip_model(pspecs)
    aparams = T.abstract_params(cfg)
    psh = jax.tree.map(lambda s: ns(s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        bspecs = make_batch_specs(cfg, shape, dp)
        batch = {k: v[0] for k, v in bspecs.items()}
        bsh = {k: ns(v[1]) for k, v in bspecs.items()}
        opt = AdamW()
        astate = jax.eval_shape(opt.init, aparams)
        # ZeRO-style optimizer-state sharding: add DP over the leading
        # (layer-stack / vocab) axis on top of the param spec.
        dp_total = mesh.devices.size // tp

        def zero_spec(spec, leaf):
            parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
            for i, (p_, dim) in enumerate(zip(parts, leaf.shape)):
                if p_ is None and dim % dp_total == 0 and dim >= dp_total:
                    parts[i] = dps
                    break
            return P(*parts)
        mv_sh = jax.tree.map(
            lambda s, l: ns(zero_spec(s, l)), pspecs, aparams,
            is_leaf=lambda x: isinstance(x, P))
        opt_sh = type(astate)(step=ns(P()), m=mv_sh, v=mv_sh)
        if fsdp:
            psh = mv_sh        # ZeRO-3: params take the dp-sharded specs
        residual = jnp.zeros(())
        args = ((aparams, astate, jax.ShapeDtypeStruct((), jnp.float32)),
                batch)
        shardings = ((psh, opt_sh, ns(P())), bsh)
        return args, shardings

    if shape.kind == "prefill":
        bspecs = make_batch_specs(cfg, shape, dp)
        bspecs.pop("labels")
        if cfg.embedding_input:
            arg = bspecs["embeds"]
        else:
            arg = bspecs["tokens"]
        return (aparams, arg[0]), (psh, ns(arg[1]))

    # decode
    acache = T.abstract_cache(cfg, shape.global_batch, shape.seq_len)
    cspecs = T.cache_specs(cfg, shape.global_batch, dp, tp)
    if isinstance(cspecs, list):
        csh = [jax.tree.map(lambda s: ns(s), c,
                            is_leaf=lambda x: isinstance(x, P))
               for c in cspecs]
    else:
        csh = jax.tree.map(lambda s: ns(s), cspecs,
                           is_leaf=lambda x: isinstance(x, P))
    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tok_sh = ns(P(dps, None)) if shape.global_batch >= mesh.devices.size // tp \
        else ns(P(None, None))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return (aparams, acache, tok, pos), (psh, csh, tok_sh, ns(P()))


def pick_microbatches(global_batch: int, dp_size: int, seq: int,
                      target_tokens: int = 8192) -> int:
    """Gradient-accumulation factor: bound live activations to ~target
    tokens per device per microbatch (must divide the global batch)."""
    b_local = max(1, global_batch // dp_size)
    want = max(1, (b_local * seq) // target_tokens)
    m = min(want, b_local)
    while global_batch % m or (global_batch // m) % dp_size:
        m -= 1
    return max(m, 1)


def step_callable(cfg: ArchConfig, shape: ShapeConfig, mesh,
                  force_m1: bool = False, tp_fold: bool | None = None,
                  force_m: int | None = None):
    if tp_fold is None:
        tp_fold = choose_tp_fold(cfg, shape, int(mesh.devices.size))
    dp = dp_axes_of(mesh) + (("model",) if tp_fold else ())
    ctx = ShardCtx(mesh=mesh, dp_axes=dp,
                   tp_axis=None if tp_fold else "model")
    if shape.kind == "train":
        opt = AdamW()
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        if force_m1:
            m = 1
        elif force_m:
            m = force_m
        else:
            m = pick_microbatches(shape.global_batch, dp_size, shape.seq_len)
        fn = make_train_step(cfg, ctx, opt, num_microbatches=m)
        return fn
    if shape.kind == "prefill":
        def prefill_fn(params, x):
            if cfg.embedding_input:
                return T.prefill(params, cfg, ctx, embeds=x)
            return T.prefill(params, cfg, ctx, tokens=x)
        return prefill_fn

    def decode_fn(params, cache, tok, pos):
        return T.decode_step(params, cache, tok, pos, cfg, ctx)
    return decode_fn


def _compile_cell(cfg: ArchConfig, shape: ShapeConfig, mesh,
                  force_m1: bool = False, force_m: int | None = None,
                  fsdp: bool = False) -> dict:
    """Lower + compile one cell; return raw HLO-derived numbers."""
    t0 = time.perf_counter()
    args, shardings = input_specs(cfg, shape, mesh, fsdp=fsdp)
    fn = step_callable(cfg, shape, mesh, force_m1=force_m1, force_m=force_m)
    # donate the mutable state: train state (params/opt) and decode cache —
    # XLA aliases the buffers so cache/param updates happen in place
    # (§Perf decode iteration 2: an undonated KV cache costs a full
    # read+write copy of the cache per token)
    donate = (0,) if shape.kind == "train" else \
        ((1,) if shape.kind == "decode" else ())
    with use_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=shardings,
                          donate_argnums=donate).lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    return {
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "collective_bytes": collective_bytes_from_hlo(hlo),
        "memory_analysis": _mem_dict(compiled.memory_analysis()),
        "hlo_bytes": len(hlo),
    }


def jaxpr_flops_cell(cfg: ArchConfig, shape: ShapeConfig, mesh) -> float:
    """Exact global FLOPs of the cell's step (loop-aware jaxpr walk)."""
    from repro.launch.flops import flops_of_callable
    args, _ = input_specs(cfg, shape, mesh)
    fn = step_callable(cfg, shape, mesh)
    with use_mesh(mesh):
        return flops_of_callable(fn, *args)


def _extrapolate(r1: dict, r2: dict, L: int) -> dict:
    """XLA's cost_analysis counts a while-loop (layer scan) body ONCE.

    The stack is layer-uniform, so HLO terms are affine in L:
    T(L) = T(1) + (L-1) * (T(2) - T(1)).  Exact for flops/bytes/collectives
    — except when XLA fuses/CSEs the 1- and 2-layer modules differently,
    which can make the slope negative; clamp each term to the max of the
    single-compile values (a safe lower bound) in that case.
    """
    def lin(a, b):
        v = a + (L - 1) * (b - a)
        return v if v >= max(a, b) else max(a, b)

    out = {}
    for k in ("flops", "bytes_accessed"):
        out[k] = lin(r1[k], r2[k])
    c1, c2 = r1["collective_bytes"], r2["collective_bytes"]
    coll = {}
    for k in list(_COLLECTIVES) + ["total", "weighted"]:
        coll[k] = lin(c1[k], c2[k])
    out["collective_bytes"] = coll
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, tp_size: int = 16,
             save_coll: bool = False, force_m: int | None = None,
             variant: str = "", kv_int8: bool = False,
             fsdp: bool = False) -> dict:
    cfg = get_arch(arch)
    if save_coll:
        cfg = cfg.scaled(remat_save_collectives=True)
    if kv_int8:
        cfg = cfg.scaled(kv_cache_dtype="int8")
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod, tp_size=tp_size)
    dp_ = 256 // tp_size
    mesh_tag = (f"2x{dp_}x{tp_size}" if multi_pod else f"{dp_}x{tp_size}")
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": mesh_tag,
        "variant": variant,
        "devices": int(mesh.devices.size),
    }
    if not shape_applicable(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = ("full-attention arch at 524k decode "
                         "(needs sub-quadratic attention; DESIGN.md §6)")
        return rec

    try:
        full = _compile_cell(cfg, shape, mesh, force_m=force_m, fsdp=fsdp)
        rec.update(full)
        rec["status"] = "ok"
        rec["jaxpr_flops_global"] = jaxpr_flops_cell(cfg, shape, mesh)
        # scan-body linearization (hybrid decode is an unrolled loop: exact).
        # Accounting compiles run with microbatching OFF: per-step totals of
        # flops/bytes/collectives are schedule-invariant, and M=1 keeps them
        # outside any loop body XLA would count once.
        if not (cfg.family == "hybrid" and shape.kind == "decode"):
            r1 = _compile_cell(cfg.scaled(num_layers=1), shape, mesh,
                               force_m1=True, fsdp=fsdp)
            r2 = _compile_cell(cfg.scaled(num_layers=2), shape, mesh,
                               force_m1=True, fsdp=fsdp)
            rec["extrapolated"] = _extrapolate(r1, r2, cfg.num_layers)
        else:
            rec["extrapolated"] = {
                "flops": full["flops"],
                "bytes_accessed": full["bytes_accessed"],
                "collective_bytes": full["collective_bytes"],
            }
        if verbose:
            e = rec["extrapolated"]
            print(f"[ok] {arch} x {shape_name} x {rec['mesh']}  "
                  f"flops={e['flops']:.3e} bytes={e['bytes_accessed']:.3e} "
                  f"coll={e['collective_bytes']['weighted']:.3e}  "
                  f"(compile {full['compile_s']:.1f}s)")
            print("   memory:", rec["memory_analysis"])
    except Exception as e:          # a failing cell is a bug; record it
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[ERROR] {arch} x {shape_name} x {rec['mesh']}: "
                  f"{rec['error']}")
    return rec


def _mem_dict(mem):
    if mem is None:
        return None
    out = {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "temp_size_in_bytes"):
        if hasattr(mem, attr):
            out[attr] = int(getattr(mem, attr))
    return out or str(mem)


def save_record(rec: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"__{rec['variant']}" if rec.get("variant") else ""
    name = (f"dryrun_{rec['mesh'].replace('x', '_')}_{rec['arch']}_"
            f"{rec['shape']}{suffix}.json")
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tp", type=int, default=16,
                    help="TP degree (256/tp becomes DP) — §Perf variants")
    ap.add_argument("--save-coll", action="store_true",
                    help="remat policy: save post-psum activations")
    ap.add_argument("--force-m", type=int, default=None,
                    help="override gradient-accumulation factor")
    ap.add_argument("--variant", default="",
                    help="tag for the results file (perf experiments)")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 KV cache (decode shapes)")
    ap.add_argument("--fsdp", action="store_true",
                    help="ZeRO-3: shard PARAMS over dp (fit-HBM variant)")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}
    cells = []
    if args.all:
        for a in ARCH_NAMES:
            for s in SHAPES:
                for mp in meshes[args.mesh]:
                    cells.append((a, s, mp))
    else:
        assert args.arch and args.shape
        for mp in meshes[args.mesh]:
            cells.append((args.arch, args.shape, mp))

    failures = 0
    for a, s, mp in cells:
        if args.skip_existing:
            mesh_tag = "2_16_16" if mp else "16_16"
            p = os.path.join(RESULTS_DIR, f"dryrun_{mesh_tag}_{a}_{s}.json")
            if os.path.exists(p):
                with open(p) as f:
                    if json.load(f).get("status") in ("ok", "skipped"):
                        continue
        rec = run_cell(a, s, mp, tp_size=args.tp, save_coll=args.save_coll,
                       force_m=args.force_m, variant=args.variant,
                       kv_int8=args.kv_int8, fsdp=args.fsdp)
        save_record(rec)
        failures += rec["status"] == "error"
    print(f"done; {failures} failing cells")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    force_fake_devices()
    main()
