"""MODEL_FLOPS / model-bytes accounting (6*N*D-style MFU denominators)."""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeConfig


def param_count(cfg: ArchConfig, active_only: bool = False) -> float:
    """Parameter count from the config (matches models/transformer init)."""
    d, v = cfg.d_model, cfg.vocab_size
    n = 2 * d * v + d                      # embed + unembed + final norm
    for _ in range(1):                     # per-layer, x num_layers below
        pass
    per_layer = d                          # ln1
    if cfg.family in ("dense", "moe", "hybrid"):
        hd = cfg.head_dim
        per_layer += d * (cfg.num_heads * hd) * 2 \
            + d * (cfg.num_kv_heads * hd) * 2          # wq, wo, wk, wv
        per_layer += d                                   # ln2
    if cfg.family == "dense":
        mult = 3 if cfg.ffn_activation == "swiglu" else 2
        per_layer += mult * d * cfg.d_ff
    elif cfg.family == "moe":
        e = cfg.top_k if active_only else cfg.num_experts
        per_layer += d * cfg.num_experts                 # router (always)
        per_layer += 3 * d * cfg.moe_d_ff * e
        per_layer += 3 * d * cfg.moe_d_ff * cfg.num_shared_experts
    elif cfg.family in ("ssm", "hybrid"):
        di, ns, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        conv_ch = di + 2 * ns
        per_layer += d * di + d * conv_ch + d * h        # in_z/in_xbc/in_dt
        per_layer += cfg.ssm_conv_width * conv_ch + conv_ch
        per_layer += 3 * h + di + di * d                 # A/D/dtb, norm, out
        if cfg.family == "hybrid":
            per_layer += 2 * d                           # attn/ssm norms
            mult = 3 if cfg.ffn_activation == "swiglu" else 2
            per_layer += mult * d * cfg.d_ff
    return n + cfg.num_layers * per_layer


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Global 'useful' FLOPs of one step — the standard MFU numerator.

    train: 6*N*D (fwd 2ND + bwd 4ND); prefill: 2*N*D; decode: 2*N*B
    (one token per sequence).  N excludes embedding lookups (standard),
    uses active params for MoE.
    """
    n_active = param_count(cfg, active_only=True) \
        - cfg.d_model * cfg.vocab_size          # embed lookup is a gather
    if shape.kind == "train":
        d_tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * d_tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.seq_len * shape.global_batch
    return 2.0 * n_active * shape.global_batch


def model_bytes_decode(cfg: ArchConfig, shape: ShapeConfig,
                       param_bytes: int = 2) -> float:
    """Minimum HBM bytes of one decode step: params + KV/SSM state read.

    This is the bandwidth-roofline numerator for decode shapes (decode is
    bandwidth-bound; FLOP-based MFU is meaningless there).
    """
    n = param_count(cfg, active_only=True)
    b = shape.global_batch
    if cfg.family == "ssm":
        state = b * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
        state += b * (cfg.ssm_conv_width - 1) * (cfg.d_inner
                                                 + 2 * cfg.ssm_state) * 2
        kv = cfg.num_layers * state
    elif cfg.family == "hybrid":
        kv = 0.0
        for i in range(cfg.num_layers):
            glob = i in (0, cfg.num_layers // 2, cfg.num_layers - 1)
            s = shape.seq_len if glob else min(cfg.sliding_window,
                                               shape.seq_len)
            kv += 2 * b * s * cfg.num_kv_heads * cfg.head_dim * param_bytes
            kv += b * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
    else:
        kv = (2 * cfg.num_layers * b * shape.seq_len
              * cfg.num_kv_heads * cfg.head_dim * param_bytes)
    return n * param_bytes + kv
