"""Cluster router: N slot-engine replicas behind one admission queue.

One ``ContinuousScheduler`` (DESIGN.md §8) saturates a single slot batch;
serving the paper's stack to real traffic needs MANY such batches.  The
``SlotState`` runtime is functional — the engine holds only compiled
executables and parameters, all mutable serving state lives in the pytree
— so N replicas are simply N independent ``SlotState``s driven through
ONE engine's cached executables.  No per-replica compile, no parameter
copies, and per-request images stay bit-identical to the one-shot engine
no matter which replica serves them.

What the router adds over the single-replica scheduler (DESIGN.md §13):

* **Occupancy routing** — each admissible request (FIFO) enters the
  least-occupied replica with a free slot, keeping step batches evenly
  full so no replica idles while another queues.
* **SLO-aware admission: degrade, don't queue** — with a
  ``RouterSLO(deadline_steps=...)`` and a sampler bank, a request whose
  queue wait has eaten its deadline budget is admitted at a LOWER tier
  from the bank (largest step budget that still meets the deadline, else
  the bank's cheapest tier best-effort) instead of waiting for its
  original tier.  Deadlines are counted in ROUNDS (one round = one
  ``slot_step`` across the cluster), so degradation decisions — and the
  committed bench result that degradation beats queueing on p95 SLO
  attainment — are deterministic on any machine.
* **Decode off the hot loop** — retirement decodes and progressive
  preview decodes are DISPATCHED between steps (JAX async) and fetched
  only after the next admission pass, so pixel movement never blocks
  admission or stepping.
* **Streaming** — ``stream()`` yields per-request progress events
  (``admitted`` / ``preview`` / ``finished``); previews are in-flight
  latents decoded every ``preview_every`` rounds (time-to-first-pixel).

Ledger contract: every replica scatters INTEGER counters into the same
``LedgerAccum`` bucket layout, and
``pipeline.merge_ledger_accums``/``energy_report_cluster`` sum them
before reporting — the energy headline is bit-identical across replica
counts, routing decisions, and admission orders, and (degradation aside)
to the same requests served one-shot.  Tests: tests/test_router.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterator, Optional

import numpy as np

from repro.launch.scheduler import _latency_metrics, poll_arrivals


@dataclasses.dataclass(frozen=True)
class RouterSLO:
    """Round-denominated latency SLO for cluster admission.

    ``deadline_steps``: enqueue->image budget in router rounds (a round
    advances every occupied replica by one denoising iteration, so the
    budget reads as "denoising-step times").  ``degrade=True`` is the
    router's contract — under overload, serve a cheaper tier now rather
    than the requested tier late; ``degrade=False`` is the queueing
    baseline (positive control in tests/benches: it misses the SLO the
    degrading router meets).
    """
    deadline_steps: Optional[int] = None
    degrade: bool = True

    def met(self, req) -> Optional[bool]:
        """Did ``req`` finish within its round budget? (None: no SLO.)"""
        if self.deadline_steps is None or req.finish_round is None:
            return None
        return (req.finish_round - req.arrival_round) <= self.deadline_steps


class ClusterRouter:
    """Route requests across ``replicas`` slot-state replicas.

    ``engine`` is shared: replica ``i`` is an independent ``SlotState``
    stepped through the same cached executables (the functional slot API
    makes this safe — see ``DiffusionEngine.init_slots``).  ``engines``
    optionally supplies one engine per replica instead (e.g. each built
    over its own device subset); they must share the pipeline config so
    executables, images and ledger buckets agree.

    ``bank`` defaults from ``engine.policies.bank`` (the ``ServePolicies``
    bundle), like the single-replica scheduler.  ``preview_every=K`` (>0)
    dispatches a progressive preview decode of every in-flight row each K
    rounds and streams it as a ``preview`` event.
    """

    def __init__(self, engine, replicas: int, slots_per_replica: int,
                 bank=None, slo: Optional[RouterSLO] = None,
                 preview_every: int = 0, engines=None):
        from repro.diffusion import solvers

        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if engines is not None:
            engines = list(engines)
            if len(engines) != replicas:
                raise ValueError(
                    f"engines= carries {len(engines)} engines for "
                    f"{replicas} replicas")
            for e in engines:
                if e.cfg != engine.cfg:
                    raise ValueError(
                        "per-replica engines must share the pipeline "
                        "config — differing configs fork executables, "
                        "images and ledger buckets")
        self.engine = engine
        self.engines = engines or [engine] * replicas
        self.replicas = replicas
        self.slots_per_replica = slots_per_replica
        if bank is None:
            bank = engine.policies.bank
        self.bank = solvers.as_bank(bank) if bank is not None else None
        self.slo = slo or RouterSLO()
        if (self.slo.deadline_steps is not None and self.slo.degrade
                and self.bank is None):
            raise ValueError(
                "RouterSLO degradation needs a sampler bank — the lower "
                "tiers a request can degrade to must be compiled into the "
                "step executable (pass bank= or build the engine with "
                "ServePolicies(bank=...))")
        self.preview_every = preview_every

    # -- lifecycle -------------------------------------------------------
    def warmup(self) -> float:
        """Compile step/encode/decode executables off the serving clock.

        One warmup covers every replica: shared-engine replicas reuse the
        same cache entries, per-replica engines each warm their own.
        """
        import jax
        import jax.numpy as jnp

        t0 = time.perf_counter()
        for eng in dict.fromkeys(self.engines):  # unique, order-kept
            cfg = eng.cfg
            state = eng.init_slots(self.slots_per_replica, bank=self.bank)
            toks = jnp.zeros((1, cfg.text.max_len), jnp.int32)
            un = toks if state.uncond_context is not None else None
            state = eng.admit(state, 0, toks, jax.random.PRNGKey(0),
                              uncond_tokens=un)
            state = eng.slot_step(state)
            k = 1
            while k <= self.slots_per_replica:
                jax.block_until_ready(
                    eng.decode_slots(state, list(range(k))))
                k *= 2
        return time.perf_counter() - t0

    # -- SLO admission ---------------------------------------------------
    def _admission_tier(self, req, round_idx: int) -> int:
        """Bank index to admit ``req`` at, degrading if its wait demands.

        Deterministic round arithmetic: with ``waited`` rounds already
        spent queueing, the request meets its deadline only if
        ``waited + num_steps <= deadline_steps``.  When the requested
        tier cannot, pick the LARGEST-budget strictly-lower tier that
        can (cheapest acceptable quality loss); when none can, fall back
        to the bank's cheapest tier (best effort).  Never upgrades.
        """
        pidx = req.policy_index
        slo = self.slo
        if (slo.deadline_steps is None or not slo.degrade
                or self.bank is None):
            return pidx
        waited = round_idx - req.arrival_round
        steps = self.bank[pidx].num_steps
        if waited + steps <= slo.deadline_steps:
            return pidx
        fitting = [i for i, p in enumerate(self.bank)
                   if p.num_steps < steps
                   and waited + p.num_steps <= slo.deadline_steps]
        if fitting:
            return max(fitting, key=lambda i: (self.bank[i].num_steps, -i))
        cheapest = min(range(len(self.bank)),
                       key=lambda i: (self.bank[i].num_steps, i))
        return cheapest if self.bank[cheapest].num_steps < steps else pidx

    # -- serving ---------------------------------------------------------
    def stream(self, requests: list) -> Iterator[dict]:
        """Serve ``requests``, yielding progress events as they happen.

        Events are dicts with ``event`` in ``{"admitted", "preview",
        "finished"}`` plus ``rid`` / ``replica`` / ``slot`` / ``round`` /
        ``t_s``; ``preview`` events carry the decoded in-flight ``image``
        and the row's current ``step``; ``finished`` events carry the
        final ``image`` (also stored on the request).  The generator
        returns once every request has finished — the router never drops
        a request.
        """
        import jax

        if self.bank is None:
            for r in requests:
                if r.policy_index != 0:
                    raise ValueError(
                        f"request {r.rid} carries policy_index="
                        f"{r.policy_index} but the router has no bank")
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        ready: list = []
        owners = [dict() for _ in range(self.replicas)]
        states = [eng.init_slots(self.slots_per_replica, bank=self.bank)
                  for eng in self.engines]
        decode_jobs: list = []    # (req, round, images_row) dispatched
        preview_jobs: list = []   # (req, slot_step_idx, images_row)
        completed = 0
        round_idx = 0
        stepped_rows = 0
        step_calls = 0
        step_wall = 0.0
        self._t0 = t0 = time.perf_counter()
        while completed < len(requests) or decode_jobs or preview_jobs:
            now = time.perf_counter() - t0
            poll_arrivals(pending, ready, now)
            for r in ready:
                if r.arrival_round is None:
                    r.arrival_round = round_idx
            # FIFO admission, least-occupied replica first; degrade
            # decision happens HERE, with the request's realized wait
            while ready:
                free = [(len(owners[i]), i) for i in range(self.replicas)
                        if len(owners[i]) < self.slots_per_replica]
                if not free:
                    break
                req = ready.pop(0)
                _, ri = min(free)
                slot = next(s for s in range(self.slots_per_replica)
                            if s not in owners[ri])
                pidx = self._admission_tier(req, round_idx)
                if pidx != req.policy_index:
                    req.degraded_from = req.tier
                    req.policy_index = pidx
                    req.tier = self.bank[pidx].label()
                states[ri] = self.engines[ri].admit(
                    states[ri], slot, req.tokens, None,
                    uncond_tokens=req.uncond_tokens, latents=req.latents,
                    policy_index=req.policy_index)
                owners[ri][slot] = req
                req.replica = ri
                req.admitted_s = time.perf_counter() - t0
                yield {"event": "admitted", "rid": req.rid, "replica": ri,
                       "slot": slot, "round": round_idx,
                       "tier": req.tier, "degraded_from": req.degraded_from,
                       "t_s": req.admitted_s}
            # fetch decodes dispatched LAST round — they computed while
            # we admitted, so pixel movement never blocked admission
            for req, fin_round, row in decode_jobs:
                req.image = np.asarray(jax.device_get(row))[0]
                req.finished_s = time.perf_counter() - t0
                req.finish_round = fin_round
                completed += 1
                yield {"event": "finished", "rid": req.rid,
                       "replica": req.replica, "round": fin_round,
                       "tier": req.tier, "image": req.image,
                       "t_s": req.finished_s}
            decode_jobs = []
            for req, at_step, row in preview_jobs:
                img = np.asarray(jax.device_get(row))[0]
                req.previews += 1
                pv_t = time.perf_counter() - t0
                if req.first_preview_s is None:
                    req.first_preview_s = pv_t
                yield {"event": "preview", "rid": req.rid,
                       "replica": req.replica, "round": round_idx,
                       "step": at_step, "image": img, "t_s": pv_t}
            preview_jobs = []
            if not any(owners):
                if completed < len(requests) and pending:
                    time.sleep(max(pending[0].arrival_s
                                   - (time.perf_counter() - t0), 0.0))
                continue
            # one router round: step every occupied replica
            for ri in range(self.replicas):
                if not owners[ri]:
                    continue
                states[ri] = self.engines[ri].slot_step(states[ri])
                step_calls += 1
                step_wall += self.engines[ri].last_wall_s
                stepped_rows += len(owners[ri])
            round_idx += 1
            # dispatch retirement decodes (async) and free the slots NOW
            # — the freed rows are admissible next pass, the pixels are
            # fetched after it
            for ri in range(self.replicas):
                if not owners[ri]:
                    continue
                eng = self.engines[ri]
                done = [s for s in eng.finished_slots(states[ri])
                        if s in owners[ri]]
                if done:
                    imgs = eng.decode_slots(states[ri], done)
                    for j, slot in enumerate(done):
                        decode_jobs.append((owners[ri].pop(slot),
                                            round_idx, imgs[j:j + 1]))
                    states[ri] = eng.retire(states[ri], done)
            # progressive previews of rows still in flight
            if self.preview_every and round_idx % self.preview_every == 0:
                for ri in range(self.replicas):
                    slots = sorted(owners[ri])
                    if not slots:
                        continue
                    eng = self.engines[ri]
                    pv = eng.decode_preview(states[ri], slots)
                    step_of = jax.device_get(states[ri].step_idx)
                    for j, slot in enumerate(slots):
                        preview_jobs.append((owners[ri][slot],
                                             int(step_of[slot]),
                                             pv[j:j + 1]))
        self._states = states
        self._rounds = round_idx
        self._step_calls = step_calls
        self._step_wall = step_wall
        self._stepped_rows = stepped_rows

    def run(self, requests: list, ledger: bool = False) -> dict:
        """Drain :meth:`stream` and return serving metrics.

        ``ledger=True`` adds the merged-replica energy report
        (``pipeline.energy_report_cluster``) — bit-identical across
        replica counts.  ``metrics["states"]`` carries the per-replica
        ``SlotState``s (callers pop it before serializing).
        """
        events = {"admitted": 0, "preview": 0, "finished": 0}
        for ev in self.stream(requests):
            events[ev["event"]] += 1
        makespan = time.perf_counter() - self._t0
        states = self._states
        metrics = {
            "mode": "cluster_router",
            "denoiser_family": self.engine.denoiser.family,
            "replicas": self.replicas,
            "slots_per_replica": self.slots_per_replica,
            "rounds": self._rounds,
            "engine_steps": self._step_calls,
            "step_wall_s": self._step_wall,
            "mean_occupancy": self._stepped_rows / max(
                self._step_calls * self.slots_per_replica, 1),
            "events": events,
            "dropped": len(requests) - events["finished"],
            "policies": self.engine.policies.describe(),
            **_latency_metrics(requests, makespan, bank=self.bank,
                               default_steps=self.engine.cfg.ddim
                               .num_inference_steps),
        }
        if self.slo.deadline_steps is not None:
            met = [self.slo.met(r) for r in requests]
            metrics["slo"] = {
                "deadline_steps": self.slo.deadline_steps,
                "degrade": self.slo.degrade,
                "met": int(sum(bool(m) for m in met)),
                "attainment": sum(bool(m) for m in met)
                / max(len(met), 1),
            }
        if self.preview_every:
            firsts = [r.first_preview_s for r in requests
                      if r.first_preview_s is not None]
            metrics["preview"] = {
                "every": self.preview_every,
                "decodes": events["preview"],
                "first_preview_s": (_summary_or_none(firsts)),
            }
        if ledger:
            from repro.diffusion.pipeline import energy_report_cluster

            rep = energy_report_cluster(self.engine.cfg,
                                        [st.accum for st in states],
                                        bank=self.bank)
            # banked summaries carry per-policy breakdown lists; the
            # unbanked summary is all scalars
            metrics["energy"] = (rep.summary() if self.bank is not None
                                 else {k: float(v)
                                       for k, v in rep.summary().items()})
        metrics["states"] = states
        return metrics


def _summary_or_none(vals):
    from repro.launch.scheduler import _lat_summary

    return _lat_summary(vals) if vals else None


def _main(argv=None) -> int:
    """Router smoke entrypoint (the CI router-smoke step).

    ``--check-identity`` serves the same trace at 1 replica and at
    ``--replicas``, then asserts the merged energy headline is
    bit-identical and no request was dropped — the DESIGN.md §13
    invariant, executable anywhere.
    """
    import argparse
    import json

    import jax

    from repro.diffusion.engine import DiffusionEngine
    from repro.launch.cli import (add_policy_args, config_from_args,
                                  policies_from_args)
    from repro.launch.scheduler import (apply_trace, bursty_trace,
                                        make_requests)

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    add_policy_args(ap)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--slots", type=int, default=2,
                    help="slots per replica")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--burst", type=int, default=4)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--slo-steps", type=int, default=0,
                    help="deadline in router rounds (0: no SLO)")
    ap.add_argument("--no-degrade", action="store_true",
                    help="queue instead of degrading under overload")
    ap.add_argument("--preview-every", type=int, default=0)
    ap.add_argument("--check-identity", action="store_true",
                    help="assert ledger bit-identity 1 vs N replicas")
    args = ap.parse_args(argv)

    policies = policies_from_args(args)
    cfg = config_from_args(args, policies=policies, steps=args.steps)
    eng = DiffusionEngine(cfg, key=jax.random.PRNGKey(0),
                          policies=policies)
    slo = RouterSLO(deadline_steps=args.slo_steps or None,
                    degrade=not args.no_degrade)

    def serve(replicas):
        router = ClusterRouter(eng, replicas, args.slots,
                               slo=slo if replicas == args.replicas
                               else RouterSLO(),
                               preview_every=args.preview_every)
        reqs = make_requests(cfg, args.requests, seed=7,
                             bank=router.bank)
        apply_trace(reqs, bursty_trace(args.requests, args.burst, 0.05))
        router.warmup()
        m = router.run(reqs, ledger=True)
        m.pop("states")
        return m, reqs

    m, reqs = serve(args.replicas)
    out = {k: v for k, v in m.items()}
    if args.check_identity:
        m1, reqs1 = serve(1)
        out["ledger_bit_identical_across_replicas"] = (
            m["energy"] == m1["energy"])
        out["images_bit_identical_across_replicas"] = all(
            np.array_equal(a.image, b.image)
            for a, b in zip(reqs, reqs1))
        assert out["ledger_bit_identical_across_replicas"], (
            m["energy"], m1["energy"])
        assert out["images_bit_identical_across_replicas"]
        assert m["dropped"] == 0 and m1["dropped"] == 0, "dropped requests"
    print(json.dumps(out, indent=2, default=str))
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
