"""Continuous-batching scheduler over the slot-state DiffusionEngine.

The micro-batching front-end (``repro.launch.serve_diffusion.serve``) drains
a pre-collected request list: a request arriving while a batch is mid-scan
waits the batch's FULL generation before it can even start, so tail latency
under bursty traffic approaches 2x the generation time.  The continuous
scheduler here instead keeps a persistent slot batch in flight
(``DiffusionEngine.init_slots`` / ``slot_step``): every denoising step
advances all occupied slots — each at its OWN iteration index — and between
steps finished rows are decoded + retired and queued requests admitted into
the freed slots.  A new request therefore starts at the next step boundary
(one UNet iteration away) instead of the next batch boundary (a whole
generation away).

The denoising steps are phase-heterogeneous by construction (the paper's
``tips_active_iters`` schedule: TIPS only active in late iterations), so a
slot batch legitimately mixes precision regimes across rows — the per-row
``tips_active`` plumbing in the UNet is what makes the interleaving exact.

Determinism contract: images are bit-identical per request to the one-shot
engine at the same per-request latents, and the drained ``LedgerAccum``
yields an energy headline bit-identical to the same requests served
one-shot (``pipeline.energy_report_from_accum``) — slot count, arrival
order, and occupancy cannot move a counter.  Tests: tests/test_continuous.py.

Two schedulers share the request/trace vocabulary so benchmarks compare
them under identical traces:

``ContinuousScheduler``  — slot-based in-flight batching (this module's point)
``FixedBatchScheduler``  — the micro-batching baseline, same arrival gating
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One text-to-image request flowing through a scheduler."""
    rid: int
    tokens: object                  # (1, text_len) int32 prompt tokens
    arrival_s: float                # seconds after serving start
    latents: object = None          # (1, S, S, C) initial noise (per-request)
    uncond_tokens: object = None    # (1, text_len) or None (CFG off)
    policy_index: int = 0           # SamplerPolicy slot in the serving bank
    tier: str = ""                  # quality-tier label (trace bookkeeping)
    edit_window: object = None      # (y0, x0, h, w) latent px (edit requests)
    # filled by the scheduler:
    admitted_s: Optional[float] = None
    finished_s: Optional[float] = None
    image: object = None
    # filled by the cluster router (repro.launch.router):
    replica: Optional[int] = None   # replica that served the request
    degraded_from: str = ""         # original tier label if SLO-degraded
    arrival_round: Optional[int] = None   # router round of arrival
    finish_round: Optional[int] = None    # router round the image finished
    previews: int = 0               # progressive preview decodes streamed
    first_preview_s: Optional[float] = None  # time-to-first-pixel proxy

    @property
    def latency_s(self) -> Optional[float]:
        if self.finished_s is None:
            return None
        return self.finished_s - self.arrival_s

    @property
    def queue_s(self) -> Optional[float]:
        if self.admitted_s is None:
            return None
        return self.admitted_s - self.arrival_s


def make_requests(cfg, n: int, seed: int = 7, key=None,
                  use_cfg: Optional[bool] = None, bank=None) -> list:
    """n requests with per-request prompt tokens and initial latents.

    Latents are drawn PER REQUEST (independent fold of ``seed``), so the
    same request produces the same image no matter which scheduler, slot,
    or batch serves it — the property the bit-identity tests lean on.
    Arrival times start at 0; apply a trace with :func:`apply_trace`.

    ``bank`` (tuple of ``solvers.SamplerPolicy``): assign quality tiers
    round-robin — request ``i`` carries ``policy_index = i % len(bank)``
    and the policy's label as its ``tier``, so a mixed-tier trace
    exercises every bank entry evenly and per-tier latency metrics have
    balanced populations.
    """
    import jax
    import jax.numpy as jnp

    key = key if key is not None else jax.random.PRNGKey(seed)
    toks = jax.random.randint(jax.random.fold_in(key, 0),
                              (n, cfg.text.max_len), 0, cfg.text.vocab_size)
    if use_cfg is None:
        use_cfg = cfg.ddim.guidance_scale != 1.0
    s, c = cfg.unet.latent_size, cfg.unet.in_channels
    reqs = []
    for i in range(n):
        lat = jax.random.normal(jax.random.fold_in(key, 1 + i),
                                (1, s, s, c))
        un = (jnp.zeros((1, cfg.text.max_len), jnp.int32) if use_cfg
              else None)
        pidx = i % len(bank) if bank else 0
        tier = bank[pidx].label() if bank else ""
        reqs.append(Request(rid=i, tokens=toks[i:i + 1], arrival_s=0.0,
                            latents=lat, uncond_tokens=un,
                            policy_index=pidx, tier=tier))
    return reqs


def make_edit_requests(cfg, n: int, seed: int = 7, key=None,
                       use_cfg: Optional[bool] = None,
                       edit_fraction: float = 0.25) -> list:
    """n img2img/EDIT requests: one base latent, localized per-request edits.

    Every request starts from the SAME base noise latent (the img2img
    source image's encoding) with an independent perturbation confined to
    a random ``edit_fraction``-sided square window — the workload shape
    temporal patch reuse is built for: outside the window, consecutive
    requests (and consecutive denoising steps early in the schedule)
    present near-identical activations, so a reuse-enabled engine
    recomputes only the edited patches.  Requests flow through the SAME
    ``admit(..., latents=)`` path as ``make_requests`` — the scheduler is
    oblivious to which workload it is serving.

    Each request records its perturbation rectangle as ``edit_window``
    (``(y0, x0, h, w)`` in latent pixels) — the a-priori changed-region
    knowledge an inpainting/edit front-end has up front.  Feeding it to
    ``ReusePolicy(apriori_window=...)`` lets the edit engine skip the
    patch-delta kernel and activate exactly the window's patches.
    """
    import jax
    import jax.numpy as jnp

    key = key if key is not None else jax.random.PRNGKey(seed)
    toks = jax.random.randint(jax.random.fold_in(key, 0),
                              (n, cfg.text.max_len), 0, cfg.text.vocab_size)
    if use_cfg is None:
        use_cfg = cfg.ddim.guidance_scale != 1.0
    s, c = cfg.unet.latent_size, cfg.unet.in_channels
    base = jax.random.normal(jax.random.fold_in(key, 1), (1, s, s, c))
    w = max(1, int(round(edit_fraction * s)))
    reqs = []
    for i in range(n):
        ek = jax.random.fold_in(key, 2 + i)
        yi, xi = (int(v) for v in jax.random.randint(
            jax.random.fold_in(ek, 0), (2,), 0, s - w + 1))
        patch = jax.random.normal(jax.random.fold_in(ek, 1), (1, w, w, c))
        lat = base.at[:, yi:yi + w, xi:xi + w, :].set(
            base[:, yi:yi + w, xi:xi + w, :] * 0.5 + patch)
        un = (jnp.zeros((1, cfg.text.max_len), jnp.int32) if use_cfg
              else None)
        reqs.append(Request(rid=i, tokens=toks[i:i + 1], arrival_s=0.0,
                            latents=lat, uncond_tokens=un,
                            edit_window=(yi, xi, w, w)))
    return reqs


def bursty_trace(n: int, burst: int, gap_s: float, start_s: float = 0.0
                 ) -> list:
    """Deterministic bursty arrivals: ``burst`` requests every ``gap_s``."""
    return [start_s + (i // max(burst, 1)) * gap_s for i in range(n)]


def poisson_trace(n: int, rate_per_s: float, seed: int = 0) -> list:
    """Poisson arrivals at ``rate_per_s`` (cumulative exponential gaps)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate_per_s, 1e-9), size=n)
    return list(np.cumsum(gaps))


def apply_trace(requests: list, arrivals: list) -> list:
    for r, a in zip(requests, arrivals):
        r.arrival_s = float(a)
    return requests


def poll_arrivals(pending: list, ready: list, now: float) -> None:
    """Move every request whose ``arrival_s`` has passed onto ``ready``.

    ``pending`` must be sorted by ``(arrival_s, rid)``; FIFO order within
    the ready queue follows from that sort.  Shared by both schedulers
    and the cluster router so arrival gating cannot drift between them.
    """
    while pending and pending[0].arrival_s <= now:
        ready.append(pending.pop(0))


def _lat_summary(lats) -> dict:
    lats = np.asarray(lats, dtype=np.float64)
    return {
        "mean": float(lats.mean()),
        "p50": float(np.percentile(lats, 50)),
        "p95": float(np.percentile(lats, 95)),
        "max": float(lats.max()),
    }


def _latency_metrics(requests: list, makespan_s: float,
                     bank=None, default_steps: int = 0) -> dict:
    lats = [r.latency_s for r in requests]
    queues = np.asarray([r.queue_s for r in requests], dtype=np.float64)
    out = {
        "requests": len(requests),
        "makespan_s": makespan_s,
        "goodput_imgs_per_s": len(requests) / max(makespan_s, 1e-9),
        "latency_s": _lat_summary(lats),
        "queue_wait_s": {
            "mean": float(queues.mean()),
            "p95": float(np.percentile(queues, 95)),
        },
    }
    # steps-normalized goodput: mixed step budgets make raw imgs/s unfair
    # (an 8-step draft is not a 25-step quality image) — denoising steps
    # completed per second is the tier-neutral throughput
    steps_of = (lambda r: bank[r.policy_index].num_steps) if bank \
        else (lambda r: default_steps)
    total_steps = sum(steps_of(r) for r in requests)
    if total_steps:
        out["goodput_steps_per_s"] = total_steps / max(makespan_s, 1e-9)
    tiers = sorted({r.tier for r in requests if r.tier})
    if tiers:
        out["per_tier"] = {
            t: {"requests": sum(r.tier == t for r in requests),
                "latency_s": _lat_summary(
                    [r.latency_s for r in requests if r.tier == t])}
            for t in tiers}
    degraded = sorted({r.degraded_from for r in requests if r.degraded_from})
    if degraded:
        # SLO-aware admission (router): per ORIGINAL tier, how many
        # requests were served at a lower tier instead of queueing
        out["degraded_per_tier"] = {
            t: sum(r.degraded_from == t for r in requests) for t in degraded}
        out["degraded_requests"] = sum(bool(r.degraded_from)
                                       for r in requests)
    return out


class ContinuousScheduler:
    """Slot-based in-flight scheduler (continuous batching).

    ``engine`` is a ``DiffusionEngine``; ``num_slots`` fixes the step
    executable's batch signature for the whole run.  ``run`` drives a
    request list with wall-clock arrival gating: a request becomes
    admissible once ``now >= arrival_s``, enters the first free slot
    between steps, and its image is decoded the step its slot finishes.

    ``bank`` (tuple of ``solvers.SamplerPolicy``) turns on mixed-tier
    serving: each request's ``policy_index`` selects its solver and step
    budget from the bank, all inside ONE step executable (the engine's
    per-row coefficient gathers).  ``policy_index`` is a dynamic admit
    argument, so the step program never retraces on tier composition.
    """

    def __init__(self, engine, num_slots: int, bank=None):
        from repro.diffusion import solvers

        self.engine = engine
        self.num_slots = num_slots
        if bank is None:
            # engine built with ServePolicies(bank=...) — the scheduler
            # serves that bank without restating it
            bank = engine.policies.bank
        self.bank = solvers.as_bank(bank) if bank is not None else None

    def warmup(self) -> float:
        """Compile the step/encode/decode executables off the clock."""
        import jax
        import jax.numpy as jnp

        eng = self.engine
        cfg = eng.cfg
        t0 = time.perf_counter()
        state = eng.init_slots(self.num_slots, bank=self.bank)
        toks = jnp.zeros((1, cfg.text.max_len), jnp.int32)
        un = toks if state.uncond_context is not None else None
        state = eng.admit(state, 0, toks, jax.random.PRNGKey(0),
                          uncond_tokens=un)
        state = eng.slot_step(state)
        # warm every power-of-two retirement-decode size a run can hit
        k = 1
        while k <= self.num_slots:
            jax.block_until_ready(eng.decode_slots(state, list(range(k))))
            k *= 2
        return time.perf_counter() - t0

    def run(self, requests: list, ledger: bool = False) -> dict:
        import jax

        eng = self.engine
        if self.bank is None:
            for r in requests:
                if r.policy_index != 0:
                    raise ValueError(
                        f"request {r.rid} carries policy_index="
                        f"{r.policy_index} but the scheduler has no bank — "
                        f"pass bank= to ContinuousScheduler")
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        ready: list = []
        owner: dict = {}
        state = eng.init_slots(self.num_slots, bank=self.bank)
        completed = 0
        steps = 0
        step_wall = 0.0
        occupancy_rows = 0
        t0 = time.perf_counter()
        while completed < len(requests):
            now = time.perf_counter() - t0
            poll_arrivals(pending, ready, now)
            free = [s for s in range(self.num_slots) if s not in owner]
            for slot in free:
                if not ready:
                    break
                req = ready.pop(0)
                state = eng.admit(state, slot, req.tokens, None,
                                  uncond_tokens=req.uncond_tokens,
                                  latents=req.latents,
                                  policy_index=req.policy_index)
                owner[slot] = req
                req.admitted_s = time.perf_counter() - t0
            if not owner:
                # nothing in flight: sleep to the next arrival
                if pending:
                    time.sleep(max(pending[0].arrival_s - now, 0.0))
                continue
            state = eng.slot_step(state)
            steps += 1
            step_wall += eng.last_wall_s
            occupancy_rows += len(owner)
            done = eng.finished_slots(state)
            if done:
                images = np.asarray(jax.device_get(
                    eng.decode_slots(state, done)))
                now = time.perf_counter() - t0
                for j, slot in enumerate(done):
                    req = owner.pop(slot)
                    req.finished_s = now
                    req.image = images[j]
                    completed += 1
                state = eng.retire(state, done)
        makespan = time.perf_counter() - t0
        metrics = {
            "mode": "continuous",
            "denoiser_family": eng.denoiser.family,
            "num_slots": self.num_slots,
            "engine_steps": steps,
            "step_wall_s": step_wall,
            "iter_wall_ms": 1e3 * step_wall / max(steps, 1),
            "mean_occupancy": occupancy_rows / max(steps * self.num_slots,
                                                   1),
            **_latency_metrics(requests, makespan, bank=self.bank,
                               default_steps=eng.cfg.ddim
                               .num_inference_steps),
        }
        if self.bank is not None:
            metrics["bank"] = [p.describe() for p in self.bank]
        if ledger and self.bank is not None:
            from repro.diffusion.pipeline import (energy_report_banked,
                                                  phase_breakdown_from_accum)

            cfg = eng.cfg
            rep = energy_report_banked(cfg, state.accum, self.bank)
            metrics["energy"] = rep.summary()
            metrics["phase_breakdown"] = phase_breakdown_from_accum(
                cfg, state.accum, self.bank)
        elif ledger:
            from repro.core import tips
            from repro.diffusion.pipeline import (energy_report_from_accum,
                                                  reuse_ratios_from_accum,
                                                  tips_ratios_from_accum)
            import jax.numpy as jnp

            cfg = eng.cfg
            rep = energy_report_from_accum(cfg, state.accum)
            metrics["energy"] = {k: float(v)
                                 for k, v in rep.summary().items()}
            ratios = tips_ratios_from_accum(cfg, state.accum)
            metrics["tips_low_ratio_per_iter"] = [float(r) for r in ratios]
            metrics["tips_workload_low_fraction"] = float(
                tips.workload_low_precision_fraction(jnp.asarray(ratios),
                                                     ddim=cfg.ddim))
            # realized temporal-reuse ratio per DDIM iteration, from the
            # same integer accumulator (all-zeros when reuse is off)
            metrics["reuse_ratio_per_iter"] = [
                float(r) for r in reuse_ratios_from_accum(cfg, state.accum)]
        metrics["state"] = state
        return metrics


class FixedBatchScheduler:
    """Micro-batching baseline under the SAME arrival gating.

    Packs admissible requests into fixed-size batches in arrival order; a
    batch launches when full, or — if the queue has drained and nothing
    is in flight — as a padded partial (``stats_rows`` masks the padding
    out of the ledger, exactly like ``serve_diffusion.serve``).  Every
    request in a batch finishes when the batch's whole scan does, which is
    precisely the tail-latency failure mode continuous batching removes.
    """

    def __init__(self, engine, micro_batch: int):
        self.engine = engine
        self.micro_batch = micro_batch

    def warmup(self) -> float:
        eng = self.engine
        use_cfg = eng.cfg.ddim.guidance_scale != 1.0
        t0 = time.perf_counter()
        eng.warmup(self.micro_batch, use_cfg)
        return time.perf_counter() - t0

    def run(self, requests: list, ledger: bool = False) -> dict:
        import jax.numpy as jnp

        from repro.launch.serve_diffusion import micro_batches

        eng = self.engine
        if any(r.policy_index != 0 for r in requests):
            raise ValueError(
                "FixedBatchScheduler cannot serve mixed quality tiers: a "
                "micro-batch shares one scan executable, so rows cannot "
                "carry different solvers/step budgets — use "
                "ContinuousScheduler(bank=...) for tiered traces")
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        ready: list = []
        stats_per_batch = []
        calls = 0
        call_wall = 0.0
        t0 = time.perf_counter()
        completed = 0
        while completed < len(requests):
            now = time.perf_counter() - t0
            poll_arrivals(pending, ready, now)
            if len(ready) < self.micro_batch and pending:
                # wait for a full batch while more arrivals are due
                time.sleep(max(pending[0].arrival_s - now, 0.0))
                continue
            if not ready:
                break
            batch = [ready.pop(0)
                     for _ in range(min(self.micro_batch, len(ready)))]
            valid = len(batch)

            def pack(rows):
                # one micro_batches chunk: the exact padding semantics
                # (repeat the first row) serve_diffusion uses and
                # tests/test_serving.py pins
                chunk, v = micro_batches(jnp.concatenate(rows, axis=0),
                                         self.micro_batch)[0]
                assert v == valid, (v, valid)
                return chunk

            toks = pack([r.tokens for r in batch])
            lats = pack([r.latents for r in batch])
            uncond = (pack([r.uncond_tokens for r in batch])
                      if batch[0].uncond_tokens is not None else None)
            admit_t = time.perf_counter() - t0
            out = eng.generate(toks, None, uncond_tokens=uncond,
                               latents=lats,
                               stats_rows=valid if valid < self.micro_batch
                               else None)
            calls += 1
            call_wall += eng.last_wall_s
            images = np.asarray(out.images)
            fin = time.perf_counter() - t0
            for i, req in enumerate(batch):
                req.admitted_s = admit_t
                req.finished_s = fin
                req.image = images[i]
                completed += 1
            stats_per_batch.append(out.stats)
        makespan = time.perf_counter() - t0
        metrics = {
            "mode": "fixed_micro_batch",
            "denoiser_family": eng.denoiser.family,
            "micro_batch": self.micro_batch,
            "engine_calls": calls,
            "call_wall_s": call_wall,
            **_latency_metrics(requests, makespan),
        }
        if ledger and stats_per_batch:
            from repro.core import tips
            from repro.diffusion.pipeline import (
                aggregated_tips_ratios_per_iter, energy_report_multi)

            cfg = eng.cfg
            fetched = [s.ledger_fetch() for s in stats_per_batch]
            rep = energy_report_multi(cfg, fetched)
            metrics["energy"] = {k: float(v)
                                 for k, v in rep.summary().items()}
            ratios = aggregated_tips_ratios_per_iter(cfg, fetched)
            metrics["tips_low_ratio_per_iter"] = [float(r) for r in ratios]
            metrics["tips_workload_low_fraction"] = float(
                tips.workload_low_precision_fraction(jnp.asarray(ratios),
                                                     ddim=cfg.ddim))
        return metrics
