"""Production serving launcher: prefill + batched decode for any arch.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 32 [--kv-int8]

Serving-path features: grouped-GQA decode (no KV repeat), donated cache
buffers (in-place update), optional int8 KV cache, TIPS sink-token mixed
precision in the FFN (cfg.tips).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_arch
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.kv_int8:
        cfg = cfg.scaled(kv_cache_dtype="int8")
    max_seq = args.prompt_len + args.new_tokens

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    t0 = time.time()
    logits, pcache = T.prefill(params, cfg, None, tokens=prompts)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time() - t0:.2f}s")

    # decode cache sized for the full sequence; copy the prefill KV in
    cache = T.init_cache(cfg, args.batch, max_seq)
    if cfg.family in ("dense", "moe"):
        from repro.models.layers import _kv_store
        cache = {
            k: jax.lax.dynamic_update_slice_in_dim(
                cache[k], _kv_store(pcache[k], cache[k].dtype), 0, axis=2)
            for k in ("k", "v")}
    elif cfg.family == "ssm":
        cache = pcache

    step_fn = jax.jit(
        lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg, None),
        donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        logits, cache = step_fn(params, cache, tok, pos)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    seq = jnp.concatenate(out, axis=1)
    print(f"decode: {seq.shape[1]} tokens x {args.batch} in {dt:.2f}s "
          f"({args.batch * seq.shape[1] / max(dt, 1e-9):.1f} tok/s)"
          f"{' [int8 KV]' if args.kv_int8 else ''}")
    print("sample:", seq[0, :12].tolist())


if __name__ == "__main__":
    main()
