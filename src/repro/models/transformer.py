"""Decoder stack assembly for all assigned families (dense/moe/ssm/hybrid).

Layers are ``lax.scan``-stacked (stacked weights, leading L axis) so the HLO
stays small at 60 layers and AOT compiles fast across 512 fake devices.
The hybrid (hymba) decode path unrolls a Python loop instead, because its
per-layer KV caches differ in size (3 global layers, 29 sliding-window).

Paper features (first-class, per DESIGN.md §4):
  * PSSA  — post-softmax score pruning in self-attention (cfg.pssa)
  * TIPS  — sink-token CAS -> per-token INT12/INT6 FFN precision (cfg.tips)
  * DBSC  — bit-slice integer FFN execution for serving (cfg.dbsc; the
            Pallas kernel path is exercised by examples/serve_lm.py and the
            kernel tests; the lowered dry-run uses the bf16 tensor path)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.layers import ShardCtx, maybe_cs


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _is_global_layer(cfg: ArchConfig, i: int) -> bool:
    if not cfg.sliding_window:
        return True
    return i in (0, cfg.num_layers // 2, cfg.num_layers - 1)


# ----------------------------------------------------------------------------
# Parameter init / specs
# ----------------------------------------------------------------------------
def init_layer_params(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {"ln1": jnp.ones((d,), dtype)}
    if cfg.family in ("dense", "moe", "hybrid"):
        p.update(L.init_attn_params(ks[0], cfg, dtype))
        p["ln2"] = jnp.ones((d,), dtype)
    if cfg.family == "dense":
        p.update(L.init_ffn_params(ks[1], d, cfg.d_ff, cfg.ffn_activation,
                                   dtype))
    elif cfg.family == "moe":
        p["moe"] = MOE.init_moe_params(ks[1], cfg, dtype)
    elif cfg.family == "ssm":
        p["ssm"] = SSM.init_ssm_params(ks[1], cfg, dtype)
    elif cfg.family == "hybrid":
        p["ssm"] = SSM.init_ssm_params(ks[1], cfg, dtype)
        p["attn_norm"] = jnp.ones((d,), dtype)
        p["ssm_norm"] = jnp.ones((d,), dtype)
        p.update(L.init_ffn_params(ks[2], d, cfg.d_ff, cfg.ffn_activation,
                                   dtype))
    return p


def layer_param_specs(cfg: ArchConfig, tp_size: int):
    p = {"ln1": P(None)}
    if cfg.family in ("dense", "moe", "hybrid"):
        p.update(L.attn_param_specs(cfg))
        p["ln2"] = P(None)
    if cfg.family == "dense":
        p.update(L.ffn_param_specs(cfg.ffn_activation))
    elif cfg.family == "moe":
        p["moe"] = MOE.moe_param_specs(cfg, tp_size)
    elif cfg.family == "ssm":
        p["ssm"] = SSM.ssm_param_specs(cfg)
    elif cfg.family == "hybrid":
        p["ssm"] = SSM.ssm_param_specs(cfg)
        p["attn_norm"] = P(None)
        p["ssm_norm"] = P(None)
        p.update(L.ffn_param_specs(cfg.ffn_activation))
    return p


def init_params(key, cfg: ArchConfig):
    dtype = _dtype(cfg)
    k_embed, k_out, k_layers = jax.random.split(key, 3)
    d, v = cfg.d_model, cfg.vocab_size
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    stacked = jax.vmap(lambda k: init_layer_params(k, cfg, dtype))(layer_keys)
    return {
        "embed": (jax.random.normal(k_embed, (v, d)) * 0.02).astype(dtype),
        "unembed": (jax.random.normal(k_out, (d, v)) * d ** -0.5).astype(dtype),
        "final_norm": jnp.ones((d,), dtype),
        "layers": stacked,
    }


def abstract_params(cfg: ArchConfig):
    """ShapeDtypeStruct param tree — no allocation (dry-run path)."""
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))


def param_specs(cfg: ArchConfig, tp_size: int):
    lspecs = layer_param_specs(cfg, tp_size)
    stacked = jax.tree.map(lambda s: P(None, *s), lspecs,
                           is_leaf=lambda x: isinstance(x, P))
    # vocab-parallel embeddings when the vocab divides the TP axis; otherwise
    # shard the hidden axis (50280/92553/32001-style vocabs — explicit
    # in_shardings require exact divisibility, unlike constraints)
    if cfg.vocab_size % tp_size == 0:
        embed, unembed = P("model", None), P(None, "model")
    else:
        embed, unembed = P(None, "model"), P("model", None)
    return {
        "embed": embed,
        "unembed": unembed,
        "final_norm": P(None),
        "layers": stacked,
    }


# ----------------------------------------------------------------------------
# Blocks
# ----------------------------------------------------------------------------
def _block_train(x, lp, cfg: ArchConfig, ctx, positions, is_global=None,
                 collect_cache=False):
    """One layer, full-sequence.  Returns (x, aux_loss, cache_entry)."""
    aux = jnp.zeros((), jnp.float32)
    tips_mask = None
    cache = None
    prune = cfg.pssa_threshold if cfg.pssa else 0.0

    if cfg.family == "ssm":
        xa = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        if collect_cache:
            h, cache = SSM.mamba_mixer(xa, lp["ssm"], cfg, ctx,
                                       return_cache=True)
        else:
            h = SSM.mamba_mixer(xa, lp["ssm"], cfg, ctx)
        return x + h, aux, cache

    xa = L.rms_norm(x, lp["ln1"], cfg.norm_eps)

    if cfg.family == "hybrid":
        attn_out, sink, kv = L.gqa_attention(xa, lp, cfg, ctx, positions,
                                             window=cfg.sliding_window,
                                             prune_threshold=prune,
                                             global_flag=is_global)
        if collect_cache:
            ssm_out, ssm_cache = SSM.mamba_mixer(xa, lp["ssm"], cfg, ctx,
                                                 return_cache=True)
            cache = {"k": kv[0], "v": kv[1], "ssm": ssm_cache}
        else:
            ssm_out = SSM.mamba_mixer(xa, lp["ssm"], cfg, ctx)
        attn_out = L.rms_norm(attn_out, lp["attn_norm"], cfg.norm_eps)
        ssm_out = L.rms_norm(ssm_out, lp["ssm_norm"], cfg.norm_eps)
        h = 0.5 * (attn_out + ssm_out)
    else:
        attn_out, sink, kv = L.gqa_attention(xa, lp, cfg, ctx, positions,
                                             prune_threshold=prune)
        if collect_cache:
            cache = {"k": kv[0], "v": kv[1]}
        h = attn_out
    x = x + h

    if cfg.tips:
        tips_mask = sink < cfg.tips_threshold      # important tokens

    xf = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        f, aux = MOE.moe_ffn(xf, lp["moe"], cfg, ctx,
                             tips_important=tips_mask)
    else:
        f = L.ffn(xf, lp, cfg.ffn_activation, ctx, tips_important=tips_mask)
    return x + f.astype(x.dtype), aux, cache


# ----------------------------------------------------------------------------
# Forward (train / prefill)
# ----------------------------------------------------------------------------
def forward(params, cfg: ArchConfig, ctx: Optional[ShardCtx],
            tokens=None, embeds=None, remat: bool = True,
            collect_cache: bool = False, last_logit_only: bool = False):
    """-> (logits float32, aux, cache-or-None)."""
    if embeds is None:
        x = L.embed(tokens, params["embed"])
        if ctx is not None:
            x = ctx.cs(x, ctx.dp, None, None)
    else:
        x = embeds
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))

    if cfg.sliding_window:
        is_global = jnp.array([_is_global_layer(cfg, i)
                               for i in range(cfg.num_layers)])
    else:
        is_global = None

    def body(carry, xs):
        x, aux = carry
        lp = xs["lp"]
        ig = xs.get("ig")
        x, a, cache = _block_train(x, lp, cfg, ctx, positions, is_global=ig,
                                   collect_cache=collect_cache)
        return (x, aux + a), cache

    if remat:
        if cfg.remat_save_collectives:
            # §Perf: save the two post-psum activations per layer so the
            # backward replay does NOT re-run the TP all-reduces (cuts the
            # per-layer AR count 6 -> 4 at ~2 extra saved tensors/layer)
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.save_only_these_names(
                    "tp_psum_out"))
        else:
            body = jax.checkpoint(body)   # save-nothing: full recompute

    xs = {"lp": params["layers"]}
    if is_global is not None:
        xs["ig"] = is_global
    (x, aux), cache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)

    if last_logit_only:
        x = x[:, -1:, :]
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(x, params["unembed"])
    if not last_logit_only:
        logits = maybe_cs(ctx, logits, ctx.dp if ctx else None, None, "model")
    return logits, aux, cache


def loss_fn(params, batch, cfg: ArchConfig, ctx, aux_coef: float = 0.01):
    logits, aux, _ = forward(params, cfg, ctx,
                             tokens=batch.get("tokens"),
                             embeds=batch.get("embeds"))
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    return nll + aux_coef * aux, {"nll": nll, "aux": aux}


# ----------------------------------------------------------------------------
# Decode (serving): KV/SSM caches, one-token step
# ----------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_seq: int):
    dtype = _dtype(cfg)
    lcount = cfg.num_layers
    if cfg.family == "ssm":
        one = SSM.init_ssm_cache(cfg, batch, dtype)
        return jax.tree.map(
            lambda a: jnp.zeros((lcount,) + a.shape, a.dtype), one)
    if cfg.family == "hybrid":
        caches = []
        for i in range(lcount):
            s = max_seq if _is_global_layer(cfg, i) else min(
                cfg.sliding_window, max_seq)
            caches.append({
                "k": jnp.zeros((batch, s, cfg.num_kv_heads, cfg.head_dim),
                               dtype),
                "v": jnp.zeros((batch, s, cfg.num_kv_heads, cfg.head_dim),
                               dtype),
                "ssm": SSM.init_ssm_cache(cfg, batch, dtype),
            })
        return caches
    # dense / moe: uniform stacked KV (optionally int8-compressed — §Perf)
    kv_dtype = jnp.int8 if cfg.kv_cache_dtype == "int8" else dtype
    kv = jnp.zeros((lcount, batch, max_seq, cfg.num_kv_heads, cfg.head_dim),
                   kv_dtype)
    return {"k": kv, "v": jnp.zeros_like(kv)}


def abstract_cache(cfg: ArchConfig, batch: int, max_seq: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq))


def cache_specs(cfg: ArchConfig, batch: int, dp_axes: tuple, tp_size: int):
    """PartitionSpecs for the decode cache (DESIGN.md §5 rules)."""
    bspec = dp_axes if batch >= 2 * tp_size else None
    if cfg.family == "ssm":
        # state (L, B, h, p, n): shard the head_dim axis p (64 — always
        # TP-divisible); the head count (24/50) generally is not.
        state = P(None, bspec, None, "model", None)
        conv = P(None, bspec, None, "model")
        return {"state": state, "conv": conv}
    if cfg.num_kv_heads % tp_size == 0:
        kvspec = P(None, bspec, None, "model", None)
    elif bspec is None:
        # long-context single-request: shard the sequence everywhere
        kvspec = P(None, None, tuple(dp_axes) + ("model",), None, None)
    else:
        kvspec = P(None, bspec, "model", None, None)
    if cfg.family == "hybrid":
        per_layer = {
            "k": P(*kvspec[1:]), "v": P(*kvspec[1:]),
            "ssm": {"state": P(bspec, None, "model", None),
                    "conv": P(bspec, None, "model")},
        }
        return [per_layer] * cfg.num_layers
    return {"k": kvspec, "v": kvspec}


def decode_step(params, cache, tokens, position, cfg: ArchConfig,
                ctx: Optional[ShardCtx]):
    """One decode step.  tokens: (B, 1) int32; position: scalar int32.

    Returns (logits (B, 1, V), new_cache).
    """
    x = L.embed(tokens, params["embed"])
    if ctx is not None:
        x = ctx.cs(x, ctx.dp if tokens.shape[0] > 1 else None, None, None)
    b = x.shape[0]

    if cfg.family == "ssm":
        def body(carry, xs):
            x = carry
            lp, c = xs["lp"], xs["cache"]
            h, nc = SSM.mamba_decode(
                L.rms_norm(x, lp["ln1"], cfg.norm_eps), c, lp["ssm"], cfg, ctx)
            return x + h, nc
        x, new_cache = jax.lax.scan(
            body, x, {"lp": params["layers"], "cache": cache})
    elif cfg.family == "hybrid":
        new_cache = []
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            c = cache[i]
            xa = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            win = c["k"].shape[1]
            is_g = _is_global_layer(cfg, i)
            # ring-buffer slot for SWA layers; linear slot for global layers
            slot = position if is_g else position % win
            attn_out, ck, cv, sink = L.decode_attention_slot(
                xa, lp, cfg, ctx, c["k"], c["v"], position, slot,
                window=0 if is_g else win)
            ssm_out, nssm = SSM.mamba_decode(xa, c["ssm"], lp["ssm"], cfg, ctx)
            attn_out = L.rms_norm(attn_out, lp["attn_norm"], cfg.norm_eps)
            ssm_out = L.rms_norm(ssm_out, lp["ssm_norm"], cfg.norm_eps)
            x = x + 0.5 * (attn_out + ssm_out)
            tips_mask = (sink < cfg.tips_threshold) if cfg.tips else None
            xf = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            x = x + L.ffn(xf, lp, cfg.ffn_activation, ctx,
                          tips_important=tips_mask)
            new_cache.append({"k": ck, "v": cv, "ssm": nssm})
    else:
        def body(carry, xs):
            x = carry
            lp, ck, cv = xs["lp"], xs["k"], xs["v"]
            xa = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            attn_out, nk, nv, sink = L.decode_attention(
                xa, lp, cfg, ctx, ck, cv, position)
            x = x + attn_out
            tips_mask = (sink < cfg.tips_threshold) if cfg.tips else None
            xf = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                f, _ = MOE.moe_ffn(xf, lp["moe"], cfg, ctx,
                                   tips_important=tips_mask)
            else:
                f = L.ffn(xf, lp, cfg.ffn_activation, ctx,
                          tips_important=tips_mask)
            return x + f, {"k": nk, "v": nv}

        x, new_cache = jax.lax.scan(
            body, x, {"lp": params["layers"], **cache})

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(x, params["unembed"])
    return logits, new_cache


def prefill(params, cfg: ArchConfig, ctx, tokens=None, embeds=None):
    """Prefill: last-token logits + the populated per-layer cache.

    Writing the cache out is the honest serving cost (it dominates prefill
    HBM traffic at 32k context); logits are trimmed to the final position,
    which is all decoding needs.
    """
    logits, _, cache = forward(params, cfg, ctx, tokens=tokens, embeds=embeds,
                               remat=False, collect_cache=True,
                               last_logit_only=True)
    return logits, cache
