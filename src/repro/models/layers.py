"""Shared transformer layers: norms, RoPE, GQA attention, FFN (+paper hooks).

All functions are pure; parameters are plain dict pytrees.  Sharding is
expressed through ``with_sharding_constraint`` on activations when a
``ShardCtx`` is supplied (the dry-run / production path); smoke tests pass
``ctx=None`` and run unconstrained on one device.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


# ----------------------------------------------------------------------------
# Sharding context
# ----------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Carries the mesh + canonical axis roles through the model.

    ``tp_axis=None`` folds tensor parallelism away (TP-fold, §Perf): model
    code keeps writing the literal "model" in its constraints and ``cs``
    rewrites it — to the physical axis normally, to replicated when folded
    (the physical 'model' axis then serves as extra data parallelism via
    ``dp_axes``)."""
    mesh: object                       # jax.sharding.Mesh
    dp_axes: tuple                     # ('pod', 'data') or ('data',)
    tp_axis: str | None = "model"

    @property
    def dp(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis] if self.tp_axis else 1

    def cs(self, x, *spec):
        """Constraint helper: cs(x, dp, None, 'model') etc."""
        spec = tuple(self.tp_axis if s == "model" else s for s in spec)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))


def maybe_cs(ctx: Optional[ShardCtx], x, *spec):
    return ctx.cs(x, *spec) if ctx is not None else x


# ----------------------------------------------------------------------------
# Norms / embeddings
# ----------------------------------------------------------------------------
def rms_norm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def embed(tokens, table):
    return jnp.take(table, tokens, axis=0)


def unembed(x, table):
    """(B, T, d) @ (d, V) -> logits in float32."""
    return jnp.einsum("btd,dv->btv", x.astype(jnp.float32),
                      table.astype(jnp.float32))


# ----------------------------------------------------------------------------
# RoPE (supports partial rotary — chatglm3's 2-D RoPE rotates half the dims)
# ----------------------------------------------------------------------------
def rope_frequencies(head_dim: int, rotary_pct: float, theta: float):
    rot_dim = int(head_dim * rotary_pct) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32)
                           / rot_dim))
    return inv, rot_dim


def apply_rope(x, positions, rotary_pct: float, theta: float):
    """x: (B, T, H, hd); positions: (B, T) or (T,)."""
    hd = x.shape[-1]
    inv, rot_dim = rope_frequencies(hd, rotary_pct, theta)
    if rot_dim == 0:
        return x
    pos = positions.astype(jnp.float32)
    ang = pos[..., None] * inv                      # (B, T, rot/2)
    sin = jnp.sin(ang)[..., None, :]                # (B, T, 1, rot/2)
    cos = jnp.cos(ang)[..., None, :]
    xr = x[..., :rot_dim]
    xp = x[..., rot_dim:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr.astype(x.dtype), xp], axis=-1)


# ----------------------------------------------------------------------------
# Attention (GQA, causal / sliding-window / decode-with-cache) + PSSA hook
# ----------------------------------------------------------------------------
def init_attn_params(key, cfg: ArchConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(k1, (d, h * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, kv * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, kv * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (h * hd, d)) * s).astype(dtype),
    }


def attn_param_specs(cfg: ArchConfig):
    """PartitionSpecs (without the stacked layer axis)."""
    return {
        "wq": P(None, "model"),
        "wk": P(None, "model"),
        "wv": P(None, "model"),
        "wo": P("model", None),
    }


def _causal_mask(tq, tk, offset=0):
    q = jnp.arange(tq)[:, None] + offset
    k = jnp.arange(tk)[None, :]
    return q >= k


def _window_mask(tq, tk, window, offset=0):
    q = jnp.arange(tq)[:, None] + offset
    k = jnp.arange(tk)[None, :]
    return (q >= k) & (q - k < window)


def gqa_attention(x, p, cfg: ArchConfig, ctx: Optional[ShardCtx],
                  positions, window: int = 0,
                  prune_threshold: float = 0.0,
                  q_chunk: int = 1024,
                  global_flag=None):
    """Full-sequence causal GQA attention.  (B, T, d) -> (B, T, d).

    ``prune_threshold`` > 0 applies PSSA step-1 pruning to the post-softmax
    scores (the pruned SAS is what the PSXU compresses on its way to DRAM).

    For T > q_chunk the score/softmax/PV block runs chunked over queries
    (lax.scan), bounding the materialized score block to (B, H, qc, T) —
    the TPU-native replacement for spilling the full SAS.
    """
    b, t, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("btd,dk->btk", x, p["wq"]).reshape(b, t, h, hd)
    k = jnp.einsum("btd,dk->btk", x, p["wk"]).reshape(b, t, kv, hd)
    v = jnp.einsum("btd,dk->btk", x, p["wv"]).reshape(b, t, kv, hd)
    q = apply_rope(q, positions, cfg.rotary_pct, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rotary_pct, cfg.rope_theta)
    if ctx is not None:
        q = ctx.cs(q, ctx.dp, None, "model", None)

    g = h // kv

    def block(qb, offset):
        """qb: (b, qc, h, hd) -> (out (b, qc, h*hd), sink (b, qc)).

        Grouped-query einsums: KV is NEVER repeated to full heads (§Perf —
        a materialized repeat multiplies KV reads by the group factor g)."""
        qc = qb.shape[1]
        qg = qb.reshape(b, qc, kv, g, hd)
        scores = jnp.einsum("btkgd,bskd->bkgts", qg, k) \
            / jnp.sqrt(float(hd))
        if ctx is not None:
            scores = ctx.cs(scores, ctx.dp, "model", None, None, None)
        causal = _causal_mask(qc, t, offset)
        if window and global_flag is not None:
            # scan-uniform hybrid: per-layer traced global/SWA select
            band = _window_mask(qc, t, window, offset)
            mask = causal & jnp.logical_or(global_flag, band)
        elif window:
            mask = _window_mask(qc, t, window, offset)
        else:
            mask = causal
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
        if prune_threshold > 0.0:
            probs = jnp.where(probs >= prune_threshold, probs, 0.0)
        # TIPS sink CAS: attention of every query to the first (sink) token,
        # averaged over heads — the LM generalization of the CLS score.
        sink = jnp.mean(probs[..., 0], axis=(1, 2))               # (b, qc)
        probs = probs.astype(x.dtype)
        ob = jnp.einsum("bkgts,bskd->btkgd", probs, v)
        return ob.reshape(b, qc, h * hd), sink

    if t > q_chunk and t % q_chunk == 0:
        nq = t // q_chunk
        qs = jnp.moveaxis(q.reshape(b, nq, q_chunk, h, hd), 1, 0)

        def body(carry, inp):
            i, qb = inp
            ob, sink = block(qb, i * q_chunk)
            return carry, (ob, sink)

        _, (outs, sinks) = jax.lax.scan(
            body, 0, (jnp.arange(nq), qs))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, t, h * hd)
        sink_cas = jnp.moveaxis(sinks, 0, 1).reshape(b, t)
    else:
        out, sink_cas = block(q, 0)

    out = jnp.einsum("btk,kd->btd", out, p["wo"])
    out = maybe_cs(ctx, out, ctx.dp if ctx else None, None, None)
    # row-parallel psum lives here; name it so the remat policy can pin it
    out = checkpoint_name(out, "tp_psum_out")
    return out, sink_cas, (k, v)


def swa_attention_chunked(x, p, cfg: ArchConfig, ctx: Optional[ShardCtx],
                          positions, window: int):
    """Banded (sliding-window) attention, truly sub-quadratic.

    Queries are chunked at ``window``; each chunk attends to itself and the
    previous chunk with the band mask — O(T * 2w) instead of O(T^2).  Used
    for long prefill on SWA layers (hymba).  Sink-CAS is not defined for a
    banded layer (the sink leaves the band), so TIPS masks come from the
    global layers only.
    """
    b, t, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    assert t % window == 0, (t, window)
    nc = t // window
    q = jnp.einsum("btd,dk->btk", x, p["wq"]).reshape(b, t, h, hd)
    k = jnp.einsum("btd,dk->btk", x, p["wk"]).reshape(b, t, kv, hd)
    v = jnp.einsum("btd,dk->btk", x, p["wv"]).reshape(b, t, kv, hd)
    q = apply_rope(q, positions, cfg.rotary_pct, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rotary_pct, cfg.rope_theta)

    g = h // kv
    qc = q.reshape(b, nc, window, kv, g, hd)
    kc = k.reshape(b, nc, window, kv, hd)
    vc = v.reshape(b, nc, window, kv, hd)
    # previous chunk (zero-padded for the first)
    kprev = jnp.pad(kc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    vprev = jnp.pad(vc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    kcat = jnp.concatenate([kprev, kc], axis=2)        # (b,nc,2w,kv,hd)
    vcat = jnp.concatenate([vprev, vc], axis=2)
    scores = jnp.einsum("bclkgh,bcskh->bckgls", qc, kcat) / jnp.sqrt(float(hd))
    qpos = jnp.arange(window)[:, None] + window        # within [w, 2w)
    kpos = jnp.arange(2 * window)[None, :]
    band = (qpos >= kpos) & (qpos - kpos < window)
    first = jnp.zeros((nc,), bool).at[0].set(True)
    pad_valid = kpos >= window                          # first chunk: no prev
    mask = jnp.where(first[:, None, None], band & pad_valid, band)
    scores = jnp.where(mask[None, :, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bckgls,bcskh->bclkgh", probs, vcat)
    out = out.reshape(b, t, h * hd)
    out = jnp.einsum("btk,kd->btd", out, p["wo"])
    return maybe_cs(ctx, out, ctx.dp if ctx else None, None, None)


# int8 KV-cache grid (§Perf decode iteration 3 — the serving analogue of
# PSSA: compress the attention-side DRAM traffic).  RoPE'd keys/values from
# unit-scale projections sit within ~|4|; 0.05 granularity covers ±6.35.
KV_INT8_SCALE = 0.05


def _kv_store(x, dtype):
    if dtype == jnp.int8:
        return jnp.clip(jnp.round(x / KV_INT8_SCALE), -127, 127
                        ).astype(jnp.int8)
    return x.astype(dtype)


def _kv_load(x):
    if x.dtype == jnp.int8:
        return x.astype(jnp.bfloat16) * KV_INT8_SCALE
    return x


def decode_attention(x, p, cfg: ArchConfig, ctx: Optional[ShardCtx],
                     cache_k, cache_v, position, window: int = 0):
    """Single-token decode with a KV cache.

    x: (B, 1, d); cache_k/v: (B, S, kv, hd); position: scalar int (same for
    every row — the serving batch is position-aligned).
    Returns (out (B, 1, d), new_cache_k, new_cache_v).
    """
    b, _, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s = cache_k.shape[1]
    q = jnp.einsum("btd,dk->btk", x, p["wq"]).reshape(b, 1, h, hd)
    knew = jnp.einsum("btd,dk->btk", x, p["wk"]).reshape(b, 1, kv, hd)
    vnew = jnp.einsum("btd,dk->btk", x, p["wv"]).reshape(b, 1, kv, hd)
    pos = jnp.full((b, 1), position, jnp.int32)
    q = apply_rope(q, pos, cfg.rotary_pct, cfg.rope_theta)
    knew = apply_rope(knew, pos, cfg.rotary_pct, cfg.rope_theta)

    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, _kv_store(knew, cache_k.dtype), position, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, _kv_store(vnew, cache_v.dtype), position, axis=1)

    g = h // kv
    # grouped-query decode: no KV repeat (a materialized repeat multiplies
    # the cache read — the dominant decode HBM term — by g; §Perf)
    qg = q.reshape(b, kv, g, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.bfloat16),
                        _kv_load(cache_k)) / jnp.sqrt(float(hd))
    idx = jnp.arange(s)
    valid = idx <= position
    if window:
        valid &= idx > position - window
    scores = jnp.where(valid[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    sink_cas = jnp.mean(probs[..., 0], axis=(1, 2))[:, None]   # (b, 1)
    probs = probs.astype(jnp.bfloat16)
    out = jnp.einsum("bkgs,bskd->bkgd", probs,
                     _kv_load(cache_v)).astype(x.dtype).reshape(b, 1, h * hd)
    out = jnp.einsum("btk,kd->btd", out, p["wo"])
    return out, cache_k, cache_v, sink_cas


def decode_attention_slot(x, p, cfg: ArchConfig, ctx: Optional[ShardCtx],
                          cache_k, cache_v, position, slot, window: int = 0):
    """Decode attention over a ring-buffer cache (hybrid SWA layers).

    The cache holds W slots; the new KV is written at ``slot``
    (= position % W for SWA, = position for global layers with W = max_seq).
    RoPE is applied at write time, so slots are position-agnostic; validity
    is derived from the absolute position window.
    """
    b, _, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    w = cache_k.shape[1]
    q = jnp.einsum("btd,dk->btk", x, p["wq"]).reshape(b, 1, h, hd)
    knew = jnp.einsum("btd,dk->btk", x, p["wk"]).reshape(b, 1, kv, hd)
    vnew = jnp.einsum("btd,dk->btk", x, p["wv"]).reshape(b, 1, kv, hd)
    pos = jnp.full((b, 1), position, jnp.int32)
    q = apply_rope(q, pos, cfg.rotary_pct, cfg.rope_theta)
    knew = apply_rope(knew, pos, cfg.rotary_pct, cfg.rope_theta)

    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, knew.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, vnew.astype(cache_v.dtype), slot, axis=1)

    # absolute position stored in each slot (ring arithmetic)
    idx = jnp.arange(w)
    if window:
        # slot i currently holds the latest position p with p % w == i, p <= position
        slot_pos = position - ((position - idx) % w)
    else:
        slot_pos = idx
    valid = (slot_pos >= 0) & (slot_pos <= position)
    if window:
        valid &= slot_pos > position - window

    g = h // kv
    qg = q.reshape(b, kv, g, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, cache_k) \
        / jnp.sqrt(float(hd))
    scores = jnp.where(valid[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    # sink CAS only meaningful for global layers (slot 0 holds position 0)
    sink_cas = jnp.mean(probs[..., 0], axis=(1, 2))[:, None]
    probs = probs.astype(x.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, cache_v).reshape(b, 1, h * hd)
    out = jnp.einsum("btk,kd->btd", out, p["wo"])
    return out, cache_k, cache_v, sink_cas


# ----------------------------------------------------------------------------
# FFN (SwiGLU / GELU) + TIPS mixed-precision hook
# ----------------------------------------------------------------------------
def init_ffn_params(key, d_model: int, d_ff: int, activation: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s = d_model ** -0.5
    p = {
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * s).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model))
                   * d_ff ** -0.5).astype(dtype),
    }
    if activation == "swiglu":
        p["w_gate"] = (jax.random.normal(k1, (d_model, d_ff)) * s).astype(dtype)
    return p


def ffn_param_specs(activation: str):
    p = {"w_up": P(None, "model"), "w_down": P("model", None)}
    if activation == "swiglu":
        p["w_gate"] = P(None, "model")
    return p


def ffn(x, p, activation: str, ctx: Optional[ShardCtx],
        tips_important=None):
    """(B, T, d) -> (B, T, d).

    ``tips_important``: bool (B, T) — rows kept at INT12; others fake-quant
    to INT6 on the shared scale grid before the FFN matmuls (TIPS §IV-A).
    """
    if tips_important is not None:
        from repro.core import tips as tips_mod
        x = tips_mod.apply_precision_mask(x, tips_important)
    if activation == "swiglu":
        g = jnp.einsum("btd,df->btf", x, p["w_gate"])
        u = jnp.einsum("btd,df->btf", x, p["w_up"])
        hmid = jax.nn.silu(g) * u
    else:
        hmid = jax.nn.gelu(jnp.einsum("btd,df->btf", x, p["w_up"]))
    hmid = maybe_cs(ctx, hmid, ctx.dp if ctx else None, None, "model")
    out = jnp.einsum("btf,fd->btd", hmid, p["w_down"])
    out = maybe_cs(ctx, out, ctx.dp if ctx else None, None, None)
    return checkpoint_name(out, "tp_psum_out")


def tips_sink_mask(x, p_attn, cfg: ArchConfig, probs_sink):
    """Sink-token CAS -> importance mask (the LM generalization of TIPS)."""
    from repro.core import tips as tips_mod
    # probs_sink: (B, H, T) attention of each query to the sink (first) token
    cas = jnp.mean(probs_sink, axis=1)
    return cas < cfg.tips_threshold
