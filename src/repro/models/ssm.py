"""Mamba-2 SSD (state-space duality) in JAX (arXiv:2405.21060).

Chunked SSD algorithm: the sequence is split into chunks; each chunk's
diagonal block is computed quadratically (attention-like, MXU-friendly),
inter-chunk information flows through a small recurrent state carried by a
``lax.scan`` over chunks.  Decode is the O(1) recurrent update.

Shapes follow the minimal-mamba2 reference: x (B, T, H, P), dt (B, T, H),
A (H,) negative reals, B/C (B, T, G, N) with G=1 group.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import ShardCtx, maybe_cs, rms_norm


def _segsum(x):
    """(..., L) -> (..., L, L) lower-triangular segment sums.

    out[..., l, s] = sum_{s < i <= l} x[..., i]  (for l >= s, else -inf)
    """
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_scan(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD forward.

    x: (b, t, h, p); dt: (b, t, h) (post-softplus); A: (h,) < 0;
    B, C: (b, t, n) (single group).  Returns (y (b,t,h,p), state (b,h,p,n)).
    """
    b, t, h, p = x.shape
    n = B.shape[-1]
    assert t % chunk == 0, (t, chunk)
    c = t // chunk

    # fold dt into x; dA = dt * A per step
    xdt = x * dt[..., None]                          # (b,t,h,p)
    dA = dt * A[None, None, :]                       # (b,t,h)

    # chunk views
    xc = xdt.reshape(b, c, chunk, h, p)
    Bc = B.reshape(b, c, chunk, n)
    Cc = C.reshape(b, c, chunk, n)
    dAc = dA.reshape(b, c, chunk, h)

    dA_cum = jnp.cumsum(dAc, axis=2)                 # (b,c,l,h)

    # 1) intra-chunk (diagonal blocks): quadratic, attention-like.
    # The (b,c,h,l,l) decay tensor dominates the layer's HBM footprint
    # (§Perf mamba2 iteration 2): the segment-sum/exp run in f32 for
    # stability, then the big operands drop to bf16 for the MXU einsum
    # with f32 accumulation — halves the dominant memory term.
    Ldec = jnp.exp(_segsum(jnp.moveaxis(dAc, 3, 2)))  # (b,c,h,l,l)
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)    # (b,c,l,s)
    y_diag = jnp.einsum("bcls,bchls,bcshp->bclhp",
                        scores.astype(jnp.bfloat16),
                        Ldec.astype(jnp.bfloat16),
                        xc.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)

    # 2) per-chunk states: contribution of each chunk to the running state
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)   # (b,c,l,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, decay_to_end, xc)

    # 3) inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])               # (b,c,h)
    if initial_state is None:
        s0 = jnp.zeros((b, h, p, n), x.dtype)
    else:
        s0 = initial_state

    def step(carry, inp):
        st, dec = inp                                # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                            # emit state *entering* chunk

    (final_state, prev_states) = jax.lax.scan(
        step, s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)    # (b,c,h,p,n)

    # 4) off-diagonal: prior state read out through the chunk
    state_decay_in = jnp.exp(dA_cum)                 # (b,c,l,h)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, prev_states,
                       state_decay_in)

    y = (y_diag + y_off).reshape(b, t, h, p)
    return y, final_state


def ssd_decode_step(x, dt, A, B, C, state):
    """O(1) recurrent update.  x: (b,h,p); dt: (b,h); B,C: (b,n);
    state: (b,h,p,n) -> (y (b,h,p), new_state)."""
    dA = jnp.exp(dt * A[None, :])                    # (b,h)
    dBx = jnp.einsum("bn,bh,bhp->bhpn", B, dt, x)
    new_state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", new_state, C)
    return y, new_state


# ----------------------------------------------------------------------------
# Full Mamba-2 mixer layer (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ----------------------------------------------------------------------------
def init_ssm_params(key, cfg: ArchConfig, dtype):
    """Input projection is SPLIT into (z, xBC, dt) heads — fused-width TP
    slicing would cross segment boundaries AND the fused width
    (2*di + 2*n + heads) is generally not divisible by the TP degree."""
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * n
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s = d ** -0.5
    return {
        "in_z": (jax.random.normal(k1, (d, di)) * s).astype(dtype),
        "in_xbc": (jax.random.normal(k4, (d, conv_ch)) * s).astype(dtype),
        "in_dt": (jax.random.normal(k5, (d, h)) * s).astype(dtype),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv_width, conv_ch))
                   * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),       # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "gate_norm": jnp.ones((di,), dtype),
        "out_proj": (jax.random.normal(k3, (di, d))
                     * di ** -0.5).astype(dtype),
    }


def ssm_param_specs(cfg: ArchConfig):
    return {
        "in_z": P(None, "model"),
        "in_xbc": P(None, "model"),
        "in_dt": P(None, None),         # heads (24/50) rarely divide TP=16
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "A_log": P(None),
        "D": P(None),
        "dt_bias": P(None),
        "gate_norm": P("model"),
        "out_proj": P("model", None),
    }


def _causal_conv(xBC, w, b):
    """Depthwise causal conv, width W, via shifted adds (W is tiny)."""
    W = w.shape[0]
    out = xBC * w[-1]
    for i in range(1, W):
        shifted = jnp.pad(xBC, ((0, 0), (i, 0), (0, 0)))[:, :-i, :]
        out = out + shifted * w[-1 - i]
    return out + b


def mamba_mixer(x, p, cfg: ArchConfig, ctx: Optional[ShardCtx],
                chunk: int = 128, return_cache: bool = False):
    """Full-sequence Mamba-2 mixer: (B, T, d) -> (B, T, d)."""
    b, t, d = x.shape
    di, n, h, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z = jnp.einsum("btd,dk->btk", x, p["in_z"])
    xBC_raw = jnp.einsum("btd,dk->btk", x, p["in_xbc"])
    dt = jnp.einsum("btd,dk->btk", x, p["in_dt"])
    xBC = jax.nn.silu(_causal_conv(xBC_raw, p["conv_w"], p["conv_b"]))
    xs = xBC[..., :di].reshape(b, t, h, hd)
    Bm = xBC[..., di:di + n]
    Cm = xBC[..., di + n:]
    if ctx is not None:
        xs = ctx.cs(xs, ctx.dp, None, "model", None)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    ck = min(chunk, t)
    while t % ck:
        ck //= 2
    if cfg.use_ssd_kernel:
        # fused Pallas path (§Perf A4): decay tensors stay in VMEM
        from repro.kernels.ssd_scan import ssd_scan_fused
        y, final_state = ssd_scan_fused(xs, dt, A, Bm, Cm, chunk=ck)
    else:
        y, final_state = ssd_scan(xs.astype(jnp.float32), dt, A,
                                  Bm.astype(jnp.float32),
                                  Cm.astype(jnp.float32), ck)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, t, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("btk,kd->btd", y, p["out_proj"])
    out = maybe_cs(ctx, out, ctx.dp if ctx else None, None, None)
    if return_cache:
        w = cfg.ssm_conv_width
        cache = {"state": final_state,
                 "conv": xBC_raw[:, t - (w - 1):, :]}
        return out, cache
    return out


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype):
    """Decode cache per layer: recurrent state + conv window."""
    di, n, h, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    return {
        "state": jnp.zeros((batch, h, hd, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, di + 2 * n), dtype),
    }


def mamba_decode(x, cache, p, cfg: ArchConfig, ctx: Optional[ShardCtx]):
    """One-token decode: x (B, 1, d) -> (out (B, 1, d), new cache)."""
    b = x.shape[0]
    di, n, h, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    x0 = x[:, 0]
    z = jnp.einsum("bd,dk->bk", x0, p["in_z"])
    xBC = jnp.einsum("bd,dk->bk", x0, p["in_xbc"])
    dt = jnp.einsum("bd,dk->bk", x0, p["in_dt"])
    # conv over the rolling window
    win = jnp.concatenate([cache["conv"], xBC[:, None, :]], axis=1)
    new_conv = win[:, 1:, :]
    conv_out = jnp.einsum("bwc,wc->bc", win, p["conv_w"]) + p["conv_b"]
    xBC = jax.nn.silu(conv_out)
    xs = xBC[..., :di].reshape(b, h, hd).astype(jnp.float32)
    Bm = xBC[..., di:di + n].astype(jnp.float32)
    Cm = xBC[..., di + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    y, new_state = ssd_decode_step(xs, dt, A, Bm, Cm, cache["state"])
    y = y + xs * p["D"][None, :, None]
    y = y.reshape(b, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bk,kd->bd", y, p["out_proj"])[:, None, :]
    return out, {"state": new_state, "conv": new_conv}
