"""Mixture-of-Experts FFN with shard_map-local dispatch (EP or TP).

Two sharding modes, chosen per config (DESIGN.md §5):

  * ``ep`` — experts sharded over the ``model`` axis (llama4-scout: 16e on a
    16-wide axis).  Activations are replicated over ``model`` (they are only
    batch-sharded), so each shard simply computes its *local* experts on the
    tokens routed to them and a single psum('model') combines — the same
    psum a row-parallel TP matmul needs, i.e. EP here costs no extra
    collective.
  * ``tp`` — every shard holds all experts with the hidden dim sliced
    (qwen2: 60e x 1408; 60 % 16 != 0 so EP would imbalance).  One
    psum('model') after the down-projection.

Dispatch is capacity-based and *local to the shard* (no global sort): the
position of each token within its expert's buffer is a cumsum over the local
one-hot assignment matrix.  Overflowing tokens are dropped (their combine
weight is zero), matching capacity-factor semantics of production MoE stacks.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ArchConfig
from repro.models.layers import ShardCtx


def init_moe_params(key, cfg: ArchConfig, dtype):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * s).astype(jnp.float32),
        "we_gate": (jax.random.normal(ks[1], (e, d, f)) * s).astype(dtype),
        "we_up": (jax.random.normal(ks[2], (e, d, f)) * s).astype(dtype),
        "we_down": (jax.random.normal(ks[3], (e, f, d))
                    * f ** -0.5).astype(dtype),
    }
    if cfg.num_shared_experts:
        fs = cfg.moe_d_ff * cfg.num_shared_experts
        p["ws_gate"] = (jax.random.normal(ks[4], (d, fs)) * s).astype(dtype)
        p["ws_up"] = (jax.random.normal(ks[5], (d, fs)) * s).astype(dtype)
        p["ws_down"] = (jax.random.normal(ks[6], (fs, d))
                        * fs ** -0.5).astype(dtype)
    return p


def moe_mode(cfg: ArchConfig, tp_size: int) -> str:
    return "ep" if cfg.num_experts % tp_size == 0 else "tp"


def moe_param_specs(cfg: ArchConfig, tp_size: int):
    mode = moe_mode(cfg, tp_size)
    if mode == "ep":
        expert = {"we_gate": P("model", None, None),
                  "we_up": P("model", None, None),
                  "we_down": P("model", None, None)}
    else:
        expert = {"we_gate": P(None, None, "model"),
                  "we_up": P(None, None, "model"),
                  "we_down": P(None, "model", None)}
    p = {"router": P(None, None), **expert}
    if cfg.num_shared_experts:
        p.update({"ws_gate": P(None, "model"),
                  "ws_up": P(None, "model"),
                  "ws_down": P("model", None)})
    return p


def _local_moe(x, router, wg, wu, wd, *, cfg: ArchConfig, mode: str,
               tp_axis: str, capacity_factor: float):
    """Per-shard MoE compute.  x: (N, d) local tokens; weights local slices."""
    n, d = x.shape
    e = cfg.num_experts
    k = cfg.top_k
    e_local = wg.shape[0]

    logits = jnp.einsum("nd,de->ne", x.astype(jnp.float32), router)
    gates = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(gates, k)          # (n, k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)

    # local capacity per expert
    cap = max(8, int(k * n * capacity_factor) // e)

    # one-hot over experts for each of the k assignments -> position via cumsum
    flat_e = top_idx.reshape(-1)                          # (n*k,)
    flat_w = top_vals.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)   # (n*k, e)
    pos = jnp.cumsum(onehot, axis=0) - onehot             # positions start at 0
    mypos = jnp.sum(pos * onehot, axis=-1)                # (n*k,)
    keep = mypos < cap

    if mode == "ep":
        shard = jax.lax.axis_index(tp_axis)
        base = shard * e_local
        local = (flat_e >= base) & (flat_e < base + e_local)
        keep = keep & local
        local_e = flat_e - base
    else:
        local_e = flat_e

    tok = jnp.arange(n * k) // k
    safe_e = jnp.where(keep, local_e, 0)
    safe_p = jnp.where(keep, mypos, cap - 1)

    # gather tokens into (e_local, cap, d) buffers
    xe = jnp.zeros((e_local, cap, d), x.dtype)
    xe = xe.at[safe_e, safe_p].add(
        jnp.where(keep[:, None], x[tok], 0).astype(x.dtype))

    # expert FFN (SwiGLU)
    g = jnp.einsum("ecd,edf->ecf", xe, wg)
    u = jnp.einsum("ecd,edf->ecf", xe, wu)
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, wd)                # (e_local, cap, d)

    # combine back: weighted scatter-add into token rows
    contrib = ye[safe_e, safe_p] * jnp.where(keep, flat_w, 0.0)[:, None]
    y = jnp.zeros_like(x).at[tok].add(contrib.astype(x.dtype))

    # aux load-balance loss terms (local sums; caller psums over dp)
    me = jnp.mean(gates, axis=0)                          # (e,)
    ce = jnp.mean(jax.nn.one_hot(top_idx, e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(me * ce)
    return y, aux


def moe_ffn(x, p, cfg: ArchConfig, ctx: Optional[ShardCtx],
            capacity_factor: float | None = None, tips_important=None):
    """(B, T, d) -> (B, T, d) mixture-of-experts FFN (+ shared experts)."""
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    if tips_important is not None:
        from repro.core import tips as tips_mod
        x = tips_mod.apply_precision_mask(x, tips_important)

    b, t, d = x.shape
    if ctx is None:
        # single-device path (smoke tests): same math, one "shard"
        y, aux = _local_moe(x.reshape(-1, d), p["router"], p["we_gate"],
                            p["we_up"], p["we_down"], cfg=cfg, mode="tp",
                            tp_axis=None, capacity_factor=capacity_factor)
        y = y.reshape(b, t, d)
    else:
        mode = moe_mode(cfg, ctx.tp_size)
        specs = moe_param_specs(cfg, ctx.tp_size)
        dp = ctx.dp_axes

        def body(xl, router, wg, wu, wd):
            n = xl.shape[0] * xl.shape[1]
            y, aux = _local_moe(xl.reshape(n, d), router, wg, wu, wd,
                                cfg=cfg, mode=mode, tp_axis=ctx.tp_axis,
                                capacity_factor=capacity_factor)
            y = jax.lax.psum(y, ctx.tp_axis) if mode == "ep" else \
                jax.lax.psum(y, ctx.tp_axis)
            aux = jax.lax.pmean(aux, dp)
            return y.reshape(xl.shape), aux

        y, aux = shard_map(
            body, mesh=ctx.mesh,
            in_specs=(P(dp, None, None), specs["router"], specs["we_gate"],
                      specs["we_up"], specs["we_down"]),
            out_specs=(P(dp, None, None), P()),
            check_rep=False,
        )(x, p["router"], p["we_gate"], p["we_up"], p["we_down"])

    if cfg.num_shared_experts:
        g = jnp.einsum("btd,df->btf", x, p["ws_gate"])
        u = jnp.einsum("btd,df->btf", x, p["ws_up"])
        y = y + jnp.einsum("btf,fd->btd", jax.nn.silu(g) * u, p["ws_down"])
    return y, aux
