"""Block-size autotuner for the fused Pallas kernels.

The five ``KernelPolicy`` block knobs (``attn_block_q``/``attn_block_k``,
``cross_block_q``, ``bitmap_block_rows``, ``reuse_block_patches``) default
to safe-everywhere values; the right blocks depend on the backend and the
operand geometry.  This module sweeps each kernel family's candidates
with the same min-of-k block-until-ready timing every bench uses
(``runtime.min_wall_s``) and persists the winners to a committed JSON
table keyed exactly like the dispatch layer routes ops::

    {backend}/{op}/{field=value,...}     e.g.
    cpu/self_attention/b=1,h=8,t=4096,d=40,patch=64

At run time ``KernelPolicy.autotuned()`` (see ``dispatch.py``) looks the
table up AT TRACE TIME from the static operand shapes and feeds the
winning blocks into the kernel calls as ordinary block arguments — table
values never enter an executable cache key beyond the hashable policy
itself, so flipping tables cannot cause retracing churn.  Unknown
(backend, op, geometry) keys fall back to the policy's defaults; a
malformed or version-stale table is a hard ``AutotuneTableError`` (a
silently ignored table would masquerade as a tuning regression).

Each kernel family exposes three hooks on its ``ops`` module:

* ``AUTOTUNE_KNOBS``             — the policy field names it tunes
* ``autotune_candidates(geom)``  — block-dict candidates for a geometry
* ``autotune_probe(geom, blocks, *, interpret=None)`` — (jitted fn, args)

Regenerate the committed table with::

    python -m repro.kernels.autotune            # full geometry (minutes)
    python -m repro.kernels.autotune --smoke    # tiny geometry (CI)
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
from typing import Any, Sequence

import jax

from repro.kernels import runtime

AUTOTUNE_VERSION = 1
DEFAULT_TABLE_PATH = os.path.join(os.path.dirname(__file__),
                                  "autotune_table.json")

# op name (as the dispatch layer routes it) -> (ops module, geometry
# field names — the order is the canonical key order)
_OPS: dict[str, tuple[str, tuple[str, ...]]] = {
    "self_attention": ("repro.kernels.pssa_attention.ops",
                       ("b", "h", "t", "d", "patch")),
    "cross_attention": ("repro.kernels.cross_attention_tips.ops",
                        ("b", "h", "tq", "d", "tk")),
    "bitmap": ("repro.kernels.patch_bitmap.ops",
               ("rows", "tk", "patch")),
    "reuse": ("repro.kernels.patch_reuse.ops",
              ("b", "t", "c", "patch")),
}

# the geometries the serving paths actually run (paper smoke model:
# 64x64 latents -> T=4096 self-attention rows, Tk=77 text keys) — these
# are what the committed table is generated over
DEFAULT_GEOMS: dict[str, tuple[tuple[int, ...], ...]] = {
    "self_attention": ((1, 8, 4096, 40, 64),),
    "cross_attention": ((1, 8, 1024, 40, 77), (1, 8, 4096, 40, 77)),
    "bitmap": ((4096, 4096, 64),),
    "reuse": ((1, 4096, 320, 64),),
}

# tiny geometries for the CI smoke sweep (seconds, not minutes)
SMOKE_GEOMS: dict[str, tuple[tuple[int, ...], ...]] = {
    "self_attention": ((1, 2, 256, 32, 16),),
    "cross_attention": ((1, 2, 256, 32, 77),),
    "bitmap": ((256, 256, 16),),
    "reuse": ((1, 256, 64, 16),),
}


class AutotuneTableError(ValueError):
    """The autotune table is malformed or stale — regenerate it."""


def _op_module(op: str):
    if op not in _OPS:
        raise KeyError(f"unknown autotune op {op!r}; "
                       f"known: {sorted(_OPS)}")
    # the family ops modules reach repro.core via their ref imports;
    # importing core.attention first keeps that cycle resolvable no
    # matter which repro module the caller touched first
    importlib.import_module("repro.core.attention")
    return importlib.import_module(_OPS[op][0])


# ---------------------------------------------------------------------------
# Keys
# ---------------------------------------------------------------------------
def make_key(backend: str, op: str, geom: Sequence[int]) -> str:
    """(backend, op, geometry) -> the canonical table key string."""
    fields = _OPS[op][1]
    if len(geom) != len(fields):
        raise ValueError(f"{op} geometry needs {fields}, got {tuple(geom)}")
    dims = ",".join(f"{f}={int(v)}" for f, v in zip(fields, geom))
    return f"{backend}/{op}/{dims}"


def parse_key(key: str) -> tuple[str, str, tuple[int, ...]]:
    """Canonical key string -> (backend, op, geometry); strict inverse."""
    try:
        backend, op, dims = key.split("/")
    except ValueError:
        raise AutotuneTableError(
            f"bad autotune key {key!r}: want 'backend/op/f=v,...'") from None
    if op not in _OPS:
        raise AutotuneTableError(f"bad autotune key {key!r}: "
                                 f"unknown op {op!r}")
    fields = _OPS[op][1]
    parts = dims.split(",") if dims else []
    got: dict[str, int] = {}
    for part in parts:
        name, _, val = part.partition("=")
        if not val or not val.lstrip("-").isdigit():
            raise AutotuneTableError(
                f"bad autotune key {key!r}: field {part!r} is not 'name=int'")
        got[name] = int(val)
    if tuple(got) != fields:
        raise AutotuneTableError(
            f"bad autotune key {key!r}: {op} geometry fields must be "
            f"{fields} in order, got {tuple(got)}")
    return backend, op, tuple(got[f] for f in fields)


# ---------------------------------------------------------------------------
# Table load / lookup
# ---------------------------------------------------------------------------
_TABLE_CACHE: dict[str, dict[str, Any]] = {}


def clear_cache() -> None:
    """Drop memoized tables (tests monkeypatching the table path)."""
    _TABLE_CACHE.clear()


def validate_table(table: Any, *, source: str = "<table>") -> dict:
    """Structural validation; returns the table or raises loudly."""
    if not isinstance(table, dict):
        raise AutotuneTableError(f"{source}: autotune table must be a JSON "
                                 f"object, got {type(table).__name__}")
    version = table.get("version")
    if version != AUTOTUNE_VERSION:
        raise AutotuneTableError(
            f"{source}: autotune table version {version!r} != expected "
            f"{AUTOTUNE_VERSION}; regenerate with "
            f"'python -m repro.kernels.autotune'")
    entries = table.get("entries")
    if not isinstance(entries, dict):
        raise AutotuneTableError(f"{source}: 'entries' must be an object")
    for key, blocks in entries.items():
        _, op, _ = parse_key(key)                 # raises on bad keys
        knobs = _op_knobs(op)
        if not isinstance(blocks, dict) or not blocks:
            raise AutotuneTableError(
                f"{source}: entry {key!r} must map knob names to ints")
        for name, val in blocks.items():
            if name not in knobs:
                raise AutotuneTableError(
                    f"{source}: entry {key!r} tunes unknown knob {name!r}; "
                    f"{op} knobs are {knobs}")
            if not isinstance(val, int) or isinstance(val, bool) or val <= 0:
                raise AutotuneTableError(
                    f"{source}: entry {key!r} knob {name!r} must be a "
                    f"positive int, got {val!r}")
    return table


def _op_knobs(op: str) -> tuple[str, ...]:
    # knob names are static metadata; avoid importing jax-heavy ops
    # modules just to validate a table
    return {
        "self_attention": ("attn_block_q", "attn_block_k"),
        "cross_attention": ("cross_block_q",),
        "bitmap": ("bitmap_block_rows",),
        "reuse": ("reuse_block_patches",),
    }[op]


def load_table(path: str | None = None) -> dict:
    """Load + validate the table at ``path`` (default: committed table).

    A missing file is a valid empty table (fresh checkouts before the
    first sweep, exotic backends); a PRESENT but malformed or stale file
    raises ``AutotuneTableError``.
    """
    path = path or DEFAULT_TABLE_PATH
    cached = _TABLE_CACHE.get(path)
    if cached is not None:
        return cached
    if not os.path.exists(path):
        table: dict[str, Any] = {"version": AUTOTUNE_VERSION, "entries": {}}
    else:
        try:
            with open(path) as f:
                table = json.load(f)
        except json.JSONDecodeError as e:
            raise AutotuneTableError(
                f"{path}: autotune table is not valid JSON ({e}); "
                f"regenerate with 'python -m repro.kernels.autotune'"
            ) from None
        validate_table(table, source=path)
    _TABLE_CACHE[path] = table
    return table


def lookup(op: str, geom: Sequence[int], *, backend: str | None = None,
           path: str | None = None) -> dict[str, int] | None:
    """Winning blocks for (backend, op, geometry), or None (use defaults)."""
    backend = backend or jax.default_backend()
    entries = load_table(path)["entries"]
    blocks = entries.get(make_key(backend, op, geom))
    return dict(blocks) if blocks is not None else None


# ---------------------------------------------------------------------------
# Sweep
# ---------------------------------------------------------------------------
def sweep_op(op: str, geom: Sequence[int], *, reps: int = 2,
             interpret: bool | None = None, verbose: bool = True):
    """Time every candidate for one (op, geometry); return (best, trace)."""
    mod = _op_module(op)
    geom = tuple(int(v) for v in geom)
    results = []
    for blocks in mod.autotune_candidates(geom):
        fn, args = mod.autotune_probe(geom, blocks, interpret=interpret)
        wall = runtime.min_wall_s(fn, *args, reps=reps)
        results.append({"blocks": dict(blocks), "wall_s": wall})
        if verbose:
            print(f"  {op} {geom} {blocks} -> {wall * 1e3:.2f} ms",
                  file=sys.stderr)
    best = min(results, key=lambda r: r["wall_s"])
    return dict(best["blocks"]), results


def tune(geoms: dict[str, Sequence[Sequence[int]]] | None = None, *,
         reps: int = 2, interpret: bool | None = None,
         backend: str | None = None, verbose: bool = True) -> dict:
    """Sweep every (op, geometry) and return a full, valid table dict."""
    geoms = geoms or DEFAULT_GEOMS
    backend = backend or jax.default_backend()
    entries: dict[str, Any] = {}
    trace: dict[str, Any] = {}
    for op, op_geoms in geoms.items():
        for geom in op_geoms:
            key = make_key(backend, op, geom)
            if verbose:
                print(f"[autotune] {key}", file=sys.stderr)
            best, results = sweep_op(op, geom, reps=reps,
                                     interpret=interpret, verbose=verbose)
            entries[key] = best
            trace[key] = results
    table = {
        "version": AUTOTUNE_VERSION,
        "generated_on": {
            "backend": backend,
            "interpret": runtime.resolve_interpret(interpret),
        },
        "entries": entries,
        "sweep": trace,
    }
    return validate_table(table, source="<tune>")


def save_table(table: dict, path: str | None = None) -> str:
    path = path or DEFAULT_TABLE_PATH
    with open(path, "w") as f:
        json.dump(table, f, indent=2, sort_keys=True)
        f.write("\n")
    _TABLE_CACHE.pop(path, None)
    return path


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=DEFAULT_TABLE_PATH,
                    help="table path to write (default: committed table)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny geometries (CI wiring check, seconds)")
    ap.add_argument("--reps", type=int, default=2,
                    help="timed repetitions per candidate (min is kept)")
    ap.add_argument("--ops", default=None,
                    help="comma-separated op subset (default: all)")
    args = ap.parse_args(argv)

    geoms = dict(SMOKE_GEOMS if args.smoke else DEFAULT_GEOMS)
    if args.ops:
        wanted = args.ops.split(",")
        unknown = [o for o in wanted if o not in geoms]
        if unknown:
            ap.error(f"unknown ops {unknown}; known: {sorted(geoms)}")
        geoms = {op: geoms[op] for op in wanted}

    table = tune(geoms, reps=args.reps)
    path = save_table(table, args.out)
    print(f"[autotune] wrote {len(table['entries'])} entries -> {path}",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
