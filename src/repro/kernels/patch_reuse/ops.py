"""Public temporal-reuse ops: patch delta + gather/scatter row plans.

``patch_delta`` is the dispatchable change-detection op (reference vs
Pallas kernel, selected by ``KernelPolicy.reuse``).  The plan helpers
below are pure index arithmetic shared by both routes — the model layer
(``diffusion.unet._transformer_block``) uses them to gather only the
active patch rows into the attention/FFN kernels and scatter the results
back over the cached activations.

Exactness: the plan orders ACTIVE patches first in ascending patch index
(stable argsort of the inverted bitmap), so an all-active row yields the
identity permutation and gather -> compute -> scatter returns the dense
result bit-for-bit (attention queries and FFN rows are row-independent;
the scatter is a pure copy).  When active patches exceed the static
capacity, the highest-index actives are dropped — deterministic, and
counted honestly by the gate (dropped patches fall back to the cache).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.patch_reuse.kernel import patch_delta_kernel
from repro.kernels.patch_reuse.ref import patch_delta_ref
from repro.kernels.runtime import pad_axis_to


@functools.partial(jax.jit, static_argnames=("patch", "threshold",
                                             "use_kernel", "interpret",
                                             "bp"))
def patch_delta(x: jax.Array, x_ref: jax.Array, patch: int,
                threshold: float, use_kernel: bool = True,
                interpret: bool | None = None, bp: int = 8):
    """(B, T, C) tokens vs cached reference -> (delta, active) per patch.

    ``delta`` is (B, T/patch) float32 max-abs difference; ``active`` the
    (B, T/patch) bool bitmap ``delta >= threshold``.  ``threshold=0``
    marks every patch active (dense bit-exactness).  ``patch`` must
    divide T.
    """
    b, t, c = x.shape
    assert t % patch == 0, (t, patch)
    if use_kernel:
        fold = lambda a: a.reshape(b, t // patch, patch * c)
        xf = pad_axis_to(fold(x), bp, 1)
        rf = pad_axis_to(fold(x_ref), bp, 1)
        delta = patch_delta_kernel(xf, rf, bp=bp,
                                   interpret=interpret)[:, :t // patch]
    else:
        delta = patch_delta_ref(x, x_ref, patch)
    return delta, delta >= threshold


# ---------------------------------------------------------------------------
# Autotune hooks (repro.kernels.autotune): geometry = (b, t, c, patch)
# ---------------------------------------------------------------------------
AUTOTUNE_KNOBS = ("reuse_block_patches",)


def autotune_candidates(geom: tuple) -> tuple:
    """Patch-block candidates for a (b, t, c, patch) geometry."""
    b, t, c, patch = geom
    n_patches = t // patch
    sizes = sorted({min(s, n_patches) for s in (8, 16, 32, 64, 128)})
    return tuple({"reuse_block_patches": s} for s in sizes)


def autotune_probe(geom: tuple, blocks: dict, *,
                   interpret: bool | None = None):
    """(jitted fn, args) the autotuner times for one block config."""
    b, t, c, patch = geom
    x = jax.random.normal(jax.random.PRNGKey(0), (b, t, c), jnp.float32)
    x_ref = x + 1e-4 * jax.random.normal(jax.random.PRNGKey(1), (b, t, c),
                                         jnp.float32)
    fn = jax.jit(functools.partial(
        patch_delta, patch=patch, threshold=1e-3, interpret=interpret,
        bp=blocks["reuse_block_patches"]))
    return fn, (x, x_ref)


def reuse_plan(active: jax.Array, cap: int):
    """(B, P) active bitmap -> static-width gather plan (order, gate).

    ``order`` (B, cap) int32 lists patch indices with actives first in
    ascending index order (stable sort — all-active rows get the identity
    prefix); ``gate`` (B, cap) marks which plan slots hold a genuinely
    active patch (padding slots scatter nothing).
    """
    order = jnp.argsort(jnp.logical_not(active), axis=1,
                        stable=True)[:, :cap].astype(jnp.int32)
    gate = jnp.take_along_axis(active, order, axis=1)
    return order, gate


def plan_token_rows(order: jax.Array, patch: int):
    """Patch-index plan -> token-row indices (B, cap*patch), plan-major."""
    b, k = order.shape
    rows = order[:, :, None] * patch \
        + jnp.arange(patch, dtype=jnp.int32)[None, None, :]
    return rows.reshape(b, k * patch)


def gather_rows(x: jax.Array, rows: jax.Array) -> jax.Array:
    """(B, T, C) tokens + (B, R) row ids -> (B, R, C) gathered rows."""
    return jnp.take_along_axis(x, rows[:, :, None], axis=1)


def scatter_rows(base: jax.Array, rows: jax.Array, values: jax.Array,
                 gate_rows: jax.Array) -> jax.Array:
    """Write gated computed rows over the cached activations.

    ``base`` (B, T, C) is the cache; ``values`` (B, R, C) the rows
    computed on the gathered plan; ``gate_rows`` (B, R) masks plan
    padding (ungated slots keep the cache payload even though their row
    index aliases a real token).  Plan rows are unique per batch row, so
    the scatter is a deterministic copy.
    """
    cur = jnp.take_along_axis(base, rows[:, :, None], axis=1)
    vals = jnp.where(gate_rows[:, :, None], values, cur)
    bidx = jnp.arange(base.shape[0], dtype=jnp.int32)[:, None]
    return base.at[bidx, rows].set(vals)
