"""Reference per-patch change detection for temporal reuse (SIGE-style).

The temporal-reuse runtime compares each transformer block's token-space
input against the cached reference from the previous denoising step (or an
edit request's base) and marks a PATCH active when any of its token
channels moved by at least the policy threshold.  This is the pure-JAX
oracle the Pallas kernel (``kernel.py``) is verified against; both reduce
to max/abs over the same values, which are exactly commutative, so the
implementations are bit-identical.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def patch_delta_ref(x: jax.Array, x_ref: jax.Array,
                    patch: int) -> jax.Array:
    """(B, T, C) tokens vs cached reference -> (B, T/patch) max-abs delta.

    Tokens are grouped into contiguous runs of ``patch`` (the same token
    grouping the PSSA bitmap machinery uses along the key axis), and the
    delta is the max absolute difference over the patch's tokens and
    channels.
    """
    b, t, c = x.shape
    assert t % patch == 0, (t, patch)
    d = jnp.abs(x.astype(jnp.float32) - x_ref.astype(jnp.float32))
    return jnp.max(d.reshape(b, t // patch, patch * c), axis=-1)
