"""Per-patch change-bitmap Pallas kernel (temporal reuse front end).

Streams the current and cached token activations block-by-block and emits
the per-patch max-abs delta — the signal the reuse plan thresholds into an
active-patch bitmap.  One grid step owns ``bp`` patches of one batch row;
the patch's tokens and channels arrive pre-folded into the trailing axis
(``patch * C``), so the reduction is a single row-wise max and the block
is MXU/VPU-friendly (last dim is the wide one).

The wrapper (``ops.py``) pads the patch axis to the block multiple with
zeros on BOTH operands — padded patches read delta 0 and are sliced off,
so padding is exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _kernel(x_ref, r_ref, o_ref):
    d = jnp.abs(x_ref[0].astype(jnp.float32) - r_ref[0].astype(jnp.float32))
    o_ref[0] = jnp.max(d, axis=-1)


@functools.partial(jax.jit, static_argnames=("bp", "interpret"))
def patch_delta_kernel(xf: jax.Array, rf: jax.Array, bp: int = 8,
                       interpret: bool | None = None) -> jax.Array:
    """(B, P, patch*C) folded tokens/reference -> (B, P) max-abs delta.

    ``P`` must be a multiple of ``bp`` (the ops wrapper pads).
    ``interpret=None`` auto-selects from the backend.
    """
    b, p, w = xf.shape
    assert p % bp == 0, (p, bp)
    return pl.pallas_call(
        _kernel,
        grid=(b, p // bp),
        in_specs=[
            pl.BlockSpec((1, bp, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bp, w), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bp), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, p), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(xf, rf)
