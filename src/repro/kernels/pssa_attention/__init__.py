from repro.kernels.pssa_attention.ops import pssa_attention  # noqa: F401
from repro.kernels.pssa_attention.ref import pssa_attention_ref  # noqa: F401
