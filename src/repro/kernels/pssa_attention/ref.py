"""Pure-jnp oracle for the PSSA pruned-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pssa


def pssa_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                       threshold: float):
    """(BH, T, d) -> (out, nnz): full softmax, prune, matmul."""
    d = q.shape[-1]
    scores = jnp.einsum("btd,bsd->bts", q, k) / jnp.sqrt(float(d))
    p = jax.nn.softmax(scores, axis=-1)
    keep = p >= threshold
    p = jnp.where(keep, p, 0.0)
    out = jnp.einsum("bts,bsd->btd", p, v)
    nnz = jnp.sum(keep.astype(jnp.int32), axis=-1)
    return out, nnz


def pssa_attention_stats_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                             threshold: float, patch: int):
    """(BH, T, d) -> (out, nnz, xor_ones): materializing stats oracle.

    ``xor_ones`` is the per-query popcount of the patch-XOR'd sparsity
    bitmap (``core.pssa.patch_xor`` over the pruned-score bitmap) — the
    counter the blocked kernel accumulates without building the SAS.
    """
    d = q.shape[-1]
    scores = jnp.einsum("btd,bsd->bts", q, k) / jnp.sqrt(float(d))
    p = jax.nn.softmax(scores, axis=-1)
    keep = p >= threshold
    out = jnp.einsum("bts,bsd->btd", jnp.where(keep, p, 0.0), v)
    nnz = jnp.sum(keep.astype(jnp.int32), axis=-1)
    xor_ones = jnp.sum(pssa.patch_xor(keep, patch).astype(jnp.int32), axis=-1)
    return out, nnz, xor_ones
