"""Public op: PSSA attention over (B, H, T, d) with head folding + padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pssa_attention.kernel import pssa_attention_kernel
from repro.kernels.pssa_attention.ref import pssa_attention_ref


@functools.partial(jax.jit, static_argnames=("threshold", "use_kernel",
                                             "interpret", "bq", "bk"))
def pssa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   threshold: float,
                   use_kernel: bool = True, interpret: bool = True,
                   bq: int = 128, bk: int = 128):
    """(B, H, T, d) q/k/v -> ((B, H, T, d) out, (B, H, T) nnz counts)."""
    b, h, t, d = q.shape
    fold = lambda x: x.reshape(b * h, t, x.shape[-1])
    qf, kf, vf = fold(q), fold(k), fold(v)
    if use_kernel:
        blk = min(bq, t)
        while t % blk:
            blk //= 2
        out, nnz = pssa_attention_kernel(qf, kf, vf, threshold,
                                         bq=blk, bk=blk, interpret=interpret)
    else:
        out, nnz = pssa_attention_ref(qf, kf, vf, threshold)
    return out.reshape(b, h, t, d), nnz.reshape(b, h, t)
