"""Public op: PSSA attention over (B, H, T, d) with head folding + padding.

Block handling: instead of the seed's degenerate fallback (halving the block
until it divides T — which collapses to 1-wide blocks for non-power-of-two
T), operands are zero-padded up to the block multiple and the outputs sliced
back; the kernel masks padded key columns out of the softmax statistics and
every counter (``kv_len``), so padding is exact.

``patch`` switches on the fused PSSA accounting: a third (B, H, T) int32
output with the per-query patch-XOR bitmap popcount, accumulated inside the
kernel — the SAS never exists in memory.  The key block is rounded down to a
patch multiple (and floored at ``patch``) so the XOR carry stays
block-aligned; ``patch`` must divide T.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pssa_attention.kernel import pssa_attention_kernel
from repro.kernels.pssa_attention.ref import (pssa_attention_ref,
                                              pssa_attention_stats_ref)
from repro.kernels.runtime import pad_axis_to


@functools.partial(jax.jit, static_argnames=("threshold", "patch",
                                             "use_kernel", "interpret",
                                             "bq", "bk"))
def pssa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   threshold: float,
                   patch: int | None = None,
                   use_kernel: bool = True, interpret: bool | None = None,
                   bq: int = 128, bk: int = 128):
    """(B, H, T, d) q/k/v -> ((B, H, T, d) out, (B, H, T) nnz counts).

    With ``patch`` set, returns a third (B, H, T) array of per-query
    patch-XOR bitmap popcounts (see ``core.pssa``).  ``interpret=None``
    auto-selects interpret mode from the backend.
    """
    b, h, t, d = q.shape
    if patch is not None:
        assert t % patch == 0, (t, patch)
    fold = lambda x: x.reshape(b * h, t, x.shape[-1])
    qf, kf, vf = fold(q), fold(k), fold(v)
    if use_kernel:
        blk_q = min(bq, t)
        blk_k = min(bk, t)
        if patch is not None:
            blk_k = max(patch, blk_k - blk_k % patch)
        res = pssa_attention_kernel(
            pad_axis_to(qf, blk_q, 1), pad_axis_to(kf, blk_k, 1),
            pad_axis_to(vf, blk_k, 1), threshold,
            bq=blk_q, bk=blk_k, interpret=interpret, kv_len=t, patch=patch)
        res = tuple(x[:, :t] for x in res)          # drop padded query rows
    elif patch is None:
        res = pssa_attention_ref(qf, kf, vf, threshold)
    else:
        res = pssa_attention_stats_ref(qf, kf, vf, threshold, patch)
    out, counts = res[0], res[1:]
    return (out.reshape(b, h, t, d),) + tuple(
        c.reshape(b, h, t) for c in counts)


# ---------------------------------------------------------------------------
# Autotune hooks (repro.kernels.autotune): geometry = (b, h, t, d, patch)
# ---------------------------------------------------------------------------
AUTOTUNE_KNOBS = ("attn_block_q", "attn_block_k")
_PROBE_THRESHOLD = 1.0 / 8192.0       # the paper's PSSA operating point


def autotune_candidates(geom: tuple) -> tuple:
    """Block-dict candidates for a (b, h, t, d, patch) geometry.

    Square (bq, bk) pairs plus the asymmetric neighbours of each —
    capped at ``t`` (larger blocks would only pad) and deduplicated, so
    degenerate geometries sweep a short list.
    """
    b, h, t, d, patch = geom
    sizes = sorted({min(s, t) for s in (128, 256, 512, 1024)})
    cands = [(s, s) for s in sizes]
    cands += [(q, k) for q, k in zip(sizes, sizes[1:])]
    cands += [(q, k) for k, q in zip(sizes, sizes[1:])]
    seen, out = set(), []
    for bq, bk in cands:
        if (bq, bk) not in seen:
            seen.add((bq, bk))
            out.append({"attn_block_q": bq, "attn_block_k": bk})
    return tuple(out)


def autotune_probe(geom: tuple, blocks: dict, *,
                   interpret: bool | None = None):
    """(jitted fn, args) the autotuner times for one block config."""
    b, h, t, d, patch = geom
    q, k, v = (jax.random.normal(jax.random.PRNGKey(i), (b, h, t, d),
                                 jnp.float32) for i in range(3))
    fn = jax.jit(functools.partial(
        pssa_attention, threshold=_PROBE_THRESHOLD, patch=patch,
        interpret=interpret, bq=blocks["attn_block_q"],
        bk=blocks["attn_block_k"]))
    return fn, (q, k, v)
