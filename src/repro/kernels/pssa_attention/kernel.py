"""PSSA self-attention Pallas kernel (paper §III).

Blocked pixel-wise self-attention whose post-softmax scores are pruned at a
fixed threshold before the value matmul — the on-chip half of PSSA (the SAS
the attention core would spill to DRAM is exactly the pruned matrix that the
PSXU compresses).  The kernel additionally emits the per-query-block count of
surviving scores, which feeds the EMA ledger.

Pruning on normalized scores inside a *blocked* softmax needs the final row
max/sum, so the kernel is two-pass (FlashAttention-2 style):

  pass 1: stream K blocks, maintain running (m, l) per query row;
  pass 2: stream K blocks again, p = exp(s - m)/l, zero p < tau, accumulate
          p @ V and popcount(p >= tau).

Grid: (batch*heads, Tq/bq); the full K/V stripe of one (batch, head) lives
in VMEM (T x d x 2 operands — <= 4 MB for T=4096, d=64, fp32; half that in
bf16 on silicon).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, nnz_ref, *, bk: int, sm_scale: float,
            threshold: float):
    q = q_ref[0] * sm_scale                       # (bq, d)
    kdim = k_ref.shape[1]
    nk = kdim // bk
    bq = q.shape[0]

    def pass1(s, carry):
        m_prev, l_prev = carry
        kblk = k_ref[0, pl.dslice(s * bk, bk), :]           # (bk, d)
        scores = jnp.dot(q, kblk.T, preferred_element_type=jnp.float32)
        m_cur = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
        l_cur = l_prev * jnp.exp(m_prev - m_cur) + jnp.sum(
            jnp.exp(scores - m_cur[:, None]), axis=-1)
        return m_cur, l_cur

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    m, l = jax.lax.fori_loop(0, nk, pass1, (m0, l0))
    l = jnp.maximum(l, 1e-30)

    def pass2(s, carry):
        acc, nnz = carry
        kblk = k_ref[0, pl.dslice(s * bk, bk), :]
        vblk = v_ref[0, pl.dslice(s * bk, bk), :]
        scores = jnp.dot(q, kblk.T, preferred_element_type=jnp.float32)
        p = jnp.exp(scores - m[:, None]) / l[:, None]
        keep = p >= threshold
        p = jnp.where(keep, p, 0.0)                # PSSA step 1: prune
        acc = acc + jnp.dot(p, vblk, preferred_element_type=jnp.float32)
        nnz = nnz + jnp.sum(keep.astype(jnp.int32), axis=-1)
        return acc, nnz

    acc0 = jnp.zeros_like(o_ref[0])
    nnz0 = jnp.zeros((bq,), jnp.int32)
    acc, nnz = jax.lax.fori_loop(0, nk, pass2, (acc0, nnz0))
    o_ref[0] = acc
    nnz_ref[0] = nnz


@functools.partial(jax.jit, static_argnames=("bq", "bk", "threshold",
                                             "interpret"))
def pssa_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                          threshold: float,
                          bq: int = 128, bk: int = 128,
                          interpret: bool = True):
    """(BH, T, d) q/k/v -> ((BH, T, d) out, (BH, T) surviving-score counts)."""
    bh, t, d = q.shape
    assert t % bq == 0 and t % bk == 0, (t, bq, bk)
    sm_scale = 1.0 / (d ** 0.5)

    out, nnz = pl.pallas_call(
        functools.partial(_kernel, bk=bk, sm_scale=sm_scale,
                          threshold=threshold),
        grid=(bh, t // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, t, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, t, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, t), jnp.int32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out, nnz
