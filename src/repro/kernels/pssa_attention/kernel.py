"""PSSA self-attention Pallas kernel (paper §III).

Blocked pixel-wise self-attention whose post-softmax scores are pruned at a
fixed threshold before the value matmul — the on-chip half of PSSA (the SAS
the attention core would spill to DRAM is exactly the pruned matrix that the
PSXU compresses).  The kernel additionally emits the per-query count of
surviving scores and (optionally) the per-query popcount of the patch-XOR'd
sparsity bitmap — together the exact integer counters the PSSA byte
accounting needs, so the fused serving path never materializes the SAS.

Pruning on normalized scores inside a *blocked* softmax needs the final row
max/sum, so the kernel is two-pass (FlashAttention-2 style):

  pass 1: stream K blocks, maintain running (m, l) per query row;
  pass 2: stream K blocks again, p = exp(s - m)/l, zero p < tau, accumulate
          p @ V, popcount(p >= tau), and — when ``patch`` is set — the
          PSXU delta-bitmap popcount.  The XOR between horizontally-adjacent
          bitmap patches crosses K-block boundaries, so the last patch of
          each block rides the loop carry into the next iteration; the first
          patch overall XORs against zeros, i.e. is counted verbatim,
          matching ``core.pssa.patch_xor``.

``kv_len`` supports block-padded operands: key columns >= kv_len are masked
to -inf before the softmax statistics and excluded from every counter, so
padding to the block multiple (see ops.py) is exact.

Grid: (batch*heads, Tq/bq); the full K/V stripe of one (batch, head) lives
in VMEM (T x d x 2 operands — <= 4 MB for T=4096, d=64, fp32; half that in
bf16 on silicon).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, nnz_ref, *rest, bk: int,
            sm_scale: float, threshold: float, kv_len: int,
            patch: int | None):
    xor_ref = rest[0] if rest else None
    q = q_ref[0] * sm_scale                       # (bq, d)
    kdim = k_ref.shape[1]
    nk = kdim // bk
    bq = q.shape[0]
    padded = kv_len < kdim                        # static: mask the tail

    def kv_valid(s):                              # (1, bk) bool, col < kv_len
        col = s * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        return col < kv_len

    def pass1(s, carry):
        m_prev, l_prev = carry
        kblk = k_ref[0, pl.dslice(s * bk, bk), :]           # (bk, d)
        scores = jnp.dot(q, kblk.T, preferred_element_type=jnp.float32)
        if padded:
            scores = jnp.where(kv_valid(s), scores, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
        l_cur = l_prev * jnp.exp(m_prev - m_cur) + jnp.sum(
            jnp.exp(scores - m_cur[:, None]), axis=-1)
        return m_cur, l_cur

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    m, l = jax.lax.fori_loop(0, nk, pass1, (m0, l0))
    l = jnp.maximum(l, 1e-30)

    def pass2(s, carry):
        if patch is None:
            acc, nnz = carry
        else:
            acc, nnz, xor_cnt, prev = carry
        kblk = k_ref[0, pl.dslice(s * bk, bk), :]
        vblk = v_ref[0, pl.dslice(s * bk, bk), :]
        scores = jnp.dot(q, kblk.T, preferred_element_type=jnp.float32)
        if padded:
            scores = jnp.where(kv_valid(s), scores, NEG_INF)
        p = jnp.exp(scores - m[:, None]) / l[:, None]
        keep = p >= threshold
        if padded:                     # threshold == 0 keeps p == 0 columns
            keep = jnp.logical_and(keep, kv_valid(s))
        p = jnp.where(keep, p, 0.0)                # PSSA step 1: prune
        acc = acc + jnp.dot(p, vblk, preferred_element_type=jnp.float32)
        nnz = nnz + jnp.sum(keep.astype(jnp.int32), axis=-1)
        if patch is None:
            return acc, nnz
        # PSXU accounting: XOR each bitmap patch against its left neighbour
        # (carried across blocks); patches past kv_len are padding.
        npb = bk // patch
        kb = keep.reshape(bq, npb, patch)
        shifted = jnp.concatenate([prev[:, None, :], kb[:, :-1, :]], axis=1)
        delta = jnp.logical_xor(kb, shifted)
        if padded:
            gidx = s * npb + jax.lax.broadcasted_iota(
                jnp.int32, (1, npb, 1), 1)
            delta = jnp.logical_and(delta, gidx < kv_len // patch)
        xor_cnt = xor_cnt + jnp.sum(delta.astype(jnp.int32), axis=(1, 2))
        return acc, nnz, xor_cnt, kb[:, -1, :]

    acc0 = jnp.zeros_like(o_ref[0])
    nnz0 = jnp.zeros((bq,), jnp.int32)
    if patch is None:
        acc, nnz = jax.lax.fori_loop(0, nk, pass2, (acc0, nnz0))
    else:
        prev0 = jnp.zeros((bq, patch), jnp.bool_)
        acc, nnz, xor_cnt, _ = jax.lax.fori_loop(
            0, nk, pass2, (acc0, nnz0, jnp.zeros((bq,), jnp.int32), prev0))
        xor_ref[0] = xor_cnt
    o_ref[0] = acc
    nnz_ref[0] = nnz


@functools.partial(jax.jit, static_argnames=("bq", "bk", "threshold",
                                             "interpret", "kv_len", "patch"))
def pssa_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                          threshold: float,
                          bq: int = 128, bk: int = 128,
                          interpret: bool | None = None,
                          kv_len: int | None = None,
                          patch: int | None = None):
    """(BH, Tq, d) q x (BH, Tk, d) k/v -> (out, nnz[, xor_ones]) per query.

    ``kv_len``: true key count when Tk is block-padded (default: Tk).
    ``patch``: PSXU patch width; when set, a third (BH, Tq) int32 output
    carries the per-query patch-XOR bitmap popcount (``kv_len`` and ``bk``
    must be patch multiples).  ``interpret=None`` auto-selects from the
    backend (interpret only where Pallas has no real lowering).
    """
    bh, tq, d = q.shape
    tk = k.shape[1]
    kv_len = tk if kv_len is None else kv_len
    assert tq % bq == 0 and tk % bk == 0, (tq, tk, bq, bk)
    assert 0 < kv_len <= tk, (kv_len, tk)
    if patch is not None:
        assert bk % patch == 0 and kv_len % patch == 0, (bk, kv_len, patch)
    sm_scale = 1.0 / (d ** 0.5)

    out_specs = [
        pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
        pl.BlockSpec((1, bq), lambda b, i: (b, i)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((bh, tq, d), jnp.float32),
        jax.ShapeDtypeStruct((bh, tq), jnp.int32),
    ]
    if patch is not None:
        out_specs.append(pl.BlockSpec((1, bq), lambda b, i: (b, i)))
        out_shape.append(jax.ShapeDtypeStruct((bh, tq), jnp.int32))

    res = pl.pallas_call(
        functools.partial(_kernel, bk=bk, sm_scale=sm_scale,
                          threshold=threshold, kv_len=kv_len, patch=patch),
        grid=(bh, tq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, tk, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, tk, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=resolve_interpret(interpret),
    )(q, k, v)
    return tuple(res)
