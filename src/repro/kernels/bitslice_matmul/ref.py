"""Pure-jnp oracle for the DBSC bit-slice matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bitslice_matmul_ref(x_hi: jax.Array, x_lo: jax.Array, w: jax.Array,
                        prec: jax.Array) -> jax.Array:
    """Exact integer semantics of the DBSC PE column.

    ``prec`` (M, 1): 1 -> INT12 row (both slices), 0 -> INT6 row (hi only).
    """
    lo = x_lo * prec
    acc_hi = jnp.matmul(x_hi, w, preferred_element_type=jnp.int32)
    acc_lo = jnp.matmul(lo, w, preferred_element_type=jnp.int32)
    return (acc_hi << 6) + acc_lo
