"""Pure-jnp oracle + int8 datapath for the DBSC bit-slice matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bitslice_matmul_ref(x_hi: jax.Array, x_lo: jax.Array, w: jax.Array,
                        prec: jax.Array) -> jax.Array:
    """Exact integer semantics of the DBSC PE column.

    ``prec`` (M, 1): 1 -> INT12 row (both slices), 0 -> INT6 row (hi only).
    """
    lo = x_lo * prec
    acc_hi = jnp.matmul(x_hi, w, preferred_element_type=jnp.int32)
    acc_lo = jnp.matmul(lo, w, preferred_element_type=jnp.int32)
    return (acc_hi << 6) + acc_lo


_DOT_2D = (((1,), (0,)), ((), ()))      # plain (M,K) @ (K,N)


def bitslice_matmul_int8(x_hi: jax.Array, x_lo: jax.Array, w: jax.Array,
                         prec: jax.Array) -> jax.Array:
    """The same integers through real int8 x int8 -> int32 ``dot_general``.

    The DBSC operands already fit int8 exactly: each activation slice is
    unsigned 6-bit (``quant.bitslice_split`` -> [0, 63]) and the weights
    are signed INT8 ([-128, 127]), so narrowing the operand dtypes loses
    nothing and ``preferred_element_type=int32`` keeps the accumulator
    wide (worst-case |acc| = K * 63 * 128 — int32-safe for any K the
    model uses).  XLA maps this operand/accumulator combination onto the
    hardware integer units (TPU MXU int8 mode, GPU dp4a/imma) instead of
    simulating the arithmetic in int32 lanes, which is the point: same
    bits as ``bitslice_matmul_ref``, PE-shaped execution.

    ``prec`` gates the low slice BEFORE the narrowing (0 * [0,63] and
    1 * [0,63] both fit int8), mirroring the ref exactly.
    """
    hi8 = x_hi.astype(jnp.int8)
    lo8 = (x_lo * prec).astype(jnp.int8)
    w8 = w.astype(jnp.int8)
    acc_hi = jax.lax.dot_general(hi8, w8, _DOT_2D,
                                 preferred_element_type=jnp.int32)
    acc_lo = jax.lax.dot_general(lo8, w8, _DOT_2D,
                                 preferred_element_type=jnp.int32)
    return (acc_hi << 6) + acc_lo
