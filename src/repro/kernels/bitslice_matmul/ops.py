"""Public op: float-in/float-out DBSC matmul (quantize -> kernel -> rescale).

This is the wrapper the FFN layers call.  It performs the paper's full
datapath: INT12 activation quantization (on one shared scale, so TIPS rows
can drop to the INT6 grid), bit-slice split, the Pallas kernel, and the
output rescale that the SIMD core applies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.kernels.bitslice_matmul.kernel import bitslice_matmul_kernel
from repro.kernels.bitslice_matmul.ref import (bitslice_matmul_int8,
                                               bitslice_matmul_ref)


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("dataflow", "use_kernel",
                                             "interpret", "quant_path"))
def bitslice_matmul(x: jax.Array, w: jax.Array,
                    important: jax.Array | None = None,
                    dataflow: str = "weight_stationary",
                    use_kernel: bool = True,
                    interpret: bool | None = None,
                    quant_path: str = "model") -> jax.Array:
    """``x (M,K) @ w (K,N)`` through the DBSC integer datapath.

    ``important``: bool (M,) TIPS mask; None -> all rows INT12.
    ``quant_path``: ``"model"`` runs the int32 simulation (Pallas kernel
    or jnp oracle per ``use_kernel``); ``"int8"`` runs the same integer
    semantics as two real int8 x int8 -> int32 ``lax.dot_general`` calls
    (XLA maps them onto the hardware integer units) — bit-identical
    accumulators, so every downstream counter and the rescaled float
    output match the model path exactly.
    """
    if quant_path not in ("model", "int8"):
        raise ValueError(f"bitslice_matmul quant_path={quant_path!r}: "
                         f"expected 'model' or 'int8'")
    m, k = x.shape
    _, n = w.shape
    qx = quant.quantize_act(x, quant.ACT_BITS_HIGH)
    qw = quant.quantize_weight(w)
    if important is None:
        vals = qx.values
        prec = jnp.ones((m, 1), jnp.int32)
    else:
        mixed = quant.mixed_precision_quantize(x, important, qx.scale)
        vals = mixed.values
        prec = important.astype(jnp.int32)[:, None]
    hi, lo = quant.bitslice_split(vals)

    if quant_path == "int8":
        acc = bitslice_matmul_int8(hi, lo, qw.values, prec)
    elif use_kernel:
        bm = bn = bk = 128
        hi_p = _pad_to(_pad_to(hi, bm, 0), bk, 1)
        lo_p = _pad_to(_pad_to(lo, bm, 0), bk, 1)
        w_p = _pad_to(_pad_to(qw.values, bk, 0), bn, 1)
        prec_p = _pad_to(prec, bm, 0)
        acc = bitslice_matmul_kernel(hi_p, lo_p, w_p, prec_p,
                                     bm=bm, bn=bn, bk=bk,
                                     dataflow=dataflow,
                                     interpret=interpret)[:m, :n]
    else:
        acc = bitslice_matmul_ref(hi, lo, qw.values, prec)
    return acc.astype(jnp.float32) * (qx.scale * qw.scale)
