"""DBSC bit-slice matmul Pallas kernel (paper §IV-B).

The Dual-mode Bit-Slice Core multiplies a 12-bit unsigned activation by an
8-bit signed weight as TWO int7 x int8 bit-slice products:

    x = hi * 2^6 + lo            (hi, lo in [0, 63])
    y = (hi @ w) << 6 + lo @ w

Rows flagged low-precision (TIPS INT6) live on a 64x-coarser grid, so their
``lo`` plane is all-zero and the silicon *skips the low-slice pass* — here the
skip is expressed by masking the ``lo`` operand with the precision flag, and
the energy model credits the skipped slice (energy.MAC_PJ['int6x8']).

TPU mapping of the DBSC's dual *stationary* modes: both keep the full-K
stripe of the stationary operand resident in VMEM and sweep the other operand
with the innermost grid axis, so the stationary block's index map is constant
along the sweep (true reuse, no re-fetch):

  * ``weight_stationary`` (transformer/FFN mode): grid (N-blocks, M-blocks);
    the (K, bn) weight stripe is pinned while activations stream through.
  * ``input_stationary`` (CNN mode): grid (M-blocks, N-blocks); the (bm, K)
    activation stripe is pinned while weight columns stream through.

Each output block is visited exactly once (K is unrolled inside the kernel
with a fori_loop over bk-wide slabs), so there is no cross-iteration
accumulator hazard.  VMEM bound: (bm + bn) * K ints — with int8/int7 operand
storage on real TPU this is K <= 16k at 128-wide blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _kernel(x_hi_ref, x_lo_ref, w_ref, prec_ref, o_ref, *, bk: int):
    kdim = x_hi_ref.shape[-1]
    nsteps = kdim // bk

    def body(s, acc):
        sl = pl.dslice(s * bk, bk)
        hi = x_hi_ref[:, sl]
        lo = x_lo_ref[:, sl] * prec_ref[...]   # low slice skipped (INT6 rows)
        w = w_ref[sl, :]
        acc_hi = jnp.dot(hi, w, preferred_element_type=jnp.int32)
        acc_lo = jnp.dot(lo, w, preferred_element_type=jnp.int32)
        # bit-slice adder tree: shift-and-add recombine of the two slices
        return acc + (acc_hi << 6) + acc_lo

    o_ref[...] = jax.lax.fori_loop(
        0, nsteps, body, jnp.zeros_like(o_ref))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "dataflow",
                                             "interpret"))
def bitslice_matmul_kernel(x_hi: jax.Array, x_lo: jax.Array, w: jax.Array,
                           prec: jax.Array,
                           bm: int = 128, bn: int = 128, bk: int = 128,
                           dataflow: str = "weight_stationary",
                           interpret: bool | None = None) -> jax.Array:
    """int32 bit-planes (M,K), weights (K,N), precision flags (M,1) -> (M,N)."""
    m, kdim = x_hi.shape
    _, n = w.shape
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, (m, n, kdim)

    if dataflow == "weight_stationary":
        # FFN/transformer mode: weight stripe pinned, M innermost.
        grid = (n // bn, m // bm)
        xmap = lambda j, i: (i, 0)
        wmap = lambda j, i: (0, j)      # constant along the inner sweep
        pmap_ = lambda j, i: (i, 0)
        omap = lambda j, i: (i, j)
    elif dataflow == "input_stationary":
        # CNN mode: activation stripe pinned, N innermost.
        grid = (m // bm, n // bn)
        xmap = lambda i, j: (i, 0)      # constant along the inner sweep
        wmap = lambda i, j: (0, j)
        pmap_ = lambda i, j: (i, 0)
        omap = lambda i, j: (i, j)
    else:
        raise ValueError(dataflow)

    return pl.pallas_call(
        functools.partial(_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kdim), xmap),
            pl.BlockSpec((bm, kdim), xmap),
            pl.BlockSpec((kdim, bn), wmap),
            pl.BlockSpec((bm, 1), pmap_),
        ],
        out_specs=pl.BlockSpec((bm, bn), omap),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(x_hi, x_lo, w, prec)
