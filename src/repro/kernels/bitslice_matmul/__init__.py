from repro.kernels.bitslice_matmul.ops import bitslice_matmul  # noqa: F401
from repro.kernels.bitslice_matmul.ref import bitslice_matmul_ref  # noqa: F401
