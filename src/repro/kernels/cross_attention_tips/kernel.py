"""Cross-attention TIPS Pallas kernel (paper §IV-A).

Blocked pixel-query x text-key cross-attention that emits, alongside the
attention output, the per-query CLS attention score (CAS) — the quantity the
IPSU thresholds to spot prompt-tied pixels.  The reference implementation
materializes the full (B, H, Tq, Tk) probability tensor just to read its
CLS column; here the probabilities only ever exist one (bq, Tk) block at a
time in VMEM, and the CAS rides out as a (BH, Tq) side output.

Unlike the PSSA self-attention kernel, the key extent is the TEXT length
(77 for CLIP, single digits at smoke geometry) — the whole K/V stripe of
one (batch, head) trivially fits in VMEM, so the softmax is single-pass
over the full (masked) row rather than a two-pass online rescale: no
cross-block reassociation ever touches the denominator.  The score matmul
keeps the leading size-1 batch dimension (``dot_general`` with a batch
dim, exactly the contraction the reference einsum lowers to) and divides
by sqrt(d) after, mirroring the reference operation for operation.

The CAS this computes is therefore *ulp-identical* to the reference — not
guaranteed bitwise, because the reference is not bitwise stable against
itself across execution contexts (XLA fuses the softmax differently under
``jax.jit`` than eagerly, reassociating the row sum).  The quantities the
energy ledger consumes — the importance mask (``cas < threshold``), the
low-precision ratio, and the FFN MAC split derived from it — ARE
bit-identical across routing: a threshold decision only flips on an exact
floating-point tie, and the parity tests pin exact equality on every
seeded geometry (DESIGN.md §7, same empirical contract as the PSSA
counter equality of §5).

``kv_len`` supports block-padded text keys: columns >= kv_len are masked
to -inf before the row statistics, so their probabilities are exactly zero
and padding to a sublane multiple (see ops.py) contributes nothing to the
output or any real query's CAS.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30

# dot_general dimension numbers: contract the feature axis (2), batch the
# leading size-1 block axis (0) — the same contraction the reference
# einsum ("bhqd,bhkd->bhqk") performs per (batch, head) slice.
_QK_DIMS = (((2,), (2,)), ((0,), (0,)))
_PV_DIMS = (((2,), (1,)), ((0,), (0,)))


def _kernel(q_ref, k_ref, v_ref, o_ref, cas_ref, *, sm_denom: float,
            cls_index: int, kv_len: int):
    q = q_ref[...]                                # (1, bq, d)
    k = k_ref[...]                                # (1, tk_pad, d)
    v = v_ref[...]
    tk = k.shape[1]

    scores = jax.lax.dot_general(
        q, k, _QK_DIMS, preferred_element_type=jnp.float32) / sm_denom
    if kv_len < tk:                               # static: mask padded keys
        col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, tk), 2)
        scores = jnp.where(col < kv_len, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)                       # padded cols: exactly 0
    p = e / jnp.sum(e, axis=-1, keepdims=True)    # (1, bq, tk) probs block
    o_ref[...] = jax.lax.dot_general(
        p, v, _PV_DIMS, preferred_element_type=jnp.float32)
    cas_ref[...] = p[:, :, cls_index]


@functools.partial(jax.jit, static_argnames=("cls_index", "bq", "interpret",
                                             "kv_len"))
def cross_attention_tips_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                                cls_index: int = 0,
                                bq: int = 128,
                                interpret: bool | None = None,
                                kv_len: int | None = None):
    """(BH, Tq, d) q x (BH, Tk, d) text k/v -> (out, cas) per query row.

    ``out`` is (BH, Tq, d) float32; ``cas`` is (BH, Tq) float32 — the
    softmax probability mass the query puts on the ``cls_index`` text key.
    ``kv_len``: true text length when Tk is sublane-padded (default: Tk);
    ``cls_index`` must address a real (unpadded) key.  ``interpret=None``
    auto-selects from the backend (interpret only where Pallas has no real
    lowering).
    """
    bh, tq, d = q.shape
    tk = k.shape[1]
    kv_len = tk if kv_len is None else kv_len
    assert tq % bq == 0, (tq, bq)
    assert 0 < kv_len <= tk, (kv_len, tk)
    assert 0 <= cls_index < kv_len, (cls_index, kv_len)
    sm_denom = float(d) ** 0.5

    res = pl.pallas_call(
        functools.partial(_kernel, sm_denom=sm_denom, cls_index=cls_index,
                          kv_len=kv_len),
        grid=(bh, tq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, tk, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, tk, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, tq, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, tq), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(q, k, v)
    return tuple(res)
