from repro.kernels.cross_attention_tips.ops import cross_attention_cas

__all__ = ["cross_attention_cas"]
