"""Pure-jnp oracle for the cross-attention TIPS kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_attention_tips_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                             cls_index: int = 0):
    """(BH, Tq, d) x (BH, Tk, d) -> (out, cas): materializing reference.

    Builds the full (BH, Tq, Tk) probability tensor and reads its CLS
    column — the dataflow the blocked kernel avoids.  Same arithmetic
    order as ``core.attention.cross_attention_tips``.
    """
    d = q.shape[-1]
    scores = jnp.einsum("btd,bsd->bts", q, k) / jnp.sqrt(float(d))
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bts,bsd->btd", p, v)
    return out, p[..., cls_index]
