"""Public op: cross-attention with CAS side output over (B, H, Tq, d).

Head folding + padding around ``cross_attention_tips_kernel``: query rows
are zero-padded up to the query-block multiple and sliced back; text keys
are zero-padded up to a sublane multiple with ``kv_len`` masking them out
of the softmax statistics inside the kernel (their probabilities are
exactly zero, so the padded value rows contribute nothing to the output
and the CAS of every real query is untouched).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.cross_attention_tips.kernel import (
    cross_attention_tips_kernel)
from repro.kernels.cross_attention_tips.ref import cross_attention_tips_ref
from repro.kernels.runtime import pad_axis_to

# text keys are sublane-padded to this multiple (77 -> 80; any Tk is legal)
_KV_PAD = 8


@functools.partial(jax.jit, static_argnames=("cls_index", "use_kernel",
                                             "interpret", "bq"))
def cross_attention_cas(q: jax.Array, k: jax.Array, v: jax.Array,
                        cls_index: int = 0,
                        use_kernel: bool = True,
                        interpret: bool | None = None,
                        bq: int = 128):
    """(B, H, Tq, d) q x (B, H, Tk, d) text k/v -> (out, cas).

    ``out`` is (B, H, Tq, d); ``cas`` is (B, H, Tq) — the per-head CLS
    attention score (softmax mass on text key ``cls_index``).  The
    (B, H, Tq, Tk) probability tensor never exists in memory on the kernel
    path.  ``interpret=None`` auto-selects interpret mode per backend.
    """
    b, h, tq, d = q.shape
    tk = k.shape[2]
    fold = lambda x: x.reshape(b * h, x.shape[2], x.shape[3])
    qf, kf, vf = fold(q), fold(k), fold(v)
    if use_kernel:
        blk_q = min(bq, tq)
        out, cas = cross_attention_tips_kernel(
            pad_axis_to(qf, blk_q, 1), pad_axis_to(kf, _KV_PAD, 1),
            pad_axis_to(vf, _KV_PAD, 1), cls_index=cls_index, bq=blk_q,
            interpret=interpret, kv_len=tk)
        out, cas = out[:, :tq], cas[:, :tq]        # drop padded query rows
    else:
        out, cas = cross_attention_tips_ref(qf, kf, vf, cls_index)
    return out.reshape(b, h, tq, d), cas.reshape(b, h, tq)


# ---------------------------------------------------------------------------
# Autotune hooks (repro.kernels.autotune): geometry = (b, h, tq, d, tk)
# ---------------------------------------------------------------------------
AUTOTUNE_KNOBS = ("cross_block_q",)


def autotune_candidates(geom: tuple) -> tuple:
    """Query-block candidates for a (b, h, tq, d, tk) geometry.

    The text keys are tiny (Tk=77) so the only knob is the query block;
    candidates cap at ``tq`` (larger blocks only pad).
    """
    b, h, tq, d, tk = geom
    sizes = sorted({min(s, tq) for s in (128, 256, 512, 1024, 2048)})
    return tuple({"cross_block_q": s} for s in sizes)


def autotune_probe(geom: tuple, blocks: dict, *,
                   interpret: bool | None = None):
    """(jitted fn, args) the autotuner times for one block config."""
    import jax.numpy as jnp
    b, h, tq, d, tk = geom
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, tq, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, h, tk, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, h, tk, d), jnp.float32)
    fn = jax.jit(functools.partial(
        cross_attention_cas, interpret=interpret,
        bq=blocks["cross_block_q"]))
    return fn, (q, k, v)
