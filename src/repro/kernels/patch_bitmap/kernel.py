"""PSXU Pallas kernel (paper §III-B): bitmap generate + patch-XOR + popcount.

The Patch-Similarity XOR Unit takes one 64-wide row slab of the pruned SAS,
generates the sparsity bitmap (BGU), XORs horizontally-adjacent bitmap
patches (RXU, reconfigurable to 16/32/64-wide patches), and hands the result
to the CSR encoder.  The encoder's cost is fully determined by the per-patch
popcounts, so the kernel outputs:

  * the packed XOR'd bitmap (uint32 words, 32 lanes per word) — the payload a
    DMA engine would move, and
  * per-(row, patch) popcounts of the XOR'd bitmap — the CSR col_idx counts.

TPU mapping: the comparator bank and XOR tree are VPU-lane-parallel ops; a
64-wide SAS row slab is half a 128-lane vector register, and the bit-pack is
a dot with a power-of-two vector.  Grid tiles the query rows; the full key
row fits one block (SAS rows are <= 4096 in BK-SDM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _kernel(sas_ref, packed_ref, counts_ref, *, patch: int, threshold: float):
    s = sas_ref[...]                               # (br, Tk)
    br, tk = s.shape
    bits = (s >= threshold)                        # BGU: bitmap generator bank

    # RXU: XOR adjacent patches along the key axis (keep the first patch).
    n = tk // patch
    r = bits.reshape(br, n, patch)
    delta = jnp.concatenate(
        [r[:, :1, :], jnp.logical_xor(r[:, 1:, :], r[:, :-1, :])], axis=1)

    # popcount per (row, patch) — drives the local CSR col_idx cost
    counts_ref[...] = jnp.sum(delta.astype(jnp.int32), axis=-1)

    # pack 32 lanes per uint32 word
    flat = delta.reshape(br, tk // 32, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    packed_ref[...] = jnp.sum(flat * weights, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("patch", "threshold", "br",
                                             "interpret"))
def patch_bitmap_kernel(sas: jax.Array, patch: int, threshold: float,
                        br: int = 64, interpret: bool | None = None):
    """(R, Tk) pruned-SAS slab -> (packed (R, Tk/32) uint32, counts (R, Tk/patch))."""
    rows, tk = sas.shape
    assert tk % patch == 0 and tk % 32 == 0, (tk, patch)
    assert rows % br == 0, (rows, br)

    return pl.pallas_call(
        functools.partial(_kernel, patch=patch, threshold=threshold),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, tk), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, tk // 32), lambda i: (i, 0)),
            pl.BlockSpec((br, tk // patch), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, tk // 32), jnp.uint32),
            jax.ShapeDtypeStruct((rows, tk // patch), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(sas)
