"""Public op: PSXU bitmap/XOR/popcount over arbitrary leading axes."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.patch_bitmap.kernel import patch_bitmap_kernel
from repro.kernels.patch_bitmap.ref import patch_bitmap_ref


@functools.partial(jax.jit, static_argnames=("patch", "threshold",
                                             "use_kernel", "interpret"))
def patch_bitmap(sas: jax.Array, patch: int, threshold: float,
                 use_kernel: bool = True, interpret: bool = True):
    """(..., Tq, Tk) SAS -> packed XOR bitmap (..., Tq, Tk/32) + popcounts."""
    *lead, tq, tk = sas.shape
    flat = sas.reshape(-1, tk)
    rows = flat.shape[0]
    if use_kernel:
        br = 64
        while rows % br:
            br //= 2
        packed, counts = patch_bitmap_kernel(flat, patch, threshold, br=br,
                                             interpret=interpret)
    else:
        packed, counts = patch_bitmap_ref(flat, patch, threshold)
    return (packed.reshape(*lead, tq, tk // 32),
            counts.reshape(*lead, tq, tk // patch))
