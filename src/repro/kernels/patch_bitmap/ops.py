"""Public op: PSXU bitmap/XOR/popcount over arbitrary leading axes.

Row blocking pads the folded row count up to the block multiple and slices
the outputs back (padded rows are all-zero bitmaps and touch nothing else —
the op is row-independent), replacing the seed's degenerate halving
fallback.  ``interpret=None`` auto-selects interpret mode from the backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.patch_bitmap.kernel import patch_bitmap_kernel
from repro.kernels.patch_bitmap.ref import patch_bitmap_ref
from repro.kernels.runtime import pad_axis_to


@functools.partial(jax.jit, static_argnames=("patch", "threshold",
                                             "use_kernel", "interpret",
                                             "br"))
def patch_bitmap(sas: jax.Array, patch: int, threshold: float,
                 use_kernel: bool = True, interpret: bool | None = None,
                 br: int = 64):
    """(..., Tq, Tk) SAS -> packed XOR bitmap (..., Tq, Tk/32) + popcounts."""
    *lead, tq, tk = sas.shape
    flat = sas.reshape(-1, tk)
    rows = flat.shape[0]
    if use_kernel:
        blk = min(br, rows)
        packed, counts = patch_bitmap_kernel(
            pad_axis_to(flat, blk, 0), patch, threshold, br=blk,
            interpret=interpret)
        packed, counts = packed[:rows], counts[:rows]
    else:
        packed, counts = patch_bitmap_ref(flat, patch, threshold)
    return (packed.reshape(*lead, tq, tk // 32),
            counts.reshape(*lead, tq, tk // patch))


# ---------------------------------------------------------------------------
# Autotune hooks (repro.kernels.autotune): geometry = (rows, tk, patch)
# ---------------------------------------------------------------------------
AUTOTUNE_KNOBS = ("bitmap_block_rows",)
_PROBE_THRESHOLD = 1.0 / 8192.0       # the paper's PSSA operating point


def autotune_candidates(geom: tuple) -> tuple:
    """Row-block candidates for a (rows, tk, patch) geometry."""
    rows, tk, patch = geom
    sizes = sorted({min(s, rows) for s in (64, 128, 256, 512, 1024)})
    return tuple({"bitmap_block_rows": s} for s in sizes)


def autotune_probe(geom: tuple, blocks: dict, *,
                   interpret: bool | None = None):
    """(jitted fn, args) the autotuner times for one block config."""
    rows, tk, patch = geom
    sas = jax.random.uniform(jax.random.PRNGKey(0), (rows, tk),
                             jnp.float32) * 2e-4
    fn = jax.jit(functools.partial(
        patch_bitmap, patch=patch, threshold=_PROBE_THRESHOLD,
        interpret=interpret, br=blocks["bitmap_block_rows"]))
    return fn, (sas,)
