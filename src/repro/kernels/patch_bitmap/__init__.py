from repro.kernels.patch_bitmap.ops import patch_bitmap  # noqa: F401
from repro.kernels.patch_bitmap.ref import patch_bitmap_ref  # noqa: F401
