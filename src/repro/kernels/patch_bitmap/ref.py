"""Pure-jnp oracle for the PSXU patch-bitmap kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pssa


def patch_bitmap_ref(sas: jax.Array, patch: int, threshold: float):
    bits = sas >= threshold
    delta = pssa.patch_xor(bits, patch)
    rows, tk = sas.shape
    counts = jnp.sum(delta.reshape(rows, tk // patch, patch).astype(jnp.int32),
                     axis=-1)
    flat = delta.reshape(rows, tk // 32, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    packed = jnp.sum(flat * weights, axis=-1, dtype=jnp.uint32)
    return packed, counts
